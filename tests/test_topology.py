"""Torus topology invariants (unit + hypothesis property tests)."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core.topology import Torus, enumerate_fault_sets

DIMS = st.lists(st.integers(min_value=1, max_value=6), min_size=1, max_size=3)


def small_torus(dims):
    return Torus(tuple(dims))


def test_rank_coords_roundtrip():
    t = Torus((2, 16, 16))
    assert t.size == 512
    for r in (0, 1, 255, 256, 511):
        assert t.rank(t.coords(r)) == r


def test_row_major_matches_make_mesh_order():
    # launch/mesh.py relies on rank == row-major device index
    t = Torus((2, 3, 4))
    assert t.coords(0) == (0, 0, 0)
    assert t.coords(1) == (0, 0, 1)
    assert t.coords(4) == (0, 1, 0)
    assert t.coords(12) == (1, 0, 0)


def test_neighbors_count_3d():
    t = Torus((4, 4, 4))
    for r in t.all_ranks():
        assert len(t.neighbors(r)) == 6  # APEnet+: 6 off-board links


def test_neighbors_degenerate_dims():
    t = Torus((2, 16, 16))
    # dim of size 2: +1 and -1 neighbours coincide -> deduped
    assert len(t.neighbors(0)) == 5
    assert Torus((1, 4)).neighbors(0) == [1, 3]


@hp.given(DIMS, st.data())
def test_route_is_dimension_ordered_and_minimal(dims, data):
    t = small_torus(dims)
    src = data.draw(st.integers(0, t.size - 1))
    dst = data.draw(st.integers(0, t.size - 1))
    path = t.route(src, dst)
    assert path[0] == src and path[-1] == dst
    assert len(path) - 1 == t.hop_distance(src, dst)  # minimal
    # each consecutive pair is a first-neighbour hop; dims change in order
    changed_dims = []
    for a, b in zip(path, path[1:]):
        assert b in t.neighbors(a)
        (d,) = [i for i in range(t.ndims)
                if t.coords(a)[i] != t.coords(b)[i]]
        changed_dims.append(d)
    assert changed_dims == sorted(changed_dims)  # X -> Y -> Z ordering


@hp.given(DIMS, st.data())
def test_hop_distance_symmetry_triangle(dims, data):
    t = small_torus(dims)
    a = data.draw(st.integers(0, t.size - 1))
    b = data.draw(st.integers(0, t.size - 1))
    c = data.draw(st.integers(0, t.size - 1))
    assert t.hop_distance(a, b) == t.hop_distance(b, a)
    assert t.hop_distance(a, a) == 0
    assert t.hop_distance(a, c) <= t.hop_distance(a, b) + t.hop_distance(b, c)
    assert t.hop_distance(a, b) <= t.diameter


def test_diameter_and_bisection():
    assert Torus((16, 16)).diameter == 16
    assert Torus((2, 16, 16)).diameter == 17
    assert Torus((4, 4)).bisection_links == 8  # 4 rings x 2 wrap links


def test_links_count():
    # k-ary n-cube with all dims > 2: n * size links
    t = Torus((4, 4, 4))
    assert len(t.links()) == 3 * t.size
    # dims of size 2 halve their dimension's links (wrap == direct)
    assert len(Torus((2, 4)).links()) == 4 + 8


def test_single_fault_always_observable():
    t = Torus((4, 4))
    for f in t.all_ranks():
        assert t.is_fault_observable(f, {f})


def test_fault_observability_matches_bruteforce_k2():
    t = Torus((3, 3))
    for fs in enumerate_fault_sets(t, 2):
        assert t.all_faults_observable(fs)  # 2 faults can't isolate on 3x3


def test_isolated_fault_detected_as_unobservable():
    # surround node 5 of a 4x4 torus with dead neighbours
    t = Torus((4, 4))
    victim = 5
    failed = set(t.neighbors(victim)) | {victim}
    assert not t.is_fault_observable(victim, failed)
    # ... but each *neighbour* still has live neighbours
    for n in t.neighbors(victim):
        assert t.is_fault_observable(n, failed)


def test_live_components_partition():
    t = Torus((4, 4))
    failed = {1, 4}
    comps = t.live_components(failed)
    assert sum(len(c) for c in comps) == t.size - len(failed)
    assert len(comps) == 1  # 2 faults never disconnect a 4x4 torus


def test_invalid_inputs():
    with pytest.raises(ValueError):
        Torus((0, 4))
    t = Torus((4, 4))
    with pytest.raises(ValueError):
        t.coords(16)
    with pytest.raises(ValueError):
        t.rank((4, 0))
