"""TLB behaviour + Fig 2 bandwidth-gain model (paper §2.2)."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import apelink
from repro.core.tlb import PAGE_BYTES, T_HW_HIT, T_NIOS_WALK, Tlb


def test_hit_miss_basic():
    t = Tlb(entries=8, ways=2)
    _, c0 = t.translate(0)
    assert c0 == pytest.approx(T_NIOS_WALK + T_HW_HIT)
    _, c1 = t.translate(100)          # same page
    assert c1 == pytest.approx(T_HW_HIT)
    assert t.stats.hits == 1 and t.stats.misses == 1


def test_translation_correct_with_custom_walk():
    t = Tlb(entries=8, ways=2, walk=lambda v: v * 7 + 3)
    paddr, _ = t.translate(5 * PAGE_BYTES + 123)
    assert paddr == (5 * 7 + 3) * PAGE_BYTES + 123
    paddr2, _ = t.translate(5 * PAGE_BYTES + 99)  # hit must agree
    assert paddr2 == (5 * 7 + 3) * PAGE_BYTES + 99


def test_lru_eviction_within_set():
    t = Tlb(entries=4, ways=2)  # 2 sets; pages p and p+2 share a set
    t.translate(0)                       # set0: {0}
    t.translate(2 * PAGE_BYTES)          # set0: {0,2}
    t.translate(0)                       # touch 0 -> LRU is 2
    t.translate(4 * PAGE_BYTES)          # evicts 2
    assert t.stats.evictions == 1
    _, c = t.translate(0)
    assert c == pytest.approx(T_HW_HIT)  # 0 survived
    _, c = t.translate(2 * PAGE_BYTES)
    assert c > T_HW_HIT                  # 2 was evicted


def test_invalidate():
    t = Tlb(entries=8, ways=2)
    t.translate(0)
    t.invalidate(0)
    _, c = t.translate(0)
    assert c > T_HW_HIT
    t.invalidate()  # full shootdown
    _, c = t.translate(0)
    assert c > T_HW_HIT


@hp.given(st.lists(st.integers(0, 63), min_size=1, max_size=200))
def test_stats_and_correctness_property(vpages):
    t = Tlb(entries=16, ways=4, walk=lambda v: v + 1000)
    for v in vpages:
        paddr, cost = t.translate(v * PAGE_BYTES + 7)
        assert paddr == (v + 1000) * PAGE_BYTES + 7  # always correct
        assert cost in (pytest.approx(T_HW_HIT),
                        pytest.approx(T_NIOS_WALK + T_HW_HIT))
    assert t.stats.accesses == len(vpages)
    assert 0.0 <= t.stats.hit_rate <= 1.0


def test_rdma_deregister_invalidates_region_tlb_entries():
    """After ``RdmaEndpoint.deregister`` no translation of the region may
    hit — a stale entry would hand out a mapping for unpinned memory."""
    from repro.core.rdma import RdmaEndpoint
    from repro.core.topology import Torus

    ep = RdmaEndpoint(Torus((4,)), rank=0)
    region = ep.register(3 * PAGE_BYTES + 100)     # partial last page too
    ep.translate_region(region)                    # populate the TLB
    for off in range(0, region.nbytes, PAGE_BYTES):
        _, c = ep.tlb.translate(region.vaddr + off)
        assert c == pytest.approx(T_HW_HIT)        # hot before deregister
    ep.deregister(region)
    for off in range(0, region.nbytes, PAGE_BYTES):
        _, c = ep.tlb.translate(region.vaddr + off)
        assert c > T_HW_HIT, f"stale TLB hit at offset {off} after " \
                             "deregister"


def test_rdma_deregister_sweeps_zero_byte_region_page():
    """A zero-byte region still owns (and translates) its first page —
    the regression: deregister swept ``range(0, 0)`` and left that
    translation live."""
    from repro.core.rdma import RdmaEndpoint
    from repro.core.topology import Torus

    ep = RdmaEndpoint(Torus((4,)), rank=0)
    region = ep.register(0)
    ep.translate_region(region)                    # walks page 0
    _, c = ep.tlb.translate(region.vaddr)
    assert c == pytest.approx(T_HW_HIT)
    ep.deregister(region)
    _, c = ep.tlb.translate(region.vaddr)
    assert c > T_HW_HIT


def test_rdma_zero_byte_region_owns_its_page_exclusively():
    """A zero-byte region must still RESERVE its page: were it to alias
    the next registration's vaddr, deregistering it would shoot down a
    live region's translations."""
    from repro.core.rdma import RdmaEndpoint
    from repro.core.topology import Torus

    ep = RdmaEndpoint(Torus((4,)), rank=0)
    r0 = ep.register(0)
    r1 = ep.register(PAGE_BYTES)
    assert r1.vaddr >= r0.vaddr + PAGE_BYTES       # no address aliasing
    ep.translate_region(r1)                        # r1's page is hot
    ep.deregister(r0)                              # must not touch r1
    _, c = ep.tlb.translate(r1.vaddr)
    assert c == pytest.approx(T_HW_HIT), \
        "deregistering a zero-byte region invalidated a live region"


def test_fig2_bandwidth_gain_up_to_60_percent():
    """Paper §2.2: 'A speedup of up to 60% in bandwidth ... has been
    measured' — hot TLB vs all-miss (Nios II on every page)."""
    t = Tlb()
    wire = apelink.sustained_bandwidth()
    nbytes = 1 << 20
    bw_cold = t.receive_bandwidth(nbytes, wire, hit_rate=0.0)
    bw_hot = t.receive_bandwidth(nbytes, wire, hit_rate=1.0)
    gain = bw_hot / bw_cold - 1.0
    assert gain == pytest.approx(0.60, abs=0.03)
    # monotone in hit rate
    bws = [t.receive_bandwidth(nbytes, wire, hit_rate=h)
           for h in (0.0, 0.25, 0.5, 0.75, 1.0)]
    assert all(a < b for a, b in zip(bws, bws[1:]))
    # and the hot path is still below the raw wire limit
    assert bw_hot < wire


def test_receive_bandwidth_uses_measured_stats():
    t = Tlb(entries=16, ways=4)
    for v in range(8):
        t.translate(v * PAGE_BYTES)   # all misses
    assert t.receive_bandwidth(1 << 20, 2.2e9) == pytest.approx(
        t.receive_bandwidth(1 << 20, 2.2e9, hit_rate=0.0))


def test_entries_ways_validation():
    with pytest.raises(ValueError):
        Tlb(entries=10, ways=4)
