"""Sharding-rule unit tests: DP-prefix batching, dp_only policy, ZeRO-1
extension, decode-state layouts.  Pure spec-level (no device allocation),
so they run against the production 256/512-chip meshes via AbstractMesh.
"""
import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models import api
from repro.parallel import sharding


def mesh_pod():
    return sharding.abstract_mesh((16, 16), ("data", "model"))


def mesh_multipod():
    return sharding.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _cfg(name, **over):
    c = configs.get_config(name)
    return dataclasses.replace(c, **over) if over else c


def test_dp_prefix_divides():
    m = mesh_pod()
    cfg = _cfg("smollm-135m")  # dp_only in production
    assert cfg.parallelism == "dp_only"
    # train batch 256 covers the full grid
    axes, n = sharding._dp_prefix(m, cfg, 256)
    assert axes == ("data", "model") and n == 256
    # prefill batch 32: only 'data' divides
    axes, n = sharding._dp_prefix(m, cfg, 32)
    assert axes == ("data",) and n == 16
    # batch 1: nothing divides
    axes, n = sharding._dp_prefix(m, cfg, 1)
    assert axes == () and n == 1


def test_batch_specs_never_replicate_when_seq_can_shard():
    """dp_only prefill (batch 32 < 256 devices) must put seq over 'model'
    instead of replicating the computation 16x (§Perf regression fix)."""
    m = mesh_pod()
    cfg = _cfg("qwen2-0.5b")
    batch = {"tokens": jax.ShapeDtypeStruct((32, 32768), jnp.int32)}
    spec = sharding.batch_specs(cfg, batch, m)["tokens"]
    assert spec == P(("data",), "model")


def test_batch_specs_tp_dp_unchanged():
    m = mesh_pod()
    cfg = _cfg("deepseek-7b")
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    assert sharding.batch_specs(cfg, batch, m)["tokens"] == P(("data",), None)


def test_param_specs_dp_only_replicates():
    m = mesh_pod()
    cfg = _cfg("smollm-135m")
    shapes = api.param_shapes(cfg)
    specs = sharding.param_specs(cfg, shapes, m)
    assert all(all(ax is None for ax in s) for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))


def test_zero1_dp_only_shards_moments_over_grid():
    m = mesh_pod()
    cfg = _cfg("smollm-135m")
    shapes = api.param_shapes(cfg)
    specs = sharding.zero1_specs(cfg, shapes, m)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    # the embedding moment (49152, 576) shards over the full 256-dev grid
    assert any(("data", "model") in s for s in flat)


def test_moe_expert_sharding():
    m = mesh_pod()
    cfg = _cfg("olmoe-1b-7b")
    shapes = api.param_shapes(cfg)
    specs = sharding.param_specs(cfg, shapes, m)
    wg = specs["layers"]["moe"]["w_gate"]
    assert wg == P(None, "model", None, None)  # (L, E, d, f): E over model


def test_decode_state_long500k_seq_over_data():
    m = mesh_pod()
    cfg = _cfg("rwkv6-1.6b")
    _, spec = api.input_specs(cfg, "long_500k")
    st = sharding.decode_state_specs(cfg, spec["state"], m, 1)
    # wkv state (L, 1, H, dh, dh): nothing > 1024 divisible -> replicated;
    # the shift buffers likewise; just assert no axis leaks
    for s in jax.tree.leaves(st, is_leaf=lambda x: isinstance(x, P)):
        for ax in s:
            assert ax in (None, "data", "model") or isinstance(ax, tuple)


def test_decode_state_batch_prefix_multipod():
    m = mesh_multipod()
    cfg = _cfg("deepseek-7b")
    state = {"k": jax.ShapeDtypeStruct((30, 128, 32768, 32, 128),
                                       jnp.bfloat16)}
    st = sharding.decode_state_specs(cfg, state, m, 128)
    # batch 128 divides pod*data = 32; model picks up a head/seq dim
    assert st["k"][1] == ("pod", "data")
    assert "model" in st["k"]
