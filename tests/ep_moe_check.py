"""EP all-to-all MoE vs dense per-token reference — subprocess check
(needs 8 forced host devices; launched by tests/test_moe_ep.py).

With an ample capacity factor nothing drops, so both the global sort-based
dispatch and the shard_map EP dispatch must equal the dense reference
y_t = sum_k p_k FFN_{e_k}(x_t) computed directly per token.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import moe  # noqa: E402
from repro.models.common import ArchCfg, MoeCfg  # noqa: E402
from repro.parallel import sharding  # noqa: E402


def dense_reference(cfg, p, x):
    """y_t = sum_k p_k FFN_{e_k}(x_t), computed with every expert on every
    token (no capacity, no dispatch)."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", xt,
                               p["w_gate"]).astype(jnp.float32))
    u = jnp.einsum("td,edf->tef", xt, p["w_up"]).astype(jnp.float32)
    h = (g * u).astype(x.dtype)
    every = jnp.einsum("tef,efd->ted", h, p["w_down"])   # (T, E, d)
    sel = jnp.take_along_axis(every, top_e[:, :, None], axis=1)
    y = (sel.astype(jnp.float32) * top_p[:, :, None]).sum(1)
    return y.reshape(B, S, d).astype(x.dtype)


def main() -> None:
    assert jax.device_count() == 8
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(
        configs.get_config("olmoe-1b-7b").reduced(),
        moe=MoeCfg(n_experts=8, top_k=2, d_expert=32, capacity_factor=8.0),
        d_model=64, dtype=jnp.float32, moe_impl="ep_a2a")
    p = moe.init_moe(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)) * 0.3, jnp.float32)

    want = dense_reference(cfg, p, x)
    y_global, aux_g = moe.apply_moe(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_global), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print("[ep_moe] global dispatch == dense reference")

    sharding.set_runtime_mesh(mesh)
    try:
        with mesh:
            y_ep, aux_e = jax.jit(
                lambda p, x: moe.apply_moe_ep(cfg, p, x))(p, x)
    finally:
        sharding.set_runtime_mesh(None)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_e), float(aux_g), rtol=1e-3)
    print("[ep_moe] shard_map EP all-to-all == dense reference; aux matches")

    # drop regime: tight capacity must still run and stay finite
    cfg2 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    sharding.set_runtime_mesh(mesh)
    try:
        with mesh:
            y2, _ = jax.jit(
                lambda p, x: moe.apply_moe_ep(cfg2, p, x))(p, x)
    finally:
        sharding.set_runtime_mesh(None)
    assert np.isfinite(np.asarray(y2)).all()
    print("[ep_moe] drop regime finite")
    print("ALL EP MOE CHECKS PASSED")


if __name__ == "__main__":
    main()
