"""FabricSim — the event-driven link-level timeline (core/fabric/sim).

Three contracts:
  * differential: ``backend="sim"`` == the analytic estimate on
    single-flow schedules (exact, not just within the 10% bar);
  * contention: flows sharing a link direction serialize, disjoint ones
    don't, credit backpressure propagates upstream, host-IF resources
    FIFO;
  * routing: candidate enumeration + probe-by-simulated-completion picks
    the detour exactly when the direct link is congested.
"""
import copy

import pytest

from repro.core import fabric
from repro.core.apelink import NetModel
from repro.core.fabric.sim import FabricSim
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus


NET = NetModel()


# ---------------------------------------------------------------------------
# single-flow agreement with the analytic model
# ---------------------------------------------------------------------------

def test_single_flow_matches_message_time_one_hop():
    for nbytes in (0, 1, 4096, 1 << 20):
        s = FabricSim(Torus((8,)))
        fid = s.inject(0, 1, nbytes)
        assert s.finish_s(fid) == pytest.approx(
            fabric.message_time(nbytes, NET, hops=1), rel=1e-12)


def test_single_flow_multi_hop_within_tolerance():
    t = Torus((4, 4, 4))
    dst = t.rank((2, 2, 2))
    for nbytes in (64, 1 << 20):
        s = FabricSim(t)
        fid = s.inject(0, dst, nbytes)
        analytic = fabric.message_time(nbytes, NET, hops=6)
        # packet pipelining adds (hops-1) * pkt/bw of store-and-forward
        # fill — a few us, inside the differential bar
        assert s.finish_s(fid) == pytest.approx(analytic, rel=0.10)
        assert s.finish_s(fid) >= analytic * (1 - 1e-12)


def test_zero_byte_flow_prices_header_latency_only():
    s = FabricSim(Torus((4, 4)))
    fid = s.inject(0, 5, 0)         # 2 hops, no payload
    assert s.finish_s(fid) == pytest.approx(
        NET.t_inject + NET.t_receive + 2 * NET.t_hop, rel=1e-12)


@pytest.mark.parametrize("dims,axes", [((8,), ("x",)),
                                       ((2, 4), ("a", "b")),
                                       ((2, 2, 2), ("u", "v", "w"))])
@pytest.mark.parametrize("lower_name", ["lower_all_reduce",
                                        "lower_reduce_scatter",
                                        "lower_all_gather"])
def test_sim_backend_matches_analytic_on_ring_schedules(dims, axes,
                                                        lower_name):
    """The acceptance differential: single-flow 1D/2D/3D ring schedules
    agree across backends within 10% (they agree exactly — every round's
    messages ride disjoint link directions)."""
    t = Torus(dims)
    sched = getattr(fabric, lower_name)(t, axes)
    for nbytes in (0, 4096, 1 << 20):
        a = fabric.estimate(sched, nbytes)
        s = fabric.estimate(sched, nbytes, backend="sim")
        assert s.total_s == pytest.approx(a.total_s, rel=0.10)
        assert s.total_s == pytest.approx(a.total_s, rel=1e-9)  # exact
        assert s.rounds == a.rounds and s.max_hops == a.max_hops
        for ps, pa in zip(s.phase_s, a.phase_s):
            assert ps == pytest.approx(pa, rel=1e-9)


def test_sim_backend_matches_analytic_on_p2p():
    t = Torus((4, 4, 4))
    sched = fabric.lower_p2p(t, 0, t.rank((2, 2, 2)))
    for nbytes in (64, 1 << 20):
        a = fabric.estimate(sched, nbytes).total_s
        s = fabric.estimate(sched, nbytes, backend="sim").total_s
        assert s == pytest.approx(a, rel=0.10)
    # degenerate self-route prices zero on both backends
    self_sched = fabric.lower_p2p(t, 3, 3)
    assert fabric.estimate(self_sched, 1 << 20,
                           backend="sim").total_s == 0.0


def test_sim_backend_detoured_schedule_costs_more():
    t = Torus((8,))
    clean = fabric.lower_all_reduce(t, ("x",))
    detoured = fabric.rewrite(clean,
                              fabric.FaultMap.normalized(links=[(2, 3)]))
    n = 1 << 20
    assert fabric.estimate(detoured, n, backend="sim").total_s \
        > fabric.estimate(clean, n, backend="sim").total_s


def test_unknown_backend_rejected():
    sched = fabric.lower_all_reduce(Torus((4,)), ("x",))
    with pytest.raises(ValueError, match="backend"):
        fabric.estimate(sched, 1024, backend="simulated")


def test_estimate_overlapped_accepts_backend():
    sched = fabric.lower_reduce_scatter(Torus((8,)), ("x",))
    plan = [1 << 20] * 4
    a = fabric.estimate_overlapped(sched, plan, 1e-3)
    s = fabric.estimate_overlapped(sched, plan, 1e-3, backend="sim")
    assert s.total_s == pytest.approx(a.total_s, rel=1e-9)


# ---------------------------------------------------------------------------
# contention mechanics
# ---------------------------------------------------------------------------

def test_shared_link_serializes_disjoint_links_dont():
    n = 4 << 20
    iso = FabricSim(Torus((8,)))
    t_iso = iso.finish_s(iso.inject(0, 1, n))
    shared = FabricSim(Torus((8,)))
    fids = [shared.inject(0, d, n) for d in (1, 2)]   # both cross (0, 1)
    t_shared = max(shared.finish_s(f) for f in fids)
    assert t_shared > 1.8 * t_iso                     # ~2x serialization
    disjoint = FabricSim(Torus((8,)))
    fids = [disjoint.inject(0, 1, n), disjoint.inject(2, 3, n)]
    t_disj = max(disjoint.finish_s(f) for f in fids)
    assert t_disj == pytest.approx(t_iso, rel=1e-6)   # full parallelism


def test_fair_interleave_both_flows_slowed():
    """Concurrent flows round-robin at packet granularity: BOTH see ~2x,
    not FIFO-whole-flow (one unharmed, one doubled)."""
    n = 4 << 20
    iso = FabricSim(Torus((8,)))
    t_iso = iso.finish_s(iso.inject(0, 1, n))
    s = FabricSim(Torus((8,)))
    a, b = s.inject(0, 1, n), s.inject(0, 1, n)
    for f in (a, b):
        assert s.finish_s(f) > 1.7 * t_iso


def test_opposite_ring_directions_do_not_contend():
    """Dual-DMA: the two directions of a link are distinct channels."""
    n = 4 << 20
    iso = FabricSim(Torus((8,)))
    t_iso = iso.finish_s(iso.inject(0, 1, n))
    s = FabricSim(Torus((8,)))
    fwd, bwd = s.inject(0, 1, n), s.inject(1, 0, n)
    assert max(s.finish_s(fwd), s.finish_s(bwd)) \
        == pytest.approx(t_iso, rel=1e-6)


def test_two_ring_dual_directions_ride_parallel_cables():
    """On a 2-ring the +1/-1 transfers join the SAME rank pair but ride
    the two physical cables — the channel hint keeps them concurrent."""
    n = 4 << 20
    iso = FabricSim(Torus((2,)))
    t_iso = iso.finish_s(iso.inject(0, 1, n, channel=0))
    s = FabricSim(Torus((2,)))
    c0, c1 = s.inject(0, 1, n, channel=0), s.inject(0, 1, n, channel=1)
    assert max(s.finish_s(c0), s.finish_s(c1)) \
        == pytest.approx(t_iso, rel=1e-6)
    # same channel: genuinely shared cable
    s2 = FabricSim(Torus((2,)))
    d0, d1 = s2.inject(0, 1, n, channel=0), s2.inject(0, 1, n, channel=0)
    assert max(s2.finish_s(d0), s2.finish_s(d1)) > 1.8 * t_iso


def test_credit_backpressure_propagates_upstream():
    """A merge bottleneck at (1, 2) fills node 1's buffers; the credit
    window then throttles flow A on the (0, 1) link even though nothing
    else uses (0, 1)."""
    n = 4 << 20
    iso = FabricSim(Torus((8,)))
    t_iso = iso.finish_s(iso.inject(0, 2, n))
    iso1 = FabricSim(Torus((8,)))
    t_iso1 = iso1.finish_s(iso1.inject(1, 2, n))
    s = FabricSim(Torus((8,)))
    a = s.inject(0, 2, n)            # 0 -> 1 -> 2
    b = s.inject(1, 2, n)            # merges at link (1, 2)
    assert s.finish_s(a) > 1.5 * t_iso
    assert s.finish_s(b) > 1.5 * t_iso1              # both flows slowed


def test_credit_window_bounds_in_flight_bytes():
    """With a one-packet credit window the pipeline still flows, but a
    stalled consumer-side link visibly stretches a multi-hop flow vs an
    uncongested one (store-and-forward backpressure)."""
    n = 1 << 20
    wide = FabricSim(Torus((8,)), credit_bytes=1 << 20)
    t_wide = wide.finish_s(wide.inject(0, 4, n))
    narrow = FabricSim(Torus((8,)), credit_bytes=4096, packet_bytes=4096)
    t_narrow = narrow.finish_s(narrow.inject(0, 4, n))
    assert t_narrow >= t_wide          # less credit can never be faster


def test_occupy_resource_fifo():
    s = FabricSim(Torus((4,)))
    a = s.occupy(("hostif", 0), 1e-3)
    b = s.occupy(("hostif", 0), 1e-3)
    c = s.occupy(("hostif", 1), 1e-3)   # different card: parallel
    assert s.finish_s(a) == pytest.approx(1e-3)
    assert s.finish_s(b) == pytest.approx(2e-3)
    assert s.finish_s(c) == pytest.approx(1e-3)


def test_dependencies_chain_flows():
    s = FabricSim(Torus((8,)))
    a = s.inject(0, 1, 1 << 20)
    b = s.inject(2, 3, 1 << 20, after=(a,))   # disjoint links, dep-ordered
    t_a, t_b = s.finish_s(a), s.finish_s(b)
    assert t_b > t_a
    assert t_b == pytest.approx(
        t_a + fabric.message_time(1 << 20, NET, hops=1), rel=1e-9)


def test_probe_route_does_not_mutate_timeline():
    s = FabricSim(Torus((4, 4)))
    bg = s.inject(0, 1, 8 << 20)
    before = copy.deepcopy(s.link_stats())
    t = s.probe_route((0, 1), 1 << 20)
    assert t > 0
    assert s.link_stats() == before
    assert s.finish_s(bg) > 0          # background still completes


def test_prune_drops_settled_flows_keeps_pending():
    s = FabricSim(Torus((8,)))
    done = s.inject(0, 1, 4096)
    s.finish_s(done)                   # settled
    pending = s.inject(2, 3, 4096, start_s=s.now + 1.0)
    assert s.prune() == 1
    with pytest.raises(KeyError):
        s.finish_s(done)               # pruned ids are gone
    assert s.finish_s(pending) > 1.0   # pending flow unaffected
    assert s.prune() == 1              # now settled too


def test_clock_advance_monotone():
    s = FabricSim(Torus((4,)))
    assert s.now == 0.0
    s.advance(1.5)
    assert s.now == 1.5
    s.advance(1.0)                     # never backwards
    assert s.now == 1.5
    fid = s.inject(0, 1, 4096)         # injected at the frontier
    assert s.finish_s(fid) > 1.5


def test_inject_validates_route_and_faults():
    t = Torus((4,))
    s = FabricSim(t)
    with pytest.raises(ValueError):
        s.inject(0, 2, 1024, route=(0, 1))      # route doesn't reach dst
    dead = FabricSim(t, faults=fabric.FaultMap.normalized(
        links=[(0, 1), (3, 0)]))
    with pytest.raises(fabric.UnroutableError):
        dead.inject(0, 2, 1024)                 # rank 0 partitioned off


# ---------------------------------------------------------------------------
# congestion-aware route selection
# ---------------------------------------------------------------------------

def test_candidate_routes_cover_detour_family():
    t = Torus((4, 4))
    routes = fabric.candidate_routes(t, 0, 5)
    assert all(r[0] == 0 and r[-1] == 5 for r in routes)
    assert len(routes[0]) - 1 == t.hop_distance(0, 5)   # minimal first
    assert len(routes) >= 3                              # real alternatives
    for r in routes:
        assert len(set(r)) == len(r)                     # loop-free
    with pytest.raises(fabric.UnroutableError):
        fabric.candidate_routes(
            Torus((2,)), 0, 1,
            fabric.FaultMap.normalized(links=[(0, 1)]))


def test_best_route_prefers_minimal_on_quiet_fabric():
    t = Torus((4, 4))
    s = FabricSim(t)
    route, _ = fabric.best_route(s, 0, 1, 1 << 20)
    assert len(route) - 1 == 1


def test_best_route_detours_around_congestion():
    t = Torus((4, 4))
    s = FabricSim(t)
    s.inject(0, 1, 64 << 20)           # hammer the direct link
    direct_t = s.probe_route(tuple(t.route(0, 1)), 4 << 20)
    route, best_t = fabric.best_route(s, 0, 1, 4 << 20)
    assert len(route) - 1 > 1          # took a detour
    assert best_t < direct_t


def test_best_route_respects_faults():
    t = Torus((4,))
    s = FabricSim(t)
    faults = fabric.FaultMap.normalized(links=[(0, 1)])
    route, _ = fabric.best_route(s, 0, 1, 1 << 20, faults=faults)
    assert route == (0, 3, 2, 1)       # the only surviving path


# ---------------------------------------------------------------------------
# RDMA endpoint as a timeline client
# ---------------------------------------------------------------------------

def test_put_pages_quiet_sim_close_to_isolated():
    t = Torus((4, 4))
    sim = FabricSim(t)
    ep = RdmaEndpoint(t, 0, sim=sim)
    region = ep.register(64 << 10)
    total = ep.put_pages(5, region, list(range(4)), page_nbytes=16 << 10)
    rep = ep.last_put_report
    assert rep["total_s"] == total
    # a quiet fabric prices within packet-pipelining slack of isolated
    assert total == pytest.approx(rep["isolated_s"], rel=0.05)


def test_put_pages_contended_slower_than_isolated():
    t = Torus((4, 4))
    sim = FabricSim(t)
    # saturate the route links first
    sim.inject(0, 1, 64 << 20)
    sim.inject(1, 2, 64 << 20)
    ep = RdmaEndpoint(t, 0, sim=sim)
    region = ep.register(8 << 20)
    total = ep.put_pages(2, region, list(range(8)), page_nbytes=1 << 20)
    rep = ep.last_put_report
    assert total > 1.5 * rep["isolated_s"]


def test_put_pages_without_sim_unchanged_closed_form():
    t = Torus((4, 4))
    ep = RdmaEndpoint(t, 0)
    region = ep.register(64 << 10)
    total = ep.put_pages(5, region, list(range(4)), page_nbytes=16 << 10)
    assert total == ep.last_put_report["isolated_s"]


def test_get_time_sim_matches_closed_form_on_quiet_fabric():
    t = Torus((4, 4))
    plain = RdmaEndpoint(t, 0)
    r1 = plain.register(1 << 20)
    closed = plain.get_time(3, 1 << 20, r1)
    simmed = RdmaEndpoint(t, 0, sim=FabricSim(t))
    r2 = simmed.register(1 << 20)
    assert simmed.get_time(3, 1 << 20, r2) == pytest.approx(closed,
                                                            rel=0.05)


def test_put_queues_behind_busy_host_interface():
    """A PUT issued while the card's host interface is already draining
    another operation queues its DMA behind it — the host-IF is a shared
    FIFO resource on the timeline, not a free closed-form term."""
    t = Torus((8,))
    sim = FabricSim(t)
    ep = RdmaEndpoint(t, 0, sim=sim)
    region = ep.register(8 << 20)
    busy_s = 5e-3
    sim.occupy(("hostif", 0), busy_s)       # e.g. another slot's export
    total = ep.put_pages(1, region, list(range(8)), page_nbytes=1 << 20)
    rep = ep.last_put_report
    assert total > rep["isolated_s"]
    # DMA waits for the busy host-IF: total = busy window + DMA + wire
    assert total == pytest.approx(busy_s + rep["dma_s"] + rep["wire_s"],
                                  rel=0.05)
