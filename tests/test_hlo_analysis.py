"""hlo_analysis: trip-count-aware flop/byte/collective counting.

Validated against (a) hand-computed flop counts, (b) XLA's own
cost_analysis on loop-free programs (where XLA is correct), and (c) the
scan-vs-unrolled equivalence that motivates the analyzer.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def _analyze(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return H.analyze(c.as_text()), c


def _xla_cost(c) -> dict:
    """compiled.cost_analysis() returns a dict on new JAX, [dict] on old."""
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_matmul_flops_exact():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    a, c = _analyze(lambda x, w: x @ w, x, w)
    assert a.flops == 2 * 64 * 128 * 32
    # agrees with XLA on a loop-free program
    assert a.flops == pytest.approx(_xla_cost(c)["flops"], rel=1e-6)


def test_batched_dot_flops():
    x = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
    a, _ = _analyze(lambda x, w: jnp.einsum("bij,bjk->bik", x, w), x, w)
    assert a.flops == 2 * 4 * 8 * 16 * 8


def test_scan_flops_multiplied_by_trip_count():
    d, L = 64, 11
    x = jax.ShapeDtypeStruct((8, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)

    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    def unrolled(x, ws):
        for i in range(L):
            x = jnp.tanh(x @ ws[i])
        return x

    a_scan, c_scan = _analyze(scanned, x, ws)
    a_unroll, _ = _analyze(unrolled, x, ws)
    want = L * 2 * 8 * d * d
    assert a_scan.flops == want
    assert a_unroll.flops == want
    assert a_scan.max_trip == L
    # ...and XLA's own counter misses the loop (this is why we exist)
    assert _xla_cost(c_scan)["flops"] < want / 2


def test_nested_scan():
    d, L1, L2 = 16, 3, 5
    x = jax.ShapeDtypeStruct((4, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((L1, L2, d, d), jnp.float32)

    def inner(c, wset):
        c, _ = jax.lax.scan(lambda h, w: (h @ w, None), c, wset)
        return c, None

    def fn(x, ws):
        y, _ = jax.lax.scan(inner, x, ws)
        return y

    a, _ = _analyze(fn, x, ws)
    assert a.flops == L1 * L2 * 2 * 4 * d * d


def test_bytes_are_sane():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    a, _ = _analyze(lambda x: (x @ x).sum(), x)
    nb = 256 * 256 * 4
    # at least: read x twice + write result; far below pathological 10x
    assert 2 * nb <= a.bytes <= 12 * nb


def test_collectives_inside_while_multiplied_by_trips():
    text = """
HloModule m

%body (t: (s32[], f32[64])) -> (s32[], f32[64]) {
  %t = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[64]{0} get-tuple-element(%t), index=1
  %ar = f32[64]{0} all-reduce(%x), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[64]) tuple(%ip, %ar)
}

%cond (t: (s32[], f32[64])) -> pred[] {
  %t = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[64]) tuple(%c0, %p)
  %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[64]{0} get-tuple-element(%w), index=1
}
"""
    a = H.analyze(text)
    nb = 64 * 4
    assert a.collectives["all-reduce"]["count"] == 24
    assert a.collectives["all-reduce"]["link_bytes"] == 24 * 2 * nb
    assert a.max_trip == 24


def test_collective_parse_from_text():
    text = """
HloModule m

ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %ag = f32[16,128]{1,0} all-gather(%ar), replica_groups=[4]<=[4], dimensions={0}
}
"""
    a = H.analyze(text)
    nb = 16 * 128 * 4
    assert a.collectives["all-reduce"]["count"] == 1
    assert a.collectives["all-reduce"]["link_bytes"] == 2 * nb
    assert a.collectives["all-gather"]["link_bytes"] == nb
    assert a.link_bytes == 3 * nb


def test_model_train_flops_match_6nd():
    """End-to-end: analyzer flops on a small transformer ~= 6*N*D (+attn)."""
    from repro import configs
    from repro.models import api

    cfg = configs.get_config("smollm-135m").reduced()
    model = api.get_model(cfg)
    shapes = api.param_shapes(cfg)
    B, S = 2, 32

    def loss(p, batch):
        return model.train_loss(p, batch, remat=False)

    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    grad = jax.jit(jax.grad(loss))
    c = grad.lower(shapes, batch).compile()
    a = H.analyze(c.as_text())
    n = api.param_count(cfg)
    model_flops = 6 * n * B * S
    # embeddings are lookups (not matmul flops) and attention adds O(S^2 d);
    # accept a generous band around 6ND
    assert 0.5 * model_flops <= a.flops <= 2.0 * model_flops
