"""Per-kernel validation: Pallas (interpret=True) vs. pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests on invariants.
"""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_scan import mamba2_scan
from repro.kernels.paged_attention import paged_attention
from repro.kernels.rwkv6_scan import rwkv6_scan

RNG = np.random.default_rng(42)


def tol(dtype):
    return dict(rtol=6e-2, atol=6e-2) if dtype == jnp.bfloat16 else \
           dict(rtol=3e-4, atol=3e-4)


def assert_close(got, want, dtype):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# ----------------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,Sq,Skv,D,bq,bk", [
    (1, 2, 2, 128, 128, 32, 64, 64),       # MHA square
    (2, 4, 2, 128, 128, 64, 128, 64),      # GQA group=2
    (1, 8, 1, 64, 64, 16, 32, 32),         # MQA
    (1, 2, 2, 64, 256, 32, 64, 64),        # cross Sq != Skv (right-aligned)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, H, Hkv, Sq, Skv, D, bq, bk, causal,
                                     dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, Sq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.mha_attention(q, k, v, causal=causal)
    assert got.dtype == dtype
    assert_close(got, want, dtype)


def test_flash_attention_is_jittable():
    q = jnp.asarray(RNG.normal(size=(1, 2, 64, 32)), jnp.float32)
    f = jax.jit(lambda q: flash_attention(q, q, q, interpret=True,
                                          block_q=32, block_k=32))
    out = f(q)
    assert out.shape == q.shape and not bool(jnp.any(jnp.isnan(out)))


@hp.given(st.integers(1, 3), st.integers(0, 2), st.integers(1, 4))
@hp.settings(max_examples=10, deadline=None)
def test_flash_attention_property(batch, group_log2, blocks):
    """softmax(QK^T)V rows are convex combinations of V rows: outputs stay
    within [min(V), max(V)] per feature."""
    group = 2 ** group_log2
    Hkv, D = 2, 16
    S = 32 * blocks
    q = jnp.asarray(RNG.normal(size=(batch, Hkv * group, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(batch, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(batch, Hkv, S, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    hi = np.asarray(v).max() + 1e-4
    lo = np.asarray(v).min() - 1e-4
    assert np.all(np.asarray(out) <= hi) and np.all(np.asarray(out) >= lo)


# ----------------------------------------------------------------------------
# paged attention (the TLB kernel)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,D,page,max_pages,pool", [
    (2, 4, 2, 32, 16, 4, 12),
    (3, 4, 4, 64, 8, 8, 30),
    (1, 8, 1, 16, 32, 2, 4),
])
def test_paged_attention_matches_ref(B, H, Hkv, D, page, max_pages, pool,
                                     dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, D)), dtype)
    kp = jnp.asarray(RNG.normal(size=(pool, page, Hkv, D)), dtype)
    vp = jnp.asarray(RNG.normal(size=(pool, page, Hkv, D)), dtype)
    pt = jnp.asarray(RNG.permutation(pool)[:B * max_pages].reshape(
        B, max_pages).astype(np.int32))
    sl = jnp.asarray(RNG.integers(1, page * max_pages + 1, size=B)
                     .astype(np.int32))
    got = paged_attention(q, kp, vp, pt, sl, interpret=True)
    want = ref.paged_attention(q, kp, vp, pt, sl)
    assert got.dtype == dtype
    assert_close(got, want, dtype)


def test_paged_attention_ignores_unmapped_pages():
    """Pages past seq_len must not influence the result even if the page
    table points at garbage there (RDMA safety: no reads beyond the
    registered region)."""
    B, H, D, page, mp, pool = 1, 2, 16, 8, 4, 8
    q = jnp.asarray(RNG.normal(size=(B, H, D)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(pool, page, H, D)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(pool, page, H, D)), jnp.float32)
    sl = jnp.asarray([9], np.int32)  # 2 pages resident
    pt_a = jnp.asarray([[0, 1, 2, 3]], np.int32)
    pt_b = jnp.asarray([[0, 1, 7, 6]], np.int32)  # same resident pages
    out_a = paged_attention(q, kp, vp, pt_a, sl, interpret=True)
    out_b = paged_attention(q, kp, vp, pt_b, sl, interpret=True)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b))


def test_paged_vs_contiguous_attention():
    """Paged decode == dense decode when pages are laid out contiguously."""
    B, H, D, page, mp = 2, 2, 32, 16, 4
    S = page * mp
    kp = jnp.asarray(RNG.normal(size=(B * mp, page, H, D)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(B * mp, page, H, D)), jnp.float32)
    pt = jnp.arange(B * mp, dtype=jnp.int32).reshape(B, mp)
    sl = jnp.asarray([S, S - 5], np.int32)
    q = jnp.asarray(RNG.normal(size=(B, H, D)), jnp.float32)
    got = paged_attention(q, kp, vp, pt, sl, interpret=True)
    # dense oracle: q attends over the flattened cache with length mask
    k_dense = kp.reshape(B, S, H, D).transpose(0, 2, 1, 3)
    v_dense = vp.reshape(B, S, H, D).transpose(0, 2, 1, 3)
    logits = jnp.einsum("bhd,bhsd->bhs", q * D ** -0.5, k_dense)
    mask = jnp.arange(S)[None, :] < sl[:, None]
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    want = jnp.einsum("bhs,bhsd->bhd", jax.nn.softmax(logits, -1), v_dense)
    assert_close(got, want, jnp.float32)


# ----------------------------------------------------------------------------
# mamba2 SSD scan
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,dh,ds,chunk", [
    (2, 128, 3, 32, 16, 32),
    (1, 64, 2, 16, 8, 64),    # single chunk
    (1, 256, 1, 8, 4, 32),    # long, tiny
])
def test_mamba2_matches_ref(B, S, H, dh, ds, chunk, dtype):
    x = jnp.asarray(RNG.normal(size=(B, S, H, dh)), dtype)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.1 + 0.01, dtype)
    A = jnp.asarray(-np.abs(RNG.normal(size=(H,))) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, ds)), dtype)
    Cm = jnp.asarray(RNG.normal(size=(B, S, ds)), dtype)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)
    got = mamba2_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    want = ref.mamba2_scan(x, dt, A, Bm, Cm, D)
    assert got.dtype == dtype
    assert_close(got, want, dtype)


def test_mamba2_chunk_invariance():
    """The chunked closed form must not depend on the chunk size."""
    B, S, H, dh, ds = 1, 128, 2, 16, 8
    args = (jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32),
            jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.1, jnp.float32),
            jnp.asarray(-np.abs(RNG.normal(size=(H,))), jnp.float32),
            jnp.asarray(RNG.normal(size=(B, S, ds)), jnp.float32),
            jnp.asarray(RNG.normal(size=(B, S, ds)), jnp.float32),
            jnp.asarray(RNG.normal(size=(H,)), jnp.float32))
    outs = [mamba2_scan(*args, chunk=c, interpret=True) for c in (16, 32, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=2e-4)


@hp.given(st.floats(0.01, 0.5), st.integers(1, 3))
@hp.settings(max_examples=8, deadline=None)
def test_mamba2_decay_property(dt_scale, heads):
    """With x = 0 after t0, outputs decay toward D-skip only (state decays:
    A < 0)."""
    B, S, dh, ds = 1, 64, 8, 4
    x = np.zeros((B, S, heads, dh), np.float32)
    x[:, 0] = 1.0
    dt = np.full((B, S, heads), dt_scale, np.float32)
    A = np.full((heads,), -5.0, np.float32)
    Bm = np.ones((B, S, ds), np.float32)
    Cm = np.ones((B, S, ds), np.float32)
    D = np.zeros((heads,), np.float32)
    out = mamba2_scan(*map(jnp.asarray, (x, dt, A, Bm, Cm, D)), chunk=32,
                      interpret=True)
    mags = np.abs(np.asarray(out)).max(axis=(0, 2, 3))
    assert mags[-1] < mags[1] + 1e-6  # decayed


# ----------------------------------------------------------------------------
# rwkv6 scan
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,dh,chunk", [
    (2, 64, 2, 16, 16),
    (1, 128, 1, 32, 64),
    (1, 32, 4, 8, 32),    # single chunk
])
def test_rwkv6_matches_ref(B, S, H, dh, chunk, dtype):
    r = jnp.asarray(RNG.normal(size=(B, S, H, dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, H, dh)) * 0.3, dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, H, dh)), dtype)
    w = jnp.asarray(1 / (1 + np.exp(-RNG.normal(size=(B, S, H, dh)))) * 0.5
                    + 0.5, dtype)
    u = jnp.asarray(RNG.normal(size=(H, dh)), jnp.float32)
    got = rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    want = ref.rwkv6_scan(r, k, v, w, u)
    assert got.dtype == dtype
    assert_close(got, want, dtype)


def test_rwkv6_chunk_invariance():
    B, S, H, dh = 1, 64, 2, 8
    args = (jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32),
            jnp.asarray(RNG.normal(size=(B, S, H, dh)) * 0.3, jnp.float32),
            jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32),
            jnp.asarray(np.full((B, S, H, dh), 0.9), jnp.float32),
            jnp.asarray(RNG.normal(size=(H, dh)), jnp.float32))
    outs = [rwkv6_scan(*args, chunk=c, interpret=True) for c in (8, 16, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=2e-4)


def test_rwkv6_zero_decay_is_memoryless():
    """w == 0 wipes the state every step: y_t depends only on step t
    (bonus term), so permuting earlier steps must not change later outputs
    ... actually with w=0: y_t = r_t.(k_{t-1} (x) v_{t-1} + u k_t (x) v_t)."""
    B, S, H, dh = 1, 16, 1, 4
    r = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    w = jnp.zeros((B, S, H, dh), jnp.float32)
    u = jnp.zeros((H, dh), jnp.float32)
    out = rwkv6_scan(r, k, v, w, u, chunk=8, interpret=True)
    # with u=0 and w=0: y_t = r_t . (k_{t-1} (x) v_{t-1});  y_0 = 0
    want = np.zeros((B, S, H, dh), np.float32)
    rn, kn, vn = map(np.asarray, (r, k, v))
    for t in range(1, S):
        s = np.einsum("bhk,bhv->bhkv", kn[:, t - 1], vn[:, t - 1])
        want[:, t] = np.einsum("bhk,bhkv->bhv", rn[:, t], s)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------------
# ops dispatch
# ----------------------------------------------------------------------------

def test_ops_dispatch_ref_equals_pallas():
    q = jnp.asarray(RNG.normal(size=(1, 2, 64, 16)), jnp.float32)
    a = ops.flash_attention(q, q, q, impl="pallas", block_q=32, block_k=32)
    b = ops.flash_attention(q, q, q, impl="ref")
    assert_close(a, b, jnp.float32)
    # auto on CPU routes to ref
    c = ops.flash_attention(q, q, q, impl="auto")
    np.testing.assert_allclose(np.asarray(b), np.asarray(c))


# ----------------------------------------------------------------------------
# chunked (SSD-style) jnp scans — the optimized GSPMD path (§Perf H1)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(67, 16), (128, 64), (31, 64), (256, 32)])
def test_mamba2_chunked_jnp_matches_oracle(S, chunk):
    B, H, dh, ds = 2, 3, 16, 8
    x = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.2 + 1e-3,
                     jnp.float32)
    A = jnp.asarray(-np.abs(RNG.normal(size=(H,))) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, ds)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, ds)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(H,)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(B, H, ds, dh)), jnp.float32)
    y0, hf0 = ref.mamba2_scan(x, dt, A, Bm, Cm, D, h0=h0, return_state=True)
    y1, hf1 = ref.mamba2_scan_chunked(x, dt, A, Bm, Cm, D, h0=h0,
                                      return_state=True, chunk=chunk)
    np.testing.assert_allclose(y0, y1, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(hf0, hf1, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("S,chunk", [(53, 16), (128, 32), (20, 32)])
def test_rwkv6_chunked_jnp_matches_oracle(S, chunk):
    B, H, dh = 2, 3, 8
    r = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(
        RNG.normal(size=(B, S, H, dh)) * 0.5 - 1.5)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, dh)) * 0.1, jnp.float32)
    s0 = jnp.asarray(RNG.normal(size=(B, H, dh, dh)), jnp.float32)
    y0, sf0 = ref.rwkv6_scan(r, k, v, w, u, s0=s0, return_state=True)
    y1, sf1 = ref.rwkv6_scan_chunked(r, k, v, w, u, s0=s0,
                                     return_state=True, chunk=chunk)
    np.testing.assert_allclose(y0, y1, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(sf0, sf1, rtol=2e-3, atol=2e-3)


def test_rwkv6_chunked_strong_decay_stable():
    """w underflowing to exactly 0 (decay ~ e^-400) must stay finite and
    match the sequential oracle (the factored exp(-cum) form blows up
    here; the exact pairwise form must not)."""
    B, S, H, dh = 2, 53, 3, 8
    r = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(
        RNG.normal(size=(B, S, H, dh)) * 2 + 1.0)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, dh)) * 0.1, jnp.float32)
    y0 = np.asarray(ref.rwkv6_scan(r, k, v, w, u))
    y1 = np.asarray(ref.rwkv6_scan_chunked(r, k, v, w, u, chunk=16))
    assert np.isfinite(y1).all()
    np.testing.assert_allclose(y0, y1, rtol=5e-3, atol=5e-3)


@hp.given(st.integers(1, 64), st.integers(1, 2))
@hp.settings(deadline=None, max_examples=12)
def test_chunked_scans_arbitrary_length_property(S, B):
    """Chunked == oracle for any sequence length (padding invariant)."""
    H, dh, ds = 2, 8, 4
    rng = np.random.default_rng(S * 7 + B)
    x = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))) * 0.1 + 1e-3,
                     jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=(H,))) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    np.testing.assert_allclose(
        ref.mamba2_scan(x, dt, A, Bm, Cm, D),
        ref.mamba2_scan_chunked(x, dt, A, Bm, Cm, D, chunk=16),
        rtol=3e-4, atol=3e-4)


def test_ops_scan_dispatch_chunked_default_on_cpu():
    """impl='auto' must resolve to the chunked path off-TPU and agree with
    the sequential oracle."""
    B, S, H, dh = 1, 40, 2, 8
    r, k, v = (jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(np.exp(-np.exp(
        RNG.normal(size=(B, S, H, dh)) * 0.5 - 1.5)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, dh)) * 0.1, jnp.float32)
    got = ops.rwkv6_scan(r, k, v, w, u, impl="auto")
    want = ops.rwkv6_scan(r, k, v, w, u, impl="pertoken")
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_mamba2_chunked_strong_decay_stable():
    """Large A*dt (upper-triangle exponents >> 0 before masking) must not
    produce inf*0 = NaN and must match the oracle."""
    B, S, H, dh, ds = 2, 40, 4, 8, 8
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))) * 2.0 + 0.5,
                     jnp.float32)
    A = jnp.asarray(-np.linspace(1, 16, H), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
    D = jnp.ones((H,), jnp.float32)
    y1 = np.asarray(ref.mamba2_scan_chunked(x, dt, A, Bm, Cm, D, chunk=16))
    assert np.isfinite(y1).all()
    y0 = np.asarray(ref.mamba2_scan(x, dt, A, Bm, Cm, D))
    np.testing.assert_allclose(y0, y1, rtol=1e-3, atol=1e-3)
