"""Fluid fidelity tier (fabric/fluid) + the sim fast-path satellites.

The differential contract: the packet-level ``FabricSim`` is the bitwise
oracle; the fluid tier must reproduce its completion times EXACTLY on
quiet routes (single flow — same closed-form terms) and within 10% under
contention (random flow sets, QoS policies, fault maps, striped PUTs).
Per-class byte accounting has no tolerance at all: every wire hop is
attributed to its flow's class identically in both tiers.

Also here: the packet-sim fast-path satellites this tier rides with —
route/BFS memoization (one BFS per (src, dst, fault-epoch)), the
copy-on-write probe journal (bitwise-untouched timelines, bounded
snapshot cost), lazy heap compaction, and escape-credit deadlock
recovery (cyclic buffer waits under partitioned multi-class credits).
"""
import random

import numpy as np
import pytest

from repro.core import fabric
from repro.core.fabric import sim as simmod
from repro.core.fabric.fluid import FluidSim, HybridSim, make_sim
from repro.core.fabric.qos import QosPolicy, TrafficClass
from repro.core.fabric.sim import FabricSim, clear_route_cache
from repro.core.topology import Torus

MESHES = [(8,), (2, 4), (2, 2, 2), (4, 4)]
REL_TOL = 0.10


def _tol(sim, tp: float) -> float:
    """10% of the packet-oracle time, floored by packet-granularity
    quantization: a flow a few packets long can meet a transient queue
    the rate model cannot see, so tiny flows carry an absolute slack of
    a handful of packet serializations (documented in the README's
    fidelity-tier contract; the gated differentials use >= packet-sized
    payloads where the relative bar is the binding one)."""
    quant = 8 * sim.packet_bytes / sim.link_bw + 8 * sim.net.t_hop
    return max(REL_TOL * tp, quant)


def _rand_flows(rnd, n, n_flows, nb_hi=1 << 20):
    flows = []
    for _ in range(n_flows):
        s = rnd.randrange(n)
        d = rnd.randrange(n)
        while d == s:
            d = rnd.randrange(n)
        flows.append((s, d, rnd.randint(1024, nb_hi),
                      rnd.choice(list(TrafficClass)),
                      rnd.randint(0, 3) * 100e-6))
    return flows


def _run_both(torus, flows, **kw):
    out = []
    for fidelity in ("packet", "fluid"):
        sim = make_sim(torus, fidelity=fidelity, **kw)
        fids = [sim.inject(s, d, nb, cls=c, start_s=st)
                for s, d, nb, c, st in flows]
        sim.run()
        out.append((sim, fids))
    return out


# ---------------------------------------------------------------------------
# dispatch + knob threading
# ---------------------------------------------------------------------------

def test_make_sim_dispatch():
    t = Torus((4,))
    assert type(make_sim(t)) is FabricSim
    assert type(make_sim(t, fidelity="packet")) is FabricSim
    assert type(make_sim(t, fidelity="fluid")) is FluidSim
    assert type(make_sim(t, fidelity="hybrid")) is HybridSim
    with pytest.raises(ValueError, match="fidelity"):
        make_sim(t, fidelity="exact")


def test_estimate_validates_fidelity():
    sched = fabric.lower_all_reduce(Torus((4,)), ("x",))
    with pytest.raises(ValueError, match="fidelity"):
        fabric.estimate(sched, 4096, backend="sim", fidelity="nope")
    # analytic backend ignores the knob but still validates it
    with pytest.raises(ValueError, match="fidelity"):
        fabric.estimate(sched, 4096, fidelity="nope")


# ---------------------------------------------------------------------------
# differential: quiet routes are EXACT
# ---------------------------------------------------------------------------

def test_single_flow_exact_vs_packet():
    for dims in MESHES:
        torus = Torus(dims)
        n = torus.size
        for nbytes in (1, 4096, 1 << 20):
            for src_gpu, dst_gpu in ((False, False), (True, True)):
                p = FabricSim(torus)
                f = FluidSim(torus)
                kw = dict(src_gpu=src_gpu, dst_gpu=dst_gpu)
                tp = p.finish_s(p.inject(0, n - 1, nbytes, **kw))
                tf = f.finish_s(f.inject(0, n - 1, nbytes, **kw))
                assert tp > 0
                assert abs(tf - tp) / tp < 1e-9, \
                    f"dims={dims} nbytes={nbytes} gpu={src_gpu}"


def test_self_send_and_occupy_match_packet():
    torus = Torus((4,))
    p, f = FabricSim(torus), FluidSim(torus)
    assert f.finish_s(f.inject(2, 2, 4096)) == \
        p.finish_s(p.inject(2, 2, 4096))
    tp = p.finish_s(p.occupy(("hostif", 0), 3e-6, start_s=1e-6))
    tf = f.finish_s(f.occupy(("hostif", 0), 3e-6, start_s=1e-6))
    assert abs(tf - tp) < 1e-12
    # FIFO serialization of the same resource
    p2, f2 = FabricSim(torus), FluidSim(torus)
    for sim in (p2, f2):
        a = sim.occupy(("hostif", 0), 5e-6, start_s=0.0)
        b = sim.occupy(("hostif", 0), 5e-6, start_s=1e-6)
        assert sim.finish_s(b) >= sim.finish_s(a) + 5e-6 - 1e-12


def test_dependency_chain_matches_packet():
    torus = Torus((8,))
    p, f = FabricSim(torus), FluidSim(torus)
    for sim in (p, f):
        a = sim.inject(0, 2, 64 * 1024, start_s=0.0)
        b = sim.inject(2, 4, 64 * 1024, after=(a,))
        sim._last = sim.finish_s(b)
    assert abs(f._last - p._last) / p._last < 1e-9


# ---------------------------------------------------------------------------
# differential: contention within 10%, class bytes exact
# ---------------------------------------------------------------------------

def test_random_schedule_differential(rng):
    """Fluid holds the 10% per-flow bar on collective-schedule traffic —
    the workloads every consumer (trainer, engine, cost model) prices."""
    rnd = random.Random(int(rng.integers(1 << 30)))
    kinds = [fabric.AR, fabric.AG, fabric.RS, fabric.A2A]
    for _ in range(8):
        dims = rnd.choice(MESHES)
        torus = Torus(dims)
        kind = rnd.choice(kinds)
        # all_to_all lowers along a single axis only
        axes = ((rnd.randrange(len(dims)),) if kind is fabric.A2A
                else tuple(range(len(dims))))
        sched = fabric.lower(kind, torus, axes)
        nbytes = rnd.choice([64 * 1024, 1 << 20])
        kw = dict(backend="sim", cls=rnd.choice(list(TrafficClass)))
        if rnd.random() < 0.5:
            kw["qos"] = QosPolicy()
        p = fabric.estimate(sched, nbytes, fidelity="packet", **kw).total_s
        f = fabric.estimate(sched, nbytes, fidelity="fluid", **kw).total_s
        assert abs(f - p) / p <= REL_TOL, (dims, nbytes)


def test_random_flow_differential(rng):
    """Random flow soups: the fluid tier conserves per-class bytes
    exactly and tracks the aggregate; per-flow, the saturated-soup
    regime is the HYBRID tier's contract (escalated links re-run on the
    packet engine), and it must hold the 10% bar there."""
    rnd = random.Random(int(rng.integers(1 << 30)))
    for trial in range(4):
        dims = rnd.choice(MESHES)
        torus = Torus(dims)
        qos = QosPolicy() if rnd.random() < 0.5 else None
        flows = _rand_flows(rnd, torus.size, rnd.randint(4, 16))
        kw = {"qos": qos} if qos else {}
        (p, pfids), (f, ffids) = _run_both(torus, flows, **kw)
        h = make_sim(torus, fidelity="hybrid", **kw)
        hfids = [h.inject(s, d, nb, cls=c, start_s=st)
                 for s, d, nb, c, st in flows]
        h.run()
        for pf, hf, (s, d, nb, c, st) in zip(pfids, hfids, flows):
            tp = p.finish_s(pf) - st
            th = h.finish_s(hf) - st
            assert abs(th - tp) <= _tol(p, tp), \
                (dims, trial, s, d, nb, c)
        # fluid: per-class byte conservation is exact, and the aggregate
        # timeline tracks the oracle (per-flow FIFO-merge effects are
        # what hybrid escalation recovers)
        pc, fc = p.class_stats(), f.class_stats()
        for cls in TrafficClass:
            assert fc[cls] == pytest.approx(pc[cls], rel=1e-12, abs=1e-6)
        mk_p = max(p.finish_s(x) for x in pfids)
        mk_f = max(f.finish_s(x) for x in ffids)
        assert abs(mk_f - mk_p) <= max(0.15 * mk_p, _tol(p, mk_p))


def test_fault_detour_differential(rng):
    rnd = random.Random(int(rng.integers(1 << 30)))
    torus = Torus((4, 4))
    faults = fabric.FaultMap.normalized(set(), {(0, 1)})
    flows = _rand_flows(rnd, torus.size, 8, nb_hi=256 * 1024)
    (p, pfids), (f, ffids) = _run_both(torus, flows, faults=faults)
    for pf, ff, (s, d, nb, c, st) in zip(pfids, ffids, flows):
        tp = p.finish_s(pf) - st
        tf = f.finish_s(ff) - st
        assert abs(tf - tp) <= _tol(p, tp)
    # the detour is identical: same hop count per flow
    for pf, ff in zip(pfids, ffids):
        assert f.flow(ff).hops == p.flow(pf).hops


def test_striped_put_differential():
    torus = Torus((4, 4, 4))
    dst = torus.rank((2, 0, 0))
    results = {}
    for fidelity in ("packet", "fluid"):
        clear_route_cache()
        sim = make_sim(torus, fidelity=fidelity)
        sim.inject(0, dst, 8 << 20)   # background load on the direct path
        plan = fabric.striped_routes(sim, 0, dst, 4 << 20, k=3)
        fids = [sim.inject(0, dst, frac * (4 << 20), route=route)
                for route, frac in plan if frac > 0]
        results[fidelity] = max(sim.finish_s(x) for x in fids)
    tp, tf = results["packet"], results["fluid"]
    assert abs(tf - tp) / tp <= REL_TOL


def test_qos_weighted_shares_fluid():
    """Two saturating classes split a shared link per QoS weights —
    the fluid solver must reproduce the packet arbiter's split."""
    qos = QosPolicy()
    torus = Torus((8,))
    nb = 4 << 20
    for fidelity in ("packet", "fluid"):
        sim = make_sim(torus, fidelity=fidelity, qos=qos)
        a = sim.inject(0, 4, nb, cls=TrafficClass.DECODE)
        b = sim.inject(0, 4, nb, cls=TrafficClass.BULK)
        ta, tb = sim.finish_s(a), sim.finish_s(b)
        # DECODE (weight 16) finishes far ahead of BULK (weight 1)
        assert ta < tb
        if fidelity == "packet":
            ref = (ta, tb)
    assert abs(ta - ref[0]) / ref[0] <= REL_TOL
    assert abs(tb - ref[1]) / ref[1] <= REL_TOL


def test_solver_jnp_matches_np(rng):
    rnd = random.Random(int(rng.integers(1 << 30)))
    torus = Torus((4, 4))
    flows = _rand_flows(rnd, torus.size, 12)
    fins = {}
    for solver in ("np", "jnp"):
        sim = FluidSim(torus, qos=QosPolicy(), solver=solver)
        fids = [sim.inject(s, d, nb, cls=c, start_s=st)
                for s, d, nb, c, st in flows]
        sim.run()
        fins[solver] = np.array([sim.finish_s(x) for x in fids])
    np.testing.assert_allclose(fins["jnp"], fins["np"], rtol=5e-4)


# ---------------------------------------------------------------------------
# hybrid escalation
# ---------------------------------------------------------------------------

def test_hybrid_escalates_contended_link():
    torus = Torus((8,))
    nb = 2 << 20
    sims = {}
    for fidelity in ("packet", "fluid", "hybrid"):
        sim = make_sim(torus, fidelity=fidelity)
        fids = [sim.inject(0, 3, nb), sim.inject(0, 2, nb),
                sim.inject(1, 3, nb)]
        sim.run()
        sims[fidelity] = (sim, [sim.finish_s(x) for x in fids])
    hy = sims["hybrid"][0]
    assert hy.last_escalation is not None
    assert hy.last_escalation["escalated_flows"] >= 2   # shared link hot
    for th, tp in zip(sims["hybrid"][1], sims["packet"][1]):
        assert abs(th - tp) / tp <= REL_TOL
    # quiet fabric: nothing escalates
    hq = make_sim(torus, fidelity="hybrid")
    hq.finish_s(hq.inject(0, 4, 4096))
    assert hq.last_escalation is None


def test_fluid_probe_rollback_bitwise():
    """Probing the fluid tier leaves the timeline bitwise untouched —
    the never-probed control finishes identically."""
    torus = Torus((8,))
    flows = [(0, 3, 1 << 20), (1, 4, 1 << 19), (5, 7, 1 << 18)]

    def build():
        sim = FluidSim(torus, qos=QosPolicy())
        return sim, [sim.inject(s, d, nb) for s, d, nb in flows]

    probed, pf = build()
    control, cf = build()
    route = tuple(torus.route(0, 3))
    t1 = probed.probe_route(route, 1 << 20)
    t2 = probed.probe_route(route, 1 << 20)
    assert t1 == t2   # probe is idempotent (no state leaked)
    late_p = probed.inject(2, 6, 1 << 19)
    late_c = control.inject(2, 6, 1 << 19)
    for a, b in zip(pf + [late_p], cf + [late_c]):
        assert probed.finish_s(a) == control.finish_s(b)


# ---------------------------------------------------------------------------
# satellite: route/BFS memoization
# ---------------------------------------------------------------------------

def test_route_cache_one_bfs_per_epoch(monkeypatch):
    clear_route_cache()
    calls = []
    real = simmod._bfs_path

    def counting(torus, src, dst, faults):
        calls.append((src, dst, faults))
        return real(torus, src, dst, faults)

    monkeypatch.setattr(simmod, "_bfs_path", counting)
    torus = Torus((4, 4))
    faults = fabric.FaultMap.normalized(set(), {(0, 1)})
    sim = FabricSim(torus, faults=faults)
    r1 = fabric.candidate_routes(torus, 0, 5, faults)
    n1 = len(calls)
    assert n1 > 0
    # same epoch: every later consumer hits the cache, zero new BFS
    r2 = fabric.candidate_routes(torus, 0, 5, faults)
    assert len(calls) == n1
    assert r2 == r1
    # flow-route resolution uses its own (plain-faults) key: ONE BFS on
    # first use, cached for every later inject
    sim.inject(0, 5, 4096)
    n_inject = len(calls)
    assert n_inject == n1 + 1
    sim.inject(0, 5, 4096)
    assert len(calls) == n_inject
    fabric.best_route(sim, 0, 5, 4096, faults=faults)
    assert len(calls) == n_inject
    n1 = n_inject
    # new fault epoch = new key: BFS runs again
    faults2 = fabric.FaultMap.normalized(set(), {(0, 1), (1, 5)})
    fabric.candidate_routes(torus, 0, 5, faults2)
    assert len(calls) > n1
    # cache clear forces a re-run within the same epoch
    n2 = len(calls)
    clear_route_cache()
    fabric.candidate_routes(torus, 0, 5, faults)
    assert len(calls) > n2


def test_route_cache_results_stable_across_epoch_flip():
    """Flipping faults back restores the original cached answer — stale
    entries can never leak across epochs (keys carry the FaultMap)."""
    clear_route_cache()
    torus = Torus((4, 4))
    faults = fabric.FaultMap.normalized(set(), {(0, 4)})
    healthy = fabric.candidate_routes(torus, 0, 5)
    faulted = fabric.candidate_routes(torus, 0, 5, faults)
    again = fabric.candidate_routes(torus, 0, 5)
    assert again == healthy
    for r in faulted:   # the faulted epoch's routes avoid the dead link
        assert (0, 4) not in set(zip(r, r[1:]))


# ---------------------------------------------------------------------------
# satellite: probe journal (packet tier)
# ---------------------------------------------------------------------------

def test_probe_journal_bitwise_vs_never_probed():
    torus = Torus((4, 4, 4))
    flows = [(0, 5, 1 << 20), (9, 13, 1 << 19), (40, 44, 1 << 18),
             (60, 63, 1 << 20)]

    def build():
        sim = FabricSim(torus, qos=QosPolicy())
        fids = [sim.inject(s, d, nb,
                           cls=list(TrafficClass)[i % len(TrafficClass)])
                for i, (s, d, nb) in enumerate(flows)]
        return sim, fids

    probed, pf = build()
    control, cf = build()
    for _ in range(3):
        probed.probe_route(tuple(torus.route(0, 5)), 1 << 19)
        fabric.best_route(probed, 9, 13, 1 << 18)
    late_p = probed.inject(3, 7, 1 << 19)
    late_c = control.inject(3, 7, 1 << 19)
    for a, b in zip(pf + [late_p], cf + [late_c]):
        assert probed.finish_s(a) == control.finish_s(b)
    assert probed.link_stats() == control.link_stats()
    assert probed._heap == control._heap


def test_probe_report_bounded_to_touched_state():
    """The journal only records state the ghost traffic touches — far
    corners of a big torus stay out of the probe's footprint."""
    torus = Torus((8, 8))
    sim = FabricSim(torus)
    # resident traffic in the far corner, unrelated to the probed route;
    # settled before probing (unsettled flows contend with the ghost and
    # legitimately enter its footprint)
    for i in range(8):
        sim.inject(56 + (i % 4), 60 + (i % 4), 1 << 18)
    sim.run()
    sim.probe_route(tuple(torus.route(0, 2)), 1 << 18)
    rep = sim.last_probe_report
    assert rep is not None
    assert rep["links_total"] >= 4    # the far corner's links exist
    # strictly bounded: the probe touched only its own route's links and
    # none of the settled far-corner flows
    assert rep["links_touched"] <= 4
    assert rep["links_touched"] < rep["links_total"]
    assert rep["flows_touched"] == 0


# ---------------------------------------------------------------------------
# satellite: heap compaction + deadlock recovery
# ---------------------------------------------------------------------------

def test_heap_compaction_bounds_heap_and_preserves_results():
    """Many same-link flows churn superseded retry events; compaction
    must keep the heap bounded by live events without changing any
    finish time (control: compaction disabled)."""
    torus = Torus((8,))

    def build(compact: bool):
        sim = FabricSim(torus)
        if not compact:
            sim._compact = lambda: None
        fids = []
        for i in range(40):
            fids.append(sim.inject(0, 1 + (i % 4), 256 * 1024,
                                   start_s=i * 1e-7))
        return sim, fids

    on, on_f = build(True)
    off, off_f = build(False)
    peak = 0
    orig = on._push

    def watch(t, kind, arg):
        nonlocal peak
        orig(t, kind, arg)
        peak = max(peak, len(on._heap))

    on._push = watch
    for a, b in zip(on_f, off_f):
        assert on.finish_s(a) == off.finish_s(b)
    assert on._stale <= max(64, len(on._heap))
    assert peak <= 4 * len(on_f) + 64   # bounded by live events, not churn


def test_heap_bounded_across_probe_and_fault_cycles():
    torus = Torus((4, 4))
    sim = FabricSim(torus)
    base = [sim.inject(i, (i + 5) % 16, 128 * 1024) for i in range(8)]
    sizes = []
    for cycle in range(6):
        for _ in range(4):
            sim.probe_route(tuple(torus.route(0, 5)), 64 * 1024)
        sim.faults = fabric.FaultMap.normalized(set(), {(0, 1)}) \
            if cycle % 2 == 0 else fabric.FaultMap()
        clear_route_cache()
        sim.inject(2, 9, 64 * 1024)
        sizes.append(len(sim._heap))
    sim.run()
    assert max(sizes) < 512          # probe/fault churn cannot grow it
    for f in base:
        assert sim.finish_s(f) > 0


def test_credit_deadlock_recovery():
    """Multi-class partitioned credits + wrap-around rings form a cyclic
    buffer wait (the 512-node workload's failure mode, reproduced small):
    without escape-credit recovery flows strand forever; with it, every
    flow completes and the breaks are counted."""
    def build():
        rnd = random.Random(1)
        torus = Torus((8,))
        sim = FabricSim(torus, qos=QosPolicy())
        fids = []
        for _ in range(64):
            s = rnd.randrange(8)
            d = rnd.randrange(8)
            while d == s:
                d = rnd.randrange(8)
            fids.append(sim.inject(s, d, rnd.randint(256 * 1024, 1 << 20),
                                   cls=rnd.choice(list(TrafficClass))))
        return sim, fids

    # control: recovery disabled -> the deadlock strands flows
    stuck, stuck_fids = build()
    stuck._unstick = lambda: False
    stuck.run()
    stranded = sum(1 for f in stuck_fids
                   if stuck._flows[f].finish_s is None)
    assert stranded > 0, "workload no longer deadlocks; pick a new seed"
    # recovery on: every flow completes, breaks recorded
    sim, fids = build()
    sim.run()
    assert sim.deadlock_breaks > 0
    for f in fids:
        assert sim._flows[f].finish_s is not None
    # recovery engages ONLY in the stuck state: a quiet run never breaks
    quiet = FabricSim(Torus((8,)), qos=QosPolicy())
    quiet.finish_s(quiet.inject(0, 4, 1 << 20))
    assert quiet.deadlock_breaks == 0
