"""EP all-to-all MoE (§Perf H2): numerics in a forced-8-device subprocess."""
import os
import subprocess
import sys
from pathlib import Path

import pytest


@pytest.mark.slow
def test_ep_moe_multidevice():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    r = subprocess.run(
        [sys.executable, str(Path(__file__).parent / "ep_moe_check.py")],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "ALL EP MOE CHECKS PASSED" in r.stdout
