"""manual_sp (hand-SPMD Megatron-SP layer stack) numerics — subprocess
check on 8 forced host devices (launched by tests/test_manual_sp.py)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_reduced  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import api  # noqa: E402
from repro.parallel import sharding  # noqa: E402


def main() -> None:
    assert jax.device_count() == 8
    cfg = dataclasses.replace(get_reduced("deepseek-7b"), n_heads=4,
                              n_kv_heads=4, d_ff=128, dtype=jnp.float32,
                              attn_dtype="f32")  # exact parity in f32
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32)}
    m0 = api.get_model(cfg)
    p = m0.init(jax.random.key(0))
    l0, g0 = jax.value_and_grad(lambda p: m0.train_loss(p, batch))(p)

    mesh = make_mesh((2, 4), ("data", "model"))
    m2 = api.get_model(dataclasses.replace(cfg, tp_activations="manual_sp"))
    sharding.set_runtime_mesh(mesh)
    try:
        with mesh:
            l2, g2 = jax.jit(jax.value_and_grad(
                lambda p: m2.train_loss(p, batch)))(p)
    finally:
        sharding.set_runtime_mesh(None)
    np.testing.assert_allclose(float(l0), float(l2), rtol=2e-5)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-5)
    # GQA + qkv-bias flavour
    cfgq = dataclasses.replace(get_reduced("qwen2-0.5b"), n_heads=8,
                               n_kv_heads=4, d_ff=128, dtype=jnp.float32)
    mq = api.get_model(cfgq)
    pq = mq.init(jax.random.key(1))
    lq = mq.train_loss(pq, batch)
    mq2 = api.get_model(dataclasses.replace(cfgq,
                                            tp_activations="manual_sp"))
    sharding.set_runtime_mesh(mesh)
    try:
        with mesh:
            lq2 = jax.jit(lambda p: mq2.train_loss(p, batch))(pq)
    finally:
        sharding.set_runtime_mesh(None)
    np.testing.assert_allclose(float(lq), float(lq2), rtol=2e-5)
    print("ALL MANUAL_SP CHECKS PASSED")


if __name__ == "__main__":
    main()
