"""Serving-cluster behaviour: router placement, live KV-page migration
(bitwise decode equivalence, incl. under a link-fault reroute), and the
chunked-vs-whole-prompt prefill differential across model families.

The migration acceptance bar: a decode sequence with a mid-stream slot
migration produces EXACTLY the tokens of the unmigrated run — the KV
pages + seq_len are the complete decode state, so nothing else may leak
into the numerics.
"""
import numpy as np
import pytest

import jax

from repro import configs
from repro.core import fabric
from repro.core.topology import Torus
from repro.models import api
from repro.serving.cluster import ServingCluster, owners
from repro.serving.engine import Engine, PagedLM, Request


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_reduced("smollm-135m")
    return cfg, api.get_model(cfg).init(jax.random.key(0))


def _cluster(cfg, params, **kw):
    kw.setdefault("torus", Torus((4,)))
    kw.setdefault("node_ranks", (0, 1))
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_tokens", 8)
    return ServingCluster(cfg, params, **kw)


def _decode_alone(cfg, params, prompt, max_new):
    lm = PagedLM(cfg, params, max_batch=2, max_seq=64, page_tokens=8)
    eng = Engine(lm)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=max_new))
    eng.run_to_completion()
    return eng.finished[0].out_tokens


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

def test_router_places_least_loaded(dense_model, rng):
    cfg, params = dense_model
    cl = _cluster(cfg, params)
    rids = list(range(4))
    for rid in rids:
        prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
        cl.submit(Request(rid=rid, prompt=prompt, max_new_tokens=3))
    where = owners(cl, rids)
    # alternating placement: every other request lands on the other node
    assert [where[r] for r in rids] == [0, 1, 0, 1]
    assert {n.load for n in cl.nodes.values()} == {2}
    cl.run_to_completion()
    assert [r.rid for r in cl.finished] == rids
    assert cl.in_flight == 0


# ---------------------------------------------------------------------------
# live migration: bitwise decode equivalence
# ---------------------------------------------------------------------------

def test_migration_mid_decode_bitwise_identical(dense_model, rng):
    cfg, params = dense_model
    prompt = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    baseline = _decode_alone(cfg, params, prompt, max_new=8)

    cl = _cluster(cfg, params)
    assert cl.submit(Request(rid=7, prompt=prompt, max_new_tokens=8)) == 0
    for _ in range(4):                     # prefill + a few decode steps
        cl.step()
    mid = len(next(iter(cl.nodes[0].engine.running.values())).out_tokens)
    assert 0 < mid < 8                     # genuinely mid-stream
    rep = cl.migrate(7, 1)
    assert rep.src == 0 and rep.dst == 1 and not rep.rerouted
    assert rep.n_pages > 0 and rep.nbytes == rep.n_pages * 8 * \
        cl.nodes[0].lm.bytes_per_token
    assert not cl.nodes[0].engine.running  # source really let go
    assert not cl.nodes[0].lm.slot_pages   # and freed its pages
    cl.run_to_completion()
    assert cl.finished[0].out_tokens == baseline
    st = cl.stats()
    assert st["n_migrations"] == 1 and st["migrated_bytes"] == rep.nbytes


def test_migration_through_link_fault_reroute(dense_model, rng):
    cfg, params = dense_model
    prompt = rng.integers(0, cfg.vocab, size=(11,)).astype(np.int32)
    baseline = _decode_alone(cfg, params, prompt, max_new=7)

    cl = _cluster(cfg, params)
    cl.fail_link(0, 1)                     # the only direct link on a ring
    cl.submit(Request(rid=0, prompt=prompt, max_new_tokens=7))
    for _ in range(3):
        cl.step()
    rep = cl.migrate(0, 1)
    assert rep.rerouted and rep.hops == 3 and rep.min_hops == 1
    cl.run_to_completion()
    assert cl.finished[0].out_tokens == baseline
    assert cl.stats()["rerouted_migrations"] == 1


def test_migration_unroutable_when_fabric_partitioned(dense_model, rng):
    cfg, params = dense_model
    cl = ServingCluster(cfg, params, torus=Torus((2,)), node_ranks=(0, 1),
                        max_batch=2, max_seq=64, page_tokens=8)
    cl.fail_link(0, 1)                     # a 2-ring has a single link
    prompt = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)
    cl.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    for _ in range(2):
        cl.step()
    with pytest.raises(fabric.UnroutableError):
        cl.migrate(0, 1)
    # rebalance must surface the partition too, not report "balanced"
    with pytest.raises(fabric.UnroutableError):
        cl.rebalance(threshold=1)
    # the request never left the source and still completes
    assert owners(cl, [0])[0] == 0
    cl.run_to_completion()
    assert len(cl.finished) == 1


def test_migration_rejected_when_destination_full(dense_model, rng):
    cfg, params = dense_model
    cl = _cluster(cfg, params, max_batch=1)
    p0 = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    cl.submit(Request(rid=0, prompt=p0, max_new_tokens=6))
    cl.submit(Request(rid=1, prompt=p1, max_new_tokens=6))
    cl.step()
    with pytest.raises(RuntimeError):      # dst has no free decode slot
        cl.migrate(0, 1)
    assert owners(cl, [0, 1]) == {0: 0, 1: 1}
    cl.run_to_completion()
    assert len(cl.finished) == 2


def test_rebalance_moves_work_off_the_busiest_node(dense_model, rng):
    cfg, params = dense_model
    cl = _cluster(cfg, params, max_batch=3)
    # bypass the router to manufacture imbalance: all load on node 0
    for rid in range(3):
        prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
        cl.nodes[0].engine.submit(
            Request(rid=rid, prompt=prompt, max_new_tokens=6))
    for _ in range(2):
        cl.step()
    assert cl.rebalance(threshold=2) is not None
    loads = {r: n.load for r, n in cl.nodes.items()}
    assert loads == {0: 2, 1: 1}
    assert cl.rebalance(threshold=2) is None     # now balanced
    cl.run_to_completion()
    assert len(cl.finished) == 3


def test_export_import_slot_roundtrip_without_decode():
    """Slot state machinery alone (params never touched): page contents,
    page-table row and seq_len survive an export/import across nodes."""
    cfg = configs.get_reduced("smollm-135m")
    t = Torus((2,))
    a = PagedLM(cfg, None, max_batch=2, max_seq=32, page_tokens=4,
                torus=t, tp_axes=(), rank=0)
    b = PagedLM(cfg, None, max_batch=2, max_seq=32, page_tokens=4,
                torus=t, tp_axes=(), rank=1)
    slot = a.claim_slot(prompt_len=6, max_new=4)   # 3 pages of 4 tokens
    pages = a.slot_pages[slot]
    marker = np.arange(a.k_pool[:, pages].size,
                       dtype=np.float32).reshape(a.k_pool[:, pages].shape)
    a.k_pool = a.k_pool.at[:, np.asarray(pages)].set(marker.astype(
        a.k_pool.dtype))
    a.seq_lens[slot] = 6
    state = a.export_slot(slot)
    # only the 2 live pages (ceil(6/4)) travel; headroom is claimed fresh
    assert state.n_pages == 2 and state.n_alloc == len(pages) == 3
    assert state.seq_len == 6
    assert state.nbytes == 2 * 4 * a.bytes_per_token
    new = b.import_slot(state)
    assert len(b.slot_pages[new]) == 3
    live = b.slot_pages[new][:2]
    np.testing.assert_array_equal(
        np.asarray(b.k_pool[:, np.asarray(live)]),
        np.asarray(a.k_pool[:, np.asarray(pages[:2])]))
    assert int(b.seq_lens[new]) == 6
    assert list(b.page_table[new, :3]) == b.slot_pages[new]
    with pytest.raises(ValueError):        # page geometry must match
        PagedLM(cfg, None, max_batch=1, max_seq=32, page_tokens=8,
                torus=t, tp_axes=()).import_slot(state)


# ---------------------------------------------------------------------------
# chunked vs whole-prompt prefill differential (dense + moe families)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm-135m", "olmoe-1b-7b"])
def test_chunked_prefill_differential_random_shapes(arch, rng):
    """Chunk-interleaved admission must be a pure scheduling change for
    ANY prompt length / chunk size: tokens identical to whole-prompt
    prefill, on the dense and the moe family alike."""
    cfg = configs.get_reduced(arch)
    params = api.get_model(cfg).init(jax.random.key(2))
    cases = [(int(rng.integers(3, 29)), int(rng.integers(1, 4)))
             for _ in range(3)]

    for plen, chunk_pages in cases:
        prompts = [rng.integers(0, cfg.vocab, size=(plen,)).astype(np.int32),
                   rng.integers(0, cfg.vocab, size=(max(1, plen - 2),)
                                ).astype(np.int32)]

        def run(chunked):
            lm = PagedLM(cfg, params, max_batch=2, max_seq=48, page_tokens=8)
            eng = Engine(lm, chunked_prefill=chunked,
                         prefill_chunk_pages=chunk_pages)
            for i, p in enumerate(prompts):
                eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
            eng.run_to_completion()
            assert len(eng.finished) == len(prompts)
            return {r.rid: r.out_tokens for r in eng.finished}

        assert run(False) == run(True), \
            f"{arch}: plen={plen} chunk_pages={chunk_pages}"


def test_oversize_request_fails_loudly_not_livelocks(dense_model, rng):
    """A request that can never fit (needs more pages than pages_per_seq)
    must raise at admission, not re-queue forever as 'transient'."""
    cfg, params = dense_model
    lm = PagedLM(cfg, params, max_batch=2, max_seq=16, page_tokens=8)
    eng = Engine(lm)
    prompt = rng.integers(0, cfg.vocab, size=(30,)).astype(np.int32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=10))
    with pytest.raises(ValueError, match="pages_per_seq"):
        eng.step()
    # the rejected request must not vanish from every queue
    assert [r.rid for r in eng.pending] == [0]


def test_pagedlm_accepts_any_torus_rank_dims():
    """The torus/rank placement params must work for any fabric shape —
    the TP-twin axes default to one per torus dim."""
    cfg = configs.get_reduced("smollm-135m")
    for dims in ((4,), (2, 2), (2, 2, 2)):
        t = Torus(dims)
        lm = PagedLM(cfg, None, max_batch=1, max_seq=16, page_tokens=8,
                     torus=t, rank=t.size - 1)
        assert len(lm.tp_axes) == t.ndims
        assert lm.predicted_tp_comm_s >= 0.0
    with pytest.raises(ValueError):
        PagedLM(cfg, None, max_batch=1, max_seq=16, torus=Torus((2,)),
                rank=5)


# ---------------------------------------------------------------------------
# shared fabric timeline: contention + congestion-aware migration routing
# ---------------------------------------------------------------------------

def _slow_net():
    """A deliberately slow link so the reduced test model's tiny payloads
    become byte-dominated — contention then shows at test scale exactly
    like 7B-scale payloads do on the real link rate."""
    from repro.core import hw
    from repro.core.apelink import NetModel
    link = hw.ApenetLinkSpec("slow-test", lanes=1, lane_gbps=0.01,
                             encoding_efficiency=0.8)
    return NetModel(link=link)


# fine packets: the reduced model's KB-scale payloads must span many
# packets for link sharing (round-robin per packet) to cost bandwidth
_SLOW_SIM_KW = dict(credit_bytes=40e3, packet_bytes=256)


def test_migration_contends_with_live_decode(dense_model, rng):
    """A migrate() issued while the nodes' decode TP collectives are in
    flight on the shared timeline must be priced ABOVE the sum-of-
    isolated closed form — and tokens must stay bitwise identical."""
    cfg, params = dense_model
    prompt = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    baseline = _decode_alone(cfg, params, prompt, max_new=8)

    cl = _cluster(cfg, params, tp_axes=None, net=_slow_net(),
                  sim_kw=_SLOW_SIM_KW)
    cl.submit(Request(rid=7, prompt=prompt, max_new_tokens=8))
    for _ in range(4):
        cl.step()                        # window stays open: flows pending
    rep = cl.migrate(7, 1)
    assert rep.isolated_s > 0
    assert rep.modelled_s > rep.isolated_s * 1.01, \
        "migration saw no contention from the live decode flows"
    assert rep.contention_slowdown > 1.01
    cl.run_to_completion()
    assert cl.finished[0].out_tokens == baseline
    st = cl.stats()
    assert st["nodes"][0]["sim_tp_comm_s"] > 0
    assert st["migration_isolated_s"] < st["migration_modelled_s"]
    assert st["fabric_sim_now_s"] > 0


def test_migration_quiet_fabric_prices_isolated(dense_model, rng):
    """With no decode traffic on the timeline (tp_axes=()) the shared-sim
    price collapses to the closed-form sum-of-isolated one."""
    cfg, params = dense_model
    prompt = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    cl = _cluster(cfg, params)           # tp_axes=() default: no TP flows
    cl.submit(Request(rid=1, prompt=prompt, max_new_tokens=6))
    for _ in range(3):
        cl.step()
    rep = cl.migrate(1, 1)
    assert rep.modelled_s == pytest.approx(rep.isolated_s, rel=0.05)
    assert not rep.rerouted              # quiet fabric: minimal route


def test_congestion_aware_migration_beats_hop_count(dense_model, rng):
    """With a bulk transfer hammering the direct link, the congestion-
    aware route probe must pick a genuine detour AND price below the
    hop-minimal route — while decode equivalence still holds."""
    cfg, params = dense_model
    prompt = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    baseline = _decode_alone(cfg, params, prompt, max_new=6)

    def run(policy):
        cl = _cluster(cfg, params, net=_slow_net(), sim_kw=_SLOW_SIM_KW)
        cl.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
        for _ in range(2):
            cl.step()
        cl.sim.inject(0, 1, 200_000)     # bulk traffic on the direct link
        rep = cl.migrate(0, 1, route_policy=policy)
        return cl, rep

    cl_cong, rep_cong = run("congestion")
    _, rep_hops = run("hops")
    assert rep_hops.hops == 1            # hop-count routing takes the hit
    assert rep_cong.hops > 1             # the probe detoured
    assert rep_cong.rerouted and rep_cong.route_policy == "congestion"
    assert rep_cong.modelled_s < rep_hops.modelled_s
    cl_cong.run_to_completion()
    assert cl_cong.finished[0].out_tokens == baseline


def test_striped_migration_bitwise_and_reported(dense_model, rng):
    """``route_policy="striped"`` splits the PUT across several probed
    routes (multi-path bulk striping) — decode must still resume with
    bitwise-identical tokens, and the report must carry the stripe count
    and the striped price."""
    cfg, params = dense_model
    prompt = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    baseline = _decode_alone(cfg, params, prompt, max_new=8)

    cl = ServingCluster(cfg, params, torus=Torus((4, 4)),
                        node_ranks=(0, 5), max_batch=2, max_seq=64,
                        page_tokens=8, qos=fabric.QosPolicy())
    cl.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    for _ in range(4):
        cl.step()
    rep = cl.migrate(0, 5, route_policy="striped")
    assert rep.route_policy == "striped"
    assert rep.stripes > 1                 # genuinely multi-path
    assert rep.nbytes == rep.n_pages * 8 * cl.nodes[0].lm.bytes_per_token
    cl.run_to_completion()
    assert cl.finished[0].out_tokens == baseline
    assert cl.stats()["n_migrations"] == 1


def test_fail_link_relowers_decode_tp_twin(dense_model):
    """fail_link must re-lower every node's decode TP twin through
    fabric.rewrite: the per-step TP flows then price the detoured ring
    honestly (explicit detour hops + higher predicted cost), and
    clear_faults restores the healthy twin."""
    cfg, params = dense_model
    cl = ServingCluster(cfg, params, torus=Torus((4,)), node_ranks=(0, 1),
                        max_batch=2, max_seq=64, page_tokens=8,
                        tp_axes=None)
    lm = cl.nodes[0].lm
    healthy = lm.tp_schedule
    pred_healthy = lm.predicted_tp_comm_s
    assert healthy.max_hops == 1
    cl.fail_link(0, 1)
    assert lm.tp_schedule.max_hops == 3    # the ring detour, annotated
    assert lm.predicted_tp_comm_s > pred_healthy
    assert lm.tp_schedule.faults           # carries the fault map
    cl.clear_faults()
    assert lm.tp_schedule == healthy
    assert lm.predicted_tp_comm_s == pytest.approx(pred_healthy)


def test_qos_cluster_protects_decode_from_migration_bulk(dense_model, rng):
    """End-to-end decode protection: the same migrate-under-decode
    scenario as test_migration_contends_with_live_decode, but on a
    QoS-enabled cluster — the decode TP flows (DECODE class) must stretch
    LESS against the BULK migration than on the FIFO cluster, and tokens
    stay bitwise identical."""
    cfg, params = dense_model
    prompt = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    baseline = _decode_alone(cfg, params, prompt, max_new=8)

    def run(qos):
        cl = _cluster(cfg, params, tp_axes=None, net=_slow_net(),
                      sim_kw=_SLOW_SIM_KW, qos=qos)
        cl.submit(Request(rid=7, prompt=prompt, max_new_tokens=8))
        for _ in range(4):
            cl.step()
        cl.migrate(7, 1)
        cl.run_to_completion()
        assert cl.finished[0].out_tokens == baseline
        return cl.stats()["nodes"][0]["sim_tp_comm_s"]

    tp_fifo = run(None)
    tp_qos = run(fabric.QosPolicy())
    assert 0 < tp_qos < tp_fifo            # decode comm protected


def test_migrate_rejects_unknown_route_policy(dense_model, rng):
    cfg, params = dense_model
    cl = _cluster(cfg, params)
    prompt = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)
    cl.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    for _ in range(2):
        cl.step()
    with pytest.raises(ValueError, match="route_policy"):
        cl.migrate(0, 1, route_policy="shortest")


def test_stall_accounting_only_counts_real_work(dense_model, rng):
    """A step that neither admitted nor prefilled must not accrue
    decode_stall_s (the _admit walk is not a stall)."""
    cfg, params = dense_model
    lm = PagedLM(cfg, params, max_batch=2, max_seq=64, page_tokens=8)
    eng = Engine(lm)
    prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    eng.step()                             # admits + prefills: counted
    stall_after_admit = eng.decode_stall_s
    assert stall_after_admit == 0.0        # no batch was waiting yet
    for _ in range(3):                     # pure decode steps: not counted
        eng.step()
    assert eng.decode_stall_s == stall_after_admit
    # a second request admitted while the first decodes IS a stall
    eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=4))
    eng.step()
    assert eng.decode_stall_s > stall_after_admit
