"""manual_sp loss/grad parity with the baseline stack (§Perf H3 it6)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest


@pytest.mark.slow
def test_manual_sp_multidevice():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    r = subprocess.run(
        [sys.executable, str(Path(__file__).parent / "manual_sp_check.py")],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "ALL MANUAL_SP CHECKS PASSED" in r.stdout
