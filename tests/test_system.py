"""End-to-end behaviour tests for the paper's system: train -> checkpoint
-> restore, the serving engine's page lifecycle, and the full
train/serve loop on a reduced assigned arch.  Multi-device parity and the
fault drill live in multidevice_checks.py / test_runtime.py."""
import tempfile

import numpy as np

import jax

from repro import configs
from repro.models import api
from repro.optim import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.serving.engine import Engine, PagedLM, Request


def _trainer(tmp, arch="smollm-135m", **kw):
    cfg = configs.get_reduced(arch)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    tcfg = TrainerConfig(ckpt_dir=tmp, ckpt_every=kw.pop("ckpt_every", 10),
                         batch=4, seq_len=32, opt=opt, comm="single", **kw)
    return Trainer(cfg, tcfg)


def test_train_checkpoint_resume_bitexact():
    """Resuming from a checkpoint reproduces the uninterrupted run exactly
    (same params, same data stream position)."""
    with tempfile.TemporaryDirectory() as td:
        t1 = _trainer(td + "/a", ckpt_every=5)
        t1.train(10)                       # checkpoints at 5, 10
        uninterrupted = [m["loss"] for m in t1.train(3)]

        t2 = _trainer(td + "/a", ckpt_every=5)
        t2.resume()                        # restores step 10
        assert t2.data.step == 10
        resumed = [m["loss"] for m in t2.train(3)]
        np.testing.assert_allclose(resumed, uninterrupted, rtol=1e-6)


def test_training_reduces_loss_all_families():
    """One member of each model family trains (loss strictly improves)."""
    for arch in ("qwen2-0.5b", "olmoe-1b-7b", "rwkv6-1.6b", "zamba2-1.2b"):
        with tempfile.TemporaryDirectory() as td:
            tr = _trainer(td, arch=arch, ckpt_every=0)
            losses = [m["loss"] for m in tr.train(8)]
            assert all(np.isfinite(x) for x in losses), arch
            assert losses[-1] < losses[0], (arch, losses)


def test_engine_page_lifecycle_no_leak():
    """Pages claimed by finished requests are returned to the allocator;
    a second wave reuses them (TLB hit rate rises)."""
    cfg = configs.get_reduced("qwen2-0.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0))
    lm = PagedLM(cfg, params, max_batch=2, max_seq=64, page_tokens=16)
    free0 = len(lm.allocator.free)
    eng = Engine(lm)
    rng = np.random.default_rng(0)
    for rid in range(6):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32),
            max_new_tokens=6))
    eng.run_to_completion()
    assert len(eng.finished) == 6
    assert len(lm.allocator.free) == free0          # no page leak
    assert not lm.slot_pages
    assert eng.stats()["tlb_hit_rate"] > 0.3        # reuse hits the TLB


def test_engine_output_independent_of_batching():
    """Continuous batching must not change a request's tokens: the same
    prompt decoded alone equals the prompt decoded amid other traffic."""
    cfg = configs.get_reduced("smollm-135m")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)

    def run(extra):
        lm = PagedLM(cfg, params, max_batch=3, max_seq=64, page_tokens=8)
        eng = Engine(lm)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
        for i, ep in enumerate(extra):
            eng.submit(Request(rid=1 + i, prompt=ep, max_new_tokens=4))
        eng.run_to_completion()
        return next(r for r in eng.finished if r.rid == 0).out_tokens

    alone = run([])
    others = [rng.integers(0, cfg.vocab, size=(7,)).astype(np.int32)
              for _ in range(3)]
    busy = run(others)
    assert alone == busy


def test_straggler_detection():
    import time as _time
    with tempfile.TemporaryDirectory() as td:
        tr = _trainer(td, ckpt_every=0, straggler_factor=2.0)
        tr.train(6)
        orig = tr._step_fn

        def slow(*a, **k):
            _time.sleep(
                2.5 * float(np.median(tr._step_times[-20:])) + 0.05)
            return orig(*a, **k)

        tr._step_fn = slow
        tr.train(1)
        tr._step_fn = orig
        assert any("straggler" in e for e in tr.events)
