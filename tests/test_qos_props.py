"""Property-based invariants for the QoS link arbiter (fabric/qos +
FabricSim virtual channels), driven by hypothesis over random flow sets:

  * **byte conservation per class**: every wire hop of every flow is
    accounted to exactly its class — ``class_stats`` equals the per-class
    sum of ``nbytes * hops`` no matter how flows interleave;
  * **single_class ≡ FIFO**: under ``QosPolicy(single_class=True)`` (and
    the default ``qos=None``) class tags are inert — any permutation of
    tags over any flow set finishes bitwise identically;
  * **no starvation**: under adversarial BULK load a DECODE flow still
    completes within its weighted share of the link (bounded stretch),
    and the BULK flows themselves all complete (work conservation — the
    arbiter never idles a backlogged link);
  * **weight tracking**: two saturating classes split a link's goodput in
    proportion to their ``QosPolicy`` weights.
"""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from hypothesis import given, settings

from repro.core.fabric import FabricSim, QosPolicy, TrafficClass
from repro.core.topology import Torus

CLASSES = list(TrafficClass)


def _flow_specs(ring):
    """(src, dst, nbytes, cls) with src != dst on a ``ring``-rank 1D torus."""
    return st.lists(
        st.tuples(st.integers(0, ring - 1), st.integers(1, ring - 1),
                  st.integers(1, 1 << 18), st.sampled_from(CLASSES)),
        min_size=1, max_size=8)


def _inject_all(sim, specs):
    return [sim.inject(s, (s + d) % sim.torus.size, n, cls=c)
            for s, d, n, c in specs]


# ---------------------------------------------------------------------------
# byte conservation per class
# ---------------------------------------------------------------------------

@settings(deadline=None)
@given(specs=_flow_specs(8), single=st.booleans())
def test_class_bytes_conserved(specs, single):
    sim = FabricSim(Torus((8,)), qos=QosPolicy(single_class=single))
    fids = _inject_all(sim, specs)
    sim.run()
    want = {c: 0.0 for c in TrafficClass}
    for fid, (_, _, n, c) in zip(fids, specs):
        want[c] += n * sim.flow(fid).hops
    got = sim.class_stats()
    for c in TrafficClass:
        assert got[c] == pytest.approx(want[c]), c
    # and the per-link totals agree with the per-class breakdown
    for v in sim.link_stats().values():
        assert sum(v["class_bytes"]) == pytest.approx(v["bytes"])


# ---------------------------------------------------------------------------
# single_class == the pre-QoS FIFO, for ANY flow set and ANY tagging
# ---------------------------------------------------------------------------

@settings(deadline=None)
@given(specs=_flow_specs(8), data=st.data())
def test_single_class_invariant_under_tags(specs, data):
    base = FabricSim(Torus((8,)))            # default: single-class FIFO
    t_base = [base.finish_s(f) for f in _inject_all(base, specs)]
    retag = data.draw(st.lists(st.sampled_from(CLASSES),
                               min_size=len(specs), max_size=len(specs)))
    retagged = [(s, d, n, c) for (s, d, n, _), c in zip(specs, retag)]
    alt = FabricSim(Torus((8,)), qos=QosPolicy(single_class=True))
    t_alt = [alt.finish_s(f) for f in _inject_all(alt, retagged)]
    assert t_base == t_alt                   # bitwise identical


# ---------------------------------------------------------------------------
# no starvation under adversarial bulk load
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(n_bulk=st.integers(1, 6),
       bulk_mb=st.integers(1, 32),
       decode_kb=st.integers(64, 2048))
def test_decode_never_starved_by_bulk(n_bulk, bulk_mb, decode_kb):
    """However much BULK backlog shares the link, DECODE's stretch is
    bounded by the inverse of its weighted share (+ slack for packet
    granularity) — starvation would blow this bound immediately."""
    policy = QosPolicy()
    w = policy.weights
    share = w[TrafficClass.DECODE] / (w[TrafficClass.DECODE]
                                      + w[TrafficClass.BULK])
    iso = FabricSim(Torus((8,)), qos=policy)
    t_iso = iso.finish_s(iso.inject(0, 1, decode_kb << 10,
                                    cls=TrafficClass.DECODE))
    sim = FabricSim(Torus((8,)), qos=policy)
    bulks = [sim.inject(0, 1, bulk_mb << 20, cls=TrafficClass.BULK)
             for _ in range(n_bulk)]
    d = sim.inject(0, 1, decode_kb << 10, cls=TrafficClass.DECODE)
    t_d = sim.finish_s(d)
    assert t_d <= t_iso / share * 1.25 + 1e-4
    for b in bulks:                          # bulk completes too
        assert sim.finish_s(b) < float("inf")


# ---------------------------------------------------------------------------
# goodput shares track the policy weights
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(w_hi=st.integers(2, 32), w_lo=st.integers(1, 8),
       cls_pair=st.sampled_from([(TrafficClass.DECODE, TrafficClass.BULK),
                                 (TrafficClass.COLLECTIVE,
                                  TrafficClass.BULK),
                                 (TrafficClass.DECODE,
                                  TrafficClass.COLLECTIVE)]))
def test_throughput_ratio_tracks_weights(w_hi, w_lo, cls_pair):
    hi, lo = cls_pair
    hp.assume(w_hi > w_lo)
    policy = QosPolicy(weights={hi: float(w_hi), lo: float(w_lo)})
    sim = FabricSim(Torus((4,)), qos=policy)
    n = 8 << 20
    f_hi = sim.inject(0, 1, n, cls=hi)
    sim.inject(0, 1, n, cls=lo)
    t_hi = sim.finish_s(f_hi)
    share = n / t_hi / sim.link_bw
    want = w_hi / (w_hi + w_lo)
    assert share == pytest.approx(want, rel=0.10)
