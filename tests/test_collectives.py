"""Collectives + RDMA tests.

Numeric multi-device checks run in one subprocess (8 forced host devices) so
that the main pytest process keeps the default single-device view — the
dry-run explicitly forbids setting the device-count flag globally.
"""
import os
import subprocess
import sys

import pytest

from repro.core import collectives as C
from repro.core import rdma
from repro.core.topology import Torus

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_multidevice_numerics():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "multidevice_checks.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL MULTIDEVICE CHECKS PASSED" in proc.stdout


def test_ring_perms():
    perm = C._ring_perms(4, +1)
    assert perm == [(0, 1), (1, 2), (2, 3), (3, 0)]
    perm = C._ring_perms(4, -1)
    assert perm == [(0, 3), (1, 0), (2, 1), (3, 2)]


def test_flatten_pad():
    import jax.numpy as jnp
    flat, chunk = C._flatten_pad(jnp.ones((3, 5)), 4)
    assert flat.shape == (16,) and chunk == 4
    flat, chunk = C._flatten_pad(jnp.ones((8,)), 4)
    assert flat.shape == (8,) and chunk == 2


# ---------------------------------------------------------------------------
# RdmaEndpoint host-side model (registration/TLB/dual-DMA cost model)
# ---------------------------------------------------------------------------

def make_ep(**kw):
    return rdma.RdmaEndpoint(Torus((4, 4)), rank=0, **kw)


def test_registration_lifecycle():
    ep = make_ep()
    r = ep.register(10 * 4096)
    cold = ep.translate_region(r)       # all misses
    warm = ep.translate_region(r)       # all hits
    assert warm < cold / 5
    ep.deregister(r)
    with pytest.raises(KeyError):
        ep.translate_region(r)


def test_deregister_invalidates_tlb():
    ep = make_ep()
    r1 = ep.register(4 * 4096)
    ep.translate_region(r1)
    hits_before = ep.tlb.stats.hits
    ep.deregister(r1)
    r2 = ep.register(4 * 4096)
    # new region occupies fresh vaddrs; old entries were shot down
    ep.translate_region(r2)
    assert ep.tlb.stats.hits == hits_before


def test_dual_dma_fig1_claims():
    """§2.1: single-engine efficiency ~50%; dual-engine ~40% time cut."""
    ep = make_ep()
    nbytes = 1 << 20
    t1 = ep.transfer_time(nbytes, engines=1)
    t2 = ep.transfer_time(nbytes, engines=2)
    reduction = 1.0 - t2 / t1
    assert reduction == pytest.approx(0.40, abs=0.03)
    # single-engine effective bandwidth ~50% of the interface's
    eff1 = (nbytes / t1) / ep.net.host_if.effective_bandwidth
    assert eff1 == pytest.approx(0.50, abs=0.05)
    # a third engine gains nothing once the gap is hidden
    t3 = ep.transfer_time(nbytes, engines=3)
    assert t3 == pytest.approx(t2, rel=1e-6)


def test_put_time_monotone_in_hops_and_size():
    ep = make_ep()
    r = ep.register(1 << 20)
    ep.translate_region(r)  # warm the TLB
    t_near = ep.put_time(1, 4096, r)
    t_far = ep.put_time(5, 4096, r)     # rank 5 = (1,1): 2 hops
    assert t_far > t_near
    assert ep.put_time(1, 1 << 20, r) > t_near
