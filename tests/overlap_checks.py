"""Overlap-engine trainer checks that need >1 device — run in a subprocess
with XLA_FLAGS=--xla_force_host_platform_device_count=8 (see
test_runtime.py).

Acceptance bar for the overlap engine's apex path:
  * the bucketed-overlapped apex step (gradient reduce-scatter issued
    inside backward by the fabric bucket grad hook, ZeRO-1 update on the
    pre-reduced shards) is numerically IDENTICAL to the sequential apex
    step — losses equal, every param leaf bitwise equal;
  * train_step() stats report predicted vs measured overlap efficiency;
  * a LO|FA|MO link fault reroutes the bucketed schedules (fault_mode
    "reroute") and the overlapped trainer still tracks the sequential one.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models.common import ArchCfg  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: E402


def check(name):
    print(f"[overlap] {name}")


CFG = ArchCfg(name="tiny", family="dense", n_layers=2, d_model=32,
              n_heads=4, n_kv_heads=2, d_ff=64, vocab=257,
              dtype=jnp.float32)
OPT = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=50)


def make(td, tag, **kw):
    tcfg = TrainerConfig(ckpt_dir=os.path.join(td, tag), ckpt_every=0,
                         batch=8, seq_len=32, opt=OPT, comm="apex",
                         dp_axis="x", **kw)
    return Trainer(CFG, tcfg, mesh=make_mesh((8,), ("x",)))


def assert_same_params(a, b, msg):
    for pa, pb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb),
                                      err_msg=msg)


def equivalence_check(td):
    seq = make(td, "seq")
    ov = make(td, "ov", overlap=True, bucket_mb=0.05)
    assert ov.bucket_plan is not None and ov.bucket_plan.n_buckets > 1
    ms, mo = seq.train(3), ov.train(3)
    for a, b in zip(ms, mo):
        assert a["loss"] == b["loss"], (a["loss"], b["loss"])
    assert_same_params(seq, ov, "overlapped step diverged from sequential")
    check("bucketed-overlapped apex step == sequential, bitwise (8-ring)")

    last = mo[-1]
    for key in ("overlap_eff_pred", "overlap_eff_measured",
                "overlap_pred_reduction", "predicted_comm_s"):
        assert key in last, f"missing {key} in train_step() stats"
        assert np.isfinite(last[key])
    assert 0.0 <= last["overlap_eff_pred"] <= 1.0
    assert 0.0 <= last["overlap_eff_measured"] <= 1.0
    check("train_step() reports predicted vs measured overlap efficiency")
    return seq, ov


def reroute_check(seq, ov):
    """Kill a ring link mid-training: both trainers rewrite their
    schedules around it (detour hops) and must stay in lockstep."""
    for tr in (seq, ov):
        tr.tcfg.fault_mode = "reroute"

    def fault(i):
        if i == 1:
            seq.lofamo.kill_link(3, 4)
            ov.lofamo.kill_link(3, 4)

    ms = seq.train(4, fault_hook=fault)
    mo = ov.train(4, fault_hook=fault)
    assert any("rerouted collectives" in e for e in seq.events)
    assert any("rerouted collectives" in e for e in ov.events)
    assert ov.apex_schedules["rs"].max_hops == 7  # the long way around
    for a, b in zip(ms, mo):
        assert a["loss"] == b["loss"], (a["loss"], b["loss"])
    assert_same_params(seq, ov, "post-reroute divergence")
    check("overlap engine survives link-fault reroute, still bitwise")


def main() -> None:
    assert jax.device_count() == 8, jax.device_count()
    with tempfile.TemporaryDirectory() as td:
        seq, ov = equivalence_check(td)
        reroute_check(seq, ov)
    print("ALL OVERLAP CHECKS PASSED")


if __name__ == "__main__":
    main()
