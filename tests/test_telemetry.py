"""Unified fabric telemetry: counter registry determinism, Perfetto
export schema + seeded byte-identity, disabled-mode invisibility, the
counter-vs-``link_stats`` exact cross-check on both fidelity tiers,
cross-tier stats-schema parity, hybrid windowed-delta identity, and the
probe/snapshot ghost discipline (a probe moves ONE counter —
``fabric.probes`` — and nothing else).
"""
import json

import pytest

import jax

from repro import configs
from repro.core import fabric
from repro.core.fabric.fluid import FluidSim, HybridSim, make_sim
from repro.core.fabric.qos import QosPolicy, TrafficClass
from repro.core.fabric.sim import FabricSim
from repro.core.fabric.telemetry import (Telemetry, canon_key,
                                         ordered_link_items,
                                         validate_perfetto)
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus
from repro.models import api
from repro.serving.cluster import ServingCluster

D = TrafficClass.DECODE
B = TrafficClass.BULK

FLOWS = [(0, 3, 1 << 20), (1, 4, 1 << 19), (5, 7, 1 << 18)]


def _drive(sim, tel=None):
    if tel is not None:
        sim.telemetry = tel
    fids = [sim.inject(s, d, nb, cls=B, label=f"f{i}")
            for i, (s, d, nb) in enumerate(FLOWS)]
    fids.append(sim.inject(2, 6, 1 << 19, cls=D, label="dec"))
    sim.occupy(("hostif", 0), 2e-4, cls=D)
    sim.run()
    return fids


# ---------------------------------------------------------------------------
# counter registry
# ---------------------------------------------------------------------------

def test_counter_registry_deterministic_snapshot():
    a, b = Telemetry(), Telemetry()
    # same adds, different arrival order -> identical snapshot
    seq = [("link.bytes", 10.0, (0, 1, "+x"), 1),
           ("link.bytes", 4.0, (0, 1, "+x"), None),
           ("fabric.probes", 1.0, None, None),
           ("link.busy_s", 0.5, ("hostif", 3), None)]
    for name, v, key, cls in seq:
        a.add(name, v, key=key, cls=cls)
    for name, v, key, cls in reversed(seq):
        b.add(name, v, key=key, cls=cls)
    assert a.counters_snapshot() == b.counters_snapshot()
    assert list(a.counters_snapshot()) == list(b.counters_snapshot())
    assert a.value("link.bytes", key=(0, 1, "+x")) == 4.0
    assert a.value("link.bytes", key=(0, 1, "+x"), cls=1) == 10.0
    assert a.value("nope") == 0.0


def test_canon_key_total_order_over_mixed_keys():
    keys = [("hostif", 3), (0, 1, "+x"), None, (2, 0, "-y"), "plain", 7]
    ordered = sorted(keys, key=canon_key)
    assert ordered == sorted(ordered, key=canon_key)   # stable/total
    assert ordered[0] is None                          # None sorts first
    # tuples sort after scalars, and among themselves element-wise
    tuples = [k for k in ordered if isinstance(k, tuple)]
    assert tuples == [(0, 1, "+x"), (2, 0, "-y"), ("hostif", 3)]


def test_event_ring_is_bounded():
    tel = Telemetry(ring=8)
    for i in range(20):
        tel.event(("link", (0, 1, "+x")), f"e{i}", float(i))
    assert tel.n_events == 20
    assert len(tel.events_snapshot()) == 8
    assert tel.dropped == 12


# ---------------------------------------------------------------------------
# disabled-mode invisibility + exact cross-check (both tiers)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fidelity", ["packet", "fluid"])
def test_attached_hub_is_bitwise_invisible(fidelity):
    torus = Torus((8,))
    bare = make_sim(torus, fidelity=fidelity, qos=QosPolicy())
    inst = make_sim(torus, fidelity=fidelity, qos=QosPolicy())
    fb = _drive(bare)
    fi = _drive(inst, Telemetry())
    for x, y in zip(fb, fi):
        assert bare.finish_s(x) == inst.finish_s(y)
    assert bare.link_stats() == inst.link_stats()
    assert bare.class_stats() == inst.class_stats()


@pytest.mark.parametrize("fidelity", ["packet", "fluid"])
def test_counters_cross_check_exactly_zero(fidelity):
    torus = Torus((8,))
    tel = Telemetry()
    sim = make_sim(torus, fidelity=fidelity, qos=QosPolicy())
    _drive(sim, tel)
    assert tel.cross_check(sim) == 0.0
    # and the hub actually saw traffic — this is not a vacuous zero
    assert any(n == "link.bytes" for (n, *_rest) in tel.counters)


# ---------------------------------------------------------------------------
# satellite 1: unified link_stats schema + deterministic metrics()
# ---------------------------------------------------------------------------

def test_link_stats_schema_parity_across_tiers():
    torus = Torus((4, 4))
    pkt = make_sim(torus, fidelity="packet")
    flu = make_sim(torus, fidelity="fluid")
    for s in (pkt, flu):
        for i in range(8):
            s.inject(i, (i + 5) % 16, 1 << 20, cls=B)
            s.occupy(("hostif", i), 1e-4, cls=B)
        s.run()
    sp, sf = pkt.link_stats(), flu.link_stats()
    assert list(sp.keys()) == list(sf.keys())          # same canonical order
    for st in (sp, sf):
        for v in st.values():
            assert tuple(v.keys()) == ("busy_s", "bytes", "class_bytes")
    # ordering is the canon_key order, not insertion order
    assert list(sp.keys()) == [k for k, _v in
                               ordered_link_items(sp.items())]


def test_replay_metrics_ordering_is_sorted():
    from repro.serving.trace import ReplayReport
    rep = ReplayReport(n_requests=1, n_finished=1, n_shed=0,
                       ttft_p50_s=0.1, ttft_p99_s=0.2, tpt_p50_s=0.01,
                       tpt_p99_s=0.02, makespan_s=1.0, steps=3,
                       n_migrations=0, migrated_bytes=0, wall_s=0.5)
    m = rep.metrics()
    assert list(m) == sorted(m)


# ---------------------------------------------------------------------------
# satellite 2: hybrid windowed-delta identity through escalation
# ---------------------------------------------------------------------------

def test_hybrid_windowed_class_stats_identity():
    """Two identical traffic windows on a LIVE HybridSim — with packet
    escalation firing in each — yield bitwise-identical per-class
    ``class_stats(since=)`` deltas: escalation stitches finish times,
    never the byte accounting, and integer byte sums subtract exactly."""
    torus = Torus((8,))
    hy = make_sim(torus, fidelity="hybrid")
    assert isinstance(hy, HybridSim)
    nb = 2 << 20

    def window():
        before = hy.class_stats()
        for s, d in ((0, 3), (0, 2), (1, 3)):
            hy.inject(s, d, nb, cls=B)
        hy.inject(5, 7, 1 << 18, cls=D)
        hy.run()
        assert hy.last_escalation is not None          # packet tier fired
        return hy.class_stats(since=before)

    d1, d2 = window(), window()
    assert d1 == d2                                    # bitwise, per class
    assert d1[B] == 3.0 * nb * 1.0 * len(torus.route(0, 3)[:-1]) \
        or d1[B] > 0.0                                 # sanity: non-vacuous


def test_hybrid_escalation_telemetry_counters():
    torus = Torus((8,))
    tel = Telemetry()
    hy = make_sim(torus, fidelity="hybrid")
    hy.telemetry = tel
    for s, d in ((0, 3), (0, 2), (1, 3)):
        hy.inject(s, d, 2 << 20, cls=B)
    hy.run()
    assert hy.last_escalation is not None
    assert tel.value("fabric.escalations") == 1.0
    assert tel.value("fabric.escalated_flows") >= 2.0
    assert any(name == "escalation" for _ts, track, name, _d, _a
               in tel.events_snapshot() if track == ("hybrid",))


# ---------------------------------------------------------------------------
# satellite 3: probes and snapshots are telemetry ghosts
# ---------------------------------------------------------------------------

def _ghost_view(tel):
    """Everything a probe must NOT move: every counter except the
    ``fabric.probes`` stamp itself, plus the full event ring."""
    counters = {k: v for k, v in tel.counters.items()
                if k[0] != "fabric.probes"}
    return counters, tel.events_snapshot(), tel.n_events


@pytest.mark.parametrize("fidelity", ["packet", "fluid"])
def test_probe_leaves_counters_and_ring_untouched(fidelity):
    torus = Torus((8,))
    probed, control = Telemetry(), Telemetry()
    sp = make_sim(torus, fidelity=fidelity, qos=QosPolicy())
    sc = make_sim(torus, fidelity=fidelity, qos=QosPolicy())
    _drive(sp, probed)
    _drive(sc, control)
    route = tuple(torus.route(0, 3))
    t1 = sp.probe_route(route, 1 << 20)
    t2 = sp.probe_route(route, 1 << 20)
    assert t1 == t2
    # the ONE counter a probe moves is its own stamp, AFTER rollback
    assert probed.value("fabric.probes") == 2.0
    assert control.value("fabric.probes") == 0.0
    assert _ghost_view(probed) == _ghost_view(control)


def test_snapshot_restore_leaves_telemetry_untouched():
    torus = Torus((8,))
    tel = Telemetry()
    sim = FabricSim(torus, qos=QosPolicy(), telemetry=tel)
    for s, d, nb in FLOWS:
        sim.inject(s, d, nb, cls=B)
    sim.run()
    before = (dict(tel.counters), tel.events_snapshot(), tel.n_events)
    snap = sim._snapshot()
    sim._restore(snap)
    assert (dict(tel.counters), tel.events_snapshot(),
            tel.n_events) == before


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get_reduced("smollm-135m")
    return cfg, api.get_model(cfg).init(jax.random.key(0))


def test_fault_epoch_stamped_exactly_once(dense_model):
    cfg, params = dense_model
    tel = Telemetry()
    cl = ServingCluster(cfg, params, torus=Torus((4,)), node_ranks=(0, 1),
                        max_batch=2, max_seq=64, page_tokens=8,
                        telemetry=tel)
    assert cl.sim.telemetry is tel                     # threaded through
    cl.fail_link(0, 1)
    assert tel.value("fabric.fault_epochs") == 1.0
    cl.clear_faults()
    assert tel.value("fabric.fault_epochs") == 2.0
    names = [name for _ts, track, name, _d, _a in tel.events_snapshot()
             if track == ("cluster",)]
    assert names.count("fail_link") == 1
    assert names.count("clear_faults") == 1


# ---------------------------------------------------------------------------
# perfetto export
# ---------------------------------------------------------------------------

def _traced_sim():
    tel = Telemetry()
    sim = make_sim(Torus((8,)), fidelity="packet", qos=QosPolicy())
    _drive(sim, tel)
    tel.collect(sim)
    return tel


def test_perfetto_schema_and_byte_determinism():
    blob1 = _traced_sim().to_perfetto()
    blob2 = _traced_sim().to_perfetto()
    assert blob1 == blob2                              # byte-identical
    obj = json.loads(blob1)
    assert validate_perfetto(obj) == []
    evs = obj["traceEvents"]
    # one thread_name metadata row per track, spans carry ts+dur in us
    tids = {e["tid"] for e in evs if e["ph"] in ("X", "i")}
    named = {e["tid"] for e in evs if e["ph"] == "M"}
    assert tids <= named
    assert any(e["ph"] == "X" and e["dur"] > 0 for e in evs)


def test_validate_perfetto_flags_violations():
    assert validate_perfetto([]) != []                 # not a dict
    assert validate_perfetto({"traceEvents": 3}) != []
    bad = {"traceEvents": [{"ph": "X", "pid": 0, "tid": 0,
                            "name": "x", "ts": 1.0}]}  # missing dur
    assert validate_perfetto(bad) != []
    orphan = {"traceEvents": [{"ph": "X", "pid": 0, "tid": 9, "name": "x",
                               "ts": 1.0, "dur": 1.0}]}
    assert any("thread_name" in e for e in validate_perfetto(orphan))


def test_summary_table_mentions_hot_counters():
    tel = _traced_sim()
    table = tel.summary_table()
    assert "busiest links" in table and "events:" in table
    assert "link.busy_s@" in table


# ---------------------------------------------------------------------------
# endpoint + controller instrumentation
# ---------------------------------------------------------------------------

def test_rdma_put_counters_and_span():
    torus = Torus((4, 4))
    tel = Telemetry()
    sim = FabricSim(torus, telemetry=tel)
    ep = RdmaEndpoint(torus, 0, sim=sim, telemetry=tel)
    region = ep.register(64 << 10)
    ep.put_pages(5, region, list(range(4)), page_nbytes=16 << 10)
    assert tel.value("rdma.puts") == 1.0
    assert tel.value("rdma.put_bytes") == 64 << 10
    assert tel.value("rdma.descriptors") == \
        ep.last_put_report["descriptors"]
    assert any(track == ("rdma", 0) for _ts, track, _n, _d, _a
               in tel.events_snapshot())


def test_qos_controller_window_telemetry():
    from repro.core.fabric.qosctl import QosController, QosCtlPolicy

    class _Slo:
        token_target_s = 0.050
        headroom = 0.8

    tel = Telemetry()
    torus = Torus((4,))
    sim = FluidSim(torus, qos=QosPolicy())
    ctl = QosController(QosPolicy(), _Slo(), policy=QosCtlPolicy(),
                        telemetry=tel)
    sim.inject(0, 2, 1 << 20, cls=B)
    sim.run()
    ctl.window(sim, [0.2, 0.2, 0.2])                   # way past target
    assert tel.value("qosctl.windows") == 1.0
    assert tel.value("qosctl.retunes") == ctl.n_retunes
    assert any(track == ("controller",) for _ts, track, _n, _d, _a
               in tel.events_snapshot())
