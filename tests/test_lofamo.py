"""LO|FA|MO fault-awareness simulation tests (paper §4)."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core.lofamo import Health, LofamoSim, awareness_time_model
from repro.core.topology import Torus


def make_sim(dims=(4, 4), wd=0.5):
    return LofamoSim(Torus(dims), wd_period=wd)


def test_no_faults_no_alarms():
    sim = make_sim()
    sim.run(10)
    assert sim.detected_at_master() == set()


def test_host_fault_detected_and_reaches_master():
    sim = make_sim()
    sim.run(2)  # settle
    ev = sim.kill_host(5)
    sim.run(4)
    assert ev.t_local is not None and ev.t_master is not None
    assert sim.master_view[5] is Health.HOST_FAULT
    # awareness dominated by the watchdog period (paper: Ta ~ 2xWD worst case)
    assert ev.awareness_time <= 2 * sim.wd + sim.service_latency + 1e-9


def test_node_fault_detected_by_neighbours():
    sim = make_sim()
    sim.run(2)
    ev = sim.kill_node(9)
    sim.run(3)
    assert sim.master_view[9] is Health.NODE_FAULT
    # neighbours hold the status word about the dead node
    t = sim.torus
    for n in t.neighbors(9):
        assert sim.regs[n].neighbor_status[9] is Health.NODE_FAULT
    assert ev.awareness_time <= 2 * sim.wd + sim.service_latency + 1e-9


def test_awareness_time_model_matches_paper():
    # paper §4: "for a WD = 500 ms, Ta = 0.9 s"
    assert awareness_time_model(0.5) == pytest.approx(0.9, abs=0.01)
    # scaling: Ta tracks the watchdog period across the HPC range 1ms..1s
    for wd in (1e-3, 1e-2, 1e-1, 1.0):
        assert awareness_time_model(wd) == pytest.approx(1.8 * wd + 1e-3)


def test_master_fault_detected_by_neighbours_of_master():
    # even the master's own node fault is visible to its neighbours; the
    # surviving master-view logic runs on whichever host reads it (here we
    # just assert neighbours learn it)
    sim = make_sim()
    sim.run(1)
    sim.kill_node(0)
    sim.run(3)
    for n in sim.torus.neighbors(0):
        assert sim.regs[n].neighbor_status[0] is Health.NODE_FAULT


@hp.given(st.sets(st.integers(0, 15), min_size=1, max_size=6), st.data())
@hp.settings(deadline=None, max_examples=40)
def test_multi_fault_global_awareness_property(faults, data):
    """Paper: 'Even in case of multiple faults no area of the mesh can be
    isolated and no fault can remain undetected at global level'.

    In the protocol a fault becomes globally known iff some first-neighbour
    of the victim keeps a live host+NIC: that neighbour's NIC learns the
    status word (host faults are broadcast by the victim's own NIC; node
    faults are inferred from silence) and its host reports over the service
    network.  We assert the simulator agrees with that graph predicate in
    both directions.
    """
    t = Torus((4, 4))
    sim = LofamoSim(t, wd_period=0.5, master=data.draw(
        st.sampled_from([r for r in range(16) if r not in faults])))
    sim.run(1)
    kinds = {f: data.draw(st.sampled_from(["host", "node"]), label=f"kind{f}")
             for f in sorted(faults)}
    for f, kind in kinds.items():
        (sim.kill_host if kind == "host" else sim.kill_node)(f)
    sim.run(4)
    detected = sim.detected_at_master()
    for f in faults:
        has_live_reporter = any(n not in faults for n in t.neighbors(f))
        assert (f in detected) == has_live_reporter


def test_diagnostics_ride_the_protocol():
    """§4: 'the addition of LO|FA|MO features has no impact on APEnet+ data
    transfer latency' — the status exchange is piggybacked, so the model's
    data-path latency is independent of the watchdog machinery."""
    from repro.core.apelink import NetModel
    m = NetModel()
    base = m.latency(4096)
    sim = make_sim()
    sim.run(5)  # watchdog traffic has been flowing
    assert m.latency(4096) == base  # nothing in the data path changed
