"""Runtime tests: trainer loop, checkpoint integrity, data resumability,
serving engine (paged cache vs dense-decode oracle), fault recovery,
overlap engine (bucketed apex step, chunked prefill).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (CheckpointStore, latest_step, load_checkpoint,
                              save_checkpoint)
from repro.data import Prefetcher, SyntheticTokens
from repro.models import api
from repro.models.common import ArchCfg
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.serving.engine import Engine, PagedLM, Request

CFG = ArchCfg(name="tiny", family="dense", n_layers=2, d_model=32,
              n_heads=4, n_kv_heads=2, d_ff=64, vocab=257,
              dtype=jnp.float32)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ----------------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------------

def test_adamw_reduces_loss_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=100)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, grads, state, params)
    assert float(loss(params)) < 1e-2 * l0


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(cosine_schedule(cfg, 0)) == 0.0
    assert float(cosine_schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(cosine_schedule(cfg, 100)) == pytest.approx(0.1)
    assert float(cosine_schedule(cfg, 55)) < 1.0


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e9)}
    _, state, metrics = adamw_update(cfg, huge, state, params)
    assert float(metrics["grad_norm"]) > 1e8
    assert float(jnp.abs(state["m"]["w"]).max()) <= 0.11  # clipped


# ----------------------------------------------------------------------------
# data
# ----------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    a = SyntheticTokens(CFG, 4, 32, seed=7)
    b1, b2 = a.next_batch(), a.next_batch()
    resumed = SyntheticTokens.from_state(CFG, 4, 32,
                                         {"seed": 7, "step": 1})
    np.testing.assert_array_equal(resumed.next_batch()["tokens"],
                                  b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (b1["labels"][:, -1] == -1).all()


def test_prefetcher_yields_and_closes():
    src = SyntheticTokens(CFG, 2, 16, seed=0)
    pf = Prefetcher(iter(src), depth=2)
    batches = [next(pf) for _ in range(3)]
    assert all(b["tokens"].shape == (2, 16) for b in batches)
    pf.close()


# ----------------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.asarray([1, 2, 3], np.int32)}}
    save_checkpoint(str(tmp_path), 5, tree, extra={"x": 1})
    got, extra = load_checkpoint(str(tmp_path), template=tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), tree["a"])
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]), tree["b"]["c"])
    assert extra == {"x": 1}
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"a": np.arange(100, dtype=np.float32)}
    path = save_checkpoint(str(tmp_path), 1, tree)
    # corrupt a tensor in place
    z = dict(np.load(os.path.join(path, "tensors.npz")))
    z["a"][3] += 1.0
    np.savez(os.path.join(path, "tensors.npz"), **z)
    with pytest.raises(ValueError, match="CRC"):
        load_checkpoint(str(tmp_path), template=tree)


def test_checkpoint_gc_and_async(tmp_path):
    store = CheckpointStore(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        store.save_async(s, {"a": np.full(4, s, np.float32)})
    store.wait()
    assert latest_step(str(tmp_path)) == 4
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


# ----------------------------------------------------------------------------
# trainer (single device)
# ----------------------------------------------------------------------------

@pytest.mark.slow
def test_trainer_loss_decreases(tmp_path):
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=0, batch=8,
                         seq_len=64,
                         opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=60))
    tr = Trainer(CFG, tcfg)
    metrics = tr.train(40)
    first = np.mean([m["loss"] for m in metrics[:5]])
    last = np.mean([m["loss"] for m in metrics[-5:]])
    assert last < first - 0.5, (first, last)  # structured stream is learnable


@pytest.mark.slow
def test_trainer_checkpoint_restart_bitwise(tmp_path):
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    t1 = TrainerConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=5, batch=4,
                       seq_len=32, opt=opt)
    tr1 = Trainer(CFG, t1)
    tr1.train(10)   # checkpoints at steps 5 and 10
    ref = tr1.train(3)

    # restart from the step-10 checkpoint and replay
    t2 = TrainerConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=0, batch=4,
                       seq_len=32, opt=opt)
    tr2 = Trainer(CFG, t2)
    tree, extra = tr2.store.restore_latest(
        {"params": jax.tree.map(np.asarray, tr2.params),
         "opt": jax.tree.map(np.asarray, tr2.opt_state)})
    tr2.params = jax.tree.map(jnp.asarray, tree["params"])
    tr2.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
    tr2.data = SyntheticTokens.from_state(CFG, 4, 32, extra["data"])
    got = tr2.train(3)
    for a, b in zip(ref, got):
        assert a["loss"] == pytest.approx(b["loss"], rel=1e-5)


# ----------------------------------------------------------------------------
# serving engine: paged decode vs dense decode oracle
# ----------------------------------------------------------------------------

@pytest.mark.slow
def test_paged_engine_matches_dense_decode():
    cfg = CFG
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (7, 13, 5)]
    lm = PagedLM(cfg, params, max_batch=4, max_seq=64, page_tokens=8)
    eng = Engine(lm)
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, max_new_tokens=6))
    eng.run_to_completion()
    assert len(eng.finished) == 3
    st = eng.stats()
    assert 0.0 <= st["tlb_hit_rate"] <= 1.0

    # oracle: dense-cache greedy decode, one request at a time
    for req in eng.finished:
        toks = jnp.asarray(req.prompt[None])
        logits, cache = model.prefill(params, {"tokens": toks},
                                      max_len=64, remat=False)
        cur = int(jnp.argmax(logits[0, -1]))
        want = [cur]
        pos = len(req.prompt)
        for _ in range(5):
            lg, cache = model.decode_step(
                params, jnp.asarray([[cur]], jnp.int32), cache, pos)
            cur = int(jnp.argmax(lg[0, -1]))
            want.append(cur)
            pos += 1
        assert req.out_tokens == want, f"request {req.rid}"


def test_claim_slot_releases_partial_pages_on_exhaustion():
    """Regression: a mid-claim pool exhaustion must hand already-allocated
    pages back (a leak permanently shrinks the pool and admission can
    never retry)."""
    cfg = CFG
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0))
    lm = PagedLM(cfg, params, max_batch=2, max_seq=32, page_tokens=8,
                 pool_pages=3)
    free_before = len(lm.allocator.free)
    with pytest.raises(RuntimeError):
        lm.claim_slot(prompt_len=22, max_new=10)   # needs 4 of 3 pages
    assert len(lm.allocator.free) == free_before
    assert not lm.slot_pages
    # an outright oversize request (> pages_per_seq) is a ValueError, not
    # the retryable exhaustion RuntimeError — admission must not re-queue it
    with pytest.raises(ValueError):
        lm.claim_slot(prompt_len=30, max_new=10)   # needs 5 > 4 pages/seq
    assert len(lm.allocator.free) == free_before
    # and the slot is still claimable once the request fits
    slot = lm.claim_slot(prompt_len=10, max_new=6)
    assert len(lm.slot_pages[slot]) == 2


@pytest.mark.slow
def test_chunked_prefill_tokens_identical_to_whole_prompt():
    """Overlap engine, serving side: page-sized chunked prefill interleaved
    with decode must produce exactly the tokens of whole-prompt prefill."""
    cfg = CFG
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    # lengths straddle page boundaries (page_tokens=8): 5 < 8, 21 spans 3
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (7, 21, 5, 13)]

    def run(chunked):
        lm = PagedLM(cfg, params, max_batch=4, max_seq=64, page_tokens=8)
        eng = Engine(lm, chunked_prefill=chunked, prefill_chunk_pages=1)
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr, max_new_tokens=6))
        eng.run_to_completion()
        assert len(eng.finished) == len(prompts)
        return {r.rid: r.out_tokens for r in eng.finished}, eng.stats()

    whole, _ = run(False)
    chunk, st = run(True)
    assert whole == chunk
    assert st["chunked_prefill"] and st["prefill_chunks"] >= sum(
        -(-len(p) // 8) for p in prompts)


@pytest.mark.slow
def test_overlap_trainer_multidevice_equivalence():
    """Bucketed-overlapped apex step bitwise-matches the sequential step
    (8-device DP ring), stats report overlap efficiency, and the engine
    survives a link-fault reroute — see tests/overlap_checks.py."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "overlap_checks.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL OVERLAP CHECKS PASSED" in proc.stdout


@pytest.mark.slow
def test_engine_continuous_batching_reuses_pages():
    cfg = CFG
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    # pool sized so all 6 requests cannot be resident at once
    lm = PagedLM(cfg, params, max_batch=2, max_seq=32, page_tokens=8,
                 pool_pages=8)
    eng = Engine(lm)
    for i in range(6):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, size=6)
                           .astype(np.int32),
                           max_new_tokens=4))
    eng.run_to_completion()
    assert len(eng.finished) == 6
    assert len(lm.allocator.free) == 8  # all pages returned


# ----------------------------------------------------------------------------
# LO|FA|MO-driven recovery (single-device torus of 1 — logic-level test;
# the multi-device elastic re-mesh runs in tests/multidevice_checks.py)
# ----------------------------------------------------------------------------

@pytest.mark.slow
def test_trainer_fault_recovery_restores_and_replays(tmp_path):
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=4, batch=4,
                         seq_len=32, opt=opt, torus_dims=(4,))
    tr = Trainer(CFG, tcfg)
    tr.train(8)  # checkpoints at 4 and 8

    def fault_at_2(i):
        if i == 2:
            tr.lofamo.kill_host(1)  # neighbours 0 and 2 will report it

    tr.train(6, fault_hook=fault_at_2)
    evs = " | ".join(tr.events)
    assert "LO|FA|MO" in evs and "restored step" in evs
    # training continued after recovery
    assert np.isfinite(tr.metrics_log[-1]["loss"])


def test_grad_accum_matches_single_step():
    """grad_accum=2 on the same global batch must track accum=1 closely
    (same summed gradients up to fp32 association)."""
    import tempfile

    import numpy as np

    from repro import configs
    from repro.optim import AdamWConfig
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = configs.get_reduced("smollm-135m")
    losses = {}
    for accum in (1, 2, 4):
        with tempfile.TemporaryDirectory() as td:
            tcfg = TrainerConfig(
                ckpt_dir=td, ckpt_every=0, batch=8, seq_len=32,
                opt=AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=50),
                comm="single", grad_accum=accum)
            tr = Trainer(cfg, tcfg)
            losses[accum] = [m["loss"] for m in tr.train(5)]
    np.testing.assert_allclose(losses[1], losses[2], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(losses[1], losses[4], rtol=2e-4, atol=2e-4)
    assert losses[1][-1] < losses[1][0]
