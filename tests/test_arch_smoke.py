"""Per-architecture smoke tests: REDUCED same-family configs, one
forward/train step on CPU, asserting output shapes and no NaNs.
(The FULL configs are exercised only by the dry-run, which lowers without
allocating.)
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config, get_reduced
from repro.models import api
from repro.models.common import ArchCfg


def make_batch(cfg: ArchCfg, B=2, S=16, *, labels=True, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32))}
    if labels:
        batch["labels"] = batch["tokens"]
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frames, cfg.d_model)), cfg.dtype)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: model.train_loss(p, batch))(params)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    gnorm = jnp.sqrt(sum(jnp.vdot(g, g).real
                         for g in jax.tree.leaves(grads))).astype(jnp.float32)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_prefill_decode(arch):
    cfg = get_reduced(arch)
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S, labels=False)
    if cfg.family in ("dense", "moe", "vlm", "zamba2", "encdec"):
        logits, state = model.prefill(params, batch, max_len=S + 4,
                                      remat=False)
    else:
        logits, state = model.prefill(params, batch, remat=False)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    # one decode step; note VLM context includes the patch prefix
    pos = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, state2 = model.decode_step(params, tok, state, pos)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits2))), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact public-literature settings."""
    cfg = get_config(arch)
    expect = {
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen2-0_5b": (24, 896, 14, 2, 4864, 151936),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "zamba2-1_2b": (38, 2048, 32, 32, 8192, 32000),
        "rwkv6-1_6b": (24, 2048, 32, 0, 7168, 65536),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expect
    if arch == "olmoe-1b-7b":
        assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 8
    if arch == "moonshot-v1-16b-a3b":
        assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 6
    if arch == "zamba2-1_2b":
        assert cfg.ssm.d_state == 64 and not cfg.full_attention
    if arch == "rwkv6-1_6b":
        assert not cfg.full_attention
    if arch == "whisper-large-v3":
        assert cfg.n_enc_layers == 32 and cfg.n_frames == 1500


def test_param_counts_roughly_match_names():
    """Billion-scale sanity: the configs really are the sizes on the tin."""
    def b(n):
        return api.param_count(get_config(n)) / 1e9

    assert 6.0 < b("olmoe-1b-7b") < 8.0          # 7B total
    # NOTE: the assignment pins 48L x 64e x 1408 -> ~28B total (the released
    # Moonlight is 27L/16B; the assigned hyperparameters are authoritative).
    # Its ACTIVE size still matches the "A3B" name, asserted below.
    assert 24.0 < b("moonshot-v1-16b-a3b") < 32.0
    assert 2.5 < b("starcoder2-3b") < 3.5
    assert 0.3 < b("qwen2-0_5b") < 0.7
    assert 6.0 < b("deepseek-7b") < 8.0
    assert 0.10 < b("smollm-135m") < 0.17
    assert 0.9 < b("zamba2-1_2b") < 1.6
    assert 1.3 < b("rwkv6-1_6b") < 2.1
    assert 1.2 < b("whisper-large-v3") < 2.0
    assert 60.0 < b("internvl2-76b") < 80.0
    # MoE active params: ~1B (olmoe), ~3B (moonlight)
    assert 0.8 < api.active_param_count(get_config("olmoe-1b-7b")) / 1e9 < 1.7
    assert 2.0 < api.active_param_count(
        get_config("moonshot-v1-16b-a3b")) / 1e9 < 4.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_input_specs_lower_nothing(arch):
    """input_specs are pure ShapeDtypeStructs for every applicable shape."""
    cfg = get_config(arch)
    shapes = api.applicable_shapes(cfg)
    assert "train_4k" in shapes
    if arch in ("zamba2-1_2b", "rwkv6-1_6b"):
        assert "long_500k" in shapes
    else:
        assert "long_500k" not in shapes
    for s in shapes:
        _, specs = api.input_specs(cfg, s)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
