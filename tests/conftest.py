"""Shared test fixtures: deterministic seeding + hypothesis budgets.

Every test that wants randomness takes the ``rng`` fixture — a NumPy
generator seeded from the test's own nodeid, so a test's stream is stable
across runs and re-orderings but distinct between tests (no cross-test
coupling through a shared global seed).

Hypothesis (optional dep) gets two profiles: ``dev`` (default, the
library's standard budget) and ``ci`` — a small example budget the fast
CI lane selects via ``HYPOTHESIS_PROFILE=ci`` (scripts/ci.sh) so property
suites stay quick on every PR; the full lane and local runs keep the
larger budget.  Deadlines are disabled in both: model-backed properties
jit-compile on first example.
"""
from __future__ import annotations

import os
import zlib

import numpy as np
import pytest

# A stray best_configs.json (e.g. left by a local autotune run) must never
# perturb the suite: default every test to "no pinned artifact" so the
# legacy hand-tuned knobs stay in force.  Tests that exercise the load
# path opt back in by monkeypatching BEST_CONFIGS to a tmp file.
os.environ.setdefault("BEST_CONFIGS", "0")

try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.register_profile("dev", max_examples=100, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:      # property suites importorskip hypothesis anyway
    pass


@pytest.fixture
def rng(request) -> np.random.Generator:
    """Per-test deterministic RNG (seed = hash of the test's nodeid)."""
    seed = zlib.adler32(request.node.nodeid.encode()) & 0xFFFFFFFF
    return np.random.default_rng(seed)
