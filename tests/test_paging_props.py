"""Property-based invariants for the paging stack (PageAllocator, the
PagedLM claim/free machine, and the registration Tlb).

The PR-2 ``claim_slot`` partial-page-leak regression is generalised here:
instead of one hand-written exhaustion case, hypothesis drives random
claim/free/exhaust sequences and checks after EVERY operation that

  * no physical page is ever allocated twice,
  * the pool is conserved (free + claimed == total, leak-free), and
  * a failed claim (pool/slot exhaustion) leaves the allocator exactly as
    it found it.

The Tlb properties pin the §2.2 semantics: translation is always correct
w.r.t. the page walk, occupancy never exceeds capacity, and an
invalidated page ALWAYS re-walks on its next touch (the shootdown can
never leave a stale fast-path entry).
"""
import numpy as np
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core.rdma import RdmaEndpoint
from repro.core.tlb import PAGE_BYTES, T_HW_HIT, T_NIOS_WALK, Tlb
from repro.core.topology import Torus
from repro.serving.engine import PageAllocator, PagedLM


# ---------------------------------------------------------------------------
# PageAllocator: random alloc/free interleavings
# ---------------------------------------------------------------------------

N_POOL = 12

_alloc_ops = st.lists(
    st.one_of(st.just(("alloc",)),
              st.tuples(st.just("free"), st.integers(0, 63))),
    max_size=120)


@hp.given(_alloc_ops)
def test_allocator_never_double_allocates_and_conserves_pool(ops):
    alloc = PageAllocator(N_POOL, page_tokens=4, bytes_per_token=64,
                          endpoint=RdmaEndpoint(Torus((2, 2)), 0))
    held: list[int] = []
    for op in ops:
        if op[0] == "alloc":
            if alloc.free:
                page = alloc.alloc()
                assert page not in held          # never handed out twice
                held.append(page)
            else:
                with pytest.raises(RuntimeError):
                    alloc.alloc()
        else:
            if held:
                alloc.release([held.pop(op[1] % len(held))])
        # conservation after every step: free + held partition the pool
        assert sorted(alloc.free + held) == list(range(N_POOL))


# ---------------------------------------------------------------------------
# PagedLM claim/free machine (multi-page claims, exhaustion mid-claim)
# ---------------------------------------------------------------------------

def _tiny_lm() -> PagedLM:
    from repro import configs

    cfg = configs.get_reduced("smollm-135m")
    # params=None: only the slot bookkeeping runs, never the jitted compute
    return PagedLM(cfg, None, max_batch=3, max_seq=24, page_tokens=4,
                   pool_pages=8, tp_axes=(), torus=Torus((2, 2)))


_claim_ops = st.lists(
    st.one_of(
        st.tuples(st.just("claim"), st.integers(1, 30), st.integers(1, 20)),
        st.tuples(st.just("free"), st.integers(0, 63))),
    max_size=40)


@pytest.mark.slow
@hp.given(_claim_ops)
def test_claim_slot_partial_exhaustion_never_leaks(ops):
    """The PR-2 leak regression as a generated invariant: whatever the
    claim/free/exhaust interleaving, a failed multi-page claim returns its
    partial allocation and the pool stays conserved."""
    lm = _tiny_lm()
    n_pages = lm.n_pages
    for op in ops:
        if op[0] == "claim":
            free_before = sorted(lm.allocator.free)
            slots_before = dict(lm.slot_pages)
            try:
                slot = lm.claim_slot(prompt_len=op[1], max_new=op[2])
            except (RuntimeError, ValueError):
                # any failed claim — retryable exhaustion (RuntimeError:
                # pages or slots) or an oversize request (ValueError:
                # > pages_per_seq) — must be side-effect free
                assert sorted(lm.allocator.free) == free_before
                assert lm.slot_pages == slots_before
            else:
                assert slot not in slots_before
        else:
            if lm.slot_pages:
                slots = sorted(lm.slot_pages)
                lm.free_slot(slots[op[1] % len(slots)])
        claimed = [p for pages in lm.slot_pages.values() for p in pages]
        assert len(set(claimed)) == len(claimed)     # no double allocation
        assert sorted(lm.allocator.free + claimed) == list(range(n_pages))
        for slot, pages in lm.slot_pages.items():
            assert list(lm.page_table[slot, :len(pages)]) == pages


# ---------------------------------------------------------------------------
# Tlb: correctness, capacity, and invalidate-then-translate re-walk
# ---------------------------------------------------------------------------

_tlb_ops = st.lists(
    st.one_of(
        st.tuples(st.just("translate"), st.integers(0, 31)),
        st.tuples(st.just("invalidate"), st.integers(0, 31)),
        st.just(("shootdown",))),
    max_size=150)


@hp.given(_tlb_ops)
def test_tlb_invalidate_then_translate_always_rewalks(ops):
    t = Tlb(entries=16, ways=4, walk=lambda v: v + 1000)
    walked = 0
    must_walk: set[int] = set(range(32))   # cold pages walk on first touch
    for op in ops:
        if op[0] == "translate":
            v = op[1]
            paddr, cost = t.translate(v * PAGE_BYTES + 5)
            assert paddr == (v + 1000) * PAGE_BYTES + 5   # always correct
            if v in must_walk:
                # invalidated (or never-seen) page: MUST take the Nios II
                # walk — a hit here would be a stale fast-path entry
                assert cost == pytest.approx(T_NIOS_WALK + T_HW_HIT)
                must_walk.discard(v)
            else:
                assert cost in (pytest.approx(T_HW_HIT),
                                pytest.approx(T_NIOS_WALK + T_HW_HIT))
            if cost > T_HW_HIT * 1.5:
                walked += 1
        elif op[0] == "invalidate":
            t.invalidate(op[1] * PAGE_BYTES)
            must_walk.add(op[1])
        else:
            t.invalidate()
            must_walk = set(range(32))
        assert sum(len(s) for s in t._sets) <= 16      # capacity respected
    assert t.stats.misses == walked
    assert t.stats.accesses == t.stats.hits + t.stats.misses


@hp.given(st.lists(st.integers(0, 200), min_size=1, max_size=200))
def test_allocator_translation_cost_monotone(vpages):
    """Allocator translation accounting only ever grows, and hit_rate
    mirrors the endpoint TLB stats."""
    ep = RdmaEndpoint(Torus((2, 2)), 0, tlb_entries=16)
    alloc = PageAllocator(32, page_tokens=4, bytes_per_token=64, endpoint=ep)
    last = alloc.translation_cost
    took = []
    for _ in vpages:
        if not alloc.free:
            break
        took.append(alloc.alloc())
        assert alloc.translation_cost >= last
        last = alloc.translation_cost
    assert alloc.hit_rate == ep.tlb.stats.hit_rate
    assert np.isfinite(alloc.translation_cost)
