"""Fabric design-space autotuner: the typed ConfigSpace, the gym-style
env contract, seeded search determinism, the fluid-inner-loop /
packet-finalist agreement discipline, and the ``best_configs.json``
load-by-default paths in ``TrainerConfig`` / ``ServingCluster``.

conftest pins ``BEST_CONFIGS=0`` for the whole suite, so every test here
that exercises the artifact load path opts back in explicitly through a
tmp file — a stray local artifact can never leak into assertions.
"""
import json
import random

import numpy as np
import pytest

from repro.core.fabric import autotune
from repro.core.fabric.autotune import (AGENTS, ConfigSpace, FabricConfig,
                                        FabricEnv, GeneticAgent,
                                        RandomWalkAgent, finalists, rescore,
                                        search, serving_replay,
                                        torus_shapes, training_replay)

N = 16


@pytest.fixture
def space():
    return ConfigSpace(N)


# ---------------------------------------------------------------------------
# ConfigSpace: shapes, canonical points, sampling, round-trip, validation
# ---------------------------------------------------------------------------

def test_torus_shapes_canonical():
    shapes = torus_shapes(16)
    assert shapes == ((2, 2, 2, 2), (4, 2, 2), (4, 4), (8, 2), (16,))
    for s in shapes:
        assert int(np.prod(s)) == 16
    assert (8,) in torus_shapes(8)
    with pytest.raises(ValueError):
        torus_shapes(1)


def test_default_is_the_pre_qos_baseline(space):
    d = space.default()
    assert d.torus_dims == (4, 4)          # squarest 2-ish-D mesh
    assert d.qos_single and d.route_policy == "hops" and d.stripe_k == 1
    assert d.qos().single_class
    h = space.hand_tuned()
    assert not h.qos_single and h.route_policy == "striped"
    assert not h.qos().single_class
    space.validate(d)
    space.validate(h)


def test_sample_mutate_crossover_stay_valid(space):
    rng = random.Random(3)
    cfgs = [space.sample(rng) for _ in range(25)]
    for c in cfgs:
        space.validate(c)
        space.validate(space.mutate(c, rng))
    for a, b in zip(cfgs, cfgs[1:]):
        space.validate(space.crossover(a, b, rng))


def test_config_json_round_trip(space):
    rng = random.Random(11)
    for _ in range(10):
        cfg = space.sample(rng)
        again = FabricConfig.from_jsonable(
            json.loads(json.dumps(cfg.to_jsonable())))
        assert again == cfg


def test_encode_shape_and_range(space):
    rng = random.Random(5)
    for cfg in [space.default(), space.hand_tuned(),
                *(space.sample(rng) for _ in range(10))]:
        v = space.encode(cfg)
        assert v.shape == (space.encoded_dim,)
        assert np.all(v >= 0.0) and np.all(v <= 1.0)


def test_validate_rejects_bad_configs(space):
    ok = space.default()
    bad = [
        FabricConfig(torus_dims=(3, 5)),                    # 15 nodes
        FabricConfig(torus_dims=(2, 8)),                    # non-canonical
        FabricConfig(torus_dims=ok.torus_dims, stripe_k=99),
        FabricConfig(torus_dims=ok.torus_dims, route_policy="teleport"),
        FabricConfig(torus_dims=ok.torus_dims, bucket_mb=0.0),
        FabricConfig(torus_dims=ok.torus_dims, qos_weights=(1.0, 2.0)),
        FabricConfig(torus_dims=ok.torus_dims,
                     qos_weights=(1.0, -2.0, 1.0, 1.0)),
    ]
    for cfg in bad:
        with pytest.raises(ValueError):
            space.validate(cfg)


# ---------------------------------------------------------------------------
# env contract
# ---------------------------------------------------------------------------

def test_env_step_reward_contract(space):
    env = FabricEnv(space, serving_replay(N), fidelity="fluid")
    obs0 = env.reset(seed=0)
    assert obs0.shape == (space.encoded_dim + 1,)
    assert np.all(obs0 == 0.0) and env.history == []

    cfg = space.default()
    obs, reward, done, info = env.step(cfg)
    assert obs.shape == (space.encoded_dim + 1,)
    assert done is False
    assert info["config"] == cfg
    rep = info["report"]
    assert reward == -rep.objective_s
    assert rep.objective_s > 0.0 and rep.fidelity == "fluid"
    assert rep.decode_span_s > 0.0 and rep.bulk_span_s > 0.0
    assert rep.makespan_s == max(rep.decode_span_s, rep.bulk_span_s,
                                 rep.train_span_s)
    assert obs[-1] == rep.objective_s * 1e3
    assert env.history == [(cfg, rep)]
    # objective composition matches the spec weights
    spec = env.spec
    assert rep.objective_s == pytest.approx(
        spec.decode_weight * rep.decode_span_s
        + spec.bulk_weight * rep.bulk_span_s
        + spec.train_weight * rep.train_span_s)


def test_env_rejects_mismatched_spec(space):
    with pytest.raises(ValueError):
        FabricEnv(space, serving_replay(8))


def test_training_replay_prices_bucket_tradeoff(space):
    env = FabricEnv(space, training_replay(N), fidelity="fluid")
    base = space.default()
    small = env.score(FabricConfig(torus_dims=base.torus_dims,
                                   bucket_mb=0.125))
    mono = env.score(FabricConfig(torus_dims=base.torus_dims,
                                  bucket_mb=256.0))
    mid = env.score(base)
    # the interior optimum: both extremes lose to the 4 MB default
    assert mid.objective_s < small.objective_s
    assert mid.objective_s < mono.objective_s
    assert mid.train_span_s > 0.0 and mid.decode_span_s == 0.0


# ---------------------------------------------------------------------------
# search: seeded determinism, agents, finalists
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("agent_name", sorted(AGENTS))
def test_search_is_deterministic_in_seed(space, agent_name):
    env = FabricEnv(space, serving_replay(N), fidelity="fluid")
    runs = [search(env, AGENTS[agent_name](), steps=8, seed=42)
            for _ in range(2)]
    a, b = runs
    assert a.best_config == b.best_config
    assert a.best_objective_s == b.best_objective_s        # bitwise
    strip = [[{k: v for k, v in t.items()} for t in r.trajectory]
             for r in runs]
    for ta, tb in zip(*strip):
        assert ta["config"] == tb["config"]
        assert ta["objective_s"] == tb["objective_s"]


def test_search_improves_on_fifo_default(space):
    env = FabricEnv(space, serving_replay(N), fidelity="fluid")
    default_obj = env.score(space.default()).objective_s
    res = search(env, GeneticAgent(), steps=10, seed=0)
    # agents warm-start from [default, hand_tuned]: the QoS hand-tuned
    # seed alone already beats the FIFO baseline on this workload
    assert res.best_objective_s < default_obj
    assert res.trajectory[0]["config"] == space.default().to_jsonable()
    bests = [t["best_objective_s"] for t in res.trajectory]
    assert bests == sorted(bests, reverse=True)            # monotone curve


def test_finalists_distinct_and_ranked(space):
    env = FabricEnv(space, serving_replay(N), fidelity="fluid")
    res = search(env, RandomWalkAgent(), steps=6, seed=1)
    final = finalists(res, k=3)
    assert 1 <= len(final) <= 3
    keys = [json.dumps(c.to_jsonable(), sort_keys=True) for c in final]
    assert len(set(keys)) == len(keys)                     # distinct
    assert final[0] == res.best_config                     # best first


@pytest.mark.slow
def test_fluid_winner_agrees_with_packet_oracle(space):
    """The two-fidelity contract on the winner: fluid objective within
    10% of the packet oracle's for the config the search would ship."""
    env = FabricEnv(space, serving_replay(N), fidelity="fluid")
    res = search(env, GeneticAgent(), steps=8, seed=0)
    fluid = env.score(res.best_config, fidelity="fluid").objective_s
    packet, = rescore(env, [res.best_config], fidelity="packet")
    assert packet.fidelity == "packet"
    assert abs(fluid - packet.objective_s) / packet.objective_s <= 0.10


# ---------------------------------------------------------------------------
# best_configs.json: save/load, trainer + cluster default paths
# ---------------------------------------------------------------------------

def _pin(tmp_path, monkeypatch, cfg: FabricConfig, workloads=("serving",
                                                              "train")):
    path = tmp_path / "best_configs.json"
    monkeypatch.setenv(autotune.BEST_CONFIGS_ENV, str(path))
    autotune.save_best_configs(
        {w: {"config": cfg.to_jsonable()} for w in workloads})
    return path


def test_disabled_and_missing_artifact_fall_back(monkeypatch, tmp_path):
    # conftest pins BEST_CONFIGS=0: loading is disabled
    assert autotune.best_configs_path() is None
    assert autotune.load_best_configs() == {}
    assert autotune.tuned_config("serving") is None
    assert autotune.tuned_knob("train", "bucket_mb", 4.0) == 4.0
    # pointing at a missing file must not crash either
    monkeypatch.setenv(autotune.BEST_CONFIGS_ENV,
                       str(tmp_path / "nope.json"))
    assert autotune.load_best_configs() == {}
    assert autotune.tuned_config("train") is None


def test_corrupt_artifact_returns_defaults(monkeypatch, tmp_path):
    p = tmp_path / "best_configs.json"
    p.write_text("{not json")
    monkeypatch.setenv(autotune.BEST_CONFIGS_ENV, str(p))
    assert autotune.load_best_configs() == {}
    assert autotune.tuned_config("serving") is None
    # a parsable file with a broken config entry degrades the same way
    p.write_text(json.dumps({"workloads": {"serving": {"config": {}}}}))
    assert autotune.tuned_config("serving") is None


def test_save_is_deterministic(monkeypatch, tmp_path):
    cfg = ConfigSpace(N).hand_tuned()
    p1 = _pin(tmp_path, monkeypatch, cfg)
    first = p1.read_bytes()
    _pin(tmp_path, monkeypatch, cfg)
    assert p1.read_bytes() == first
    loaded = autotune.tuned_config("serving")
    assert loaded == cfg


def test_save_refuses_when_disabled():
    with pytest.raises(ValueError):
        autotune.save_best_configs({})     # conftest: BEST_CONFIGS=0


def test_trainer_config_loads_pinned_bucket(monkeypatch, tmp_path):
    from repro.runtime.trainer import TrainerConfig
    # no artifact -> legacy 4 MB default
    assert TrainerConfig().bucket_mb == 4.0
    cfg = FabricConfig(torus_dims=(4, 4), bucket_mb=12.5)
    _pin(tmp_path, monkeypatch, cfg)
    assert TrainerConfig().bucket_mb == 12.5
    # the escape hatch: an explicit value always wins
    assert TrainerConfig(bucket_mb=2.0).bucket_mb == 2.0


@pytest.fixture(scope="module")
def dense_model():
    jax = pytest.importorskip("jax")
    from repro import configs
    from repro.models import api
    cfg = configs.get_reduced("smollm-135m")
    return cfg, api.get_model(cfg).init(jax.random.key(0))


def test_cluster_defaults_without_artifact(dense_model):
    from repro.core.topology import Torus
    from repro.serving.cluster import ServingCluster
    cfg, params = dense_model
    cl = ServingCluster(cfg, params, torus=Torus((4,)), node_ranks=(0, 1),
                        max_batch=2, max_seq=64, page_tokens=8)
    assert cl._tuned is None
    assert cl.sim.qos.single_class        # legacy FIFO link


def test_cluster_loads_pinned_qos_and_route(dense_model, monkeypatch,
                                            tmp_path):
    from repro.core import fabric
    from repro.core.topology import Torus
    from repro.serving.cluster import ServingCluster
    cfg, params = dense_model
    tuned = FabricConfig(torus_dims=(4,), qos_single=False,
                         qos_weights=(4.0, 16.0, 8.0, 1.0),
                         qos_credit_frac=(0.1, 0.4, 0.3, 0.2),
                         stripe_k=2, route_policy="striped")
    _pin(tmp_path, monkeypatch, tuned)
    cl = ServingCluster(cfg, params, torus=Torus((4,)), node_ranks=(0, 1),
                        max_batch=2, max_seq=64, page_tokens=8)
    assert cl._tuned == tuned
    assert not cl.sim.qos.single_class    # searched multi-class policy
    # explicit qos still wins over the artifact
    cl2 = ServingCluster(cfg, params, torus=Torus((4,)), node_ranks=(0, 1),
                         max_batch=2, max_seq=64, page_tokens=8,
                         qos=fabric.QosPolicy(single_class=True))
    assert cl2.sim.qos.single_class
