"""Numeric checks that need >1 device — run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (see test_collectives.py).

Exit code 0 = all checks passed.  Each check prints its name so failures are
attributable from the parent test's captured output.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import collectives as C  # noqa: E402
from repro.core import jaxcompat  # noqa: E402
from repro.core import rdma  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def check(name):
    print(f"[multidevice] {name}")


def main() -> None:
    assert jax.device_count() == 8, jax.device_count()
    rng = np.random.default_rng(0)
    mesh = make_mesh((8,), ("x",))

    # --- ring all-reduce: bidirectional / unidirectional / mean, odd sizes ---
    for size in (8, 37, 64, 1000):
        for bidi in (True, False):
            for mean in (True, False):
                x = rng.normal(size=(8, size)).astype(np.float32)
                f = C.make_stacked_all_reduce(mesh, ("x",),
                                              bidirectional=bidi, mean=mean)
                out = np.asarray(f(x))
                want = x.mean(0) if mean else x.sum(0)
                np.testing.assert_allclose(out, want[None].repeat(8, 0),
                                           rtol=2e-5, atol=1e-5)
    check("ring all-reduce (1 axis) ok")

    # --- vs lax.psum oracle -------------------------------------------------
    x = rng.normal(size=(8, 129)).astype(np.float32)
    ours = np.asarray(C.make_stacked_all_reduce(mesh, ("x",))(x))
    def psum_ref(v):
        return jax.lax.psum(v, "x")
    ref = jax.jit(jaxcompat.shard_map(psum_ref, mesh=mesh, in_specs=(P("x"),),
                                out_specs=P("x")))
    got_ref = np.asarray(ref(x))
    np.testing.assert_allclose(ours, got_ref, rtol=2e-5, atol=1e-5)
    check("matches lax.psum oracle")

    # --- bf16 inputs accumulate in fp32 --------------------------------------
    xb = (rng.normal(size=(8, 256)) * 10).astype(jnp.bfloat16)
    f = C.make_stacked_all_reduce(mesh, ("x",))
    out = np.asarray(f(xb).astype(np.float32))
    want = np.asarray(xb.astype(np.float32)).sum(0)
    np.testing.assert_allclose(out, want[None].repeat(8, 0), rtol=2e-2)
    assert f(xb).dtype == jnp.bfloat16
    check("bf16 all-reduce w/ fp32 accumulation ok")

    # --- multi-axis dimension-ordered all-reduce ------------------------------
    mesh24 = make_mesh((2, 4), ("a", "b"))
    x2 = rng.normal(size=(2, 4, 77)).astype(np.float32)
    f2 = C.make_stacked_all_reduce(mesh24, ("a", "b"))
    out2 = np.asarray(f2(x2))
    want2 = x2.sum((0, 1))[None, None].repeat(2, 0).repeat(4, 1)
    np.testing.assert_allclose(out2, want2, rtol=2e-5, atol=1e-5)
    check("dim-ordered 2-axis all-reduce ok")

    # --- reduce-scatter / all-gather inverse pair -----------------------------
    def rs_ag(v):
        chunk, sizes = C.dim_ordered_reduce_scatter(v, ("a", "b"))
        return C.dim_ordered_all_gather(chunk, ("a", "b"), sizes)
    g = jax.jit(jaxcompat.shard_map(lambda v: rs_ag(v[0, 0])[None, None],
                              mesh=mesh24, in_specs=(P("a", "b"),),
                              out_specs=P("a", "b")))
    out3 = np.asarray(g(x2))
    np.testing.assert_allclose(
        out3, x2.sum((0, 1))[None, None].repeat(2, 0).repeat(4, 1),
        rtol=2e-5, atol=1e-5)
    check("RS+AG round trip ok")

    # --- reduce-scatter: every rank owns its correct chunk --------------------
    def rs_only(v):
        out = C.ring_reduce_scatter(v[0], "x")
        return out[None]
    h = jax.jit(jaxcompat.shard_map(rs_only, mesh=mesh, in_specs=(P("x"),),
                              out_specs=P("x")))
    xr = rng.normal(size=(8, 64)).astype(np.float32)
    chunks = np.asarray(h(xr))           # (8, 8): rank r -> chunk r
    want = xr.sum(0).reshape(8, 8)
    # bidirectional layout: chunk r = [front half of chunk r, back half]
    np.testing.assert_allclose(chunks, want, rtol=2e-5, atol=1e-5)
    check("reduce-scatter chunk ownership ok")

    # --- all-gather rank ordering ---------------------------------------------
    def ag_only(v):
        return C.ring_all_gather(v[0], "x")[None]
    k = jax.jit(jaxcompat.shard_map(ag_only, mesh=mesh, in_specs=(P("x"),),
                              out_specs=P("x")))
    xg = rng.normal(size=(8, 6)).astype(np.float32)
    out = np.asarray(k(xg))              # (8, 8, 6), row j == xg[j]
    for r in range(8):
        np.testing.assert_allclose(out[r], xg, rtol=1e-6)
    check("all-gather ordering ok")

    # --- ring all-to-all == transpose ------------------------------------------
    def a2a(v):
        return C.ring_all_to_all(v[0], "x")[None]
    m = jax.jit(jaxcompat.shard_map(a2a, mesh=mesh, in_specs=(P("x"),),
                              out_specs=P("x")))
    xa = rng.normal(size=(8, 8, 3)).astype(np.float32)
    out = np.asarray(m(xa))
    np.testing.assert_allclose(out, xa.transpose(1, 0, 2), rtol=1e-6)
    # fast path oracle
    def a2a_fast(v):
        return C.fast_all_to_all(v[0], "x")[None]
    mf = jax.jit(jaxcompat.shard_map(a2a_fast, mesh=mesh, in_specs=(P("x"),),
                               out_specs=P("x")))
    np.testing.assert_allclose(np.asarray(mf(xa)), out, rtol=1e-6)
    check("ring all-to-all == transpose == lax.all_to_all")

    # --- halo exchange -----------------------------------------------------------
    def halo(v):
        prev, nxt = C.halo_exchange(v[0], "x", halo=2)
        return jnp.stack([prev, nxt])[None]
    hx = jax.jit(jaxcompat.shard_map(halo, mesh=mesh, in_specs=(P("x"),),
                               out_specs=P("x")))
    xh = rng.normal(size=(8, 5, 4)).astype(np.float32)
    out = np.asarray(hx(xh))  # (8, 2, 2, 4)
    for r in range(8):
        np.testing.assert_allclose(out[r, 0], xh[(r - 1) % 8][-2:], rtol=1e-6)
        np.testing.assert_allclose(out[r, 1], xh[(r + 1) % 8][:2], rtol=1e-6)
    check("halo exchange ok")

    # --- rdma put_shift / put_coords ----------------------------------------------
    def shift3(v):
        return rdma.put_shift(v[0], "x", 3)[None]
    sh = jax.jit(jaxcompat.shard_map(shift3, mesh=mesh, in_specs=(P("x"),),
                               out_specs=P("x")))
    xs = np.arange(8 * 4, dtype=np.float32).reshape(8, 4)
    out = np.asarray(sh(xs))
    np.testing.assert_allclose(out, np.roll(xs, 3, axis=0), rtol=0)

    def coords_put(v):
        return rdma.put_coords(v[0, 0], ("a", "b"), (1, -2))[None, None]
    cp = jax.jit(jaxcompat.shard_map(coords_put, mesh=mesh24, in_specs=(P("a", "b"),),
                               out_specs=P("a", "b")))
    xc = np.arange(2 * 4 * 3, dtype=np.float32).reshape(2, 4, 3)
    out = np.asarray(cp(xc))
    np.testing.assert_allclose(out, np.roll(np.roll(xc, 1, 0), -2, 1), rtol=0)
    check("rdma put_shift / put_coords ok")

    # --- apex trainer: explicit torus-collective DP == GSPMD DP ---------------
    import tempfile
    from repro.models.common import ArchCfg
    from repro.optim import AdamWConfig
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = ArchCfg(name="tiny", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=257,
                  dtype=jnp.float32)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=50)
    with tempfile.TemporaryDirectory() as td:
        apex = Trainer(cfg, TrainerConfig(ckpt_dir=td + "/a", ckpt_every=0,
                                          batch=8, seq_len=32, opt=opt,
                                          comm="apex", dp_axis="x"),
                       mesh=make_mesh((8,), ("x",)))
        gspmd = Trainer(cfg, TrainerConfig(ckpt_dir=td + "/g", ckpt_every=0,
                                           batch=8, seq_len=32, opt=opt,
                                           comm="gspmd"),
                        mesh=make_mesh((8,), ("x",)))
        la = [m["loss"] for m in apex.train(4)]
        lg = [m["loss"] for m in gspmd.train(4)]
        # same math, different collectives: losses must track closely
        np.testing.assert_allclose(la, lg, rtol=2e-3, atol=2e-3)
        assert la[-1] < la[0]
    check("apex (torus-collective) trainer matches GSPMD trainer")

    # --- elastic re-mesh: kill a node, shrink 8 -> 4 devices, keep training ---
    with tempfile.TemporaryDirectory() as td:
        tr = Trainer(cfg, TrainerConfig(ckpt_dir=td, ckpt_every=3, batch=8,
                                        seq_len=32, opt=opt, comm="gspmd"),
                     mesh=make_mesh((8,), ("x",)))
        tr.train(4)  # checkpoint at step 3

        def fault(i):
            if i == 1:
                tr.lofamo.kill_node(5)

        metrics = tr.train(4, fault_hook=fault)
        assert tr.mesh.devices.size == 4, tr.mesh
        assert all(np.isfinite(m["loss"]) for m in metrics)
        evs = " | ".join(tr.events)
        assert "elastic re-mesh: 8 -> 4" in evs and "restored step" in evs
    check("elastic re-mesh after LO|FA|MO fault ok")

    print("ALL MULTIDEVICE CHECKS PASSED")


if __name__ == "__main__":
    main()
