"""QoS traffic-class subsystem (fabric/qos + the FabricSim VC arbiter).

Four contracts:
  * **compatibility**: ``QosPolicy(single_class=True)`` (and the default
    ``qos=None``) reproduces the pre-QoS single-FIFO link exactly — class
    tags are inert and finishes are bitwise identical;
  * **protection**: with the default multi-class policy, a DECODE flow
    sharing a saturated link with BULK traffic completes within its
    weighted share (<= 1.10x isolated), while the FIFO link lets bulk
    inflate it several-fold — and bulk still completes (no starvation in
    either direction);
  * **credit isolation**: a BULK merge bottleneck fills only BULK's
    partition of the upstream buffer; DECODE's credit window survives;
  * **striping**: ``striped_routes`` + ``put_pages(stripes=...)`` split
    one bulk PUT across the probed detour family and beat the best single
    route when spare path capacity exists.

Plus the probe_route snapshot/restore contract: a probe (and a
best_route scan) leaves the timeline bitwise identical to never having
probed.
"""
import pytest

from repro.core import fabric
from repro.core.fabric import FabricSim, QosPolicy, TrafficClass
from repro.core.fabric.qos import SINGLE_CLASS
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus


# ---------------------------------------------------------------------------
# policy object
# ---------------------------------------------------------------------------

def test_policy_defaults_and_validation():
    p = QosPolicy()
    assert p.n_classes == len(TrafficClass) == 4
    assert p.weights[TrafficClass.DECODE] > p.weights[TrafficClass.BULK]
    parts = p.partition_credits(40960.0)
    assert len(parts) == 4 and sum(parts) == pytest.approx(40960.0)
    assert all(c > 0 for c in parts)
    s = QosPolicy(single_class=True)
    assert s.n_classes == 1
    assert s.partition_credits(40960.0) == (40960.0,)
    assert s.class_index(TrafficClass.BULK) == 0
    with pytest.raises(ValueError):
        QosPolicy(weights={TrafficClass.BULK: 0.0})
    with pytest.raises(ValueError):
        QosPolicy(credit_frac={TrafficClass.DECODE: -0.1})


def test_policy_partial_override_keeps_other_defaults():
    p = QosPolicy(weights={TrafficClass.BULK: 2.0})
    assert p.weights[TrafficClass.BULK] == 2.0
    assert p.weights[TrafficClass.DECODE] \
        == QosPolicy().weights[TrafficClass.DECODE]


# ---------------------------------------------------------------------------
# single-class compatibility: the pre-QoS FIFO link, bitwise
# ---------------------------------------------------------------------------

def _mixed_flows(sim):
    fids = [
        sim.inject(0, 1, 1 << 20, cls=TrafficClass.DECODE),
        sim.inject(0, 2, 3 << 20, cls=TrafficClass.BULK),
        sim.inject(1, 2, 1 << 19, cls=TrafficClass.COLLECTIVE),
        sim.inject(0, 1, 64, cls=TrafficClass.CONTROL),
    ]
    fids.append(sim.inject(2, 3, 1 << 20, after=(fids[0],),
                           cls=TrafficClass.BULK))
    fids.append(sim.occupy(("hostif", 0), 1e-4, cls=TrafficClass.BULK))
    return [sim.finish_s(f) for f in fids]


def test_single_class_is_the_default_and_ignores_tags():
    t = Torus((8,))
    default = _mixed_flows(FabricSim(t))
    explicit = _mixed_flows(FabricSim(t, qos=QosPolicy(single_class=True)))
    assert default == explicit            # bitwise identical
    assert FabricSim(t).qos is SINGLE_CLASS
    # permuting the class tags changes nothing under single_class
    s = FabricSim(t)
    a = [s.inject(0, 1, 1 << 20, cls=c) for c in
         (TrafficClass.BULK, TrafficClass.DECODE)]
    s2 = FabricSim(t)
    b = [s2.inject(0, 1, 1 << 20, cls=c) for c in
         (TrafficClass.DECODE, TrafficClass.BULK)]
    assert [s.finish_s(f) for f in a] == [s2.finish_s(f) for f in b]


def test_flow_result_carries_class_tag():
    s = FabricSim(Torus((4,)))
    fid = s.inject(0, 1, 4096, cls=TrafficClass.DECODE)
    assert s.flow(fid).cls == TrafficClass.DECODE


# ---------------------------------------------------------------------------
# decode protection under bulk interference
# ---------------------------------------------------------------------------

def _decode_under_bulk(qos):
    """(isolated_s, contended_s, bulk_s) for one DECODE flow sharing its
    link with a 16x larger BULK transfer."""
    iso = FabricSim(Torus((8,)), qos=qos)
    t_iso = iso.finish_s(iso.inject(0, 1, 4 << 20, cls=TrafficClass.DECODE))
    sim = FabricSim(Torus((8,)), qos=qos)
    b = sim.inject(0, 1, 64 << 20, cls=TrafficClass.BULK)
    d = sim.inject(0, 1, 4 << 20, cls=TrafficClass.DECODE)
    return t_iso, sim.finish_s(d), sim.finish_s(b)


def test_decode_protected_under_default_policy():
    t_iso, t_dec, t_bulk = _decode_under_bulk(QosPolicy())
    assert t_dec / t_iso <= 1.10          # the acceptance bar
    assert t_bulk < float("inf")          # bulk still completes
    # and the same scenario on the FIFO link shows why QoS exists
    f_iso, f_dec, _ = _decode_under_bulk(QosPolicy(single_class=True))
    assert f_dec / f_iso > 1.3


def test_bulk_not_starved_and_work_conserved():
    """The arbiter is work-conserving: bulk alone runs at full link rate
    under either policy, and under contention bulk's finish is bounded by
    (total bytes / link rate) + its weighted tail."""
    t = Torus((8,))
    alone_q = FabricSim(t, qos=QosPolicy())
    t_alone = alone_q.finish_s(
        alone_q.inject(0, 1, 64 << 20, cls=TrafficClass.BULK))
    alone_f = FabricSim(t)
    t_fifo = alone_f.finish_s(
        alone_f.inject(0, 1, 64 << 20, cls=TrafficClass.BULK))
    assert t_alone == pytest.approx(t_fifo, rel=0.05)
    _, _, t_bulk = _decode_under_bulk(QosPolicy())
    # total work is 68 MB; bulk (the last finisher) pays ~the sum
    assert t_bulk == pytest.approx(t_alone * 68 / 64, rel=0.10)


def test_throughput_ratio_tracks_weights():
    """Two saturating flows on one link: while both are backlogged, each
    class's goodput share is weight-proportional."""
    w_d = QosPolicy().weights[TrafficClass.DECODE]
    w_b = QosPolicy().weights[TrafficClass.BULK]
    sim = FabricSim(Torus((8,)), qos=QosPolicy())
    n = 16 << 20
    d = sim.inject(0, 1, n, cls=TrafficClass.DECODE)
    sim.inject(0, 1, n, cls=TrafficClass.BULK)
    t_d = sim.finish_s(d)
    share = n / t_d / sim.link_bw          # decode's share while contended
    assert share == pytest.approx(w_d / (w_d + w_b), rel=0.05)


def test_credit_partition_isolates_decode_from_bulk_backpressure():
    """A BULK merge bottleneck at (1, 2) backpressures bulk's partition of
    the (0, 1) buffer; DECODE's window on (0, 1) survives, so the decode
    flow still finishes near its weighted share — on the FIFO link the
    same scenario head-of-line-blocks decode behind credit-starved bulk."""
    def run(qos):
        sim = FabricSim(Torus((8,)), qos=qos)
        iso = FabricSim(Torus((8,)), qos=qos)
        t_iso = iso.finish_s(iso.inject(0, 1, 2 << 20,
                                        cls=TrafficClass.DECODE))
        sim.inject(0, 2, 32 << 20, cls=TrafficClass.BULK)   # 0->1->2
        sim.inject(1, 2, 32 << 20, cls=TrafficClass.BULK)   # merge at (1,2)
        d = sim.inject(0, 1, 2 << 20, cls=TrafficClass.DECODE)
        return sim.finish_s(d) / t_iso
    assert run(QosPolicy()) <= 1.15
    assert run(QosPolicy(single_class=True)) > 2.0


def test_packets_never_exceed_class_credit_partition():
    """A flow's packets must fit its class's credit window, or the channel
    would deadlock head-of-line forever."""
    sim = FabricSim(Torus((4,)), credit_bytes=8192, packet_bytes=8192,
                    qos=QosPolicy())
    # CONTROL partition = 10% of 8192 ~ 819 B; a 1 MB control flow must
    # still complete (packets coarsen DOWN to the partition)
    fid = sim.inject(0, 1, 1 << 20, cls=TrafficClass.CONTROL)
    assert sim.finish_s(fid) > 0


# ---------------------------------------------------------------------------
# probe snapshot/restore (the deepcopy-ghost replacement)
# ---------------------------------------------------------------------------

def test_probe_leaves_future_bitwise_identical():
    """Probing must not perturb ANYTHING: two sims with identical traffic,
    one probed mid-stream, must finish every later flow at bitwise the
    same times."""
    def build():
        s = FabricSim(Torus((4, 4)), qos=QosPolicy())
        s.inject(0, 1, 8 << 20, cls=TrafficClass.BULK)
        s.inject(1, 2, 4 << 20, cls=TrafficClass.DECODE)
        return s
    a, b = build(), build()
    for _ in range(3):                     # repeated probes, same answer
        pa = a.probe_route((0, 1), 1 << 20)
    pb = a.probe_route((0, 4), 1 << 20)
    assert pa > 0 and pb > 0
    fa = a.inject(2, 3, 2 << 20, cls=TrafficClass.COLLECTIVE)
    fb = b.inject(2, 3, 2 << 20, cls=TrafficClass.COLLECTIVE)
    assert a.finish_s(fa) == b.finish_s(fb)
    assert a.link_stats() == b.link_stats()


def test_probe_restores_after_partial_run():
    """Probe AFTER the timeline already ran some events (settled flows,
    credits in flight) — state must still round-trip exactly."""
    s = FabricSim(Torus((8,)))
    done = s.inject(0, 1, 1 << 20)
    s.finish_s(done)                       # heap drained once
    s.advance(s.now + 1e-3)
    pending = s.inject(2, 3, 4 << 20, start_s=s.now + 5e-3)
    before = s.link_stats()
    t0 = s.now
    t = s.probe_route((2, 3), 1 << 20, start_s=t0)
    assert t > 0
    assert s.now == t0                     # probe did not move the clock
    assert s.link_stats() == before
    assert s.finish_s(pending) > t0 + 5e-3


def test_best_route_unchanged_semantics_with_snapshot_probe():
    t = Torus((4, 4))
    s = FabricSim(t)
    s.inject(0, 1, 64 << 20)
    direct = s.probe_route(tuple(t.route(0, 1)), 4 << 20)
    route, best = fabric.best_route(s, 0, 1, 4 << 20)
    assert len(route) - 1 > 1 and best < direct


# ---------------------------------------------------------------------------
# multi-path striping
# ---------------------------------------------------------------------------

def test_striped_routes_shares_and_bias():
    t = Torus((4, 4))
    s = FabricSim(t)
    plan = fabric.striped_routes(s, 0, 1, 4 << 20, k=3)
    assert 1 <= len(plan) <= 3
    assert sum(f for _, f in plan) == pytest.approx(1.0)
    assert all(r[0] == 0 and r[-1] == 1 for r, _ in plan)
    # hammer the direct link: its share must shrink below the others'
    s.inject(0, 1, 64 << 20)
    biased = dict()
    for r, f in fabric.striped_routes(s, 0, 1, 4 << 20, k=3):
        biased[r] = f
    direct = tuple(t.route(0, 1))
    if direct in biased:
        assert biased[direct] <= min(f for r, f in biased.items()
                                     if r != direct)
    with pytest.raises(ValueError):
        fabric.striped_routes(s, 0, 1, 1024, k=0)


def test_stripe_counts_sum_exactly_with_remainders():
    plan = [((0, 1), 0.5), ((0, 2, 1), 0.3), ((0, 3, 1), 0.2)]
    for n in (0, 1, 2, 3, 7, 32, 101):
        counts = fabric.stripe_counts(plan, n)
        assert sum(counts) == n
        assert all(c >= 0 for c in counts)
    assert fabric.stripe_counts(plan, 1).count(1) == 1   # largest frac wins
    with pytest.raises(ValueError):
        fabric.stripe_counts(plan, -1)


def test_striped_put_pages_beats_single_route():
    """With spare capacity on the detour family, splitting the PUT across
    k probed routes aggregates bandwidth: faster than the best single
    route, even after the receiver's reorder/settle charge."""
    t = Torus((4, 4))
    nbytes_page = 1 << 20

    def put(striped):
        sim = FabricSim(t, packet_bytes=40960)
        ep = RdmaEndpoint(t, 0, sim=sim)
        region = ep.register(32 * nbytes_page)
        pages = list(range(32))
        if not striped:
            route, _ = fabric.best_route(sim, 0, 1, 32 * nbytes_page)
            sched = fabric.lower_route(t, route)
            return ep.put_pages(1, region, pages, page_nbytes=nbytes_page,
                                schedule=sched), ep.last_put_report
        plan = fabric.striped_routes(sim, 0, 1, 32 * nbytes_page, k=3)
        counts = fabric.stripe_counts(plan, 32)      # the production split
        stripes = [(fabric.lower_route(t, r), c * nbytes_page)
                   for (r, _), c in zip(plan, counts) if c > 0]
        return ep.put_pages(1, region, pages, page_nbytes=nbytes_page,
                            stripes=stripes), ep.last_put_report
    t_single, single_rep = put(False)
    t_striped, rep = put(True)
    assert rep["stripes"] > 1
    assert rep["settle_s"] > 0
    # translation + host-IF DMA are fixed costs both variants pay; the
    # WIRE leg is what striping parallelises (~k x)
    assert rep["wire_s"] < 0.5 * single_rep["wire_s"]
    assert t_striped < 0.8 * t_single
    assert rep["total_s"] == t_striped


def test_put_pages_rejects_bad_stripes():
    t = Torus((4,))
    ep = RdmaEndpoint(t, 0)
    region = ep.register(8192)
    sched = fabric.lower_p2p(t, 0, 1)
    with pytest.raises(ValueError, match="not both"):
        ep.put_pages(1, region, [0, 1], page_nbytes=4096, schedule=sched,
                     stripes=[(sched, 8192)])
    with pytest.raises(ValueError, match="stripe bytes"):
        ep.put_pages(1, region, [0, 1], page_nbytes=4096,
                     stripes=[(sched, 4096)])
    with pytest.raises(ValueError, match="at least one"):
        ep.put_pages(1, region, [0, 1], page_nbytes=4096, stripes=[])


def test_striped_put_closed_form_without_sim():
    """No sim attached: stripes price as max-of-legs + settle, and the
    report still carries the stripe count."""
    t = Torus((4,))
    ep = RdmaEndpoint(t, 0)
    region = ep.register(8192)
    s1 = fabric.lower_route(t, (0, 1))
    s2 = fabric.lower_route(t, (0, 3, 2, 1))
    total = ep.put_pages(1, region, [0, 1], page_nbytes=4096,
                         stripes=[(s1, 4096), (s2, 4096)])
    rep = ep.last_put_report
    assert rep["stripes"] == 2
    assert total == rep["isolated_s"] == rep["total_s"]


# ---------------------------------------------------------------------------
# per-class accounting
# ---------------------------------------------------------------------------

def test_class_stats_conserve_bytes_per_class():
    sim = FabricSim(Torus((8,)), qos=QosPolicy())
    specs = [(0, 2, 1 << 20, TrafficClass.DECODE),
             (3, 4, 2 << 20, TrafficClass.BULK),
             (5, 6, 1 << 19, TrafficClass.COLLECTIVE)]
    fids = [sim.inject(s, d, n, cls=c) for s, d, n, c in specs]
    sim.run()
    want = {c: 0.0 for c in TrafficClass}
    for fid, (_, _, n, c) in zip(fids, specs):
        want[c] += n * sim.flow(fid).hops    # every wire hop carries it
    got = sim.class_stats()
    for c in TrafficClass:
        assert got[c] == pytest.approx(want[c])
    # link_stats carries the per-class breakdown too
    assert all(len(v["class_bytes"]) == len(TrafficClass)
               for v in sim.link_stats().values())
