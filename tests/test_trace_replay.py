"""Trace replay + SLO layer: seeded trace generation determinism, the
modelled-cluster replay driver, SLO admission (queue/shed), proactive
rebalancing, and the two driver/router regressions this PR fixes:

* ``run_to_completion`` silently returning with requests still in
  flight (now ``TruncatedRunError``) — silent truncation corrupts
  exactly the p99 tail a replay exists to measure;
* ``rebalance`` giving up when the single least-loaded destination was
  slot/page-full (now it tries the next destination / candidate).
"""
import dataclasses

import numpy as np
import pytest

from repro import configs
from repro.core.topology import Torus
from repro.serving.cluster import ServingCluster, SloPolicy
from repro.serving.engine import Engine, PagedLM, Request, TruncatedRunError
from repro.serving.trace import (TraceConfig, TraceRequest, generate_trace,
                                 replay)

N_PARAMS = 7.0e9


@pytest.fixture(scope="module")
def cfg():
    return configs.get_config("deepseek-7b")


def _cluster(cfg, **kw):
    kw.setdefault("torus", Torus((4,)))
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 96)
    kw.setdefault("page_tokens", 8)
    kw.setdefault("tp_axes", ())
    kw.setdefault("fidelity", "fluid")
    return ServingCluster(cfg, None, modelled=True, n_params=N_PARAMS, **kw)


def _req(rid, n_prompt=8, max_new=4, **kw):
    return Request(rid=rid, prompt=np.zeros(n_prompt, dtype=np.int32),
                   max_new_tokens=max_new, **kw)


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------

def test_trace_same_seed_bitwise_identical():
    cfg = TraceConfig(n_requests=300, seed=42)
    a = [dataclasses.astuple(r) for r in generate_trace(cfg)]
    b = [dataclasses.astuple(r) for r in generate_trace(cfg)]
    assert a == b


def test_trace_different_seed_differs():
    a = generate_trace(TraceConfig(n_requests=100, seed=1))
    b = generate_trace(TraceConfig(n_requests=100, seed=2))
    assert [dataclasses.astuple(r) for r in a] \
        != [dataclasses.astuple(r) for r in b]


def test_trace_shape_invariants():
    cfg = TraceConfig(n_requests=400, seed=5)
    tr = generate_trace(cfg)
    assert len(tr) == cfg.n_requests
    assert all(isinstance(r, TraceRequest) for r in tr)
    ts = [r.t for r in tr]
    assert ts == sorted(ts) and ts[0] >= 0.0
    assert [r.rid for r in tr] == list(range(cfg.n_requests))
    sessions = {}
    for r in tr:
        assert cfg.output_min <= r.output_tokens <= cfg.output_max
        assert r.prompt_tokens >= cfg.prompt_min
        assert r.prompt_tokens + r.output_tokens <= cfg.max_context
        if r.turn == 0:
            # a fresh session starts cold with a Zipf-bounded prompt
            assert r.warm_tokens == 0
            assert r.prompt_tokens <= cfg.prompt_max
            assert r.session not in sessions
        else:
            # a continuation carries the whole prior context warm and
            # appends the new turn's tokens on top of it
            assert r.session in sessions
            prev = sessions[r.session]
            assert r.turn == prev.turn + 1
            assert r.warm_tokens == prev.prompt_tokens + prev.output_tokens
            assert r.prompt_tokens > r.warm_tokens
            assert r.t >= prev.t + cfg.session_gap_s
        sessions[r.session] = r
    # the session mechanism must actually engage at these defaults
    assert any(r.turn > 0 for r in tr)


# ---------------------------------------------------------------------------
# replay determinism + fidelity differential
# ---------------------------------------------------------------------------

def _small_trace(n=48, seed=3, util=0.9, n_nodes=4):
    t_tok = 2.0 * N_PARAMS / 1.6e12
    rate = util * n_nodes / (t_tok * 50.8)
    return generate_trace(TraceConfig(
        n_requests=n, seed=seed, base_rate=rate,
        diurnal_period_s=n / (2 * rate)))


def _small_cluster(cfg, fidelity="fluid"):
    return _cluster(cfg, torus=Torus((2, 2)), max_batch=4, max_seq=576,
                    page_tokens=16, chunked_prefill=True,
                    fidelity=fidelity,
                    slo=SloPolicy(token_target_s=0.066, queue_limit=64,
                                  max_queue_wait_s=2.0))


def test_replay_metrics_deterministic(cfg):
    tr = _small_trace()
    a = replay(_small_cluster(cfg), tr, rebalance="proactive").metrics()
    b = replay(_small_cluster(cfg), tr, rebalance="proactive").metrics()
    assert a == b


def test_replay_fluid_vs_hybrid_within_10pct(cfg):
    tr = _small_trace(n=80, seed=9)
    f = replay(_small_cluster(cfg, "fluid"), tr,
               rebalance="proactive").metrics()
    h = replay(_small_cluster(cfg, "hybrid"), tr,
               rebalance="proactive").metrics()
    assert f["n_finished"] == h["n_finished"] == 80
    for k in ("ttft_p50_s", "ttft_p99_s", "tpt_p50_s", "tpt_p99_s"):
        assert abs(f[k] - h[k]) / f[k] <= 0.10, (k, f[k], h[k])


def test_replay_finishes_every_request(cfg):
    tr = _small_trace()
    cl = _small_cluster(cfg)
    rep = replay(cl, tr, rebalance="reactive")
    assert rep.n_finished == len(tr) and rep.n_shed == 0
    assert cl.in_flight == 0
    assert rep.makespan_s > 0.0
    # first token can't precede arrival; finish can't precede first token
    for r in cl.finished:
        assert r.arrival_s <= r.first_token_s <= r.finish_s


# ---------------------------------------------------------------------------
# bugfix 1: run_to_completion must raise on truncation, not return
# ---------------------------------------------------------------------------

def test_engine_run_to_completion_raises_on_truncation(cfg):
    lm = PagedLM(cfg, None, max_batch=2, max_seq=96, page_tokens=8,
                 modelled=True)
    eng = Engine(lm)
    eng.submit(_req(0, max_new=50))
    with pytest.raises(TruncatedRunError) as ei:
        eng.run_to_completion(max_steps=3)
    assert ei.value.steps == 3 and ei.value.in_flight == 1
    eng.run_to_completion()          # the work itself is still sound
    assert [r.rid for r in eng.finished] == [0]


def test_cluster_run_to_completion_raises_on_truncation(cfg):
    cl = _cluster(cfg)
    cl.submit(_req(0, max_new=40))
    with pytest.raises(TruncatedRunError) as ei:
        cl.run_to_completion(max_steps=2)
    assert ei.value.in_flight == 1
    cl.run_to_completion()
    assert cl.in_flight == 0 and [r.rid for r in cl.finished] == [0]


# ---------------------------------------------------------------------------
# bugfix 2: rebalance must try the next destination when the idlest
# one is full
# ---------------------------------------------------------------------------

def test_rebalance_skips_full_destination(cfg):
    cl = _cluster(cfg, torus=Torus((4,)), node_ranks=(0, 1, 2))
    # node 0: 2 running + 3 pending (the hotspot, load 5)
    for i in range(5):
        cl.nodes[0].engine.submit(_req(i, max_new=30))
    # node 1: 2 running (slot-full at max_batch=2, but load only 2 —
    # the pre-fix "idlest" pick, which cannot host anything)
    for i in range(5, 7):
        cl.nodes[1].engine.submit(_req(i, max_new=30))
    # node 2: 1 running (free slot) ...
    cl.nodes[2].engine.submit(_req(7, max_new=30))
    cl.step()
    cl.step()
    # ... + 2 pending submitted between windows, so its load (3) sits
    # above node 1's while a slot stays genuinely free
    cl.nodes[2].engine.submit(_req(8, max_new=30))
    cl.nodes[2].engine.submit(_req(9, max_new=30))
    assert len(cl.nodes[1].engine.running) == cl.nodes[1].lm.max_batch
    assert cl.nodes[0].load == 5 and cl.nodes[1].load == 2 \
        and cl.nodes[2].load == 3
    rep = cl.rebalance(threshold=2)
    # pre-fix: the single shot at slot-full node 1 raised/gave up; now
    # the move lands on the next destination that can actually host
    assert rep is not None and rep.src == 0 and rep.dst == 2
    cl.run_to_completion(max_steps=2000)
    assert sorted(r.rid for r in cl.finished) == list(range(10))


# ---------------------------------------------------------------------------
# router load audit: queued-but-not-prefilling requests count
# ---------------------------------------------------------------------------

def test_pending_requests_count_toward_load(cfg):
    lm = PagedLM(cfg, None, max_batch=1, max_seq=96, page_tokens=8,
                 modelled=True)
    eng = Engine(lm)
    for i in range(3):
        eng.submit(_req(i))
    # nothing admitted yet — pending alone must already show as load,
    # or the router would pile every burst onto one "empty" node
    assert not eng.running and not eng.prefilling
    assert eng.load == 3


def test_router_sees_pending_load(cfg):
    cl = _cluster(cfg, node_ranks=(0, 1), max_batch=1)
    ranks = [cl.submit(_req(i)) for i in range(4)]
    assert ranks == [0, 1, 0, 1]
    assert {n.load for n in cl.nodes.values()} == {2}


# ---------------------------------------------------------------------------
# SLO admission: queue, shed, drain
# ---------------------------------------------------------------------------

def test_admission_queues_then_sheds(cfg):
    cl = _cluster(cfg, node_ranks=(0,), max_batch=1,
                  slo=SloPolicy(token_target_s=0.05, queue_limit=2,
                                max_queue_wait_s=100.0))
    assert cl.submit(_req(0)) == 0
    assert cl.submit(_req(1)) is None     # queued
    assert cl.submit(_req(2)) is None     # queued (limit reached)
    assert cl.submit(_req(3)) is None     # shed
    assert cl.submit(_req(4)) is None     # shed
    assert len(cl.admission_queue) == 2 and len(cl.shed) == 2
    assert all(r.shed_s is not None for r in cl.shed)
    assert cl.in_flight == 3              # running + the queue, not shed
    cl.run_to_completion(max_steps=2000)
    assert sorted(r.rid for r in cl.finished) == [0, 1, 2]
    assert sorted(r.rid for r in cl.shed) == [3, 4]


def test_admission_sheds_after_wait_cap(cfg):
    cl = _cluster(cfg, node_ranks=(0,), max_batch=1,
                  slo=SloPolicy(token_target_s=0.05, queue_limit=8,
                                max_queue_wait_s=0.0))
    cl.submit(_req(0, max_new=20))
    cl.submit(_req(1))                    # queued behind a long decode
    cl.run_to_completion(max_steps=2000)
    # the zero wait cap sheds it at the first window boundary
    assert [r.rid for r in cl.finished] == [0]
    assert [r.rid for r in cl.shed] == [1]


def test_warm_prefix_home_node_affinity(cfg):
    cl = _cluster(cfg, node_ranks=(0, 1), max_batch=1,
                  slo=SloPolicy(token_target_s=0.05, queue_limit=8))
    r0 = _req(0, n_prompt=16)
    r0.warm_tokens = 12
    assert cl.submit(r0, prefer=0) == 0
    assert r0.warm_tokens == 12           # home node keeps the prefix
    r1 = _req(1, n_prompt=16)
    r1.warm_tokens = 12
    assert cl.submit(r1, prefer=0) == 1   # home full -> routed away
    assert r1.warm_tokens == 0            # prefix cache is node-local


# ---------------------------------------------------------------------------
# proactive rebalancer
# ---------------------------------------------------------------------------

def test_proactive_moves_before_predicted_breach(cfg):
    # token budget 0.012*0.8 = 9.6 ms vs the 8.75 ms analytic step:
    # two concurrent decode streams on node 0 predict a breach, one
    # stream fits — exactly one move to the idle node is the fix
    cl = _cluster(cfg, node_ranks=(0, 1),
                  slo=SloPolicy(token_target_s=0.012, headroom=0.8))
    cl.nodes[0].engine.submit(_req(0, max_new=30))
    cl.nodes[0].engine.submit(_req(1, max_new=30))
    cl.step()
    cl.step()
    assert len(cl.nodes[0].engine.running) == 2
    budget = cl.slo.token_target_s * cl.slo.headroom
    assert cl._predicted_token_latency(cl.nodes[0]) > budget
    moves = cl.rebalance_proactive()
    assert len(moves) == 1
    assert moves[0].src == 0 and moves[0].dst == 1
    assert cl._predicted_token_latency(cl.nodes[0]) <= budget
    # no further predicted breach -> no further churn
    assert cl.rebalance_proactive() == []
    cl.run_to_completion(max_steps=2000)
    assert sorted(r.rid for r in cl.finished) == [0, 1]


def test_proactive_requires_slo(cfg):
    cl = _cluster(cfg)
    with pytest.raises(ValueError, match="SloPolicy"):
        cl.rebalance_proactive()


# ---------------------------------------------------------------------------
# closed-loop QoS in the replay driver
# ---------------------------------------------------------------------------

def _qos_cluster(cfg):
    from repro.core import fabric
    base = fabric.QosPolicy()
    cl = _cluster(cfg, torus=Torus((2, 2)), max_batch=4, max_seq=576,
                  page_tokens=16, chunked_prefill=True, qos=base,
                  slo=SloPolicy(token_target_s=0.066, queue_limit=64,
                                max_queue_wait_s=2.0))
    return cl, base


def test_replay_quiescent_controller_is_bitwise_invisible(cfg):
    """A controller that never leaves the safe band must not perturb the
    replay at all: same metrics as no controller, zero retunes."""
    from repro.core import fabric
    tr = _small_trace(n=24, seed=5, util=0.4)
    cl0, _ = _qos_cluster(cfg)
    plain = replay(cl0, tr, rebalance="proactive").metrics()
    cl1, base = _qos_cluster(cfg)
    ctl = fabric.QosController(base, cl1.slo)
    watched = replay(cl1, tr, rebalance="proactive", qos_ctl=ctl).metrics()
    assert plain == watched
    assert ctl.n_retunes == 0 and not ctl.engaged
    assert any(b == "safe" for b, _, _ in ctl.history)


def test_replay_controller_fires_under_tight_slo(cfg):
    """The same trace under a 1000x tighter token SLO must engage the
    controller and actually retune the live fabric policy."""
    from repro.core import fabric
    tr = _small_trace(n=24, seed=5, util=0.9)
    cl, base = _qos_cluster(cfg)
    cl.slo = dataclasses.replace(cl.slo, token_target_s=1e-5)
    ctl = fabric.QosController(base, cl.slo)
    rep = replay(cl, tr, rebalance="proactive", qos_ctl=ctl)
    assert rep.n_finished > 0
    assert ctl.engaged and ctl.n_retunes >= 1
    assert cl.sim.qos.weights[fabric.TrafficClass.DECODE] \
        != base.weights[fabric.TrafficClass.DECODE]


def test_replay_background_callback_injects_cross_traffic(cfg):
    """``background`` runs once per hook tick with the cluster and the
    hook time; injected flows land on the shared timeline."""
    from repro.core import fabric
    tr = _small_trace(n=16, seed=5, util=0.4)
    calls = []

    def background(cluster, t):
        calls.append(t)
        cluster.sim.inject(0, 1, 1 << 20, cls=fabric.TrafficClass.BULK)

    cl, _ = _qos_cluster(cfg)
    quiet = cl.sim.class_stats()
    replay(cl, tr, rebalance="proactive", background=background,
           rebalance_every_s=0.25)
    noisy = cl.sim.class_stats(since=quiet)
    assert len(calls) >= 2
    assert calls == sorted(calls)
    assert noisy[fabric.TrafficClass.BULK] >= len(calls) * (1 << 20)
