"""Multi-pod dry-run integration: lower+compile on the production meshes.

The full 64-cell sweep runs via ``python -m repro.launch.dryrun``; here we
gate the machinery itself: one real cell on the 512-chip multi-pod mesh in
a subprocess (forced host devices), plus the cell-enumeration logic.
"""
import json
import subprocess
import sys

import pytest

from repro import configs
from repro.models import api


def test_cell_enumeration_counts():
    from repro.launch import dryrun

    cells = list(dryrun.all_cells(
        [configs.canonical(a) for a in configs.ALL_ARCHS], None,
        ["pod", "multipod"]))
    # 10 archs x 3 shapes + 2 long_500k (zamba2, rwkv6) = 32 per mesh
    assert len(cells) == 64
    longs = [c for c in cells if c[1] == "long_500k"]
    assert sorted({c[0] for c in longs}) == ["rwkv6-1_6b", "zamba2-1_2b"]


def test_long500k_gated_on_full_attention():
    for arch in configs.ALL_ARCHS:
        cfg = configs.get_config(arch)
        shapes = api.applicable_shapes(cfg)
        assert ("long_500k" in shapes) == (not cfg.full_attention)


@pytest.mark.slow
def test_dryrun_cell_multipod(tmp_path):
    """One full lower+compile on the 2x16x16 mesh must succeed and emit
    roofline-ready JSON."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "smollm-135m", "--shape", "train_4k",
         "--mesh", "multipod", "--force", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.loads(
        (tmp_path / "smollm-135m_train_4k_multipod.json").read_text())
    assert out["chips"] == 512
    assert out["flops_per_device"] > 0
    assert out["link_bytes_per_device"] > 0
    assert out["roofline"]["bottleneck"] in ("compute_s", "memory_s",
                                             "collective_s")
    # useful-flop sanity: params+attention model flops within 3x of the
    # analyzer count (smollm replicates its 9 heads over TP=16, so the
    # compiled flops carry real redundancy — the ratio sits well below 1)
    assert 0.01 <= out["useful_flop_ratio_attn"] <= 3.0
    assert out["useful_flop_ratio"] <= out["useful_flop_ratio_attn"]
    mem = out["memory_analysis"]
    assert "live_bytes_per_device" in mem
