"""APElink codec + efficiency/latency model tests (paper §2.3, §3)."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import numpy as np

from repro.core import apelink, hw

WORDS = st.lists(st.integers(0, 2**32 - 1), min_size=0, max_size=300)


def test_single_packet_roundtrip():
    pay = np.arange(40, dtype=np.uint32)
    [(dest, got)] = apelink.decode_stream(apelink.encode_packet(pay, dest=9))
    assert dest == 9
    np.testing.assert_array_equal(got, pay)


@hp.given(WORDS, st.integers(0, 255))
def test_roundtrip_property(words, dest):
    pay = np.array(words, dtype=np.uint32)
    [(d, got)] = apelink.decode_stream(apelink.encode_packet(pay, dest=dest))
    assert d == dest
    np.testing.assert_array_equal(got, pay)


@hp.given(st.lists(WORDS, min_size=1, max_size=5))
def test_multi_packet_stream_roundtrip(packets):
    stream = np.concatenate(
        [apelink.encode_packet(np.array(p, np.uint32), dest=i % 256)
         for i, p in enumerate(packets)])
    decoded = apelink.decode_stream(stream)
    assert len(decoded) == len(packets)
    for i, (d, got) in enumerate(decoded):
        assert d == i % 256
        np.testing.assert_array_equal(got, np.array(packets[i], np.uint32))


def test_stuffing_payload_full_of_magic():
    pay = np.full(64, apelink.MAGIC, dtype=np.uint32)
    enc = apelink.encode_packet(pay)
    assert enc.size == 64 * 2 + 4  # every payload word doubled + 4 framing
    [(_, got)] = apelink.decode_stream(enc)
    np.testing.assert_array_equal(got, pay)


def test_corruption_detected():
    pay = np.arange(32, dtype=np.uint32)
    enc = apelink.encode_packet(pay)
    enc = enc.copy()
    enc[5] ^= np.uint32(1)  # flip a payload bit
    with pytest.raises(ValueError):
        apelink.decode_stream(enc)


def test_truncation_detected():
    enc = apelink.encode_packet(np.arange(32, dtype=np.uint32))
    with pytest.raises(ValueError):
        apelink.decode_stream(enc[:-3])


# ---------------------------------------------------------------------------
# resynchronisation after mid-stream corruption (what word stuffing buys)
# ---------------------------------------------------------------------------

# MAGIC-heavy payloads included: stuffing escapes are the interesting case
_RESYNC_WORD = st.one_of(
    st.sampled_from([int(apelink.MAGIC), 0, 1, 0xFFFFFFFF]),
    st.integers(0, 2**32 - 1))
_RESYNC_PACKETS = st.lists(
    st.lists(_RESYNC_WORD, min_size=0, max_size=24), min_size=2, max_size=6)


def _spans(packets):
    """Wire [start, end) span of each encoded packet in the stream."""
    spans, pos = [], 0
    for i, p in enumerate(packets):
        enc = apelink.encode_packet(np.array(p, np.uint32), dest=i % 256)
        spans.append((pos, pos + enc.size))
        pos += enc.size
    return spans


@hp.given(_RESYNC_PACKETS, st.data())
def test_resync_recovers_packets_after_corruption(packets, data):
    """Corrupt ONE wire word inside packet k (anywhere but its header —
    the header is not CRC-protected, so corrupting it forges a valid
    packet with a different dest): strict decoding must detect it, and
    ``resync=True`` must recover every packet after the damage, with the
    packets before it untouched."""
    stream = np.concatenate(
        [apelink.encode_packet(np.array(p, np.uint32), dest=i % 256)
         for i, p in enumerate(packets)])
    spans = _spans(packets)
    k = data.draw(st.integers(0, len(packets) - 1), label="victim packet")
    lo, hi = spans[k]
    offsets = [o for o in range(lo, hi) if o != lo + 1]  # skip the header
    pos = data.draw(st.sampled_from(offsets), label="corrupt position")
    flip = data.draw(st.integers(1, 2**32 - 1), label="xor mask")
    corrupted = stream.copy()
    corrupted[pos] ^= np.uint32(flip)

    with pytest.raises(ValueError):
        apelink.decode_stream(corrupted)          # strict mode detects it

    decoded = apelink.decode_stream(corrupted, resync=True)
    want = [(i % 256, np.array(p, np.uint32))
            for i, p in enumerate(packets)]
    # prefix: packets before the victim decode exactly
    assert len(decoded) >= k
    for (d, got), (wd, wp) in zip(decoded[:k], want[:k]):
        assert d == wd
        np.testing.assert_array_equal(got, wp)
    # suffix: every packet after the victim is recovered
    tail = want[k + 1:]
    assert len(decoded) >= k + len(tail)
    for (d, got), (wd, wp) in zip(decoded[len(decoded) - len(tail):], tail):
        assert d == wd
        np.testing.assert_array_equal(got, wp)


@hp.given(_RESYNC_PACKETS)
def test_resync_on_clean_stream_is_identity(packets):
    stream = np.concatenate(
        [apelink.encode_packet(np.array(p, np.uint32), dest=i % 256)
         for i, p in enumerate(packets)])
    strict = apelink.decode_stream(stream)
    lenient = apelink.decode_stream(stream, resync=True)
    assert len(strict) == len(lenient) == len(packets)
    for (d1, p1), (d2, p2) in zip(strict, lenient):
        assert d1 == d2
        np.testing.assert_array_equal(p1, p2)


def test_resync_recovers_boundary_after_magic_heavy_corruption():
    """Deterministic spot-check: a corrupted stuffed-MAGIC escape in a
    MAGIC-saturated payload must not desynchronise the following packet."""
    p0 = np.full(16, apelink.MAGIC, dtype=np.uint32)
    p1 = np.arange(10, dtype=np.uint32)
    stream = np.concatenate([apelink.encode_packet(p0, dest=3),
                             apelink.encode_packet(p1, dest=4)])
    corrupted = stream.copy()
    corrupted[4] ^= np.uint32(0x5A5A5A5A)   # break an escape pair
    decoded = apelink.decode_stream(corrupted, resync=True)
    assert (4, p1.tolist()) in [(d, p.tolist()) for d, p in decoded]


def test_efficiency_matches_paper():
    # paper §2.3: total efficiency 0.784
    assert apelink.protocol_efficiency() == pytest.approx(0.784, abs=1e-3)
    rng = np.random.default_rng(1)
    pay = rng.integers(0, 2**32, size=16 * 1024, dtype=np.uint32)
    meas = apelink.measured_efficiency(pay, apelink.DEFAULT_PAYLOAD_WORDS)
    assert meas == pytest.approx(0.784, abs=1e-3)


def test_efficiency_monotone_in_packet_size():
    etas = [apelink.protocol_efficiency(p) for p in (2, 4, 8, 16, 64, 256)]
    assert all(a < b for a, b in zip(etas, etas[1:]))
    assert all(0 < e < 1 for e in etas)


def test_channel_numbers_match_paper():
    # 28 Gbps raw -> 2.8 GB/s channel -> ~2.2 GB/s sustained; ~40 KB buffer
    assert hw.APELINK_28G.raw_bandwidth == pytest.approx(3.5e9)
    assert hw.APELINK_28G.channel_bandwidth == pytest.approx(2.8e9)
    assert apelink.sustained_bandwidth() == pytest.approx(2.2e9, rel=0.01)
    assert apelink.channel_footprint_bytes() == pytest.approx(40e3, rel=0.02)


def test_latency_headlines_match_paper():
    m = apelink.NetModel()
    small = 16
    gg_p2p = m.latency(small, src_gpu=True, dst_gpu=True)
    gg_staged = m.latency(small, src_gpu=True, dst_gpu=True, p2p=False)
    gg_ib = m.latency(small, fabric="ib")
    hh = m.latency(small)
    assert gg_p2p == pytest.approx(8.2e-6, rel=0.02)     # Fig 3b
    assert gg_staged == pytest.approx(16.8e-6, rel=0.02)  # Fig 3b
    assert gg_ib == pytest.approx(17.4e-6, rel=0.02)      # Fig 3b
    # GPU involvement costs ~30% over host-host for small messages (Fig 3a)
    assert gg_p2p / hh == pytest.approx(1.30, abs=0.05)
    # roundtrip is twice one-way in this model
    assert m.roundtrip(small) == pytest.approx(2 * hh)


def test_p2p_beats_ib_up_to_128k():
    # Fig 3b: advantage of P2P over IB for message size up to 128 KB
    m = apelink.NetModel()
    for nbytes in (64, 1024, 16 * 1024, 100 * 1024):
        assert (m.latency(nbytes, src_gpu=True, dst_gpu=True)
                < m.latency(nbytes, fabric="ib"))
    assert (m.latency(1 << 20, src_gpu=True, dst_gpu=True)
            > m.latency(1 << 20, fabric="ib"))  # large messages: IB wins


def test_bandwidth_plateaus():
    m = apelink.NetModel()
    big = 8 << 20
    assert m.bandwidth(big) == pytest.approx(2.2e9, rel=0.02)  # link limit
    # GPU-outbound bottleneck (Fig 3c): well below the link limit
    assert m.bandwidth(big, src_gpu=True) == pytest.approx(1.4e9, rel=0.05)
    # bandwidth is monotone in message size (latency amortisation)
    bws = [m.bandwidth(1 << k) for k in range(6, 24, 2)]
    assert all(a < b for a, b in zip(bws, bws[1:]))


@hp.given(st.integers(4, 1 << 22), st.integers(1, 8))
def test_latency_model_sane(nbytes, hops):
    m = apelink.NetModel()
    t = m.latency(nbytes, hops=hops)
    assert t > 0
    # more hops or more bytes never reduce latency
    assert m.latency(nbytes, hops=hops + 1) >= t
    assert m.latency(nbytes + 4096, hops=hops) >= t


def test_nextgen_link_rates():
    # §6: 56 Gb/s class links; measured 45.2 Gbps/channel preliminary
    assert hw.APELINK_56G.raw_bandwidth == pytest.approx(7.05e9)
    assert hw.APELINK_45G.raw_bandwidth == pytest.approx(5.65e9)
    assert hw.PCIE_GEN3_X8.effective_bandwidth == pytest.approx(7.9e9, rel=0.01)
