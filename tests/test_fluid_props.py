"""Property-style differential sweeps for the fluid fidelity tiers.

Random tori x random QoS policies x random fault maps x random flow
soups, asserting the tier contract from ``tests/test_fluid_sim.py`` at
property scale:

  * **per-flow completion time**: the fluid tier with packet-mode
    escalation (``fidelity="hybrid"``) stays within 10% of the packet
    oracle per flow (plus the packet-granularity quantization slack for
    few-packet flows);
  * **per-class byte conservation**: the pure fluid tier attributes
    every wire hop of every flow to its class EXACTLY as the packet
    oracle does — no tolerance;
  * **fault parity**: under a random dead link both tiers take the same
    detour (identical hop counts) and the per-flow bar still holds.

Gating follows the PR-5 pattern: hypothesis drives the sweep when the
dev extra is installed (shrinking, example database); otherwise a
hand-rolled seeded sweep covers the same space, so the coverage does
not vanish on boxes without dev extras.
"""
import random

import pytest

from repro.core import fabric
from repro.core.fabric.fluid import make_sim
from repro.core.fabric.qos import QosPolicy, TrafficClass
from repro.core.topology import Torus

try:
    import hypothesis
    from hypothesis import strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:        # hand-rolled fallback sweep below
    HAVE_HYPOTHESIS = False

MESHES = [(6,), (8,), (2, 4), (3, 3), (2, 2, 2), (2, 2, 4)]
REL_TOL = 0.10
_FALLBACK_SEEDS = list(range(10))


def sweep(trial):
    """Drive ``trial(seed)`` by hypothesis when installed, else by a
    fixed seeded sweep (same trial body, deterministic coverage)."""
    if HAVE_HYPOTHESIS:
        return hypothesis.settings(deadline=None, max_examples=25)(
            hypothesis.given(seed=hyp_st.integers(0, 2 ** 31 - 1))(trial))
    return pytest.mark.parametrize("seed", _FALLBACK_SEEDS)(trial)


def _tol(sim, tp: float) -> float:
    # 10% of the oracle time, floored by packet-granularity quantization
    # (few-packet flows meet transient queues the rate model cannot see)
    quant = 8 * sim.packet_bytes / sim.link_bw + 8 * sim.net.t_hop
    return max(REL_TOL * tp, quant)


def _rand_qos(rnd):
    r = rnd.random()
    if r < 0.30:
        return None
    if r < 0.45:
        return QosPolicy(single_class=True)
    if r < 0.70:
        return QosPolicy()
    return QosPolicy(
        weights={c: float(rnd.randint(1, 16)) for c in TrafficClass},
        credit_frac={c: float(rnd.randint(1, 8)) for c in TrafficClass})


def _rand_flows(rnd, n, n_flows, nb_hi=1 << 20):
    flows = []
    for _ in range(n_flows):
        s = rnd.randrange(n)
        d = rnd.randrange(n)
        while d == s:
            d = rnd.randrange(n)
        flows.append((s, d, rnd.randint(1024, nb_hi),
                      rnd.choice(list(TrafficClass)),
                      rnd.randint(0, 3) * 100e-6))
    return flows


def _setup(seed, *, with_fault=False):
    rnd = random.Random(seed)
    dims = rnd.choice(MESHES)
    torus = Torus(dims)
    kw = {}
    qos = _rand_qos(rnd)
    if qos is not None:
        kw["qos"] = qos
    if with_fault:
        # one random dead link: every mesh in MESHES stays connected
        # (multi-dim tori trivially; 1D rings >= 3 degrade to a line)
        u = rnd.randrange(torus.size)
        v = rnd.choice(torus.neighbors(u))
        kw["faults"] = fabric.FaultMap.normalized(set(), {(u, v)})
    flows = _rand_flows(rnd, torus.size, rnd.randint(3, 12))
    return torus, flows, kw


def _run(torus, flows, fidelity, kw):
    sim = make_sim(torus, fidelity=fidelity, **kw)
    fids = [sim.inject(s, d, nb, cls=c, start_s=st)
            for s, d, nb, c, st in flows]
    sim.run()
    return sim, fids


# ---------------------------------------------------------------------------
# per-flow differential: hybrid holds the 10% bar on random soups
# ---------------------------------------------------------------------------

@sweep
def test_per_flow_differential(seed):
    torus, flows, kw = _setup(seed)
    p, pfids = _run(torus, flows, "packet", kw)
    h, hfids = _run(torus, flows, "hybrid", kw)
    for pf, hf, (s, d, nb, c, st) in zip(pfids, hfids, flows):
        tp = p.finish_s(pf) - st
        th = h.finish_s(hf) - st
        assert abs(th - tp) <= _tol(p, tp), (seed, s, d, nb, c)


# ---------------------------------------------------------------------------
# per-class byte conservation: fluid == packet, exactly
# ---------------------------------------------------------------------------

@sweep
def test_class_bytes_conserved(seed):
    torus, flows, kw = _setup(seed)
    p, pfids = _run(torus, flows, "packet", kw)
    f, ffids = _run(torus, flows, "fluid", kw)
    want = {c: 0.0 for c in TrafficClass}
    for fid, (_, _, nb, c, _) in zip(ffids, flows):
        want[c] += nb * f.flow(fid).hops
    got_f, got_p = f.class_stats(), p.class_stats()
    for c in TrafficClass:
        assert got_f[c] == pytest.approx(want[c]), (seed, c)
        assert got_f[c] == pytest.approx(got_p[c]), (seed, c)
    # fluid tracks the aggregate finish too (soup regime: 15% + quant)
    mk_p = max(p.finish_s(x) for x in pfids)
    mk_f = max(f.finish_s(x) for x in ffids)
    assert abs(mk_f - mk_p) <= max(0.15 * mk_p, _tol(p, mk_p)), seed


# ---------------------------------------------------------------------------
# fault maps: both tiers take the identical detour
# ---------------------------------------------------------------------------

@sweep
def test_fault_detour_parity(seed):
    torus, flows, kw = _setup(seed, with_fault=True)
    p, pfids = _run(torus, flows, "packet", kw)
    h, hfids = _run(torus, flows, "hybrid", kw)
    for pf, hf, (s, d, nb, c, st) in zip(pfids, hfids, flows):
        assert h.flow(hf).hops == p.flow(pf).hops, (seed, s, d)
        tp = p.finish_s(pf) - st
        th = h.finish_s(hf) - st
        assert abs(th - tp) <= _tol(p, tp), (seed, s, d, nb, c)
