"""Closed-loop QoS: the ``QosController`` control law, windowed
``class_stats`` deltas, live ``set_qos`` retunes on both fabric tiers
(warm-started on the fluid one), ``run_until`` checkpointing, mid-flight
re-striping, descriptor-granular BULK preemption, and the escape-credit
x descriptor-preemption credit invariant."""
import random

import pytest

from repro.core.apelink import NetModel
from repro.core.fabric import autotune
from repro.core.fabric.fluid import FluidSim
from repro.core.fabric.qos import QosPolicy, TrafficClass
from repro.core.fabric.qosctl import QosController, QosCtlPolicy
from repro.core.fabric.sim import FabricSim
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus

D = TrafficClass.DECODE
B = TrafficClass.BULK


class _Slo:
    token_target_s = 0.050
    headroom = 0.8          # at-risk band starts at 40 ms


class _StubSim:
    """Just the controller's actuator surface: canned per-class byte
    deltas plus a record of every ``set_qos`` call."""

    def __init__(self, decode_delta=1.0):
        self._total = {c: 0.0 for c in TrafficClass}
        self._decode_delta = decode_delta
        self.applied: list[QosPolicy] = []

    def class_stats(self, since=None):
        out = dict(self._total)
        if since is not None:
            out = {c: out[c] - since.get(c, 0.0) for c in out}
        return out

    def tick(self):
        self._total[D] += self._decode_delta

    def set_qos(self, policy):
        self.applied.append(policy)


def _ctl(sim_decode=1.0, **pol):
    policy = QosCtlPolicy(**pol) if pol else QosCtlPolicy()
    return QosController(QosPolicy(), _Slo(), policy=policy), \
        _StubSim(sim_decode)


# --- control law ----------------------------------------------------------

def test_ctl_policy_validation():
    for bad in (dict(gain=1.0), dict(gain=0.5), dict(decay=0.0),
                dict(decay=1.0), dict(max_boost=0.9), dict(floor=0.0),
                dict(floor=1.5), dict(credit_gain=-0.1),
                dict(min_credit_frac=0.25)):
        with pytest.raises(ValueError):
            QosCtlPolicy(**bad)


def test_ctl_rejects_single_class_baseline():
    with pytest.raises(ValueError):
        QosController(QosPolicy(single_class=True), _Slo())


def test_ctl_latched_quiescent_until_first_at_risk():
    ctl, sim = _ctl()
    for _ in range(5):
        sim.tick()
        assert ctl.window(sim, [0.010, 0.012]) is False   # safe band
    assert not ctl.engaged and ctl.n_retunes == 0
    assert sim.applied == [] and ctl.boost == 1.0
    assert [b for b, _, _ in ctl.history] == ["safe"] * 5
    # idle windows (no finished requests) keep the latch closed too
    assert ctl.window(sim, []) is False
    assert ctl.history[-1][0] == "idle" and not ctl.engaged


def test_ctl_boosts_at_risk_and_caps():
    ctl, sim = _ctl(gain=2.0, max_boost=3.0)
    for expect in (2.0, 3.0, 3.0):
        sim.tick()
        ctl.window(sim, [0.045])                          # at-risk band
        assert ctl.boost == pytest.approx(expect)
    # cap reached: the third window changed nothing -> no third retune
    assert ctl.n_retunes == 2
    w = sim.applied[-1].weights[D]
    assert w == pytest.approx(QosPolicy().weights[D] * 3.0)


def test_ctl_at_risk_holds_without_decode_bytes():
    ctl, sim = _ctl(sim_decode=0.0)
    sim.tick()
    assert ctl.window(sim, [0.045]) is False
    assert ctl.engaged and ctl.boost == 1.0   # engaged but held: the
    #                                           replica is compute-bound


def test_ctl_releases_to_floor_on_breach():
    ctl, sim = _ctl(decay=0.5, floor=0.2)
    sim.tick()
    ctl.window(sim, [0.045])                  # engage (at-risk)
    for expect in (0.8, 0.4, 0.2, 0.2):       # 1.6 * 0.5^k, floored
        sim.tick()
        ctl.window(sim, [0.080])              # breached: release
        assert ctl.boost == pytest.approx(expect)
    w = sim.applied[-1].weights[D]
    assert w == pytest.approx(QosPolicy().weights[D] * 0.2)


def test_ctl_retuned_credit_floor():
    ctl, _ = _ctl(floor=0.05, decay=0.25, min_credit_frac=0.08)
    ctl.engaged = True
    ctl.boost = 0.05                          # deep release
    pol = ctl.retuned()
    total = sum(pol.credit_frac.values())
    for cls in TrafficClass:
        assert pol.credit_frac[cls] >= 0.08 * total - 1e-12
    assert pol.weights[D] == pytest.approx(QosPolicy().weights[D] * 0.05)


def test_ctl_tuned_knobs_load_from_artifact(tmp_path, monkeypatch):
    cfg = autotune.FabricConfig(torus_dims=(4, 4), ctl_gain=2.5,
                                ctl_decay=0.45, ctl_floor=0.33)
    path = tmp_path / "best_configs.json"
    autotune.save_best_configs(
        {"serving": {"config": cfg.to_jsonable()}}, path=str(path))
    monkeypatch.setenv("BEST_CONFIGS", str(path))
    pol = QosCtlPolicy.tuned()
    assert (pol.gain, pol.decay, pol.floor) == (2.5, 0.45, 0.33)
    monkeypatch.setenv("BEST_CONFIGS", "0")
    assert QosCtlPolicy.tuned() == QosCtlPolicy()


# --- windowed class_stats (both tiers) ------------------------------------

def _tiers():
    t = Torus((4,))
    return [FabricSim(t, qos=QosPolicy()), FluidSim(t, qos=QosPolicy())]


@pytest.mark.parametrize("tier", ["packet", "fluid"])
def test_identical_windows_identical_deltas(tier):
    """Two byte-identical traffic windows must report byte-identical
    per-class deltas through ``class_stats(since=...)`` — the controller
    steers on windows, so windowing must not smear."""
    sim = _tiers()[0 if tier == "packet" else 1]

    def window(t0):
        before = sim.class_stats()
        sim.inject(0, 1, 256 * 1024, start_s=t0, cls=D)
        sim.inject(1, 3, 512 * 1024, start_s=t0, cls=B)
        sim.inject(2, 0, 128 * 1024, start_s=t0 + 1e-4,
                   cls=TrafficClass.COLLECTIVE)
        sim.run()
        return sim.class_stats(since=before)

    d1, d2 = window(0.0), window(sim.now + 1e-3)
    assert d1 == d2                           # bitwise, not approx
    assert d1[D] > 0.0 and d1[B] > 0.0
    # and the deltas telescope back to the absolute totals
    total = sim.class_stats()
    for cls in TrafficClass:
        assert total[cls] == pytest.approx(d1[cls] + d2[cls])


# --- live set_qos ---------------------------------------------------------

def _put_under_decode(sim_cls, retune_at=None, boost_bulk=None):
    """32 MB BULK vs a long DECODE backlog on the same link; optionally
    retune mid-drain and return the BULK finish time."""
    t = Torus((8,))
    sim = sim_cls(t, qos=QosPolicy())
    sim.inject(0, 1, 512e6, cls=D)
    fid = sim.inject(0, 1, 32e6, cls=B)
    if retune_at is not None:
        sim.run_until(retune_at)
        sim.set_qos(QosPolicy(weights={D: boost_bulk}))
    return sim.finish_s(fid)


@pytest.mark.parametrize("sim_cls", [FabricSim, FluidSim])
def test_set_qos_live_release_speeds_bulk(sim_cls):
    static = _put_under_decode(sim_cls)
    released = _put_under_decode(sim_cls, retune_at=1e-3, boost_bulk=2.0)
    assert released < static * 0.75           # DECODE 16 -> 2 mid-drain


@pytest.mark.parametrize("sim_cls", [FabricSim, FluidSim])
def test_set_qos_rejects_channel_count_change(sim_cls):
    sim = sim_cls(Torus((4,)), qos=QosPolicy())
    with pytest.raises(ValueError):
        sim.set_qos(QosPolicy(single_class=True))


def test_set_qos_packet_credits_stay_conserved():
    """The retune re-partitions credits as a DELTA on live links; once
    the fabric drains, every link balance equals the NEW partition —
    in-flight debits and loans were carried over, not leaked."""
    sim = FabricSim(Torus((4,)), qos=QosPolicy())
    sim.inject(0, 2, 4 << 20, cls=B)
    sim.inject(1, 3, 4 << 20, cls=D)
    sim.run_until(2e-4)
    new = QosPolicy(credit_frac={D: 0.55, B: 0.05})
    sim.set_qos(new)
    sim.run()
    part = new.partition_credits(sim.credit_bytes)
    for link in sim._links.values():
        for c in range(len(part)):
            assert link.credits[c] == pytest.approx(part[c])


# --- fluid warm start (weights-only retunes) ------------------------------

def test_fluid_warm_start_bitwise_equals_cold():
    def solve(warm):
        sim = FluidSim(Torus((8,)), qos=QosPolicy())
        rnd = random.Random(7)
        fids = [sim.inject(rnd.randrange(8), (rnd.randrange(7) + f + 1) % 8,
                           rnd.randint(1 << 20, 8 << 20),
                           cls=rnd.choice(list(TrafficClass)))
                for f in range(12)]
        sim.run_until(5e-4)
        if not warm:
            sim._inc_cache = None             # force a cold rebuild
        sim.set_qos(QosPolicy(weights={D: 4.0, B: 3.0}))
        return [sim.finish_s(f) for f in fids], sim.n_warm_solves

    hot, n_hot = solve(True)
    cold, n_cold = solve(False)
    assert hot == cold                        # bitwise, not approx
    assert n_hot > n_cold                     # the retune solve was warm


# --- run_until checkpointing ----------------------------------------------

@pytest.mark.parametrize("sim_cls", [FabricSim, FluidSim])
def test_run_until_checkpoints_preserve_finishes(sim_cls):
    def finishes(checkpoints):
        sim = sim_cls(Torus((6,)), qos=QosPolicy())
        rnd = random.Random(3)
        fids = [sim.inject(rnd.randrange(6), (rnd.randrange(5) + f + 1) % 6,
                           rnd.randint(256 << 10, 4 << 20),
                           cls=rnd.choice(list(TrafficClass)))
                for f in range(10)]
        for t in checkpoints:
            sim.run_until(t)
        sim.run()
        return [sim.finish_s(f) for f in fids]

    direct, stepped = finishes([]), finishes([1e-4, 5e-4, 2e-3])
    if sim_cls is FabricSim:
        assert direct == stepped          # event-driven: bitwise
    else:
        # the fluid tier settles drain integrals at every checkpoint, so
        # the float summation re-associates — equal to 1e-9 relative
        assert direct == pytest.approx(stepped, rel=1e-9, abs=0.0)


# --- mid-flight re-striping ------------------------------------------------

def _ring_routes(n, src, dst):
    fwd = tuple(range(src, dst + 1))
    bwd = tuple((src - i) % n for i in range((src - dst) % n + 1))
    return fwd, bwd


@pytest.mark.parametrize("sim_cls", [FabricSim, FluidSim])
def test_restripe_conserves_bytes(sim_cls):
    sim = sim_cls(Torus((8,)), qos=QosPolicy())
    fwd, bwd = _ring_routes(8, 0, 3)
    total = 16 << 20
    fid = sim.inject(0, 3, total, route=fwd, cls=B)
    sim.run_until(1e-3)
    rem = sim.unsent_bytes(fid)
    assert 0.0 < rem < total                  # genuinely half-sent
    fids = sim.restripe(fid, [(fwd, 0.5), (bwd, 0.5)])
    assert fids[0] == fid and len(fids) == 2
    carried = sum(sim._flows[f].nbytes for f in fids)
    assert carried == pytest.approx(total)    # no byte invented or lost
    for f in fids:
        sim.finish_s(f)                       # every leg completes
        assert sim.unsent_bytes(f) == 0.0


def test_restripe_rejects_bad_plans():
    sim = FabricSim(Torus((8,)), qos=QosPolicy())
    fwd, _ = _ring_routes(8, 0, 3)
    fid = sim.inject(0, 3, 1 << 20, route=fwd, cls=B)
    with pytest.raises(ValueError):           # nothing committed yet
        sim.restripe(fid, [(fwd, 1.0)])
    sim.run_until(1e-4)
    with pytest.raises(ValueError):           # route joins wrong endpoints
        sim.restripe(fid, [((0, 1, 2), 1.0)])


# --- descriptor-granular preemption ---------------------------------------

_PAGE = 65536


def _endpoints(descriptor_bytes, npages):
    torus = Torus((4, 4))
    net = NetModel()
    sim = FabricSim(torus, net, qos=QosPolicy())
    src = RdmaEndpoint(torus, rank=0, net=net, sim=sim,
                       descriptor_bytes=descriptor_bytes)
    dst = RdmaEndpoint(torus, rank=1, net=net, sim=sim)
    reg = src.register(npages * _PAGE)
    dreg = dst.register(npages * _PAGE)
    src.translate_region(reg)                 # warm the TLB
    dst.translate_region(dreg)
    return sim, src, dst, reg, dreg


def _put(src, dst, reg, dreg, npages):
    return src.put_pages(dst.rank, reg, list(range(npages)),
                         page_nbytes=_PAGE, dst_endpoint=dst,
                         dst_region=dreg, dst_pages=list(range(npages)))


def test_put_pages_descriptor_chain_count():
    npages = 128                              # 8 MB payload
    sim, src, dst, reg, dreg = _endpoints(256 * 1024, npages)
    _put(src, dst, reg, dreg, npages)
    assert src.last_put_report["descriptors"] == 32   # ceil(8 MB/256 KB)
    sim, src, dst, reg, dreg = _endpoints(None, npages)
    _put(src, dst, reg, dreg, npages)
    assert src.last_put_report["descriptors"] == 1    # monolithic


def _mid_drain_wait(descriptor_bytes):
    npages = 128
    sim, src, dst, reg, dreg = _endpoints(descriptor_bytes, npages)
    t_hot = src.translate_region(reg)
    _put(src, dst, reg, dreg, npages)
    t_mid = t_hot + 0.25 * src.last_put_report["dma_s"]
    sim, src, dst, reg, dreg = _endpoints(descriptor_bytes, npages)
    fin = sim.occupy(("hostif", 0), 50e-6, start_s=t_mid,
                     cls=D, label="decode_probe")
    _put(src, dst, reg, dreg, npages)
    return sim.finish_s(fin) - t_mid - 50e-6


def test_descriptor_preemption_cuts_decode_wait():
    """A DECODE command landing mid-drain of a BULK DMA waits at most
    one descriptor, not the whole transfer (arXiv:1311.1741 §2.1)."""
    w_mono = _mid_drain_wait(None)
    w_desc = _mid_drain_wait(256 * 1024)
    assert w_mono > 1e-4                      # the mono drain does block
    assert w_desc < w_mono / 2.0


# --- escape credit x descriptor preemption (seeded repro) -----------------

def _assert_credits_restored(sim):
    for link in sim._links.values():
        for c, part in enumerate(sim._class_credits):
            assert link.credits[c] == pytest.approx(part), \
                "idle link holds a leaked/unrepaid credit balance"


def test_escape_credit_repaid_under_descriptor_preemption():
    """Seeded repro: a descriptor-chained 4 MB BULK PUT drains through a
    random multi-class storm on a wrap-around ring that credit-deadlocks
    (escape-credit loans fire while BULK heads are being preempted at
    descriptor boundaries).  The loaned credit must be repaid in full:
    every flow finishes and every idle link balance equals the policy
    partition."""
    rnd = random.Random(1)
    torus = Torus((8,))
    net = NetModel()
    sim = FabricSim(torus, net, qos=QosPolicy())
    src = RdmaEndpoint(torus, rank=0, net=net, sim=sim,
                       descriptor_bytes=128 * 1024)
    dst = RdmaEndpoint(torus, rank=5, net=net, sim=sim)
    npages = 64
    reg = src.register(npages * _PAGE)
    dreg = dst.register(npages * _PAGE)
    fids = []
    for _ in range(48):
        s = rnd.randrange(8)
        d = rnd.randrange(8)
        while d == s:
            d = rnd.randrange(8)
        fids.append(sim.inject(
            s, d, rnd.randint(256 * 1024, 1 << 20),
            cls=rnd.choice([TrafficClass.CONTROL, D,
                            TrafficClass.COLLECTIVE])))
    _put(src, dst, reg, dreg, npages)
    sim.run()
    assert sim.deadlock_breaks > 0, \
        "storm no longer deadlocks; re-seed the repro"
    assert src.last_put_report["descriptors"] == 32
    for f in fids:
        assert sim._flows[f].finish_s is not None
    _assert_credits_restored(sim)


def test_escape_credit_repaid_plain_storm():
    """The pure-deadlock invariant (no RDMA in the loop): after recovery
    the loaned escape credits are all repaid."""
    rnd = random.Random(1)
    sim = FabricSim(Torus((8,)), qos=QosPolicy())
    for _ in range(64):
        s = rnd.randrange(8)
        d = rnd.randrange(8)
        while d == s:
            d = rnd.randrange(8)
        sim.inject(s, d, rnd.randint(256 * 1024, 1 << 20),
                   cls=rnd.choice(list(TrafficClass)))
    sim.run()
    assert sim.deadlock_breaks > 0
    _assert_credits_restored(sim)
