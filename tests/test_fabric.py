"""CollectiveSchedule IR tests: lowering structure, cost-model
monotonicity, fault rewriting, and LO|FA|MO link-fault inference.

Numeric executor equivalence (schedule-executed vs oracle on 1D/2D/3D
tori) runs in a subprocess with 8 forced host devices — see
``fabric_checks.py`` and the slow test at the bottom.
"""
import os
import subprocess
import sys

import pytest

from repro.core import fabric
from repro.core.lofamo import LofamoSim
from repro.core.topology import Torus

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# lowering structure
# ---------------------------------------------------------------------------

def test_all_reduce_lowering_shape():
    s = fabric.lower_all_reduce(Torus((2, 4)), ("a", "b"))
    assert [ (p.kind, p.axis) for p in s.phases ] == [
        ("reduce_scatter", "a"), ("reduce_scatter", "b"),
        ("all_gather", "b"), ("all_gather", "a")]
    # rounds: (2-1) + (4-1) per leg
    assert s.rounds == 2 * (1 + 3)
    # dual-DMA: two concurrent transfers per round
    assert s.n_messages == 2 * s.rounds
    assert s.max_hops == 1


def test_rs_fracs_sum_to_ring_traffic():
    """A bidirectional RS over n ranks injects (n-1)/n of the input."""
    n = 8
    s = fabric.lower_reduce_scatter(Torus((n,)), ("x",))
    assert s.bytes_per_rank(n * 1000) == pytest.approx(
        (n - 1) / n * n * 1000)


def test_all_reduce_fracs_match_2n_minus_1_over_n():
    n = 8
    s = fabric.lower_all_reduce(Torus((n,)), ("x",))
    assert s.bytes_per_rank(1 << 20) == pytest.approx(
        2 * (n - 1) / n * (1 << 20))


def test_dim_ordered_scales_shrink_then_grow():
    s = fabric.lower_all_reduce(Torus((2, 2, 2)), ("x", "y", "z"))
    assert [p.scale for p in s.phases] == [1, 0.5, 0.25, 0.125, 0.25, 0.5]


def test_trivial_axis_has_no_steps():
    s = fabric.lower_all_reduce(Torus((1,)), ("x",))
    assert s.rounds == 0


def test_lowering_validates_axes():
    with pytest.raises(ValueError):
        fabric.lower_all_reduce(Torus((4,)), ("x", "y"))
    with pytest.raises(ValueError):
        fabric.lower("nope", Torus((4,)), ("x",))


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_monotone_in_bytes():
    s = fabric.lower_all_reduce(Torus((4, 4)), ("x", "y"))
    ts = [fabric.estimate(s, n).total_s for n in (1 << 10, 1 << 15, 1 << 20)]
    assert ts[0] < ts[1] < ts[2]


def test_cost_monotone_in_hops():
    clean = fabric.lower_all_reduce(Torus((8,)), ("x",))
    detoured = fabric.rewrite(
        clean, fabric.FaultMap.normalized(links=[(2, 3)]))
    n = 1 << 20
    assert detoured.max_hops > clean.max_hops
    assert fabric.estimate(detoured, n).total_s \
        > fabric.estimate(clean, n).total_s


def test_cost_monotone_in_ring_size():
    n = 1 << 20
    ts = [fabric.estimate(
        fabric.lower_all_reduce(Torus((k,)), ("x",)), n).total_s
        for k in (2, 4, 8, 16)]
    assert all(a < b for a, b in zip(ts, ts[1:]))


def test_bidirectional_predicted_faster():
    n = 1 << 22
    t = Torus((8,))
    bidi = fabric.estimate(fabric.lower_all_reduce(t, ("x",)), n).total_s
    uni = fabric.estimate(
        fabric.lower_all_reduce(t, ("x",), bidirectional=False), n).total_s
    assert bidi < uni


# ---------------------------------------------------------------------------
# fault rewriting
# ---------------------------------------------------------------------------

def test_rewrite_noop_without_faults():
    s = fabric.lower_all_reduce(Torus((8,)), ("x",))
    assert fabric.rewrite(s, fabric.FaultMap()) is s


def test_dead_node_shrinks_ring_and_drops_from_perms():
    s = fabric.lower_all_reduce(Torus((8,)), ("x",))
    r = fabric.rewrite(s, fabric.FaultMap.normalized(nodes=[3]))
    for ph in r.phases:
        assert ph.ring == (0, 1, 2, 4, 5, 6, 7)
        for st in ph.steps:
            for tr in st.transfers:
                assert all(3 not in pair for pair in tr.perm)
    # the 2->4 transfer cannot route through dead node 3 on a 1D ring:
    # it takes the 6-hop detour the long way around
    assert r.max_hops == 6


def test_dead_link_keeps_ring_bumps_hops():
    s = fabric.lower_all_reduce(Torus((8,)), ("x",))
    r = fabric.rewrite(s, fabric.FaultMap.normalized(links=[(0, 1)]))
    assert all(ph.ring == tuple(range(8)) for ph in r.phases)
    assert r.max_hops == 7  # the long way around the ring


def test_dead_link_2d_detours_through_other_dim():
    s = fabric.lower_all_reduce(Torus((4, 4)), ("x", "y"))
    r = fabric.rewrite(s, fabric.FaultMap.normalized(links=[(0, 4)]),
                       reorder_axes=False)
    # detour 0 -> 4 exists through the orthogonal dimension: 3 hops
    assert 1 < r.max_hops <= 3


def test_axis_reordering_puts_faulted_axis_last():
    s = fabric.lower_all_reduce(Torus((4, 4)), ("x", "y"))
    # kill a link on the x rings (dim 0): x should be reduced last
    r = fabric.rewrite(s, fabric.FaultMap.normalized(links=[(0, 4)]))
    assert r.axes == ("y", "x")
    assert r.axis_dims == (1, 0)
    # numerically the all-reduce is order-invariant; cheaper than not
    # reordering because the detoured axis now moves 1/4 of the bytes
    n = 1 << 22
    r_no = fabric.rewrite(s, fabric.FaultMap.normalized(links=[(0, 4)]),
                          reorder_axes=False)
    assert fabric.estimate(r, n).total_s <= fabric.estimate(r_no, n).total_s


def test_partitioned_fabric_raises():
    # 1D ring of 4: killing both links of rank 1's neighbours cuts it off
    s = fabric.lower_all_reduce(Torus((4,)), ("x",))
    with pytest.raises(fabric.UnroutableError):
        fabric.rewrite(s, fabric.FaultMap.normalized(links=[(0, 1), (1, 2)]))


def test_all_to_all_rejects_dead_nodes_allows_dead_links():
    s = fabric.lower_all_to_all(Torus((4,)), "x")
    with pytest.raises(fabric.UnroutableError):
        fabric.rewrite(s, fabric.FaultMap.normalized(nodes=[2]))
    r = fabric.rewrite(s, fabric.FaultMap.normalized(links=[(1, 2)]))
    assert r.max_hops == 3


def test_mean_and_direction_flags_survive_rewrite():
    s = fabric.lower_all_reduce(Torus((8,)), ("x",), bidirectional=False,
                                mean=True)
    r = fabric.rewrite(s, fabric.FaultMap.normalized(nodes=[0]))
    assert r.mean and not r.bidirectional
    assert all(ph.mean for ph in r.phases if ph.kind == "reduce_scatter")
    assert all(ph.directions == 1 for ph in r.phases if ph.steps)


# ---------------------------------------------------------------------------
# point-to-point lowering (the migration path's unicast)
# ---------------------------------------------------------------------------

def test_p2p_dimension_ordered_route_and_price():
    t = Torus((4, 4, 4))
    dst = t.rank((2, 3, 1))
    s = fabric.lower_p2p(t, 0, dst)
    # dimension-ordered minimal route: hops == torus hop distance, and the
    # route annotation walks X completely before Y before Z
    assert s.max_hops == t.hop_distance(0, dst)
    route = s.phases[0].ring
    assert route[0] == 0 and route[-1] == dst
    changed_dims = []
    for a, b in zip(route, route[1:]):
        ca, cb = t.coords(a), t.coords(b)
        diff = [i for i in range(3) if ca[i] != cb[i]]
        assert len(diff) == 1               # first-neighbour hops only
        changed_dims.append(diff[0])
    assert changed_dims == sorted(changed_dims)   # X fully, then Y, then Z
    # one message end-to-end: estimate equals a single message at hop count
    n = 1 << 20
    assert fabric.estimate(s, n).total_s == pytest.approx(
        fabric.message_time(n, hops=s.max_hops))
    # self-send is free (no transfer)
    assert fabric.estimate(fabric.lower_p2p(t, 3, 3), n).total_s == 0.0


def test_message_time_zero_bytes_prices_header_latency_only():
    """A zero-byte transfer (pure sync step) pays injection + reception +
    per-hop transits, and NOT a phantom 1-byte payload."""
    from repro.core.apelink import NetModel
    net = NetModel()
    for hops in (1, 3, 7):
        assert fabric.message_time(0, net, hops=hops) == pytest.approx(
            net.t_inject + net.t_receive + hops * net.t_hop, rel=1e-12)
    # strictly below any payload-carrying message, monotone at the origin
    assert fabric.message_time(0, net) < fabric.message_time(1, net)
    # fractional sub-byte payloads truncate to the header-only price, not
    # up to a phantom byte
    assert fabric.message_time(0.25, net) == fabric.message_time(0, net)


def test_lower_route_explicit_path():
    t = Torus((4, 4))
    route = (0, 4, 5, 1)                      # a deliberate detour 0 -> 1
    s = fabric.lower_route(t, route)
    assert s.route == route and s.max_hops == 3
    assert fabric.estimate(s, 1 << 20).total_s == pytest.approx(
        fabric.message_time(1 << 20, hops=3))
    with pytest.raises(ValueError):
        fabric.lower_route(t, (0, 5))         # not a first-neighbour link
    with pytest.raises(fabric.UnroutableError):
        fabric.lower_route(t, (0, 1),
                           faults=fabric.FaultMap.normalized(
                               links=[(0, 1)]))


def test_p2p_fault_rewrite_detours_and_costs_more():
    t = Torus((4,))
    s = fabric.lower_p2p(t, 0, 1)
    r = fabric.rewrite(s, fabric.FaultMap.normalized(links=[(0, 1)]))
    assert s.max_hops == 1 and r.max_hops == 3      # 0 -> 3 -> 2 -> 1
    assert r.phases[0].ring == (0, 3, 2, 1)
    n = 1 << 20
    assert fabric.estimate(r, n).total_s > fabric.estimate(s, n).total_s
    # endpoints are recovered from the detoured route annotation: a second
    # rewrite under a DIFFERENT fault map re-lowers src=0, dst=1 (not the
    # detour waypoints) and finds the direct link again
    r2 = fabric.rewrite(r, fabric.FaultMap.normalized(links=[(2, 3)]))
    assert r2.phases[0].ring == (0, 1) and r2.max_hops == 1


def test_p2p_unroutable_and_dead_endpoints():
    with pytest.raises(fabric.UnroutableError):
        fabric.lower_p2p(Torus((2,)), 0, 1,
                         faults=fabric.FaultMap.normalized(links=[(0, 1)]))
    with pytest.raises(fabric.UnroutableError):
        fabric.lower_p2p(Torus((4,)), 0, 1,
                         faults=fabric.FaultMap.normalized(nodes=[1]))
    with pytest.raises(ValueError):
        fabric.lower_p2p(Torus((4,)), 0, 99)
    with pytest.raises(ValueError):
        fabric.lower("p2p", Torus((4,)), ("x",))    # rank-addressed


def test_rdma_bulk_put_get_pricing():
    from repro.core.rdma import RdmaEndpoint

    t = Torus((4,))
    src, dst = RdmaEndpoint(t, 0), RdmaEndpoint(t, 1)
    region = src.register(8 * 8192)
    dst_region = dst.register(8 * 8192)
    t1 = src.put_pages(1, region, [0, 1], page_nbytes=8192,
                       dst_endpoint=dst, dst_region=dst_region)
    assert t1 > 0 and dst.tlb.stats.accesses == 4     # 2 pages x 2 granules
    # more pages cost more; pages must fit the registered region
    assert src.put_pages(1, region, [0, 1, 2, 3], page_nbytes=8192) > \
        src.put_pages(1, region, [0], page_nbytes=8192)
    with pytest.raises(ValueError):
        src.put_pages(1, region, [7], page_nbytes=16384)   # straddles end
    with pytest.raises(KeyError):
        src.put_pages(1, dst_region, [0], page_nbytes=8192)  # not ours
    # GET: descriptor out + payload back, monotone in payload, and the
    # fault machinery reroutes/refuses it like any unicast
    g1 = src.get_time(1, 4096, region)
    g2 = src.get_time(1, 1 << 20, region)
    assert 0 < g1 < g2
    detour = src.get_time(1, 4096, region,
                          faults=fabric.FaultMap.normalized(links=[(0, 1)]))
    assert detour > 0
    with pytest.raises(fabric.UnroutableError):
        src.get_time(1, 4096, region,
                     faults=fabric.FaultMap.normalized(nodes=[1]))


# ---------------------------------------------------------------------------
# overlap engine: bucket lowering + overlap-aware cost model
# ---------------------------------------------------------------------------

def test_plan_buckets_reverse_order_covers_all_leaves():
    sizes = [100, 200, 3000, 50, 4000]
    plan = fabric.plan_buckets(sizes, 4096, itemsize=4)
    covered = [i for b in plan.buckets for i in b.leaves]
    assert sorted(covered) == list(range(len(sizes)))
    # readiness order: the LAST leaf's grads exist first in backward
    assert plan.buckets[0].leaves[0] == len(sizes) - 1
    assert plan.total_bytes == 4 * sum(sizes)
    # every bucket but the trailing remainder meets the size target
    for b in plan.buckets[:-1]:
        assert b.nbytes >= plan.bucket_bytes


def test_plan_buckets_validates():
    with pytest.raises(ValueError):
        fabric.plan_buckets([10], 0)
    with pytest.raises(ValueError):
        fabric.plan_buckets([], 1024)


def test_estimate_overlapped_accounts_for_fabric_busy_time():
    s = fabric.lower_reduce_scatter(Torus((8,)), ("x",), mean=True)
    plan = fabric.plan_buckets([1 << 16] * 16, 1 << 18)
    est = fabric.estimate_overlapped(s, plan, 0.01)
    busy = est.comm_s + est.overhead_s
    assert est.hidden_comm_s + est.exposed_comm_s == pytest.approx(busy)
    assert 0.0 <= est.efficiency <= 1.0
    assert est.total_s <= est.sequential_s + est.comm_s  # sane scale


def test_estimate_overlapped_compute_bound_hides_almost_all_comm():
    s = fabric.lower_reduce_scatter(Torus((8,)), ("x",), mean=True)
    plan = fabric.plan_buckets([1 << 16] * 64, 1 << 18)
    est = fabric.estimate_overlapped(s, plan, 10.0)
    # only the tail bucket (and issue gaps) can stay exposed
    assert est.efficiency > 0.9
    assert est.total_s == pytest.approx(est.compute_s, rel=0.05)


def test_estimate_overlapped_balanced_shape_cuts_quarter():
    """The Fig 1 regime: comm ~ compute -> >= 25% total-time reduction."""
    s = fabric.lower_reduce_scatter(Torus((8,)), ("x",), mean=True)
    plan = fabric.plan_buckets([1 << 18] * 32, 1 << 20)
    comm = fabric.estimate_overlapped(s, plan, 0.0).comm_s
    est = fabric.estimate_overlapped(s, plan, comm)  # compute == comm
    assert est.reduction >= 0.25
    assert est.total_s < est.sequential_s


def test_estimate_overlapped_single_slot_queue_never_faster():
    s = fabric.lower_reduce_scatter(Torus((8,)), ("x",), mean=True)
    plan = fabric.plan_buckets([1 << 14] * 128, 1 << 15)
    t1 = fabric.estimate_overlapped(s, plan, 1e-3, queue_depth=1).total_s
    t4 = fabric.estimate_overlapped(s, plan, 1e-3, queue_depth=4).total_s
    assert t1 >= t4


def test_estimate_overlapped_validates():
    s = fabric.lower_reduce_scatter(Torus((8,)), ("x",), mean=True)
    with pytest.raises(ValueError):
        fabric.estimate_overlapped(s, [100, 200], [0.1], queue_depth=2)
    with pytest.raises(ValueError):
        fabric.estimate_overlapped(s, [100], 0.1, queue_depth=0)


def test_bucket_grad_hook_rejects_wrong_schedules():
    ag = fabric.lower_all_gather(Torus((8,)), ("x",))
    plan = fabric.plan_buckets([10], 1024)
    with pytest.raises(ValueError):
        fabric.make_bucket_grad_hook(plan, ag)
    rs2 = fabric.lower_reduce_scatter(Torus((4, 2)), ("x", "y"))
    with pytest.raises(ValueError):
        fabric.make_bucket_grad_hook(plan, rs2)


# ---------------------------------------------------------------------------
# LO|FA|MO link-fault inference feeding the rewriter
# ---------------------------------------------------------------------------

def test_lofamo_link_fault_detected_as_link_not_node():
    sim = LofamoSim(Torus((4, 4)), wd_period=0.5)
    ev = sim.kill_link(1, 2)
    sim.run(3)
    assert sim.detected_links_at_master() == {(1, 2)}
    assert sim.detected_at_master() == set()  # both endpoints alive
    fm = fabric.fault_map_from_lofamo(sim)
    assert fm.dead_links == frozenset({(1, 2)})
    assert not fm.dead_nodes
    # awareness time is tracked for the link event like for node events
    assert ev.awareness_time is not None
    assert 0 < ev.awareness_time <= 2 * 0.5 + 1e-2


def test_lofamo_node_fault_still_node_not_link():
    sim = LofamoSim(Torus((4, 4)), wd_period=0.5)
    sim.kill_node(5)
    sim.run(3)
    assert 5 in sim.detected_at_master()
    assert sim.detected_links_at_master() == set()


def test_lofamo_fault_map_drives_rewrite():
    sim = LofamoSim(Torus((8,)), wd_period=0.5)
    sim.kill_link(3, 4)
    sim.run(3)
    sched = fabric.lower_all_reduce(Torus((8,)), ("x",))
    r = fabric.rewrite(sched, fabric.fault_map_from_lofamo(sim))
    assert r.max_hops == 7


# ---------------------------------------------------------------------------
# numeric equivalence on 1D/2D/3D tori (8 forced host devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fabric_multidevice_equivalence():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "fabric_checks.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL FABRIC CHECKS PASSED" in proc.stdout
