"""Numeric schedule-executor checks that need >1 device — run in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (see
test_fabric.py).

Covers the acceptance bar for the fabric refactor:
  * schedule-executed collectives == oracle (psum / sum / transpose / roll)
    for every collective on 1D (8), 2D (2,4) and 3D (2,2,2) tori;
  * fault-rewritten schedules: a detoured dead link changes NOTHING
    numerically (all ranks still participate); a dead node shrinks the
    ring and the live ranks reduce exactly the live contributions.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import collectives as C  # noqa: E402
from repro.core import fabric, jaxcompat  # noqa: E402
from repro.core.topology import Torus  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def check(name):
    print(f"[fabric] {name}")


MESHES = {
    "1d": ((8,), ("x",)),
    "2d": ((2, 4), ("a", "b")),
    "3d": ((2, 2, 2), ("u", "v", "w")),
}


def run_sharded(mesh, axes, fn, x):
    lead = len(axes)
    spec = P(*axes)

    def per_shard(v):
        return fn(v.reshape(v.shape[lead:])).reshape(v.shape)

    return np.asarray(jax.jit(jaxcompat.shard_map(
        per_shard, mesh=mesh, in_specs=(spec,), out_specs=spec))(x))


def all_reduce_checks(rng):
    for tag, (shape, axes) in MESHES.items():
        mesh = make_mesh(shape, axes)
        torus = Torus(shape)
        x = rng.normal(size=shape + (51,)).astype(np.float32)
        lead = tuple(range(len(shape)))
        want = x.sum(lead)
        for bidi in (True, False):
            sched = fabric.lower_all_reduce(torus, axes, bidirectional=bidi)
            out = run_sharded(
                mesh, axes,
                lambda v, s=sched: fabric.execute_all_reduce(s, v), x)
            np.testing.assert_allclose(
                out, np.broadcast_to(want, x.shape), rtol=2e-5, atol=1e-5)
        check(f"all-reduce schedule == sum oracle ({tag}, bidi+uni)")


def rs_ag_roundtrip_checks(rng):
    for tag, (shape, axes) in MESHES.items():
        mesh = make_mesh(shape, axes)
        torus = Torus(shape)
        x = rng.normal(size=shape + (37,)).astype(np.float32)
        rs = fabric.lower_reduce_scatter(torus, axes)
        ag = fabric.lower_all_gather(
            torus, tuple(reversed(axes)),
            axis_dims=tuple(reversed(range(len(axes)))))

        def round_trip(v):
            chunk, sizes = fabric.execute_reduce_scatter(rs, v)
            return fabric.execute_all_gather(ag, chunk, sizes) \
                .reshape(v.shape)

        out = run_sharded(mesh, axes, round_trip, x)
        want = np.broadcast_to(x.sum(tuple(range(len(shape)))), x.shape)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=1e-5)
        check(f"RS+AG schedule round trip ({tag})")


def chunk_ownership_check(rng):
    mesh = make_mesh((8,), ("x",))
    sched = fabric.lower_reduce_scatter(Torus((8,)), ("x",))
    x = rng.normal(size=(8, 64)).astype(np.float32)

    def rs_only(v):
        out, _ = fabric.execute_reduce_scatter(sched, v[0])
        return out[None]

    h = jax.jit(jaxcompat.shard_map(rs_only, mesh=mesh, in_specs=(P("x"),),
                                    out_specs=P("x")))
    chunks = np.asarray(h(x))
    np.testing.assert_allclose(chunks, x.sum(0).reshape(8, 8),
                               rtol=2e-5, atol=1e-5)
    check("reduce-scatter slot owns contiguous chunk")


def a2a_and_halo_checks(rng):
    mesh = make_mesh((8,), ("x",))
    torus = Torus((8,))
    sched = fabric.lower_all_to_all(torus, "x")
    xa = rng.normal(size=(8, 8, 3)).astype(np.float32)

    def a2a(v):
        return fabric.execute_all_to_all(sched, v[0])[None]

    out = np.asarray(jax.jit(jaxcompat.shard_map(
        a2a, mesh=mesh, in_specs=(P("x"),), out_specs=P("x")))(xa))
    np.testing.assert_allclose(out, xa.transpose(1, 0, 2), rtol=1e-6)
    check("all-to-all schedule == transpose")

    hs = fabric.lower_halo_exchange(torus, "x")
    xh = rng.normal(size=(8, 5, 4)).astype(np.float32)

    def halo(v):
        prev, nxt = fabric.execute_halo_exchange(hs, v[0], halo=2)
        return jax.numpy.stack([prev, nxt])[None]

    out = np.asarray(jax.jit(jaxcompat.shard_map(
        halo, mesh=mesh, in_specs=(P("x"),), out_specs=P("x")))(xh))
    for r in range(8):
        np.testing.assert_allclose(out[r, 0], xh[(r - 1) % 8][-2:], rtol=1e-6)
        np.testing.assert_allclose(out[r, 1], xh[(r + 1) % 8][:2], rtol=1e-6)
    check("halo-exchange schedule == ring neighbours")


def fault_rewrite_checks(rng):
    # dead LINK: all ranks alive, detour is logical -> results identical
    mesh = make_mesh((8,), ("x",))
    torus = Torus((8,))
    clean = fabric.lower_all_reduce(torus, ("x",))
    detoured = fabric.rewrite(clean,
                              fabric.FaultMap.normalized(links=[(2, 3)]))
    assert detoured.max_hops == 7
    x = rng.normal(size=(8, 100)).astype(np.float32)
    out_c = run_sharded(mesh, ("x",),
                        lambda v: fabric.execute_all_reduce(clean, v), x)
    out_d = run_sharded(mesh, ("x",),
                        lambda v: fabric.execute_all_reduce(detoured, v), x)
    np.testing.assert_array_equal(out_c, out_d)
    check("dead-link detour: results bit-identical")

    # dead NODE: ring shrinks to 7; live ranks reduce live contributions
    dead = 3
    shrunk = fabric.rewrite(clean, fabric.FaultMap.normalized(nodes=[dead]))
    out_s = run_sharded(mesh, ("x",),
                        lambda v: fabric.execute_all_reduce(shrunk, v), x)
    live = [r for r in range(8) if r != dead]
    want_live = x[live].sum(0)
    for r in live:
        np.testing.assert_allclose(out_s[r], want_live, rtol=2e-5, atol=1e-5)
    check("dead-node shrunk ring: live ranks reduce live contributions")

    # mean over the shrunk ring divides by the LIVE count
    shrunk_mean = fabric.rewrite(
        fabric.lower_all_reduce(torus, ("x",), mean=True),
        fabric.FaultMap.normalized(nodes=[dead]))
    out_m = run_sharded(
        mesh, ("x",),
        lambda v: fabric.execute_all_reduce(shrunk_mean, v), x)
    for r in live:
        np.testing.assert_allclose(out_m[r], want_live / 7,
                                   rtol=2e-5, atol=1e-5)
    check("shrunk-ring mean divides by live count")


def bucket_hook_equivalence_checks(rng):
    """Overlap engine: the bucketed grad hook (reduce-scatter issued inside
    the VJP) must match the sequential per-leaf schedule execution
    bit-for-bit — on a 1D ring and along one axis of a 2D torus."""
    import jax.numpy as jnp

    cases = [("1d", (8,), ("x",), 0), ("2d", (2, 4), ("a", "b"), 1)]
    shapes = [(13,), (3, 5), (4, 4, 2), (25,), (7,)]
    for tag, mshape, axes, dim in cases:
        mesh = make_mesh(mshape, axes)
        torus = Torus(mshape)
        sched = fabric.lower_reduce_scatter(torus, (axes[dim],),
                                            axis_dims=(dim,), mean=True)
        m = torus.dims[dim]
        leaves = [rng.normal(size=mshape + s).astype(np.float32)
                  for s in shapes]
        plan = fabric.plan_buckets([int(np.prod(s)) for s in shapes],
                                   40 * 4, itemsize=4)
        assert plan.n_buckets > 1  # exercise multi-bucket issue
        lead = len(mshape)

        def seq_leaf(g):
            chunk, _ = fabric.execute_reduce_scatter(sched, g)
            slot = fabric.ring_slot(sched.phases[0])
            full = jnp.zeros((chunk.shape[0] * m,), chunk.dtype)
            full = jax.lax.dynamic_update_slice(
                full, chunk, (slot * chunk.shape[0],))
            return full[:g.size].reshape(g.shape).astype(g.dtype)

        def per_shard(*gs):
            gs = [g.reshape(g.shape[lead:]) for g in gs]
            hook = fabric.make_bucket_grad_hook(plan, sched)
            _, vjp = jax.vjp(hook, [jnp.zeros_like(g) for g in gs])
            (bucketed,) = vjp(list(gs))
            seq = [seq_leaf(g) for g in gs]
            return tuple(x.reshape((1,) * lead + x.shape)
                         for x in list(bucketed) + seq)

        spec = P(*axes)
        out = jax.jit(jaxcompat.shard_map(
            per_shard, mesh=mesh, in_specs=(spec,) * len(leaves),
            out_specs=(spec,) * (2 * len(leaves)),
            check_vma=False))(*leaves)
        n = len(leaves)
        for i in range(n):
            np.testing.assert_array_equal(
                np.asarray(out[i]), np.asarray(out[n + i]),
                err_msg=f"leaf {i} ({tag})")
        check(f"bucketed grad hook == sequential RS, bitwise ({tag})")


def sim_analytic_differential_checks():
    """The two cost backends must agree on single-flow ring schedules:
    every round's messages ride disjoint link directions, so the
    event-driven sim (fabric/sim.py) and the closed-form model price the
    exact same timeline.  10% is the acceptance bar; the assertion is the
    differential that validates BOTH models."""
    for tag, (shape, axes) in MESHES.items():
        torus = Torus(shape)
        scheds = {
            "all-reduce": fabric.lower_all_reduce(torus, axes),
            "reduce-scatter": fabric.lower_reduce_scatter(torus, axes),
            "all-gather": fabric.lower_all_gather(torus, axes),
        }
        for name, sched in scheds.items():
            for nbytes in (0, 4096, 1 << 20):
                a = fabric.estimate(sched, nbytes).total_s
                s = fabric.estimate(sched, nbytes, backend="sim").total_s
                err = abs(s - a) / a if a else abs(s - a)
                assert err <= 0.10, \
                    f"{name} ({tag}, {nbytes} B): sim {s} vs analytic " \
                    f"{a} — {err * 100:.1f}% > 10%"
        check(f"sim backend == analytic on single-flow schedules ({tag})")
    # multi-hop p2p unicast rides the same differential
    t3 = Torus((2, 2, 2))
    p2p = fabric.lower_p2p(t3, 0, t3.size - 1)
    for nbytes in (64, 1 << 20):
        a = fabric.estimate(p2p, nbytes).total_s
        s = fabric.estimate(p2p, nbytes, backend="sim").total_s
        assert abs(s - a) / a <= 0.10
    check("sim backend == analytic on p2p unicast (3d)")


def main() -> None:
    assert jax.device_count() == 8, jax.device_count()
    rng = np.random.default_rng(7)
    all_reduce_checks(rng)
    rs_ag_roundtrip_checks(rng)
    chunk_ownership_check(rng)
    a2a_and_halo_checks(rng)
    fault_rewrite_checks(rng)
    bucket_hook_equivalence_checks(rng)
    sim_analytic_differential_checks()
    print("ALL FABRIC CHECKS PASSED")


if __name__ == "__main__":
    main()
