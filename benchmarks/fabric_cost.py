"""Fabric CollectiveSchedule cost model — predicted vs measured.

The schedule IR gives every collective a predicted completion time for
free (``fabric.estimate`` prices each step's transfers with the apelink
``NetModel``).  This bench reports those predictions across tori and
collectives, verifies the model's structural claims, and — where the host
can fake an 8-device ring — times the *executed* schedule so BENCH output
tracks predicted vs measured collective time.

Checked claims:
  * dual-DMA bidirectional rings finish in half the rounds and strictly
    less predicted time than unidirectional ones (paper §2.1);
  * predicted time is monotone in message size and in detour hops;
  * a fault-rewritten schedule around a dead link never gets cheaper.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from repro.core import fabric
from repro.core.topology import Torus

MiB = 1 << 20


def _sched_rows() -> list[dict]:
    rows = []
    cases = [
        ("ring8", Torus((8,)), ("x",)),
        ("torus4x4", Torus((4, 4)), ("x", "y")),
        ("torus4x4x4", Torus((4, 4, 4)), ("x", "y", "z")),
        ("pod16x16", Torus((16, 16)), ("data", "model")),
    ]
    for name, torus, axes in cases:
        sched = fabric.lower_all_reduce(torus, axes)
        est = fabric.estimate(sched, 4 * MiB)
        rows.append({"bench": "fabric_cost",
                     "metric": f"allreduce_{name}_pred_ms",
                     "value": est.total_s * 1e3,
                     "note": f"{est.rounds} rounds, 4 MiB"})
        rows.append({"bench": "fabric_cost",
                     "metric": f"allreduce_{name}_algbw_GBps",
                     "value": fabric.algorithmic_bandwidth(sched, 4 * MiB)
                     / 1e9, "note": "input bytes / predicted time"})
    return rows


def _claim_rows() -> list[dict]:
    t8 = Torus((8,))
    bidi = fabric.lower_all_reduce(t8, ("x",), bidirectional=True)
    uni = fabric.lower_all_reduce(t8, ("x",), bidirectional=False)
    t_bidi = fabric.estimate(bidi, 4 * MiB).total_s
    t_uni = fabric.estimate(uni, 4 * MiB).total_s
    rows = [
        {"bench": "fabric_cost", "metric": "bidi_rounds", "value":
         bidi.rounds,
         "note": f"{bidi.n_messages} ppermutes fused to 2-concurrent rounds"},
        {"bench": "fabric_cost", "metric": "bidi_speedup", "value":
         t_uni / t_bidi, "gate": "higher",
         "note": "dual-DMA predicted time cut"},
    ]
    # fault detour: kill link (0,1) on the 8-ring -> the 0->1 transfer
    # takes the 7-hop detour; schedule may never get cheaper
    faults = fabric.FaultMap.normalized(links=[(0, 1)])
    detour = fabric.rewrite(bidi, faults)
    rows.append({"bench": "fabric_cost", "metric": "detour_max_hops",
                 "value": detour.max_hops, "note": "dead link (0,1), 8-ring"})
    rows.append({"bench": "fabric_cost", "metric": "detour_cost_ratio",
                 "value": fabric.estimate(detour, 4 * MiB).total_s / t_bidi,
                 "note": "rewritten / clean predicted time"})
    # shrunk ring: node 3 dead -> 7 live ranks
    shrunk = fabric.rewrite(bidi, fabric.FaultMap.normalized(nodes=[3]))
    rows.append({"bench": "fabric_cost", "metric": "shrunk_ring_size",
                 "value": len(shrunk.phases[0].ring), "note": "node 3 dead"})
    return rows


_MEASURE_SRC = textwrap.dedent("""
    import os, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.core import collectives as C
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("x",))
    x = np.random.default_rng(0).normal(size=(8, 1 << 20)) \\
        .astype(np.float32)
    f = C.make_stacked_all_reduce(mesh, ("x",))
    f(x).block_until_ready()          # compile
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        f(x).block_until_ready()
    print((time.perf_counter() - t0) / reps)
""")


def _measured_rows() -> list[dict]:
    """Time the executed 8-ring schedule on forced host devices.

    Host-CPU ppermutes are not APEnet+ links, so the measured/predicted
    ratio is reported, not checked — the point is that both numbers come
    from the SAME schedule object.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    try:
        proc = subprocess.run([sys.executable, "-c", _MEASURE_SRC],
                              capture_output=True, text=True, env=env,
                              timeout=300)
        measured = float(proc.stdout.strip().splitlines()[-1])
    except Exception:
        return [{"bench": "fabric_cost", "metric": "measured_skipped",
                 "value": 1, "note": "8-device host measurement unavailable"}]
    sched = fabric.lower_all_reduce(Torus((8,)), ("x",))
    pred = fabric.estimate(sched, 4 * MiB).total_s
    return [
        {"bench": "fabric_cost", "metric": "allreduce_ring8_measured_ms",
         "value": measured * 1e3, "note": "8 host devices, 4 MiB"},
        {"bench": "fabric_cost", "metric": "measured_over_predicted",
         "value": measured / pred,
         "note": "host CPU fabric vs APEnet+ model"},
    ]


def run() -> list[dict]:
    return _sched_rows() + _claim_rows() + _measured_rows()


def check(rows) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    errs = []
    if vals["bidi_speedup"] <= 1.0:
        errs.append(f"dual-DMA not faster: x{vals['bidi_speedup']:.2f}")
    sched8 = fabric.lower_all_reduce(Torus((8,)), ("x",))
    if vals["bidi_rounds"] != sched8.rounds \
            or sched8.n_messages != 2 * sched8.rounds:
        errs.append("bidirectional fusion lost: rounds/messages mismatch")
    if vals["detour_cost_ratio"] < 1.0:
        errs.append("fault detour made the schedule cheaper")
    if vals["detour_max_hops"] <= 1:
        errs.append("dead link produced no detour hops")
    if vals["shrunk_ring_size"] != 7:
        errs.append(f"shrunk ring size {vals['shrunk_ring_size']} != 7")
    # size monotonicity on the 4x4x4 schedule
    sched = fabric.lower_all_reduce(Torus((4, 4, 4)), ("x", "y", "z"))
    times = [fabric.estimate(sched, n).total_s
             for n in (1 << 12, 1 << 16, 1 << 20, 1 << 24)]
    if not all(a < b for a, b in zip(times, times[1:])):
        errs.append("predicted time not monotone in message size")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
