"""Fig 3c — bandwidth tests (paper §3).

Reproduces: every transaction class except GPU-outbound saturates the
APEnet+ link limit (~2.2 GB/s on current hardware); GPU memory *read*
transactions bottleneck inside the GPU (~1.4 GB/s plateau).

Collective bandwidth (the old ad-hoc ring math) now comes from the fabric
layer: ``fabric.lower_all_reduce`` + ``fabric.estimate`` price the exact
schedule the executor runs, so the reported algorithm bandwidth and the
point-to-point curves share one model.
"""
from __future__ import annotations

from repro.core import fabric
from repro.core.apelink import NetModel, sustained_bandwidth
from repro.core.topology import Torus


def run() -> list[dict]:
    net = NetModel()
    rows = [{"bench": "bandwidth", "metric": "link_limit_GBps",
             "value": sustained_bandwidth() / 1e9,
             "note": "paper ~2.2 GB/s plateau"}]
    big = 4 << 20
    cases = {
        "cpu_write": dict(src_gpu=False, dst_gpu=False),   # CPU mem read->TX
        "gpu_write": dict(src_gpu=False, dst_gpu=True),    # RX into GPU mem
        "cpu_read": dict(src_gpu=False, dst_gpu=False),
        "gpu_read": dict(src_gpu=True, dst_gpu=False),     # GPU-outbound
    }
    for name, kw in cases.items():
        bw = net.bandwidth(big, **kw)
        rows.append({"bench": "bandwidth", "metric": f"{name}_GBps",
                     "value": bw / 1e9,
                     "note": "GPU-outbound read-capped" if name == "gpu_read"
                     else "saturates link"})
    # curve points (Fig 3c x-axis)
    for lg in (12, 14, 16, 18, 20, 22):
        n = 1 << lg
        rows.append({"bench": "bandwidth",
                     "metric": f"gg_p2p_bw_{n>>10}KiB_GBps",
                     "value": net.bandwidth(n, src_gpu=False, dst_gpu=True)
                     / 1e9, "note": ""})
    # collective goodput on the torus, priced from the fabric schedule
    # (replaces the old hand-rolled 2(N-1)/N ring arithmetic)
    for name, torus, axes in (("ring8", Torus((8,)), ("x",)),
                              ("torus4x4x4", Torus((4, 4, 4)),
                               ("x", "y", "z"))):
        sched = fabric.lower_all_reduce(torus, axes)
        rows.append({"bench": "bandwidth",
                     "metric": f"allreduce_{name}_algbw_GBps",
                     "value": fabric.algorithmic_bandwidth(sched, big, net)
                     / 1e9,
                     "note": f"{sched.rounds}-round fabric schedule"})
    return rows


def check(rows) -> list[str]:
    errs = []
    vals = {r["metric"]: r["value"] for r in rows}
    if not 2.0 <= vals["link_limit_GBps"] <= 2.4:
        errs.append(f"link limit {vals['link_limit_GBps']:.2f} not ~2.2")
    for k in ("cpu_write_GBps", "gpu_write_GBps", "cpu_read_GBps"):
        if vals[k] < 0.85 * vals["link_limit_GBps"]:
            errs.append(f"{k}={vals[k]:.2f} does not saturate link")
    if not 1.2 <= vals["gpu_read_GBps"] <= 1.6:
        errs.append(f"gpu_read {vals['gpu_read_GBps']:.2f} not ~1.4")
    # full-duplex rings can beat one link direction, but never both
    for k in ("allreduce_ring8_algbw_GBps", "allreduce_torus4x4x4_algbw_GBps"):
        if not 0 < vals[k] < 2 * vals["link_limit_GBps"]:
            errs.append(f"{k}={vals[k]:.2f} outside (0, 2x link limit)")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
