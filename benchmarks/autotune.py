"""Fabric design-space autotuner — searched, packet-verified configs vs
the hand-picked defaults every earlier benchmark ran.

The ArchGym-style pipeline (``repro.core.fabric.autotune``): a gym
environment replays a fixed serving workload (chained decode-step TP
all-reduces + two bulk migration PUTs + control descriptors) and search
agents tune torus shape, per-class QoS weights/credit fractions, stripe
count and route policy.  The inner loop prices every candidate on the
**fluid** tier (PR 6, ~150x cheaper); only the top-k finalists and the
default are re-scored on the **packet** oracle, and the winner is
declared on packet numbers alone.

Gated claims:

1. **``autotune_gain``** (gated, higher-is-better, tol 0.15): the
   searched config's packet-verified objective beats the pre-QoS
   hand-picked default (squarest torus, single-FIFO link, dimension-
   ordered routes, no striping) by >= 15% — the acceptance bar; in
   practice the search rediscovers-and-refines the PR-5 QoS + striping
   operating point for >= 2x.
2. **``autotune_search_determinism``** (gated, higher-is-better, tol 0):
   re-running the budgeted search with the same seed reproduces the
   bitwise-identical winner config (1.0 = identical, 0.0 = drift) — the
   property that makes ``best_configs.json`` a reviewable artifact.
3. **``autotune_fluid_packet_agreement``** (checked <= 0.10): the
   winner's fluid score is within 10% of its packet re-score — the
   fidelity contract that justifies running the inner loop fluid.
4. **``autotune_train_gain``** (checked >= 0.95): the training replay's
   searched bucket size is no worse than the hand default 4 MB (the
   carried "sim-driven bucket sizing" item) — usually a small win, since
   4 MB was already near the knee.

The winning configs persist as ``best_configs.json`` (the artifact the
nightly lane uploads and ``TrainerConfig``/``ServingCluster`` load by
default).  ``AUTOTUNE_FAST=1`` (the CI fast lane) caps the search at 20
steps with the genetic agent only; ``AUTOTUNE_NIGHTLY=1`` widens every
budget.  ``BENCH_SEED`` (set by ``benchmarks/run.py --seed``) seeds the
whole pipeline.
"""
from __future__ import annotations

import json
import os
import time

from repro.core.fabric import autotune as at

N_NODES = 16
FAST_WALL_BUDGET_S = 120.0     # fast-lane wall bar enforced by check()


def _lane() -> str:
    if os.environ.get("AUTOTUNE_FAST", "0") == "1":
        return "fast"
    if os.environ.get("AUTOTUNE_NIGHTLY", "0") == "1":
        return "nightly"
    return "full"


# per-lane budgets: (serving steps per agent, serving agents, top-k
# finalists, train steps)
_BUDGETS = {
    "fast": (20, ("genetic",), 2, 8),
    "full": (40, ("random_walk", "genetic", "gp_bo"), 3, 16),
    "nightly": (120, ("random_walk", "genetic", "gp_bo"), 4, 40),
}


def _seed() -> int:
    return int(os.environ.get("BENCH_SEED", "0"))


def _entry(workload: str, winner, packet, fluid, default_packet,
           results) -> dict:
    return {
        "workload": workload,
        "config": winner.to_jsonable(),
        "objective_packet_ms": packet * 1e3,
        "objective_fluid_ms": fluid * 1e3,
        "default_objective_packet_ms": default_packet * 1e3,
        "gain_packet": default_packet / packet,
        "searchers": [r.summary() for r in results],
    }


def run() -> list[dict]:
    lane = _lane()
    steps, agent_names, topk, train_steps = _BUDGETS[lane]
    seed = _seed()
    t_all = time.perf_counter()

    space = at.ConfigSpace(N_NODES)
    env = at.FabricEnv(space, at.serving_replay(N_NODES), fidelity="fluid")
    default = space.default()
    default_fluid = env.score(default).objective_s
    default_packet = env.score(default, fidelity="packet").objective_s

    # -- inner loop: every agent searches on the fluid tier ------------------
    results = [at.search(env, at.AGENTS[name](), steps=steps, seed=seed + i)
               for i, name in enumerate(agent_names)]
    evals = sum(r.steps for r in results)

    # -- finalists re-scored on the packet oracle; winner = best packet ------
    finals = at.finalists(results, k=topk)
    packet_reports = at.rescore(env, finals, fidelity="packet")
    widx = min(range(len(finals)),
               key=lambda i: packet_reports[i].objective_s)
    winner = finals[widx]
    winner_packet = packet_reports[widx].objective_s
    winner_fluid = env.score(winner).objective_s
    gain = default_packet / winner_packet
    agreement = abs(winner_fluid - winner_packet) / winner_packet

    # -- determinism: same seed, same agent -> bitwise-identical winner ------
    redo = at.search(env, at.AGENTS[agent_names[0]](), steps=steps,
                     seed=seed)
    deterministic = float(
        json.dumps(redo.best_config.to_jsonable(), sort_keys=True)
        == json.dumps(results[0].best_config.to_jsonable(), sort_keys=True))

    # -- training replay: the sim-driven bucket-sizing inner objective -------
    tenv = at.FabricEnv(space, at.training_replay(N_NODES),
                        fidelity="fluid")
    tdefault_packet = tenv.score(default, fidelity="packet").objective_s
    tres = at.search(tenv, at.GeneticAgent(), steps=train_steps, seed=seed)
    tfinals = at.finalists(tres, k=2)
    treports = at.rescore(tenv, tfinals, fidelity="packet")
    tidx = min(range(len(tfinals)), key=lambda i: treports[i].objective_s)
    twinner, twinner_packet = tfinals[tidx], treports[tidx].objective_s
    train_gain = tdefault_packet / twinner_packet

    # -- pin the artifact -----------------------------------------------------
    artifact = at.save_best_configs({
        "serving": _entry("serving", winner, winner_packet, winner_fluid,
                          default_packet, results),
        "train": _entry("train", twinner, twinner_packet,
                        tres.best_objective_s, tdefault_packet, [tres]),
    })
    wall = time.perf_counter() - t_all

    per_agent = [
        {"bench": "autotune", "metric": f"best_objective_{r.agent}_ms",
         "value": r.best_objective_s * 1e3,
         "note": f"{r.steps} fluid evals in {r.wall_s:.1f}s "
                 f"({lane} lane, seed {r.seed})"}
        for r in results]

    return [
        {"bench": "autotune", "metric": "autotune_gain", "value": gain,
         "gate": "higher", "tol": 0.15,
         "note": f"default {default_packet * 1e3:.2f} ms -> searched "
                 f"{winner_packet * 1e3:.2f} ms on the packet oracle "
                 f"({lane} lane; bar >= 1.15)"},
        {"bench": "autotune", "metric": "autotune_search_determinism",
         "value": deterministic, "gate": "higher", "tol": 0.0,
         "note": "same seed -> bitwise-identical winner config"},
        {"bench": "autotune", "metric": "autotune_fluid_packet_agreement",
         "value": agreement,
         "note": "winner |fluid - packet| / packet (contract: <= 0.10)"},
        {"bench": "autotune", "metric": "autotune_default_objective_ms",
         "value": default_packet * 1e3,
         "note": f"pre-QoS hand default {default.torus_dims}, FIFO link, "
                 "hop routes (packet-verified)"},
        {"bench": "autotune", "metric": "autotune_best_objective_ms",
         "value": winner_packet * 1e3,
         "note": f"winner {winner.torus_dims} "
                 f"{'FIFO' if winner.qos_single else 'QoS'} "
                 f"{winner.route_policy} k={winner.stripe_k} "
                 "(packet-verified)"},
        {"bench": "autotune", "metric": "autotune_evals",
         "value": float(evals),
         "note": f"fluid inner-loop evaluations across "
                 f"{len(agent_names)} agent(s)"},
        {"bench": "autotune", "metric": "autotune_train_gain",
         "value": train_gain,
         "note": f"bucketed reduce-scatter: default 4 MB "
                 f"{tdefault_packet * 1e3:.2f} ms -> searched "
                 f"{twinner.bucket_mb:.2f} MB {twinner_packet * 1e3:.2f} ms "
                 "(packet-verified; the sim-driven bucket-sizing item)"},
        {"bench": "autotune", "metric": "autotune_bucket_mb",
         "value": twinner.bucket_mb,
         "note": "searched gradient-bucket byte target (train replay)"},
        {"bench": "autotune", "metric": "autotune_wall_s", "value": wall,
         "note": f"whole pipeline ({lane} lane) incl. packet re-scores; "
                 f"artifact: {os.path.basename(artifact)}"},
    ] + per_agent


def check(rows: list[dict]) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    errs = []
    if vals["autotune_gain"] < 1.15:
        errs.append(f"searched config must beat the hand default by >= 15% "
                    f"on the packet oracle; gain {vals['autotune_gain']:.3f}")
    if vals["autotune_search_determinism"] != 1.0:
        errs.append("same-seed search must reproduce the bitwise-identical "
                    "winner config")
    if vals["autotune_fluid_packet_agreement"] > 0.10:
        errs.append(f"winner fluid score must agree with its packet "
                    f"re-score within 10%; "
                    f"got {vals['autotune_fluid_packet_agreement']:.3f}")
    if vals["autotune_train_gain"] < 0.95:
        errs.append(f"searched bucket size must not lose to the 4 MB hand "
                    f"default; train gain {vals['autotune_train_gain']:.3f}")
    if _lane() == "fast" and vals["autotune_wall_s"] > FAST_WALL_BUDGET_S:
        errs.append(f"fast-lane smoke must stay under "
                    f"{FAST_WALL_BUDGET_S:.0f}s wall; "
                    f"took {vals['autotune_wall_s']:.1f}s")
    return errs


if __name__ == "__main__":
    for row in run():
        print(f"{row['metric']:40s} {row['value']:12.4f}  "
              f"{row.get('note', '')}")
    problems = check(run())
    raise SystemExit(0 if not problems else f"FAIL: {problems}")
