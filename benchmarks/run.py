"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all benches, CSV
  PYTHONPATH=src python -m benchmarks.run latency    # one bench

Each module exposes ``run() -> [rows]`` and ``check(rows) -> [errors]``;
check() validates the paper's quantitative claims against our model and the
exit code reflects any violation — this is the reproduction gate.
"""
from __future__ import annotations

import csv
import importlib
import io
import sys
import time

MODULES = ["apelink_eff", "dma_overlap", "tlb", "latency", "bandwidth",
           "fabric_cost", "lofamo", "nextgen", "roofline"]


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    names = argv or MODULES
    all_rows, all_errs = [], []
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.perf_counter()
        rows = mod.run()
        dt = time.perf_counter() - t0
        errs = mod.check(rows) if hasattr(mod, "check") else []
        all_rows += rows
        all_errs += [f"{name}: {e}" for e in errs]
        status = "OK " if not errs else "FAIL"
        print(f"[{status}] {name:<12s} {len(rows):3d} rows  {dt:6.2f}s",
              flush=True)

    buf = io.StringIO()
    w = csv.writer(buf)
    w.writerow(["bench", "metric", "value", "note"])
    for r in all_rows:
        w.writerow([r["bench"], r["metric"], r["value"], r.get("note", "")])
    print()
    print(buf.getvalue())
    if all_errs:
        print("PAPER-CLAIM CHECK FAILURES:", file=sys.stderr)
        for e in all_errs:
            print("  ", e, file=sys.stderr)
        return 1
    print(f"all paper-claim checks passed ({len(all_rows)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
