"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all benches, CSV
  PYTHONPATH=src python -m benchmarks.run latency    # one bench
  PYTHONPATH=src python -m benchmarks.run --only contention   # same, for
                                                     # fast local iteration
  PYTHONPATH=src python -m benchmarks.run --profile contention  # + cProfile
                                                     # top-20 per module
  PYTHONPATH=src python -m benchmarks.run --seed 7   # reseed every module
                                                     # (default 0; exported
                                                     # as $BENCH_SEED)

Each module exposes ``run() -> [rows]`` and ``check(rows) -> [errors]``;
check() validates the paper's quantitative claims against our model and the
exit code reflects any violation — this is the reproduction gate.

Every invocation also appends a ``BENCH_<n>.json`` snapshot (per-metric
values, per-module timings, failures) to the repo root — the input of
``scripts/bench_gate.py``, which diffs the newest snapshot against the
previous one and fails CI on >10% regression of gated metrics (rows that
carry a ``"gate": "higher"|"lower"`` direction).  Set ``BENCH_DIR`` to
redirect the snapshots or ``BENCH_JSON=0`` to skip writing one.
"""
from __future__ import annotations

import csv
import importlib
import io
import json
import os
import random
import re
import sys
import time

import numpy as np

MODULES = ["apelink_eff", "dma_overlap", "tlb", "latency", "bandwidth",
           "fabric_cost", "overlap", "migration", "contention", "qos",
           "lofamo", "nextgen", "roofline", "simscale", "autotune",
           "trace_replay", "qosctl", "telemetry"]

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_dir() -> str:
    return os.environ.get("BENCH_DIR") or REPO


def list_snapshots(dirname: str) -> list[tuple[int, str]]:
    """(seq, path) pairs of existing BENCH_<n>.json, ascending."""
    out = []
    try:
        names = os.listdir(dirname)
    except FileNotFoundError:
        return []
    for name in names:
        m = re.fullmatch(r"BENCH_(\d+)\.json", name)
        if m:
            out.append((int(m.group(1)), os.path.join(dirname, name)))
    return sorted(out)


KEEP_SNAPSHOTS = 5   # the gate reads the newest 2; a few more for humans


def write_snapshot(names, rows, timings, errors, seed=0) -> str | None:
    if os.environ.get("BENCH_JSON", "1") == "0":
        return None
    d = bench_dir()
    os.makedirs(d, exist_ok=True)
    existing = list_snapshots(d)
    seq = (existing[-1][0] + 1) if existing else 1
    path = os.path.join(d, f"BENCH_{seq}.json")
    payload = {
        "seq": seq,
        "created_unix": time.time(),
        "seed": seed,
        "modules": list(names),
        "timings_s": timings,
        "rows": rows,
        "failures": errors,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    # bound the history (locally and in the CI rolling cache)
    for _, old in existing[: -(KEEP_SNAPSHOTS - 1) or None]:
        try:
            os.remove(old)
        except OSError:
            pass
    return path


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    profile = "--profile" in argv
    if profile:
        argv.remove("--profile")
    # --seed N: one seed threaded into EVERY module — exported as
    # $BENCH_SEED (modules with their own generators read it: simscale's
    # workload rng, autotune's search agents) and applied to the global
    # random/numpy streams before each module, so a snapshot is exactly
    # reproducible across CI runs from its recorded seed
    seed = 0
    if "--seed" in argv:
        i = argv.index("--seed")
        if i + 1 >= len(argv):
            print("--seed requires an integer", file=sys.stderr)
            return 2
        try:
            seed = int(argv[i + 1])
        except ValueError:
            print(f"--seed requires an integer, got {argv[i + 1]!r}",
                  file=sys.stderr)
            return 2
        del argv[i:i + 2]
    os.environ["BENCH_SEED"] = str(seed)
    if "--only" in argv:
        # --only <module>: run exactly one module (fast local iteration);
        # equivalent to the positional form but self-documenting in CI logs
        i = argv.index("--only")
        if i + 1 >= len(argv):
            print("--only requires a module name", file=sys.stderr)
            return 2
        names = [argv[i + 1]]
        extra = argv[:i] + argv[i + 2:]
        if extra:
            print(f"--only is exclusive; unexpected args {extra}",
                  file=sys.stderr)
            return 2
    else:
        names = argv or MODULES
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        print(f"unknown bench module(s) {unknown}; known: {MODULES}",
              file=sys.stderr)
        return 2
    all_rows, all_errs = [], []
    timings: dict[str, float] = {}
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        random.seed(seed)
        np.random.seed(seed % (1 << 32))
        t0 = time.perf_counter()
        if profile:
            # per-module hot-spot profile: where does the bench's wall
            # time actually go (the sim event loop? route BFS? jit?)
            import cProfile
            import pstats
            prof = cProfile.Profile()
            rows = prof.runcall(mod.run)
            dt = time.perf_counter() - t0
            print(f"--- profile: {name} (top 20 by cumulative time) ---")
            pstats.Stats(prof).sort_stats("cumulative").print_stats(20)
        else:
            rows = mod.run()
            dt = time.perf_counter() - t0
        timings[name] = dt
        errs = mod.check(rows) if hasattr(mod, "check") else []
        all_rows += rows
        all_errs += [f"{name}: {e}" for e in errs]
        status = "OK " if not errs else "FAIL"
        print(f"[{status}] {name:<12s} {len(rows):3d} rows  {dt:6.2f}s",
              flush=True)

    buf = io.StringIO()
    w = csv.writer(buf)
    w.writerow(["bench", "metric", "value", "note"])
    for r in all_rows:
        w.writerow([r["bench"], r["metric"], r["value"], r.get("note", "")])
    print()
    print(buf.getvalue())
    snap = write_snapshot(names, all_rows, timings, all_errs, seed=seed)
    if snap:
        print(f"bench snapshot: {snap}")
    if all_errs:
        print("PAPER-CLAIM CHECK FAILURES:", file=sys.stderr)
        for e in all_errs:
            print("  ", e, file=sys.stderr)
        return 1
    print(f"all paper-claim checks passed ({len(all_rows)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
