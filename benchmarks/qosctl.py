"""Closed-loop QoS — the SLO feedback loop over the live fabric policy.

Static arbitration weights (the autotuner's pick) are open-loop: under
an overload trace they keep paying DECODE its full ~27:1 share even
once decode is queue-bound, starving the BULK KV migrations that would
relieve the hotspot.  ``fabric.QosController`` closes the loop: once
per replay window it reads the measured per-token p99 and the per-class
byte deltas (``class_stats(since=...)``) and retunes ``QosPolicy``
through ``sim.set_qos`` — boosting DECODE only inside the SLO's
at-risk band, releasing toward a floor when safe or breached.

Gated claims:

1. **``closed_loop_gain``** (higher): on an identical seeded overload
   trace — long-context sessions (Zipf prompts 256-448 tokens, so KV
   migrations are tens of MB), short decodes, sustained DECODE-class
   cross-traffic injected every rebalance hook — the controller beats
   the static autotuned weights by >= 1.10x on p99 per-token decode
   latency.  The mechanism is *relief*: releasing the DECODE boost to
   the floor multiplies the BULK arbitration share, migration PUTs
   drain ~3.6x faster, and the destination nodes resume decoding
   sooner.  ``closed_loop_ttft_ratio`` must not regress (the TTFT tail
   is admission/prefill queueing that precedes the first retune).
2. **``preemption_latency``** (higher): with descriptor-granular
   command queues (``descriptor_bytes=256 KiB``) a DECODE packet
   arriving mid-drain of a 32 MB BULK PUT waits at most one descriptor
   at the host interface instead of the whole DMA — >= 2x drop in
   measured wait (the §2.1 prefetchable-queue argument, measured).
3. **``controller_quiescence_maxdiff``** (== 0): on a no-overload
   trace the controller never fires (it is latched quiescent until the
   first at-risk window), and the replay metrics are *bitwise
   identical* to the same replay without a controller; ``n_retunes``
   must be exactly 0.

``QOSCTL_FAST=1`` (the CI fast lane) skips the informational
default-weights arm; all three gated rows always run.
"""
from __future__ import annotations

import os
import time

from repro.configs import get_config
from repro.core import fabric
from repro.core.apelink import NetModel
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus
from repro.serving.cluster import ServingCluster, SloPolicy
from repro.serving.trace import TraceConfig, generate_trace, replay

N_PARAMS = 7.0e9
T_TOK_S = 2.0 * N_PARAMS / 1.6e12     # analytic decode step, 8.75 ms
SMOKE_DIMS = (4, 4)
SMOKE_SEED = 11

GAIN_BAR = 1.10                       # closed-loop vs static, tpt p99
PREEMPT_BAR = 2.0                     # mono vs descriptor probe wait
BUDGET_MS = 200_000.0                 # whole-module wall budget

# the overload scenario: long-context sessions => KV migrations of tens
# of MB; short outputs => a migration stall is amortised over few
# tokens; DECODE cross-traffic big enough to outlast every PUT under
# the static weights (injected at each rebalance hook via replay's
# ``background`` callback)
CHUNK_BYTES = 1536e6
HOOK_S = 0.25
MEAN_OUT_TOK = 6.0                    # E[output] of the 4-10 Zipf mix
DESCRIPTOR_BYTES = 256 * 1024


def _base_qos() -> fabric.QosPolicy:
    tuned = fabric.autotune.tuned_config("serving")
    return tuned.qos() if tuned is not None else fabric.QosPolicy()


def _cluster(qos, *, token_target_s, queue_limit, max_queue_wait_s):
    return ServingCluster(
        get_config("deepseek-7b"), None, torus=Torus(SMOKE_DIMS),
        modelled=True, n_params=N_PARAMS, tp_axes=None, fidelity="fluid",
        max_batch=4, max_seq=576, page_tokens=16, chunked_prefill=True,
        qos=qos, descriptor_bytes=DESCRIPTOR_BYTES,
        slo=SloPolicy(token_target_s=token_target_s,
                      queue_limit=queue_limit,
                      max_queue_wait_s=max_queue_wait_s))


def _overload_trace(n_requests, seed):
    rate = 0.5 * 16 / (T_TOK_S * MEAN_OUT_TOK)
    return generate_trace(TraceConfig(
        n_requests=n_requests, seed=seed, base_rate=rate,
        diurnal_period_s=n_requests / (2 * rate),
        burst_size=4.0, burst_rate=0.3,
        prompt_min=256, prompt_max=448, max_context=512,
        output_min=4, output_max=10))


def _light_trace(n_requests, seed):
    tokens_per_req = 50.8             # default Zipf mix (measured)
    rate = 0.30 * 16 / (T_TOK_S * tokens_per_req)
    return generate_trace(TraceConfig(
        n_requests=n_requests, seed=seed, base_rate=rate,
        diurnal_period_s=n_requests / (2 * rate)))


def _background(cluster, t) -> None:
    """Per-hook DECODE cross-traffic on every directed link: the state
    the static weights were not tuned for.  The event-driven replay
    otherwise serialises the fabric (a PUT runs the shared timeline to
    completion), so this is what makes migrations actually contend."""
    for r in range(cluster.torus.size):
        for nb in cluster.torus.neighbors(r):
            cluster.sim.inject(r, nb, CHUNK_BYTES,
                               cls=fabric.TrafficClass.DECODE)


def _closed_loop(base_qos, trace, *, controlled):
    cl = _cluster(base_qos, token_target_s=0.020, queue_limit=24,
                  max_queue_wait_s=0.5)
    ctl = fabric.QosController(base_qos, cl.slo) if controlled else None
    rep = replay(cl, trace, rebalance="proactive", qos_ctl=ctl,
                 background=_background, rebalance_every_s=HOOK_S)
    return rep, ctl


# --- descriptor preemption probe ------------------------------------------
_PAGE = 65536
_NPAGES = 512                         # 32 MB BULK drain


def _probe_endpoints(descriptor_bytes):
    torus = Torus(SMOKE_DIMS)
    net = NetModel()
    sim = fabric.FabricSim(torus, net, qos=fabric.QosPolicy())
    src = RdmaEndpoint(torus, rank=0, net=net, sim=sim,
                       descriptor_bytes=descriptor_bytes)
    dst = RdmaEndpoint(torus, rank=1, net=net, sim=sim)
    reg, dreg = src.register(_NPAGES * _PAGE), dst.register(_NPAGES * _PAGE)
    src.translate_region(reg)         # warm the TLB: pass 2 is hot
    dst.translate_region(dreg)
    return sim, src, dst, reg, dreg


def _probe_wait(descriptor_bytes) -> float:
    """DECODE wait at the source host interface when it arrives a
    quarter of the way into a 32 MB BULK DMA drain."""
    # pass 1 on a twin fabric: learn where mid-drain lands
    sim, src, dst, reg, dreg = _probe_endpoints(descriptor_bytes)
    t_hot = src.translate_region(reg)
    src.put_pages(dst.rank, reg, list(range(_NPAGES)), page_nbytes=_PAGE,
                  dst_endpoint=dst, dst_region=dreg,
                  dst_pages=list(range(_NPAGES)))
    t_mid = t_hot + 0.25 * src.last_put_report["dma_s"]
    # pass 2: the timed probe
    sim, src, dst, reg, dreg = _probe_endpoints(descriptor_bytes)
    fin = sim.occupy(("hostif", 0), 50e-6, start_s=t_mid,
                     cls=fabric.TrafficClass.DECODE, label="decode_probe")
    src.put_pages(dst.rank, reg, list(range(_NPAGES)), page_nbytes=_PAGE,
                  dst_endpoint=dst, dst_region=dreg,
                  dst_pages=list(range(_NPAGES)))
    return sim.finish_s(fin) - t_mid - 50e-6


def _restriped_count() -> int:
    """Mid-flight re-striping on a congested primary: siblings issued."""
    sim, src, dst, reg, dreg = _probe_endpoints(None)
    torus = Torus(SMOKE_DIMS)
    plan = fabric.striped_routes(sim, 0, 1, _NPAGES * _PAGE, k=3)
    stripes = []
    for (route, _), c in zip(plan, fabric.stripe_counts(plan, _NPAGES)):
        if c > 0:
            stripes.append((fabric.lower_route(torus, route), c * _PAGE))
    for i in range(8):                # hammer the direct 0->1 link
        sim.inject(0, 1, 4e6, start_s=1e-3 + i * 1e-4,
                   cls=fabric.TrafficClass.DECODE)
    src.put_pages(dst.rank, reg, list(range(_NPAGES)), page_nbytes=_PAGE,
                  dst_endpoint=dst, dst_region=dreg,
                  dst_pages=list(range(_NPAGES)),
                  stripes=stripes, restripe_s=4e-3)
    return int(src.last_put_report["restriped"])


def run() -> list[dict]:
    fast = os.environ.get("QOSCTL_FAST", "0") == "1"
    seed = int(os.environ.get("BENCH_SEED", "0"))
    t0 = time.perf_counter()
    rows: list[dict] = []

    # --- closed loop vs static on the identical overload trace --------
    base_qos = _base_qos()
    tro = _overload_trace(64, SMOKE_SEED + seed)
    sta, _ = _closed_loop(base_qos, tro, controlled=False)
    dyn, ctl = _closed_loop(base_qos, tro, controlled=True)
    rows += [
        {"bench": "qosctl", "metric": "closed_loop_gain",
         "value": sta.tpt_p99_s / dyn.tpt_p99_s,
         "gate": "higher", "tol": 0.25,
         "note": "static tpt p99 / closed-loop tpt p99 on the identical "
                 f"overload trace (bar: >= {GAIN_BAR}x); static="
                 f"{sta.tpt_p99_s * 1e3:.1f} ms, closed-loop="
                 f"{dyn.tpt_p99_s * 1e3:.1f} ms"},
        {"bench": "qosctl", "metric": "closed_loop_ttft_ratio",
         "value": sta.ttft_p99_s / dyn.ttft_p99_s,
         "gate": "higher", "tol": 0.10,
         "note": "static ttft p99 / closed-loop ttft p99 (must be >= 1: "
                 "the controller may not trade TTFT for tpt)"},
        {"bench": "qosctl", "metric": "closed_loop_retunes",
         "value": float(ctl.n_retunes),
         "note": f"set_qos calls issued; {ctl.describe()}"},
    ]

    # --- informational: the same loop over the un-tuned defaults ------
    if not fast:
        dflt = fabric.QosPolicy()
        dsta, _ = _closed_loop(dflt, tro, controlled=False)
        ddyn, _ = _closed_loop(dflt, tro, controlled=True)
        rows.append(
            {"bench": "qosctl", "metric": "closed_loop_gain_default",
             "value": dsta.tpt_p99_s / ddyn.tpt_p99_s,
             "note": "same gain over DEFAULT_WEIGHTS instead of the "
                     "autotuned baseline (informational)"})

    # --- descriptor-granular preemption -------------------------------
    w_mono = _probe_wait(None)
    w_desc = _probe_wait(DESCRIPTOR_BYTES)
    eps = 1e-6                        # 1 us floor: the descriptor path
    #                                   can land exactly on a boundary
    rows += [
        {"bench": "qosctl", "metric": "preemption_latency",
         "value": (w_mono + eps) / (w_desc + eps),
         "gate": "higher", "tol": 0.25,
         "note": "DECODE host-interface wait mid-drain of a 32 MB BULK "
                 f"PUT, monolithic / {DESCRIPTOR_BYTES // 1024} KiB "
                 f"descriptors (bar: >= {PREEMPT_BAR}x); mono="
                 f"{w_mono * 1e3:.3f} ms, desc={w_desc * 1e3:.3f} ms"},
        {"bench": "qosctl", "metric": "restriped_descriptors",
         "value": float(_restriped_count()),
         "note": "sibling descriptors issued when a striped 32 MB PUT "
                 "re-splits its remainder across re-probed routes at a "
                 "4 ms checkpoint (congested primary leg)"},
    ]

    # --- quiescence: controller attached, never fires ------------------
    trl = _light_trace(32, SMOKE_SEED + seed)
    qoff, _ = _quiescent(base_qos, trl, controlled=False)
    qon, qctl = _quiescent(base_qos, trl, controlled=True)
    m0, m1 = qoff.metrics(), qon.metrics()
    rows += [
        {"bench": "qosctl", "metric": "controller_quiescence_maxdiff",
         "value": max(abs(m0[k] - m1[k]) for k in m0),
         "note": "max |metric delta| of a no-overload replay with vs "
                 "without the controller attached (must be exactly 0: "
                 "the controller is latched quiescent)"},
        {"bench": "qosctl", "metric": "quiescent_retunes",
         "value": float(qctl.n_retunes),
         "note": "set_qos calls on the no-overload trace (must be 0); "
                 f"{qctl.describe()}"},
    ]

    rows.append(
        {"bench": "qosctl", "metric": "qosctl_wall_ms",
         "value": (time.perf_counter() - t0) * 1e3,
         "note": f"whole module (budget {BUDGET_MS:.0f} ms)"})
    return rows


def _quiescent(base_qos, trace, *, controlled):
    cl = _cluster(base_qos, token_target_s=0.066, queue_limit=256,
                  max_queue_wait_s=1.0)
    ctl = fabric.QosController(base_qos, cl.slo) if controlled else None
    rep = replay(cl, trace, rebalance="proactive", qos_ctl=ctl,
                 rebalance_every_s=HOOK_S)
    return rep, ctl


def check(rows) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    errs = []
    if vals["closed_loop_gain"] < GAIN_BAR:
        errs.append(f"closed_loop_gain = {vals['closed_loop_gain']:.3f}x: "
                    "the closed-loop controller must beat the static "
                    f"autotuned weights by >= {GAIN_BAR}x on p99 "
                    "per-token latency")
    if vals["closed_loop_ttft_ratio"] < 1.0 - 1e-9:
        errs.append(f"closed_loop_ttft_ratio = "
                    f"{vals['closed_loop_ttft_ratio']:.4f}: the "
                    "controller regressed p99 TTFT")
    if vals["closed_loop_retunes"] < 1.0:
        errs.append("the controller never retuned on the overload trace "
                    "— the gain row is not measuring the closed loop")
    if vals["preemption_latency"] < PREEMPT_BAR:
        errs.append(f"preemption_latency = "
                    f"{vals['preemption_latency']:.2f}x: descriptor-"
                    "granular queues must cut the mid-drain DECODE wait "
                    f"by >= {PREEMPT_BAR}x")
    if vals["restriped_descriptors"] < 1.0:
        errs.append("no sibling descriptors issued — mid-flight "
                    "re-striping did not engage on the congested leg")
    if vals["controller_quiescence_maxdiff"] != 0.0:
        errs.append(f"quiescence broken: attaching an idle controller "
                    f"changed replay metrics by "
                    f"{vals['controller_quiescence_maxdiff']:.3g}")
    if vals["quiescent_retunes"] != 0.0:
        errs.append(f"{vals['quiescent_retunes']:.0f} retunes fired on "
                    "the no-overload trace (must be 0)")
    if vals["qosctl_wall_ms"] > BUDGET_MS:
        errs.append(f"qosctl took {vals['qosctl_wall_ms']:.0f} ms, over "
                    f"the {BUDGET_MS:.0f} ms budget")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
