"""Fig 3a/3b — round-trip latency and GPU-GPU latency vs InfiniBand.

Reproduces the paper's headline latency numbers from the calibrated
NetModel:

  * GPU-to-GPU one-way latency with P2P:        ~8.2 us
  * same without P2P (host staging):            ~16.8 us
  * InfiniBand + MVAPICH on the same platform:  ~17.4 us
  * GPU involvement costs ~30% extra round-trip latency at small sizes
  * P2P advantage over IB holds up to ~128 KB (Fig 3b crossover)
"""
from __future__ import annotations

import numpy as np

from repro.core.apelink import NetModel


def run() -> list[dict]:
    net = NetModel()
    rows = []
    small = 32  # bytes: the small-message latency plateau

    l_p2p = net.latency(small, src_gpu=True, dst_gpu=True, p2p=True)
    l_staged = net.latency(small, src_gpu=True, dst_gpu=True, p2p=False)
    l_ib = net.latency(small, fabric="ib")
    l_hh = net.latency(small)
    rows += [
        {"bench": "latency", "metric": "gpu_gpu_p2p_us", "value": l_p2p * 1e6,
         "note": "paper ~8.2"},
        {"bench": "latency", "metric": "gpu_gpu_staged_us",
         "value": l_staged * 1e6, "note": "paper ~16.8"},
        {"bench": "latency", "metric": "gpu_gpu_ib_us", "value": l_ib * 1e6,
         "note": "paper ~17.4"},
        {"bench": "latency", "metric": "host_host_us", "value": l_hh * 1e6,
         "note": "host-bound baseline"},
    ]
    # Fig 3a: round-trip for all endpoint combinations
    for name, (sg, dg) in {"HH": (False, False), "GH": (True, False),
                           "HG": (False, True), "GG": (True, True)}.items():
        rt = net.roundtrip(small, src_gpu=sg, dst_gpu=dg)
        rows.append({"bench": "latency", "metric": f"roundtrip_{name}_us",
                     "value": rt * 1e6, "note": ""})
    gg = next(r["value"] for r in rows if r["metric"] == "roundtrip_GG_us")
    hh = next(r["value"] for r in rows if r["metric"] == "roundtrip_HH_us")
    rows.append({"bench": "latency", "metric": "gpu_latency_penalty",
                 "value": gg / hh - 1.0, "note": "paper ~30% (one endpoint "
                 "~15%, both ~30%)"})
    # Fig 3b: APEnet+ P2P vs IB crossover
    crossover = None
    for nbytes in 2 ** np.arange(5, 22):
        a = net.latency(int(nbytes), src_gpu=True, dst_gpu=True, p2p=True)
        b = net.latency(int(nbytes), fabric="ib")
        if a > b and crossover is None:
            crossover = int(nbytes)
        if nbytes in (1024, 16384, 131072, 1 << 20):
            rows.append({"bench": "latency",
                         "metric": f"p2p_vs_ib_at_{int(nbytes)>>10}KiB",
                         "value": b / a,
                         "note": ">1 means APEnet+ P2P wins"})
    rows.append({"bench": "latency", "metric": "p2p_ib_crossover_KiB",
                 "value": (crossover or 0) / 1024,
                 "note": "paper: P2P wins up to ~128 KB"})
    return rows


def check(rows) -> list[str]:
    errs = []
    vals = {r["metric"]: r["value"] for r in rows}
    for key, want, tol in (("gpu_gpu_p2p_us", 8.2, 0.6),
                           ("gpu_gpu_staged_us", 16.8, 1.2),
                           ("gpu_gpu_ib_us", 17.4, 1.0)):
        if abs(vals[key] - want) > tol:
            errs.append(f"{key}={vals[key]:.1f} vs paper {want}")
    if not 0.2 <= vals["gpu_latency_penalty"] <= 0.4:
        errs.append(f"GPU latency penalty {vals['gpu_latency_penalty']:.2f} "
                    "not ~0.3")
    if not 64 <= vals["p2p_ib_crossover_KiB"] <= 512:
        errs.append(f"crossover {vals['p2p_ib_crossover_KiB']:.0f} KiB not "
                    "~128 KiB")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
