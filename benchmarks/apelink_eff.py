"""§2.3 — APElink transmission control efficiency model.

Paper numbers reproduced here:
  * total protocol efficiency 0.784 at the operating point,
  * a channel able to sustain ~2.6 GB/s (28 Gbps raw, 8b/10b -> 2.8 GB/s
    channel; the paper quotes ~2.6 GB/s sustainable before protocol
    framing; x0.784 gives the ~2.2 GB/s Fig 3c plateau),
  * ~40 KB flow-control memory footprint per channel.

The analytic model is cross-checked against the bit-accurate word-stuffing
codec: framing overhead measured on real encoded packets must match eta(P).
"""
from __future__ import annotations

import numpy as np

from repro.core import apelink, hw


def run() -> list[dict]:
    rows = []
    eta = apelink.protocol_efficiency()
    rows.append({"bench": "apelink", "metric": "protocol_efficiency",
                 "value": eta, "note": "paper 0.784"})
    rows.append({"bench": "apelink", "metric": "channel_GBps",
                 "value": hw.APELINK_28G.channel_bandwidth / 1e9,
                 "note": "paper ~2.6-2.8 GB/s sustainable"})
    rows.append({"bench": "apelink", "metric": "sustained_GBps",
                 "value": apelink.sustained_bandwidth() / 1e9,
                 "note": "= channel x eta ~ 2.2"})
    rows.append({"bench": "apelink", "metric": "footprint_KB",
                 "value": apelink.channel_footprint_bytes() / 1024,
                 "note": "paper ~40 KB/channel"})
    # eta(P) sweep: packet-size knob of the framing protocol
    for p in (4, 8, 16, 32, 64, 256):
        rows.append({"bench": "apelink", "metric": f"eta_P{p}",
                     "value": apelink.protocol_efficiency(p), "note": ""})
    # codec-measured efficiency at the operating point must match the model
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 1 << 32, size=1 << 14, dtype=np.uint64) \
        .astype(np.uint32)
    meas = apelink.measured_efficiency(payload,
                                       apelink.DEFAULT_PAYLOAD_WORDS)
    rows.append({"bench": "apelink", "metric": "codec_measured_eff",
                 "value": meas, "note": "bit-accurate wire overhead"})
    return rows


def check(rows) -> list[str]:
    errs = []
    vals = {r["metric"]: r["value"] for r in rows}
    if abs(vals["protocol_efficiency"] - 0.784) > 1e-3:
        errs.append(f"eta={vals['protocol_efficiency']:.4f} != 0.784")
    if abs(vals["codec_measured_eff"] - vals["protocol_efficiency"]) > 0.01:
        errs.append("codec-measured efficiency diverges from model")
    if not 35 <= vals["footprint_KB"] <= 45:
        errs.append(f"footprint {vals['footprint_KB']:.1f} KB not ~40")
    if not 2.0 <= vals["sustained_GBps"] <= 2.4:
        errs.append(f"sustained {vals['sustained_GBps']:.2f} not ~2.2")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
