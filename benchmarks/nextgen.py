"""§6 — next-generation board study (28 nm FPGA: PCIe Gen3, 56 Gb/s links).

The paper's §6 upgrades become what-if rows:
  * PCIe Gen3 x8: ~7.9 GB/s raw host bandwidth, <1% encoding overhead,
  * 56 Gb/s QSFP+ links (14.1 Gb/s x 4 lanes); measured 45.2 Gb/s with
    40G-certified cables (11.3 Gb/s/lane),
  * effect of the link generation on the TPU roofline's collective term
    (scaling the ICI constant by the same 28G->56G ratio).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import apelink, hw

OUT = Path(__file__).resolve().parent / "out" / "dryrun"


def run() -> list[dict]:
    rows = []
    for spec in (hw.PCIE_GEN2_X8, hw.PCIE_GEN3_X8):
        rows.append({"bench": "nextgen", "metric": f"{spec.name}_GBps",
                     "value": spec.effective_bandwidth / 1e9,
                     "note": "paper Gen3: ~7.9 GB/s raw, <1% overhead"})
    rows.append({"bench": "nextgen", "metric": "gen3_encoding_overhead",
                 "value": 1 - hw.PCIE_GEN3_X8.encoding_efficiency,
                 "note": "128/130: <1% (Gen2: 20%)"})
    for link in (hw.APELINK_28G, hw.APELINK_45G, hw.APELINK_56G):
        rows.append({"bench": "nextgen", "metric": f"{link.name}_raw_Gbps",
                     "value": link.raw_bandwidth * 8 / 1e9,
                     "note": "paper: 28 / 45.2(meas) / 56"})
        rows.append({"bench": "nextgen",
                     "metric": f"{link.name}_sustained_GBps",
                     "value": apelink.sustained_bandwidth(link) / 1e9,
                     "note": "x encoding x eta"})
    # roofline what-if: collective term under a 2x (56G-class) ICI link,
    # averaged over the compiled dry-run cells present on disk
    cells = sorted(OUT.glob("*_pod.json"))
    if cells:
        scale = (hw.APELINK_56G.raw_bandwidth
                 / hw.APELINK_28G.raw_bandwidth)  # = 2.01
        worst = None
        for c in cells:
            d = json.loads(c.read_text())
            r = d["roofline"]
            if worst is None or r["collective_s"] > worst[1]["collective_s"]:
                worst = (d, r)
        d, r = worst
        t_now = max(r["compute_s"], r["memory_s"], r["collective_s"])
        t_up = max(r["compute_s"], r["memory_s"], r["collective_s"] / scale)
        rows.append({"bench": "nextgen", "metric": "worst_cell_speedup_2xICI",
                     "value": t_now / t_up,
                     "note": f"{d['arch']}x{d['shape']}: dominant-term model"})
    return rows


def check(rows) -> list[str]:
    errs = []
    vals = {r["metric"]: r["value"] for r in rows}
    if not 7.5 <= vals["pcie-gen3-x8_GBps"] <= 8.0:
        errs.append(f"Gen3 {vals['pcie-gen3-x8_GBps']:.2f} GB/s not ~7.9")
    if abs(vals["apelink-45g-meas_raw_Gbps"] - 45.2) > 0.1:
        errs.append("45.2 Gbps preliminary measurement not reproduced")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
