"""KV-page migration vs re-prefill — the serving cluster's wire-vs-stall
trade, priced on the paper's fabric model.

A running request's decode state is its KV-cache pages.  Moving the
request to another torus node therefore costs ONE bulk dimension-ordered
RDMA PUT (``RdmaEndpoint.put_pages`` over a ``fabric.lower_p2p``
schedule: both cards' TLB translations + host-interface DMA + multi-hop
wire).  The alternative — kill the slot and re-prefill the whole context
on the destination — is a monolithic prompt forward that stalls the
destination's running decode batch (modelled at the same paper-era GPU
rate ``benchmarks/overlap.py`` uses).

Modelled twin: a 7B-class decoder (L=32, 8 KV heads, hd=128, bf16 KV)
serving 2048-token contexts on a 4x4x4 APEnet+ torus — ~128 KB of KV per
token slot-wide, ~4 MB per 32-token page.

Gated claims:
  * ``migration_speedup`` (reprefill / migration, higher-is-better) — the
    acceptance bar: modelled migration cost < the decode stall it avoids;
  * a link fault on the route makes migration strictly slower (detour
    hops), but it must still beat re-prefill.
"""
from __future__ import annotations

from repro.core import fabric
from repro.core.hw import PAPER_GPU_EFF_FLOPS as GPU_EFF_FLOPS
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus
from repro.serving.cluster import reprefill_stall_s

TORUS = Torus((4, 4, 4))
N_PARAMS = 7_000_000_000
N_LAYERS = 32
N_KV_HEADS = 8
HEAD_DIM = 128
KV_ITEMSIZE = 2                       # bf16 K and V
PAGE_TOKENS = 32
CONTEXT = 2048

BYTES_PER_TOKEN = 2 * N_LAYERS * N_KV_HEADS * HEAD_DIM * KV_ITEMSIZE
PAGE_NBYTES = PAGE_TOKENS * BYTES_PER_TOKEN


def _migration_s(context: int, dst: int | None = None,
                 faults=None) -> tuple[float, int]:
    """(modelled seconds, route hops) for migrating a ``context``-token
    slot from the origin — the same ``put_pages`` call the cluster makes.
    Default destination is across the torus diameter."""
    if dst is None:
        dst = TORUS.rank((2, 2, 2))
    src, dst_ep = RdmaEndpoint(TORUS, 0), RdmaEndpoint(TORUS, dst)
    n_pages = -(-context // PAGE_TOKENS)
    region = src.register(n_pages * PAGE_NBYTES)
    dst_region = dst_ep.register(n_pages * PAGE_NBYTES)
    sched = fabric.lower_p2p(TORUS, 0, dst, faults=faults)
    t = src.put_pages(dst, region, list(range(n_pages)),
                      page_nbytes=PAGE_NBYTES, dst_endpoint=dst_ep,
                      dst_region=dst_region, schedule=sched)
    return t, sched.max_hops


def run() -> list[dict]:
    rows = []
    mig_s, hops = _migration_s(CONTEXT)
    pre_s = reprefill_stall_s(N_PARAMS, CONTEXT)
    rows += [
        {"bench": "migration", "metric": "kv_bytes_per_token",
         "value": BYTES_PER_TOKEN,
         "note": f"L={N_LAYERS} Hkv={N_KV_HEADS} hd={HEAD_DIM} bf16"},
        {"bench": "migration", "metric": "migration_ms",
         "value": mig_s * 1e3,
         "note": f"{CONTEXT}-token slot, {hops} hops "
                 "(TLB + DMA + dimension-ordered wire)"},
        {"bench": "migration", "metric": "reprefill_ms",
         "value": pre_s * 1e3,
         "note": f"2*P*T forward at {GPU_EFF_FLOPS / 1e12:.1f} TF/s — "
                 "the decode stall migration avoids"},
        {"bench": "migration", "metric": "migration_speedup",
         "value": pre_s / mig_s, "gate": "higher",
         "note": "avoided stall / modelled migration time (must be > 1)"},
    ]
    # context sweep: both sides scale ~linearly with T (re-prefill with
    # P*T FLOPs, the wire with T*bytes_per_token), so the advantage holds
    # across the whole serving range — the claim is "migration wins at
    # every context length", not a growth law
    for ctx in (256, 1024, 4096):
        m, _ = _migration_s(ctx)
        rows.append({"bench": "migration", "metric": f"speedup_at_{ctx}",
                     "value": reprefill_stall_s(N_PARAMS, ctx) / m,
                     "note": f"{m * 1e3:.2f} ms wire"})
    # fault reroute: migrate to the first-hop neighbour and kill the ONE
    # direct link — every surviving path is a genuine >1-hop BFS detour
    nbr = TORUS.rank((1, 0, 0))
    dead = fabric.FaultMap.normalized(links=[(0, nbr)])
    mig_n, hops_n = _migration_s(CONTEXT, dst=nbr)
    mig_f, hops_f = _migration_s(CONTEXT, dst=nbr, faults=dead)
    rows += [
        {"bench": "migration", "metric": "migration_neighbor_ms",
         "value": mig_n * 1e3, "note": f"healthy first-neighbour PUT, "
                                       f"{hops_n} hop"},
        {"bench": "migration", "metric": "migration_fault_ms",
         "value": mig_f * 1e3,
         "note": f"direct link dead: {hops_f}-hop BFS detour"},
        {"bench": "migration", "metric": "fault_detour_hops",
         "value": hops_f, "note": f"vs {hops_n} on the healthy fabric"},
        {"bench": "migration", "metric": "fault_speedup",
         "value": pre_s / mig_f, "gate": "higher",
         "note": "migration must beat re-prefill through the detour too"},
    ]
    return rows


def check(rows) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    errs = []
    if vals["migration_speedup"] <= 1.0:
        errs.append(
            f"modelled migration ({vals['migration_ms']:.3f} ms) does not "
            f"beat re-prefill ({vals['reprefill_ms']:.3f} ms)")
    if vals["fault_speedup"] <= 1.0:
        errs.append("migration loses to re-prefill under the link detour")
    # structural, not sub-ppm-timing, assertions: the detour must add hops
    # and must never be priced *cheaper* than the direct link (the per-hop
    # transit is tiny next to the DMA+translation floor, so a strict-greater
    # gate on milliseconds would be brittle to any model-constant tweak)
    if vals["fault_detour_hops"] <= 1:
        errs.append("killing the only direct link did not lengthen the "
                    "route")
    if vals["migration_fault_ms"] < vals["migration_neighbor_ms"] * (1 - 1e-9):
        errs.append("detour route priced cheaper than the healthy route")
    bad = [c for c in (256, 1024, 4096) if vals[f"speedup_at_{c}"] <= 1.0]
    if bad:
        errs.append(f"migration loses to re-prefill at contexts {bad}")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
