"""Fluid-tier scale benchmark — the petaflops-class regime the paper
aims APEnet+ at (hundreds of nodes on a 3D torus, arXiv:1102.3796's
aggregate-bandwidth-vs-concurrent-flows framing).

Two claims, both on ``fabric.make_sim`` fidelity tiers:

1. **``fluid_speedup_512``** (gated, higher-is-better): the flow-level
   fluid tier settles a 512-node (8x8x8) torus carrying 2000 concurrent
   multi-class flows >= 50x faster than the packet-level oracle on the
   identical workload.  This is the wall-clock lever that makes the
   design-space autotuner and cluster-scale trace replay affordable.

2. **``fluid_sched_maxerr`` / ``hybrid_sched_maxerr``** (gated,
   lower-is-better): on the random-schedule differential suite (random
   1D/2D/3D collectives with QoS tags — the workloads every consumer
   actually prices), fluid and hybrid completion times stay within 10%
   of the packet oracle.

The packet run doubles as the deadlock-recovery regression: at this
scale the partitioned multi-class credits form cyclic buffer waits that
the escape-credit recovery (``FabricSim._unstick``) must resolve — the
run must finish every flow (``packet_unfinished`` == 0).

3. **``warm_retune_speedup``** (reported; ``warm_retune_maxdiff`` gated
   at 0): a weights-only ``set_qos`` on a settled fluid sim warm-starts
   the rate solver from the cached incidence arrays (the active set did
   not change between solves, so only the class-weight vector differs).
   A 24-step retune sweep — the closed-loop QoS controller / autotuner
   candidate-evaluation shape — is timed with the cache enabled vs
   forcibly cleared; the two arms must produce bitwise-identical flow
   rates, and the warm arm must actually hit the cache
   (``warm_retune_solves`` >= 1).

``SIMSCALE_FAST=1`` (the CI fast lane) skips the ~90 s packet baseline:
the fluid 512-node run, the schedule differential and the warm-start
retune sweep still execute, and ``check`` enforces an absolute wall
budget on the fluid smoke.  The differential suite is identical in both
lanes, so its gated metrics diff cleanly across fast/full snapshots.
"""
from __future__ import annotations

import os
import random
import time

from repro.core import fabric
from repro.core.fabric.fluid import make_sim
from repro.core.fabric.qos import QosPolicy, TrafficClass
from repro.core.topology import Torus

DIMS = (8, 8, 8)             # 512 nodes
N_FLOWS = 2000
SEED = 0
FLUID_BUDGET_MS = 15000.0    # fast-lane wall budget for the fluid smoke

# warm-start retune sweep: candidate weight settings evaluated back to
# back on a settled sim (no events in between -> identical active set,
# so every solve after the first reuses the cached incidence arrays)
_RETUNE_STEPS = 24
_RETUNE_REPS = 5
_RETUNE_SPEEDUP_BAR = 1.05

# random-schedule differential suite: small meshes where the packet
# oracle is cheap, every collective kind, mixed sizes/classes/QoS
_MESHES = [(8,), (2, 4), (2, 2, 2), (4, 4), (2, 2, 4)]
_SIZES = [32 * 1024, 256 * 1024, 1 << 20, 4 << 20]
_DIFF_TRIALS = 40


def _workload(rng: random.Random):
    """2000 multi-class flows, 64 KB..2 MB, staggered starts — the
    trace-replay shape (same generator in fluid and packet runs)."""
    n = 1
    for d in DIMS:
        n *= d
    flows = []
    for _ in range(N_FLOWS):
        src = rng.randrange(n)
        dst = rng.randrange(n)
        while dst == src:
            dst = rng.randrange(n)
        nbytes = rng.randint(64 * 1024, 2 * 1024 * 1024)
        cls = rng.choice(list(TrafficClass))
        start = rng.randint(0, 4) * 200e-6
        flows.append((src, dst, nbytes, cls, start))
    return flows


def _run_tier(fidelity: str, flows) -> tuple[float, object]:
    torus = Torus(DIMS)
    fabric.clear_route_cache()
    t0 = time.perf_counter()
    sim = make_sim(torus, fidelity=fidelity, qos=QosPolicy())
    for src, dst, nbytes, cls, start in flows:
        sim.inject(src, dst, nbytes, cls=cls, start_s=start)
    sim.run()
    return time.perf_counter() - t0, sim


def _warm_retune(flows) -> tuple[float, float, float, int]:
    """(warm_ms, cold_ms, maxdiff, warm_solves) for a weights-only
    ``set_qos`` sweep on a settled mid-flight fluid sim — the shape the
    closed-loop QoS controller and the autotuner drive (many candidate
    weight vectors priced against one live fabric state).  The cold arm
    clears the incidence cache before every solve; both arms run
    interleaved on the same settled sim (no events fire between solves,
    so every solve sees the identical active set) and the min over
    ``_RETUNE_REPS`` repetitions de-noises the wall clocks."""
    torus = Torus(DIMS)
    fabric.clear_route_cache()
    sim = make_sim(torus, fidelity="fluid", qos=QosPolicy())
    for src, dst, nbytes, cls, start in flows:
        sim.inject(src, dst, nbytes, cls=cls, start_s=start)
    sim.run_until(5e-4)

    def sweep(cold: bool) -> float:
        t0 = time.perf_counter()
        for k in range(_RETUNE_STEPS):
            if cold:
                sim._inc_cache = None
            sim.set_qos(QosPolicy(
                weights={TrafficClass.DECODE: 8.0 + 0.5 * k}))
        return time.perf_counter() - t0

    warm_t, cold_t = [], []
    for _ in range(_RETUNE_REPS):
        cold_t.append(sweep(cold=True))
        warm_t.append(sweep(cold=False))

    # bitwise differential: at every sweep step, a cold rebuild and a
    # warm re-solve under identical weights must allocate identical
    # per-flow rates (maxdiff == 0.0 exactly, not approximately)
    maxdiff = 0.0
    for k in range(_RETUNE_STEPS):
        pol = QosPolicy(weights={TrafficClass.DECODE: 8.0 + 0.5 * k})
        sim._inc_cache = None
        sim.set_qos(pol)
        ref = [f.rate for f in sim._active.values()]
        sim.set_qos(pol)
        got = [f.rate for f in sim._active.values()]
        maxdiff = max([maxdiff] + [abs(a - b) for a, b in zip(ref, got)])
    return (min(warm_t) * 1e3, min(cold_t) * 1e3, maxdiff,
            sim.n_warm_solves)


def _schedule_differential() -> tuple[float, float]:
    """(fluid_maxerr, hybrid_maxerr) vs the packet oracle over random
    collective schedules — deterministic (fixed seed), identical in the
    fast and full lanes."""
    kinds = [fabric.AR, fabric.AG, fabric.RS, fabric.A2A, fabric.HALO]
    rng = random.Random(7)
    worst_f = worst_h = 0.0
    for _ in range(_DIFF_TRIALS):
        dims = rng.choice(_MESHES)
        torus = Torus(dims)
        kind = rng.choice(kinds)
        # all_to_all / halo_exchange lower along a single axis only
        axes = ((rng.randrange(len(dims)),)
                if kind in (fabric.A2A, fabric.HALO)
                else tuple(range(len(dims))))
        sched = fabric.lower(kind, torus, axes)
        nbytes = rng.choice(_SIZES)
        kw = dict(backend="sim", cls=rng.choice(list(TrafficClass)))
        if rng.random() < 0.5:
            kw["qos"] = QosPolicy()
        p = fabric.estimate(sched, nbytes, fidelity="packet", **kw).total_s
        f = fabric.estimate(sched, nbytes, fidelity="fluid", **kw).total_s
        h = fabric.estimate(sched, nbytes, fidelity="hybrid", **kw).total_s
        worst_f = max(worst_f, abs(f - p) / p)
        worst_h = max(worst_h, abs(h - p) / p)
    return worst_f, worst_h


def run() -> list[dict]:
    fast = os.environ.get("SIMSCALE_FAST", "0") == "1"
    # --seed threads through $BENCH_SEED (benchmarks/run.py); default
    # keeps the historical fixed workload so snapshots diff bitwise
    flows = _workload(random.Random(
        int(os.environ.get("BENCH_SEED", str(SEED)))))

    fluid_dt, fsim = _run_tier("fluid", flows)
    rows = [
        {"bench": "simscale", "metric": "fluid_wall_ms",
         "value": fluid_dt * 1e3,
         "note": f"{len(DIMS)}D torus {DIMS}, {N_FLOWS} flows, fluid tier "
                 f"({fsim.n_solves} rate solves); fast-lane budget "
                 f"{FLUID_BUDGET_MS:.0f} ms"},
        {"bench": "simscale", "metric": "fluid_solves",
         "value": float(fsim.n_solves),
         "note": "rate-allocation solver invocations (event batches)"},
    ]

    if not fast:
        packet_dt, psim = _run_tier("packet", flows)
        unfinished = sum(1 for f in psim._flows.values()
                         if f.finish_s is None)
        rows += [
            {"bench": "simscale", "metric": "packet_wall_s",
             "value": packet_dt,
             "note": "identical workload on the packet oracle"},
            {"bench": "simscale", "metric": "fluid_speedup_512",
             "value": packet_dt / fluid_dt, "gate": "higher",
             "note": "packet wall / fluid wall on the 512-node 2000-flow "
                     "workload (bar: >= 50x)"},
            {"bench": "simscale", "metric": "packet_unfinished",
             "value": float(unfinished),
             "note": "flows never completed (0 = credit-deadlock "
                     "recovery held)"},
            {"bench": "simscale", "metric": "packet_deadlock_breaks",
             "value": float(psim.deadlock_breaks),
             "note": "escape-credit recoveries during the packet run"},
        ]

    warm_ms, cold_ms, maxdiff, nwarm = _warm_retune(flows)
    rows += [
        {"bench": "simscale", "metric": "warm_retune_speedup",
         "value": cold_ms / warm_ms,
         "note": f"cold/warm wall over a {_RETUNE_STEPS}-step weights-only "
                 f"set_qos sweep (min of {_RETUNE_REPS} interleaved reps) "
                 f"on the settled 512-node sim; warm {warm_ms:.1f} ms vs "
                 f"cold {cold_ms:.1f} ms"},
        {"bench": "simscale", "metric": "warm_retune_solves",
         "value": float(nwarm),
         "note": "solves that reused the cached incidence arrays "
                 "(>= 1 required: the warm arm must actually hit)"},
        {"bench": "simscale", "metric": "warm_retune_maxdiff",
         "value": maxdiff, "gate": "lower",
         "note": "max |warm - cold| per-flow rate at identical weights "
                 "(bar: == 0.0 — warm start must be bitwise-equal)"},
    ]

    err_f, err_h = _schedule_differential()
    rows += [
        {"bench": "simscale", "metric": "fluid_sched_maxerr",
         "value": err_f, "gate": "lower",
         "note": "max |fluid - packet|/packet over the random-schedule "
                 "suite (bar: <= 0.10)"},
        {"bench": "simscale", "metric": "hybrid_sched_maxerr",
         "value": err_h, "gate": "lower",
         "note": "max |hybrid - packet|/packet over the random-schedule "
                 "suite (bar: <= 0.10)"},
    ]
    return rows


def check(rows) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    errs = []
    if vals["fluid_wall_ms"] > FLUID_BUDGET_MS:
        errs.append(f"fluid 512-node smoke took {vals['fluid_wall_ms']:.0f} "
                    f"ms, over the {FLUID_BUDGET_MS:.0f} ms budget")
    if "fluid_speedup_512" in vals and vals["fluid_speedup_512"] < 50.0:
        errs.append(f"fluid tier only {vals['fluid_speedup_512']:.1f}x "
                    "faster than packet on the 512-node workload "
                    "(bar: 50x)")
    if vals.get("packet_unfinished", 0.0) != 0.0:
        errs.append(f"{vals['packet_unfinished']:.0f} flows never finished "
                    "on the packet oracle (credit-deadlock recovery "
                    "failed)")
    for m in ("fluid_sched_maxerr", "hybrid_sched_maxerr"):
        if vals[m] > 0.10:
            errs.append(f"{m} = {vals[m]:.3f} exceeds the 10% "
                        "fluid-vs-packet differential contract")
    if vals["warm_retune_maxdiff"] != 0.0:
        errs.append(f"warm-started retune diverged from the cold solve "
                    f"(maxdiff = {vals['warm_retune_maxdiff']:.3e}, "
                    "must be bitwise 0)")
    if vals["warm_retune_solves"] < 1.0:
        errs.append("the warm retune sweep never hit the incidence "
                    "cache (warm_retune_solves == 0)")
    if vals["warm_retune_speedup"] < _RETUNE_SPEEDUP_BAR:
        errs.append(f"warm retune speedup {vals['warm_retune_speedup']:.2f}x "
                    f"below the {_RETUNE_SPEEDUP_BAR:.2f}x bar")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
