"""Fig 2 — hardware TLB on the receive path (paper §2.2).

The paper: moving virtual->physical translation from the Nios II soft-CPU
into an on-FPGA TLB gained up to 60% receive bandwidth on synthetic
benchmarks.  We reproduce the gain from the Tlb cost model (cold walk vs hot
TLB) and report the hit-rate sweep, plus the TLB behaviour under a paged-KV
serving access pattern (the TPU-side analogue of registration caching).
"""
from __future__ import annotations

import numpy as np

from repro.core.apelink import sustained_bandwidth
from repro.core.tlb import PAGE_BYTES, Tlb


def run() -> list[dict]:
    rows = []
    wire = sustained_bandwidth()  # ~2.2 GB/s APElink payload bandwidth
    tlb = Tlb(entries=512, ways=4)
    msg = 128 * 1024  # synthetic receive benchmark: 128 KiB messages

    bw_cold = tlb.receive_bandwidth(msg, wire, hit_rate=0.0)
    bw_hot = tlb.receive_bandwidth(msg, wire, hit_rate=1.0)
    rows.append({"bench": "tlb", "metric": "rx_bw_nios_MBps",
                 "value": bw_cold / 1e6, "note": "every page walked"})
    rows.append({"bench": "tlb", "metric": "rx_bw_hwtlb_MBps",
                 "value": bw_hot / 1e6, "note": "every page hits"})
    rows.append({"bench": "tlb", "metric": "bw_gain",
                 "value": bw_hot / bw_cold - 1.0,
                 "note": "paper: up to 60%"})
    for hr in (0.0, 0.25, 0.5, 0.75, 0.9, 1.0):
        bw = tlb.receive_bandwidth(msg, wire, hit_rate=hr)
        rows.append({"bench": "tlb", "metric": f"rx_bw_hit{int(hr*100)}_MBps",
                     "value": bw / 1e6, "note": ""})

    # measured hit rate under a paged-KV-style pattern: 32 sequences each
    # re-touching their pages every decode step
    tlb2 = Tlb(entries=512, ways=4)
    rng = np.random.default_rng(0)
    starts = rng.integers(0, 1 << 20, size=32) * PAGE_BYTES
    for step in range(64):
        for s in starts:
            npages = 1 + step // 16
            for p in range(npages):
                tlb2.translate(int(s) + p * PAGE_BYTES)
    rows.append({"bench": "tlb", "metric": "serving_hit_rate",
                 "value": tlb2.stats.hit_rate,
                 "note": "paged-KV decode pattern"})
    rows.append({"bench": "tlb", "metric": "serving_rx_bw_MBps",
                 "value": tlb2.receive_bandwidth(msg, wire) / 1e6,
                 "note": "at measured hit rate"})
    return rows


def check(rows) -> list[str]:
    errs = []
    vals = {r["metric"]: r["value"] for r in rows}
    if not 0.5 <= vals["bw_gain"] <= 0.7:
        errs.append(f"TLB bandwidth gain {vals['bw_gain']:.2f} not ~0.6")
    if vals["serving_hit_rate"] < 0.9:
        errs.append(f"serving hit rate {vals['serving_hit_rate']:.2f} < 0.9")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
