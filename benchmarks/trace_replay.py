"""Production trace replay at cluster scale — SLO-aware serving on the
fluid fabric tier (the regime arXiv:1102.3796 sizes APEnet+ for:
hundreds of nodes on a 3D torus, latency-critical traffic sharing links
with bulk state movement).

The workload is ``serving.trace``: a seeded heavy-tailed synthetic trace
(diurnal arrival rate, Poisson bursts, Zipf prompt/output lengths,
session reuse with warm prefixes) replayed through ``ServingCluster`` in
modelled mode — compute priced analytically at 2*N/F per token, every
KV-page migration and TP flow priced by the shared fabric timeline.

Gated claims:

1. **``smoke_proactive_gain`` / ``full_proactive_gain``** (higher):
   the SLO-aware proactive rebalancer (predicted-breach detection +
   ``best_route``-probed striped migration) beats the reactive
   ``rebalance(threshold=2)`` baseline by >= 1.15x on p99 per-token
   decode latency, on the identical seeded trace.
2. **``smoke_ttft_*`` / ``smoke_tpt_p99_s``** (lower): absolute SLO
   tails on the 16-node smoke — the regression surface for the
   admission/queueing/rebalance path.
3. **``smoke_shed_rate``** (lower): under 1.3x overload with a short
   queue, SLO admission sheds deterministically; the proactive
   rebalancer must keep the shed rate from regressing.
4. **``smoke_tier_maxerr``** (lower): fluid-vs-hybrid replay metrics
   agree within 10% — fabric pricing feeds the tails through migration
   PUT completion, so this is a live differential, not an identity.
5. **``smoke_determinism_delta``**: same seed => bitwise-identical
   trace and replay metrics (two full independent replays compared).

``TRACE_FAST=1`` (the CI fast lane) skips the 512-node (8x8x8) full
replay; the nightly lane runs it: >= 1000 requests settled on the fluid
tier, with its own gated tails and wall budget.  Lane-prefixed metric
names (``smoke_*`` vs ``full_*``) keep fast and nightly snapshots
diffing cleanly through ``scripts/bench_gate.py``.
"""
from __future__ import annotations

import dataclasses
import os
import time

from repro.configs import get_config
from repro.core.topology import Torus
from repro.serving.cluster import ServingCluster, SloPolicy
from repro.serving.trace import TraceConfig, generate_trace, replay

N_PARAMS = 7.0e9
T_TOK_S = 2.0 * N_PARAMS / 1.6e12     # analytic decode step, 8.75 ms
TOKENS_PER_REQ = 50.8                 # E[cold prefill + output] of the
                                      # default Zipf mix (measured)

SMOKE_DIMS = (4, 4)
SMOKE_SEED = 11
FULL_DIMS = (8, 8, 8)                 # 512 nodes
FULL_SEED = 7
FULL_REQUESTS = 1200

SMOKE_BUDGET_MS = 60_000.0            # fast-lane wall budget (all smoke
                                      # replays together)
FULL_BUDGET_MS = 90_000.0             # per-mode budget for the 512-node
                                      # replay
GAIN_BAR = 1.15                       # proactive vs reactive tpt p99


def _cluster(dims, *, fidelity="fluid", queue_limit=256,
             max_queue_wait_s=1.0) -> ServingCluster:
    return ServingCluster(
        get_config("deepseek-7b"), None, torus=Torus(dims),
        modelled=True, n_params=N_PARAMS, tp_axes=(), fidelity=fidelity,
        max_batch=4, max_seq=576, page_tokens=16, chunked_prefill=True,
        slo=SloPolicy(token_target_s=0.066, queue_limit=queue_limit,
                      max_queue_wait_s=max_queue_wait_s),
    )


def _trace(n_requests, n_nodes, util, seed):
    """Size the arrival rate to a target utilisation of the cluster's
    aggregate analytic token throughput; two diurnal cycles per trace."""
    rate = util * n_nodes / (T_TOK_S * TOKENS_PER_REQ)
    return generate_trace(TraceConfig(
        n_requests=n_requests, seed=seed, base_rate=rate,
        diurnal_period_s=n_requests / (2 * rate),
        burst_size=16.0, burst_rate=0.3))


def _replay(dims, trace, mode, *, fidelity="fluid", queue_limit=256,
            max_queue_wait_s=1.0):
    cl = _cluster(dims, fidelity=fidelity, queue_limit=queue_limit,
                  max_queue_wait_s=max_queue_wait_s)
    return replay(cl, trace, rebalance=mode)


def run() -> list[dict]:
    fast = os.environ.get("TRACE_FAST", "0") == "1"
    # --seed threads through $BENCH_SEED (benchmarks/run.py) as an
    # offset so the default snapshots stay bitwise comparable
    seed = int(os.environ.get("BENCH_SEED", "0"))
    rows: list[dict] = []

    # --- 16-node smoke: proactive vs reactive on one seeded trace ----
    t0 = time.perf_counter()
    n_smoke = 16
    tr = _trace(240, n_smoke, 0.92, SMOKE_SEED + seed)
    rea = _replay(SMOKE_DIMS, tr, "reactive", queue_limit=48)
    pro = _replay(SMOKE_DIMS, tr, "proactive", queue_limit=48)
    rows += [
        {"bench": "trace_replay", "metric": "smoke_ttft_p50_s",
         "value": pro.ttft_p50_s, "gate": "lower", "tol": 0.20,
         "note": "median time-to-first-token, 16-node proactive replay "
                 "(240 reqs, util 0.92)"},
        {"bench": "trace_replay", "metric": "smoke_ttft_p99_s",
         "value": pro.ttft_p99_s, "gate": "lower", "tol": 0.35,
         "note": "p99 time-to-first-token, 16-node proactive replay"},
        {"bench": "trace_replay", "metric": "smoke_tpt_p50_s",
         "value": pro.tpt_p50_s, "gate": "lower", "tol": 0.20,
         "note": "median per-token decode latency, proactive "
                 f"(analytic floor {T_TOK_S * 1e3:.2f} ms)"},
        {"bench": "trace_replay", "metric": "smoke_tpt_p99_s",
         "value": pro.tpt_p99_s, "gate": "lower", "tol": 0.35,
         "note": "p99 per-token decode latency, proactive"},
        {"bench": "trace_replay", "metric": "smoke_proactive_gain",
         "value": rea.tpt_p99_s / pro.tpt_p99_s,
         "gate": "higher", "tol": 0.25,
         "note": "reactive tpt p99 / proactive tpt p99 on the identical "
                 f"trace (bar: >= {GAIN_BAR}x); reactive="
                 f"{rea.tpt_p99_s * 1e3:.1f} ms"},
        {"bench": "trace_replay", "metric": "smoke_migrations",
         "value": float(pro.n_migrations),
         "note": f"striped BULK-class KV migrations (reactive moved "
                 f"{rea.n_migrations})"},
    ]

    # --- overload: 1.3x offered load, short queue -> deterministic
    # shedding; admission keeps the survivors' tails bounded ----------
    tro = _trace(160, n_smoke, 1.30, SMOKE_SEED + seed)
    orea = _replay(SMOKE_DIMS, tro, "reactive",
                   queue_limit=24, max_queue_wait_s=0.5)
    opro = _replay(SMOKE_DIMS, tro, "proactive",
                   queue_limit=24, max_queue_wait_s=0.5)
    rows += [
        {"bench": "trace_replay", "metric": "smoke_shed_rate",
         "value": opro.shed_rate, "gate": "lower", "tol": 0.50,
         "note": "shed fraction at 1.3x overload (queue_limit=24, "
                 f"wait 0.5 s), proactive; reactive sheds "
                 f"{orea.shed_rate:.3f}"},
        {"bench": "trace_replay", "metric": "smoke_overload_tpt_p99_s",
         "value": opro.tpt_p99_s, "gate": "lower", "tol": 0.35,
         "note": "p99 per-token latency of admitted requests under "
                 "overload, proactive"},
    ]

    # --- seeded determinism: regenerate + fully re-replay ------------
    tr2 = _trace(240, n_smoke, 0.92, SMOKE_SEED + seed)
    trace_delta = 0.0 if [dataclasses.astuple(r) for r in tr] == \
        [dataclasses.astuple(r) for r in tr2] else 1.0
    pro2 = _replay(SMOKE_DIMS, tr2, "proactive", queue_limit=48)
    m1, m2 = pro.metrics(), pro2.metrics()
    replay_delta = max(abs(m1[k] - m2[k]) for k in m1)
    rows.append(
        {"bench": "trace_replay", "metric": "smoke_determinism_delta",
         "value": trace_delta + replay_delta,
         "note": "same seed -> bitwise-identical trace and replay "
                 "metrics (must be exactly 0)"})

    # --- fidelity differential: hybrid replay of the same trace; the
    # tiers couple into the tails via migration PUT completion --------
    hyb = _replay(SMOKE_DIMS, tr, "proactive", fidelity="hybrid",
                  queue_limit=48)
    mh = hyb.metrics()
    tier_err = max(abs(m1[k] - mh[k]) / m1[k]
                   for k in ("ttft_p50_s", "ttft_p99_s",
                             "tpt_p50_s", "tpt_p99_s"))
    rows.append(
        {"bench": "trace_replay", "metric": "smoke_tier_maxerr",
         "value": tier_err, "gate": "lower", "tol": 0.50,
         "note": "max rel. diff of latency percentiles, fluid vs "
                 "hybrid replay (bar: <= 0.10)"})
    smoke_wall = (time.perf_counter() - t0) * 1e3
    rows.append(
        {"bench": "trace_replay", "metric": "smoke_wall_ms",
         "value": smoke_wall,
         "note": f"all smoke replays; fast-lane budget "
                 f"{SMOKE_BUDGET_MS:.0f} ms"})

    # --- 512-node full replay (nightly lane) -------------------------
    if not fast:
        n_full = 1
        for d in FULL_DIMS:
            n_full *= d
        trf = _trace(FULL_REQUESTS, n_full, 0.92, FULL_SEED + seed)
        t0 = time.perf_counter()
        frea = _replay(FULL_DIMS, trf, "reactive")
        rea_wall = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        fpro = _replay(FULL_DIMS, trf, "proactive")
        pro_wall = (time.perf_counter() - t0) * 1e3
        rows += [
            {"bench": "trace_replay", "metric": "full_ttft_p50_s",
             "value": fpro.ttft_p50_s, "gate": "lower", "tol": 0.20,
             "note": f"median TTFT, {n_full}-node {FULL_DIMS} fluid "
                     f"replay of {FULL_REQUESTS} requests, proactive"},
            {"bench": "trace_replay", "metric": "full_ttft_p99_s",
             "value": fpro.ttft_p99_s, "gate": "lower", "tol": 0.35,
             "note": "p99 TTFT, 512-node proactive replay"},
            {"bench": "trace_replay", "metric": "full_tpt_p99_s",
             "value": fpro.tpt_p99_s, "gate": "lower", "tol": 0.35,
             "note": "p99 per-token decode latency, 512-node proactive"},
            {"bench": "trace_replay", "metric": "full_proactive_gain",
             "value": frea.tpt_p99_s / fpro.tpt_p99_s,
             "gate": "higher", "tol": 0.25,
             "note": "reactive/proactive tpt p99 at 512 nodes (bar: "
                     f">= {GAIN_BAR}x); reactive="
                     f"{frea.tpt_p99_s * 1e3:.1f} ms"},
            {"bench": "trace_replay", "metric": "full_finished",
             "value": float(fpro.n_finished),
             "note": f"requests settled (of {FULL_REQUESTS}; shed "
                     f"{fpro.n_shed})"},
            {"bench": "trace_replay", "metric": "full_wall_ms",
             "value": max(rea_wall, pro_wall),
             "note": f"slower of the two 512-node replays (budget "
                     f"{FULL_BUDGET_MS:.0f} ms); reactive "
                     f"{rea_wall:.0f} ms, proactive {pro_wall:.0f} ms"},
        ]
    return rows


def check(rows) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    errs = []
    for m in ("smoke_proactive_gain", "full_proactive_gain"):
        if m in vals and vals[m] < GAIN_BAR:
            errs.append(f"{m} = {vals[m]:.2f}x: proactive rebalancing "
                        f"must beat reactive by >= {GAIN_BAR}x on p99 "
                        "per-token latency")
    if vals["smoke_determinism_delta"] != 0.0:
        errs.append(f"seeded replay is not deterministic (delta = "
                    f"{vals['smoke_determinism_delta']:.3g})")
    if vals["smoke_tier_maxerr"] > 0.10:
        errs.append(f"fluid-vs-hybrid replay differential "
                    f"{vals['smoke_tier_maxerr']:.3f} exceeds the 10% "
                    "fidelity contract")
    if vals["smoke_shed_rate"] <= 0.0:
        errs.append("overload scenario shed nothing — the admission "
                    "gate is not exercising (or the trace is no longer "
                    "overloaded)")
    if vals["smoke_wall_ms"] > SMOKE_BUDGET_MS:
        errs.append(f"smoke replays took {vals['smoke_wall_ms']:.0f} ms, "
                    f"over the {SMOKE_BUDGET_MS:.0f} ms fast-lane budget")
    if "full_wall_ms" in vals and vals["full_wall_ms"] > FULL_BUDGET_MS:
        errs.append(f"512-node replay took {vals['full_wall_ms']:.0f} ms, "
                    f"over the {FULL_BUDGET_MS:.0f} ms budget")
    if "full_finished" in vals and vals["full_finished"] < 1000:
        errs.append(f"only {vals['full_finished']:.0f} requests settled "
                    "at 512 nodes (need >= 1000 for the scale claim)")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
