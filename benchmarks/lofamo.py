"""§4 — LO|FA|MO fault awareness (Fig 4).

Reproduces:
  * awareness time Ta ~= 0.9 s at WD = 500 ms (Ta dominated by the watchdog
    period across the HPC range 1 ms - 1 s),
  * "even in case of multiple faults no area of the mesh can be isolated and
    no fault can remain undetected at global level" — exhaustively for all
    2-fault patterns on the QUonG 4x4x1 torus, and on random k-fault
    patterns for k<=4.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.lofamo import LofamoSim, awareness_time_model
from repro.core.topology import Torus


def _simulate_ta(wd: float, kill_phase: float = 0.2) -> float:
    """Simulated awareness time for a host fault at phase ``kill_phase`` of
    a watchdog period.  Detection needs two stale NIC reads (debounced), so
    Ta = (2 - phase) x WD + service; the paper's Ta = 0.9 s @ WD = 500 ms
    corresponds to an early-period fault (phase ~0.2 -> 1.8 x WD)."""
    t = Torus((4, 4, 1))
    sim = LofamoSim(t, wd_period=wd)
    sim.run(2)                       # steady state
    ev = sim.kill_host(6)
    ev.t_fault = sim.t + kill_phase * wd  # fault lands inside the period
    sim.run(5)
    return ev.awareness_time


def run() -> list[dict]:
    rows = []
    # Ta(WD) sweep over the paper's "time range of interest" 1 ms - 1 s
    for wd in (0.001, 0.01, 0.1, 0.5, 1.0):
        ta_model = awareness_time_model(wd)
        ta_sim = _simulate_ta(wd)
        rows.append({"bench": "lofamo", "metric": f"Ta_model_WD{wd}s",
                     "value": ta_model, "note": "analytic 1.8*WD + service"})
        rows.append({"bench": "lofamo", "metric": f"Ta_sim_WD{wd}s",
                     "value": ta_sim, "note": "simulated mid-period fault"})
    rows.append({"bench": "lofamo", "metric": "Ta_at_WD500ms",
                 "value": _simulate_ta(0.5), "note": "paper: 0.9 s"})

    # multi-fault global awareness on the QUonG 4x4x1 torus
    t = Torus((4, 4, 1))
    n_patterns = 0
    n_detected = 0
    for pair in itertools.combinations(range(t.size), 2):
        sim = LofamoSim(t, wd_period=0.5)
        sim.run(1)
        for r in pair:
            sim.kill_node(r)
        sim.run(4)
        n_patterns += 1
        n_detected += sim.all_detected(pair)
    rows.append({"bench": "lofamo", "metric": "all_2fault_detected",
                 "value": n_detected / n_patterns,
                 "note": f"{n_detected}/{n_patterns} exhaustive pairs"})
    rng = np.random.default_rng(0)
    ok = 0
    trials = 200
    for _ in range(trials):
        k = int(rng.integers(1, 5))
        faults = set(map(int, rng.choice(t.size, size=k, replace=False)))
        sim = LofamoSim(t, wd_period=0.5)
        sim.run(1)
        for r in faults:
            sim.kill_node(r)
        sim.run(6)
        ok += sim.all_detected(faults)
    rows.append({"bench": "lofamo", "metric": "random_kfault_detected",
                 "value": ok / trials, "note": "k<=4 random patterns"})
    # zero data-path impact: diagnostics ride the protocol words already
    # accounted in the APElink sync budget (cf. apelink.SYNC_FRACTION)
    rows.append({"bench": "lofamo", "metric": "data_path_latency_impact",
                 "value": 0.0, "note": "diagnostics hidden in protocol"})
    return rows


def check(rows) -> list[str]:
    errs = []
    vals = {r["metric"]: r["value"] for r in rows}
    if abs(vals["Ta_at_WD500ms"] - 0.9) > 0.15:
        errs.append(f"Ta@500ms={vals['Ta_at_WD500ms']:.2f}s vs paper 0.9s")
    if vals["all_2fault_detected"] < 1.0:
        errs.append("some 2-fault pattern went undetected")
    if vals["random_kfault_detected"] < 1.0:
        errs.append("some random k-fault pattern went undetected")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
