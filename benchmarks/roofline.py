"""Roofline reader: aggregates launch/dryrun JSON cells into the
EXPERIMENTS.md tables.

Three-term roofline per (arch x shape x mesh):

  compute_s    = per-device HLO FLOPs / peak bf16 FLOP/s
  memory_s     = per-device HLO bytes accessed / HBM bandwidth
  collective_s = per-device link traffic (parsed from partitioned HLO,
                 ring-schedule multipliers) / one ICI link direction

Per-device quantities x chips = the assignment's global formulation; the
two are identical after the chips cancel.  "fraction" is the useful-compute
roofline fraction: model_flops / (peak x dominant-term).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import hw
from repro.core.fabric import message_time

OUT = Path(__file__).resolve().parent / "out" / "dryrun"
CHIP = hw.TPU_V5E


def load_cells(variant: str | None = None, mesh: str | None = None):
    cells = []
    for p in sorted(OUT.glob("*.json")):
        d = json.loads(p.read_text())
        if variant is not None and d.get("variant", "baseline") != variant:
            continue
        if mesh is not None and d["mesh"] != mesh:
            continue
        cells.append(d)
    return cells


def cell_row(d: dict) -> dict:
    r = d["roofline"]
    dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
    step_s = max(dom, 1e-30)
    # roofline fraction: useful model FLOPs (params + causal attention) at
    # peak vs modelled step time
    useful = (d["model_flops_per_device"]
              + d.get("attn_model_flops_per_device", 0.0))
    ideal_s = useful / CHIP.peak_flops_bf16
    mem = d.get("memory_analysis", {})
    return {
        "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
        "variant": d.get("variant", "baseline"),
        "compute_s": r["compute_s"], "memory_s": r["memory_s"],
        "collective_s": r["collective_s"],
        "bottleneck": r["bottleneck"].replace("_s", ""),
        "roofline_fraction": ideal_s / step_s,
        "useful_flop_ratio": d.get("useful_flop_ratio_attn")
        or d.get("useful_flop_ratio") or 0.0,
        "live_GiB": (mem.get("live_bytes_per_device") or 0) / 2**30,
        "fits_hbm": mem.get("fits_hbm"),
        "link_GB": d["link_bytes_per_device"] / 1e9,
        # the same traffic priced by the fabric cost model (apelink
        # NetModel) instead of the raw ICI-link division
        "fabric_collective_s": message_time(
            int(d["link_bytes_per_device"])),
    }


def table(cells) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | roofline frac | useful/HLO | live GiB |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for d in cells:
        c = cell_row(d)
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.3e} | {c['memory_s']:.3e} "
            f"| {c['collective_s']:.3e} | {c['bottleneck']} "
            f"| {c['roofline_fraction']:.3f} | {c['useful_flop_ratio']:.2f} "
            f"| {c['live_GiB']:.2f} |")
    return "\n".join(lines)


def run() -> list[dict]:
    rows = []
    cells = load_cells(variant="baseline")
    if not cells:
        return [{"bench": "roofline", "metric": "cells", "value": 0,
                 "note": "run repro.launch.dryrun first"}]
    rows.append({"bench": "roofline", "metric": "cells",
                 "value": len(cells), "note": "baseline (arch,shape,mesh)"})
    fracs = [cell_row(d)["roofline_fraction"] for d in cells]
    rows.append({"bench": "roofline", "metric": "median_fraction",
                 "value": sorted(fracs)[len(fracs) // 2], "note": ""})
    worst = min(cells, key=lambda d: cell_row(d)["roofline_fraction"])
    best = max(cells, key=lambda d: cell_row(d)["roofline_fraction"])
    for tag, d in (("worst", worst), ("best", best)):
        c = cell_row(d)
        rows.append({"bench": "roofline", "metric": f"{tag}_fraction",
                     "value": c["roofline_fraction"],
                     "note": f"{c['arch']} x {c['shape']} x {c['mesh']} "
                     f"({c['bottleneck']}-bound)"})
    n_bound = {}
    for d in cells:
        b = cell_row(d)["bottleneck"]
        n_bound[b] = n_bound.get(b, 0) + 1
    for b, n in sorted(n_bound.items()):
        rows.append({"bench": "roofline", "metric": f"n_{b}_bound",
                     "value": n, "note": ""})
    return rows


def check(rows) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    if vals.get("cells", 0) == 0:
        return ["no dry-run cells found (run repro.launch.dryrun)"]
    return []


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else None
    print(table(load_cells(variant="baseline", mesh=mesh)))
