"""Fig 1 at the schedule level — sequential barrier vs bucketed overlap.

The paper's §2.1 timeline: one DMA engine leaves the PCIe bus idle between
a request's completion and the next issue (~50% efficiency); a second
engine fed by a prefetchable command queue keeps transactions in flight
and recovers up to 40% of total time.  The overlap engine replays that
trade at the collective-schedule level: ``engines=1`` is the monolithic
post-backward gradient sync (all compute, then one barrier collective);
``engines=2`` is the bucketed schedule issued inside the backward pass
(``fabric.plan_buckets`` + ``fabric.estimate_overlapped``), hiding fabric
rounds behind the remaining compute.

The modelled twin is a paper-era DP deployment: a ~125M-param model,
data-parallel over an 8-ring of the APEnet+ torus, gradients all-reduced
with the dimension-ordered ring schedule and backward compute priced at a
Fermi/Kepler-class effective rate — a *comm-bound* shape (fabric time
exceeds backward compute), which is where overlap pays.

Gated claim: the bucketed-overlapped execution models >= 25% total-time
reduction vs the sequential barrier on this shape, with the exposed/hidden
comm split consistent with the timeline.
"""
from __future__ import annotations

from repro.core import fabric
from repro.core.hw import PAPER_GPU_EFF_FLOPS as GPU_EFF_FLOPS
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus

DP = 8
N_LAYERS = 24
LAYER_PARAMS = 5_000_000       # ~125M params total (24 layers + head)
HEAD_PARAMS = 5_000_000
TOKENS_PER_RANK = 1024
# backward ~ 2x forward = 4 FLOPs per param per token at the shared
# paper-era rate (hw.PAPER_GPU_EFF_FLOPS)
BUCKET_MB = 16


def _leaf_sizes() -> list[int]:
    # per-layer leaves: wq, wk, wv, wo, mlp up, mlp down, norms — the
    # granularity the bucket packer actually sees on a real param tree
    attn = LAYER_PARAMS // 10
    layer = [attn, attn, attn, attn, 3 * attn, 3 * attn]
    return layer * N_LAYERS + [HEAD_PARAMS]


def _compute_s() -> float:
    n_params = sum(_leaf_sizes())
    return 4.0 * n_params * TOKENS_PER_RANK / GPU_EFF_FLOPS


def _schedule():
    return fabric.lower_all_reduce(Torus((DP,)), ("data",), mean=True)


def _estimate(bucket_mb: float, queue_depth: int) -> fabric.OverlapEstimate:
    plan = fabric.plan_buckets(_leaf_sizes(), int(bucket_mb * (1 << 20)),
                               itemsize=4)
    return fabric.estimate_overlapped(_schedule(), plan, _compute_s(),
                                      queue_depth=queue_depth)


def run() -> list[dict]:
    rows = []
    # command-queue depths straight from the RdmaEndpoint model: the
    # single-engine card has one descriptor in flight, the dual-engine
    # card prefetches (2 slots per engine)
    single = RdmaEndpoint(Torus((DP,)), 0, engines=1, cq_slots=1)
    dual = RdmaEndpoint(Torus((DP,)), 0, engines=2)
    est = _estimate(BUCKET_MB, dual.queue_depth)
    rows += [
        {"bench": "overlap", "metric": "sequential_ms",
         "value": est.sequential_s * 1e3,
         "note": "engines=1: barrier sync after full backward"},
        {"bench": "overlap", "metric": "overlapped_ms",
         "value": est.total_s * 1e3,
         "note": f"engines=2: {BUCKET_MB} MB buckets inside backward"},
        {"bench": "overlap", "metric": "overlap_reduction",
         "value": est.reduction, "gate": "higher",
         "note": "paper Fig 1: up to 40% total-time recovery"},
        {"bench": "overlap", "metric": "comm_hidden_ms",
         "value": est.hidden_comm_s * 1e3,
         "note": "fabric time under backward compute"},
        {"bench": "overlap", "metric": "comm_exposed_ms",
         "value": est.exposed_comm_s * 1e3,
         "note": "fabric time the step pays for"},
        {"bench": "overlap", "metric": "overlap_efficiency",
         "value": est.efficiency, "gate": "higher",
         "note": "hidden / (hidden + exposed)"},
        {"bench": "overlap", "metric": "compute_ms",
         "value": est.compute_s * 1e3,
         "note": f"4*P*T at {GPU_EFF_FLOPS / 1e12:.1f} TF/s effective"},
    ]
    # queue-depth sweep (the prefetchable command queue of §2.1): a
    # depth-1 queue pays the issue gap on every bucket
    t_cq1 = _estimate(BUCKET_MB, single.queue_depth).total_s
    t_cq = _estimate(BUCKET_MB, dual.queue_depth).total_s
    rows += [
        {"bench": "overlap", "metric": "time_cq1_ms", "value": t_cq1 * 1e3,
         "note": "single-slot command queue"},
        {"bench": "overlap", "metric": f"time_cq{dual.queue_depth}_ms",
         "value": t_cq * 1e3, "note": "prefetchable queue (dual engine)"},
    ]
    # bucket-size sweep: too-small buckets pay per-message overhead,
    # too-large ones leave nothing to overlap (the Fig 1 message-size arc)
    for mb in (1, 4, 16, 64, 256):
        e = _estimate(mb, dual.queue_depth)
        rows.append({"bench": "overlap", "metric": f"reduction_at_{mb}MB",
                     "value": e.reduction,
                     "note": f"{e.total_s * 1e3:.2f} ms overlapped"})
    return rows


def check(rows) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    errs = []
    if vals["overlap_reduction"] < 0.25:
        errs.append(f"modelled reduction {vals['overlap_reduction']:.3f} "
                    "< 0.25 on the comm-bound shape")
    if vals["overlapped_ms"] > vals["sequential_ms"]:
        errs.append("bucketed overlap slower than the sequential barrier")
    if not 0.0 <= vals["overlap_efficiency"] <= 1.0:
        errs.append(f"efficiency {vals['overlap_efficiency']} out of [0,1]")
    if vals["time_cq1_ms"] < vals["time_cq4_ms"]:
        errs.append("depth-1 command queue beat the prefetchable queue")
    # exposed/hidden split must be consistent with the timeline estimate
    est = _estimate(BUCKET_MB, 4)
    busy = est.comm_s + est.overhead_s
    if abs((est.hidden_comm_s + est.exposed_comm_s) - busy) > 1e-9 * busy \
            + 1e-12:
        errs.append("hidden + exposed comm does not account for fabric "
                    "busy time")
    if abs(est.reduction - vals["overlap_reduction"]) > 1e-9:
        errs.append("estimate not reproducible")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
