"""Fig 1 — dual DMA engines / outstanding PCIe transactions (paper §2.1).

The paper: with a single DMA engine the effective PCIe bandwidth was ~50% of
theoretical because the bus sits idle between issuing a read request and its
completion; two engines fed by a prefetchable command queue overlap the
transactions, an estimated efficiency gain of up to 40% in total time.

We reproduce both numbers from the RdmaEndpoint transfer model and report
the engine-count sweep the Fig 1 timeline implies.
"""
from __future__ import annotations

from repro.core.apelink import NetModel
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus


def run() -> list[dict]:
    ep = RdmaEndpoint(Torus((4, 4, 1)), rank=0, net=NetModel())
    rows = []
    nbytes = 1 << 20  # 1 MiB bulk transfer (many outstanding requests)
    t1 = ep.transfer_time(nbytes, engines=1)
    t2 = ep.transfer_time(nbytes, engines=2)
    t_wire = nbytes / ep.net.host_if.effective_bandwidth
    rows.append({"bench": "dma_overlap", "metric": "single_engine_eff",
                 "value": t_wire / t1,
                 "note": "paper ~0.5 effective/theoretical"})
    rows.append({"bench": "dma_overlap", "metric": "dual_engine_gain",
                 "value": 1.0 - t2 / t1, "gate": "higher",
                 "note": "paper: up to 40% time reduction"})
    for k in (1, 2, 3, 4):
        tk = ep.transfer_time(nbytes, engines=k)
        rows.append({"bench": "dma_overlap", "metric": f"time_engines_{k}_us",
                     "value": tk * 1e6, "note": "1 MiB transfer"})
    # message-size sweep at 2 engines (Fig 1 generalised)
    for lg in (12, 14, 16, 18, 20, 22):
        n = 1 << lg
        gain = 1.0 - ep.transfer_time(n, engines=2) / ep.transfer_time(
            n, engines=1)
        rows.append({"bench": "dma_overlap",
                     "metric": f"gain_at_{n>>10}KiB", "value": gain,
                     "note": ""})
    return rows


def check(rows) -> list[str]:
    errs = []
    vals = {r["metric"]: r["value"] for r in rows}
    if not 0.4 <= vals["single_engine_eff"] <= 0.6:
        errs.append(f"single-engine efficiency {vals['single_engine_eff']:.2f}"
                    " not ~0.5")
    if not 0.30 <= vals["dual_engine_gain"] <= 0.45:
        errs.append(f"dual-engine gain {vals['dual_engine_gain']:.2f}"
                    " not ~0.40")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
