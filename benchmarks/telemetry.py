"""Telemetry-hub gates: invisibility, exactness, overhead, trace schema.

The unified fabric telemetry layer (``core/fabric/telemetry.py``) is
pure observability — so the claims it must hold are about *not
changing* anything, and about its own bookkeeping being exact:

1. **``invisibility_maxdiff``** (0 tol): the seeded 16-node replay with
   a ``Telemetry`` hub attached reports bitwise-identical
   ``ReplayReport.metrics()`` to the same replay with ``telemetry=None``
   — the hub observes the timeline without perturbing it (the same
   discipline as ``qos=None`` and the quiescent controller).
2. **``counter_stats_maxdiff``** (0 tol): after an instrumented replay,
   the hub's per-link counters cross-check EXACTLY against the sim's
   own ``link_stats()`` — both sides accumulated the same floats in
   the same order, so the diff is 0.0, not epsilon.
3. **``stats_key_parity``** (0 tol): ``FabricSim`` and ``FluidSim``
   return the same ``link_stats`` schema (same per-entry key set, same
   deterministic key ordering) for the same fabric traffic.
4. **``trace_schema_errors``** (0 tol) and **``trace_roundtrip_delta``**
   (0 tol): the exported Chrome-trace JSON passes the
   ``validate_perfetto`` schema check, and two independent same-seed
   replays export BYTE-identical trace files.
5. **``enabled_overhead_frac``** (lower): wall overhead of the enabled
   hub on the 512-node fluid trace replay, bounded at <= 15%
   (``OVERHEAD_BAR``).  ``TELEMETRY_FAST=1`` (the CI fast lane) skips
   this 512-node section; the nightly lane runs it.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import fabric
from repro.core.topology import Torus
from repro.serving.trace import replay

from benchmarks.trace_replay import (FULL_DIMS, FULL_SEED, SMOKE_DIMS,
                                     SMOKE_SEED, _cluster, _trace)

OVERHEAD_BAR = 0.15           # enabled-mode wall overhead ceiling
OVERHEAD_REQUESTS = 600       # 512-node overhead probe trace length


def _replay_smoke(trace, tel):
    cl = _cluster(SMOKE_DIMS, fidelity="fluid", queue_limit=48)
    if tel is not None:
        # attach the one hub everywhere the constructor seam would:
        # cluster events, the shared sim, every endpoint
        cl.telemetry = tel
        cl.sim.telemetry = tel
        for node in cl.nodes.values():
            node.lm.endpoint.telemetry = tel
    return cl, replay(cl, trace, rebalance="proactive")


def _key_parity(seed: int) -> float:
    """Same traffic on both tiers: 0.0 iff the link_stats schemas agree
    on per-entry keys AND iterate in the same canonical order."""
    torus = Torus(SMOKE_DIMS)
    pkt = fabric.make_sim(torus, fidelity="packet")
    flu = fabric.make_sim(torus, fidelity="fluid")
    for s in (pkt, flu):
        for i in range(8):
            s.inject(i, (i + 5) % 16, 1.5e6,
                     cls=fabric.TrafficClass.BULK, label=f"par{i}")
            s.occupy(("hostif", i), 1e-4, cls=fabric.TrafficClass.BULK)
        s.run()
    sp, sf = pkt.link_stats(), flu.link_stats()
    bad = 0.0
    if list(sp.keys()) != list(sf.keys()):
        bad += 1.0
    inner = {tuple(v.keys()) for v in sp.values()} \
        | {tuple(v.keys()) for v in sf.values()}
    if inner != {("busy_s", "bytes", "class_bytes")}:
        bad += 1.0
    return bad


def run() -> list[dict]:
    fast = os.environ.get("TELEMETRY_FAST", "0") == "1"
    seed = int(os.environ.get("BENCH_SEED", "0"))
    rows: list[dict] = []

    # --- invisibility: hub attached vs telemetry=None, same trace ----
    tr = _trace(240, 16, 0.92, SMOKE_SEED + seed)
    _, bare = _replay_smoke(tr, None)
    tel = fabric.Telemetry()
    cl, inst = _replay_smoke(tr, tel)
    m0, m1 = bare.metrics(), inst.metrics()
    rows.append(
        {"bench": "telemetry", "metric": "invisibility_maxdiff",
         "value": max(abs(m0[k] - m1[k]) for k in m0),
         "gate": "lower", "tol": 0.0,
         "note": "replay metrics, hub attached vs telemetry=None "
                 "(must be exactly 0: observability never perturbs)"})

    # --- counter exactness vs the sim's own accounting ---------------
    rows.append(
        {"bench": "telemetry", "metric": "counter_stats_maxdiff",
         "value": tel.cross_check(cl.sim),
         "gate": "lower", "tol": 0.0,
         "note": "hub per-link counters vs sim.link_stats() after the "
                 "instrumented replay (same float-addition order -> "
                 "exactly 0)"})

    # --- cross-tier link_stats schema parity -------------------------
    rows.append(
        {"bench": "telemetry", "metric": "stats_key_parity",
         "value": _key_parity(seed),
         "gate": "lower", "tol": 0.0,
         "note": "FabricSim vs FluidSim link_stats key set + canonical "
                 "ordering on identical traffic (0 = unified schema)"})

    # --- trace export: schema validity + seeded byte-determinism -----
    blob1 = tel.to_perfetto()
    errs = fabric.validate_perfetto(json.loads(blob1))
    tel2 = fabric.Telemetry()
    tr2 = _trace(240, 16, 0.92, SMOKE_SEED + seed)
    _replay_smoke(tr2, tel2)
    blob2 = tel2.to_perfetto()
    rows += [
        {"bench": "telemetry", "metric": "trace_schema_errors",
         "value": float(len(errs)),
         "gate": "lower", "tol": 0.0,
         "note": "validate_perfetto violations in the exported "
                 "Chrome-trace JSON" + (f"; first: {errs[0]}" if errs
                                        else "")},
        {"bench": "telemetry", "metric": "trace_roundtrip_delta",
         "value": 0.0 if blob1 == blob2 else 1.0,
         "gate": "lower", "tol": 0.0,
         "note": "two independent same-seed replays -> byte-identical "
                 f".trace.json ({len(blob1)} bytes, "
                 f"{tel.n_events} events)"},
        {"bench": "telemetry", "metric": "trace_events",
         "value": float(tel.n_events),
         "note": f"events recorded on the 16-node replay "
                 f"({tel.dropped} dropped, ring={tel.ring})"},
    ]

    # --- enabled-mode overhead on the 512-node fluid replay ----------
    if not fast:
        n_full = 1
        for d in FULL_DIMS:
            n_full *= d
        trf = _trace(OVERHEAD_REQUESTS, n_full, 0.92, FULL_SEED + seed)

        def wall(with_tel: bool) -> float:
            cl = _cluster(FULL_DIMS, fidelity="fluid")
            if with_tel:
                hub = fabric.Telemetry()
                cl.telemetry = hub
                cl.sim.telemetry = hub
                for node in cl.nodes.values():
                    node.lm.endpoint.telemetry = hub
            t0 = time.perf_counter()
            replay(cl, trf, rebalance="proactive")
            return time.perf_counter() - t0

        # min-of-2 per mode: the overhead claim is about added work,
        # not about scheduler noise on a loaded CI box
        off = min(wall(False) for _ in range(2))
        on = min(wall(True) for _ in range(2))
        rows.append(
            {"bench": "telemetry", "metric": "enabled_overhead_frac",
             "value": max(on / off - 1.0, 0.0),
             "gate": "lower", "tol": 0.50,
             "note": f"512-node fluid replay wall overhead with the hub "
                     f"attached (bar: <= {OVERHEAD_BAR:.0%}); "
                     f"off {off * 1e3:.0f} ms, on {on * 1e3:.0f} ms"})
    return rows


def check(rows) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    errs = []
    for m in ("invisibility_maxdiff", "counter_stats_maxdiff",
              "stats_key_parity", "trace_schema_errors",
              "trace_roundtrip_delta"):
        if vals[m] != 0.0:
            errs.append(f"{m} = {vals[m]:.3g}: must be exactly 0")
    if "enabled_overhead_frac" in vals \
            and vals["enabled_overhead_frac"] > OVERHEAD_BAR:
        errs.append(f"enabled-mode overhead "
                    f"{vals['enabled_overhead_frac']:.1%} exceeds the "
                    f"{OVERHEAD_BAR:.0%} ceiling on the 512-node replay")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
