"""QoS traffic classes on the shared fabric timeline — decode protection
and multi-path bulk striping, the two behaviours that make co-located
serving + migration viable (arXiv:1102.3796 §2 arbiter/channel datapath).

Four claims, all priced on ``fabric.FabricSim`` with the 7B-class serving
twin of ``benchmarks/contention.py``:

1. **``decode_protection``** (gated, higher-is-better): a live decode TP
   stream sharing its ring links with a bulk KV-page migration stretches
   ~1.5x on the classic FIFO link (the PR-4 contention headline), but
   under the default ``QosPolicy`` the DECODE virtual channel holds its
   weighted share — decode completion stays <= 1.10x its isolated price
   while the BULK migration still completes.  The gate is the ratio of
   the two stretches.

2. **``striping_gain``** (gated, higher-is-better): one bulk PUT split
   across the k best probed candidate routes (``fabric.striped_routes``
   with probed-goodput-proportional shares + the receiver reorder/settle
   charge) beats the best single route — multi-path bandwidth
   aggregation over the loop-free detour family.

3. **Single-class compatibility differentials**: under
   ``QosPolicy(single_class=True)`` class tags are provably inert
   (``single_class_tag_invariance_maxdiff`` — permuting the tags of a
   mixed flow set changes no finish time, must be exactly 0) and the
   single-class sim keeps the pre-QoS exact-agreement contract with the
   closed-form model on single-flow schedules
   (``single_class_analytic_maxerr`` <= 1e-9) — together with the
   unchanged ``tests/fabric_checks.py`` differential, the evidence that
   the QoS subsystem is a strict superset of the pre-QoS simulator.

4. **Work conservation**: protection is not reservation — with no decode
   traffic in flight, bulk under the QoS policy runs at the same rate as
   on the FIFO link (reported, checked <= 2% apart).
"""
from __future__ import annotations

from benchmarks.contention import (
    BULK_PACKET, CONT_TORUS, DECODE_STEPS_IN_FLIGHT, MIG_DST, MIG_PAGES,
    PAGE_NBYTES, TP_STEP_BYTES)
from repro.core import fabric
from repro.core.apelink import NetModel
from repro.core.fabric import FabricSim, QosPolicy, TrafficClass
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus

QOS = QosPolicy()
FIFO = QosPolicy(single_class=True)
STRIPE_TORUS = Torus((4, 4))
STRIPE_NBYTES = MIG_PAGES * PAGE_NBYTES


def _ring_sim(qos: QosPolicy) -> FabricSim:
    return FabricSim(CONT_TORUS, packet_bytes=BULK_PACKET, qos=qos)


def _decode_stream(sim: FabricSim) -> list[int]:
    """The serving ring's in-flight decode TP collectives (DECODE class),
    steps chained — the same continuous stream ``benchmarks/contention``
    prices, now riding its own virtual channel."""
    tp = fabric.lower_all_reduce(CONT_TORUS, ("x",))
    fids: list[int] = []
    tail: list[int] = []
    for _ in range(DECODE_STEPS_IN_FLIGHT):
        tail = fabric.inject_schedule(sim, tp, TP_STEP_BYTES, start_s=0.0,
                                      after=tuple(tail),
                                      granularity="phase",
                                      cls=TrafficClass.DECODE)
        fids.extend(tail)
    return fids


def _bulk_put(sim: FabricSim) -> float:
    """The migration PUT of the contention bench, BULK class (the exact
    ``put_pages`` call the serving cluster makes)."""
    ep = RdmaEndpoint(CONT_TORUS, 0, sim=sim)
    dst_ep = RdmaEndpoint(CONT_TORUS, MIG_DST, sim=sim)
    region = ep.register(MIG_PAGES * PAGE_NBYTES)
    dst_region = dst_ep.register(MIG_PAGES * PAGE_NBYTES)
    return ep.put_pages(MIG_DST, region, list(range(MIG_PAGES)),
                        page_nbytes=PAGE_NBYTES, dst_endpoint=dst_ep,
                        dst_region=dst_region)


def _decode_protection(qos: QosPolicy) -> tuple[float, float, float]:
    """(decode_isolated_s, decode_contended_s, bulk_put_s) under one link
    policy — the migrate-under-decode scenario, measured from the decode
    side."""
    idle = _ring_sim(qos)
    decode_alone = max(idle.finish_s(f) for f in _decode_stream(idle))
    sim = _ring_sim(qos)
    decode_fids = _decode_stream(sim)
    put_s = _bulk_put(sim)
    decode_with_bulk = max(sim.finish_s(f) for f in decode_fids)
    return decode_alone, decode_with_bulk, put_s


def _bulk_only(qos: QosPolicy) -> float:
    """The PUT on a quiet fabric: protection must not tax bulk when
    nothing needs protecting (work conservation)."""
    return _bulk_put(_ring_sim(qos))


def _single_class_equivalence() -> tuple[float, float]:
    """Two differentials pinning ``single_class=True`` to the pre-QoS
    FIFO simulator:

    * **tag invariance** (max |finish diff|, must be exactly 0.0): the
      same mixed flow set under two different class-tag assignments —
      under single-class arbitration the tags must be completely inert
      (a ``cidx`` leak into scheduling would show here immediately);
    * **analytic exactness** (max rel err, must be <= 1e-9): the
      single-class sim backend vs the closed-form estimate on single-flow
      ring schedules — the same exact-agreement contract the pre-QoS sim
      satisfied (``tests/fabric_checks.py``), so any behavioural drift of
      the single-class arbiter breaks it.
    """
    def run(tags):
        sim = FabricSim(CONT_TORUS, packet_bytes=BULK_PACKET, qos=FIFO)
        fids = [sim.inject(s, d, n, cls=c) for (s, d, n), c in
                zip([(0, 1, 4 << 20), (0, 2, 16 << 20), (1, 3, 2 << 20),
                     (3, 0, 64)], tags)]
        fids.append(sim.inject(2, 3, 4 << 20, after=(fids[0],),
                               cls=tags[-1]))
        return [sim.finish_s(f) for f in fids]
    a = run([TrafficClass.DECODE, TrafficClass.BULK,
             TrafficClass.COLLECTIVE, TrafficClass.CONTROL])
    b = run([TrafficClass.BULK, TrafficClass.CONTROL,
             TrafficClass.DECODE, TrafficClass.COLLECTIVE])
    tag_maxdiff = max(abs(x - y) for x, y in zip(a, b))
    maxerr = 0.0
    for dims, axes in (((4,), ("x",)), ((2, 2), ("a", "b"))):
        t = Torus(dims)
        sched = fabric.lower_all_reduce(t, axes)
        for nbytes in (4096, 1 << 20):
            an = fabric.estimate(sched, nbytes).total_s
            si = fabric.estimate(sched, nbytes, backend="sim").total_s
            maxerr = max(maxerr, abs(si - an) / an)
    return tag_maxdiff, maxerr


def _striping() -> tuple[float, float, int]:
    """(t_best_single, t_striped, n_stripes) for one STRIPE_NBYTES PUT
    0 -> +x neighbour while background bulk hammers the direct link —
    both variants pay the same translation/DMA, so the gain is the
    multi-path wire aggregation net of the reorder/settle charge."""
    nbr = STRIPE_TORUS.rank((1, 0))

    def fresh():
        sim = FabricSim(STRIPE_TORUS, packet_bytes=BULK_PACKET, qos=QOS)
        sim.inject(0, nbr, 32 << 20, cls=TrafficClass.BULK)  # background
        ep = RdmaEndpoint(STRIPE_TORUS, 0, sim=sim)
        region = ep.register(STRIPE_NBYTES)
        return sim, ep, region

    sim, ep, region = fresh()
    route, _ = fabric.best_route(sim, 0, nbr, STRIPE_NBYTES)
    t_single = ep.put_pages(nbr, region, list(range(MIG_PAGES)),
                            page_nbytes=PAGE_NBYTES,
                            schedule=fabric.lower_route(STRIPE_TORUS, route))

    sim, ep, region = fresh()
    plan = fabric.striped_routes(sim, 0, nbr, STRIPE_NBYTES, k=3)
    counts = fabric.stripe_counts(plan, MIG_PAGES)   # the production split
    stripes = [(fabric.lower_route(STRIPE_TORUS, r), c * PAGE_NBYTES)
               for (r, _), c in zip(plan, counts) if c > 0]
    t_striped = ep.put_pages(nbr, region, list(range(MIG_PAGES)),
                             page_nbytes=PAGE_NBYTES, stripes=stripes)
    return t_single, t_striped, len(stripes)


def run() -> list[dict]:
    iso_f, cont_f, put_f = _decode_protection(FIFO)
    iso_q, cont_q, put_q = _decode_protection(QOS)
    slowdown_fifo = cont_f / iso_f
    slowdown_qos = cont_q / iso_q
    bulk_fifo, bulk_qos = _bulk_only(FIFO), _bulk_only(QOS)
    tag_maxdiff, analytic_maxerr = _single_class_equivalence()
    t_single, t_striped, n_stripes = _striping()
    rows = [
        {"bench": "qos", "metric": "decode_isolated_ms",
         "value": iso_q * 1e3,
         "note": f"{DECODE_STEPS_IN_FLIGHT} chained decode TP steps, "
                 "no bulk in flight (QoS policy)"},
        {"bench": "qos", "metric": "decode_slowdown_fifo",
         "value": slowdown_fifo,
         "note": "decode stretch under a concurrent bulk migration PUT, "
                 "single-FIFO link (the ungated PR-4 regime)"},
        {"bench": "qos", "metric": "decode_slowdown_qos",
         "value": slowdown_qos, "gate": "lower",
         "note": "same scenario under QosPolicy default weights "
                 "(acceptance bar: <= 1.10)"},
        {"bench": "qos", "metric": "decode_protection",
         "value": slowdown_fifo / slowdown_qos, "gate": "higher",
         "note": "FIFO decode stretch / QoS decode stretch (> 1 = the "
                 "virtual channels protected decode)"},
        {"bench": "qos", "metric": "bulk_put_under_decode_qos_ms",
         "value": put_q * 1e3,
         "note": "the BULK migration still completes under QoS "
                 f"(vs {put_f * 1e3:.2f} ms on the FIFO link)"},
        {"bench": "qos", "metric": "bulk_stretch_qos",
         "value": put_q / bulk_qos,
         "note": "BULK PUT under the live decode stream vs quiet fabric "
                 "— bounded (weight-1 share, not starvation)"},
        {"bench": "qos", "metric": "bulk_quiet_overhead",
         "value": bulk_qos / bulk_fifo,
         "note": "bulk PUT on a QUIET QoS fabric vs FIFO — protection is "
                 "work-conserving, not a reservation (~1.0)"},
        {"bench": "qos", "metric": "single_class_tag_invariance_maxdiff",
         "value": tag_maxdiff,
         "note": "max |finish diff| across class-tag permutations under "
                 "single_class=True (tags must be inert: exactly 0)"},
        {"bench": "qos", "metric": "single_class_analytic_maxerr",
         "value": analytic_maxerr,
         "note": "single-class sim vs closed-form on single-flow ring "
                 "schedules — the pre-QoS exact-agreement contract "
                 "(must be <= 1e-9)"},
        {"bench": "qos", "metric": "striped_migration_ms",
         "value": t_striped * 1e3,
         "note": f"{STRIPE_NBYTES / 1e6:.1f} MB PUT across {n_stripes} "
                 "probed routes (goodput-proportional shares + "
                 "reorder/settle)"},
        {"bench": "qos", "metric": "single_route_migration_ms",
         "value": t_single * 1e3,
         "note": "same PUT on the best single probed route"},
        {"bench": "qos", "metric": "striping_gain",
         "value": t_single / t_striped, "gate": "higher",
         "note": "best-single-route time / striped time (> 1 = the "
                 "multi-path split won)"},
        {"bench": "qos", "metric": "stripe_count",
         "value": n_stripes, "note": "wire legs of the striped PUT"},
    ]
    return rows


def check(rows) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    errs = []
    if vals["decode_slowdown_qos"] > 1.10:
        errs.append(
            f"decode stretch {vals['decode_slowdown_qos']:.3f}x under QoS "
            "exceeds the 1.10x protection bar")
    if vals["decode_slowdown_fifo"] < 1.2:
        errs.append(
            f"FIFO decode stretch only {vals['decode_slowdown_fifo']:.3f}x "
            "— the interference scenario lost its teeth")
    if vals["decode_protection"] < 1.15:
        errs.append(
            f"QoS protection gain only {vals['decode_protection']:.3f}x "
            "over the FIFO link")
    if vals["bulk_stretch_qos"] > 3.0:
        errs.append(
            f"BULK stretched {vals['bulk_stretch_qos']:.2f}x under the "
            "decode stream — weight-1 share should bound it ~2x, this "
            "looks like starvation")
    if abs(vals["bulk_quiet_overhead"] - 1.0) > 0.02:
        errs.append(
            f"quiet-fabric bulk overhead {vals['bulk_quiet_overhead']:.4f} "
            "— QoS must be work-conserving when uncontended")
    if vals["single_class_tag_invariance_maxdiff"] != 0.0:
        errs.append(
            "class tags leaked into single_class scheduling: finish diff "
            f"{vals['single_class_tag_invariance_maxdiff']} s (must be 0)")
    if vals["single_class_analytic_maxerr"] > 1e-9:
        errs.append(
            "single-class sim drifted from the closed-form model on "
            f"single-flow schedules ({vals['single_class_analytic_maxerr']}"
            " rel err) — the pre-QoS exact-agreement contract broke")
    if vals["striping_gain"] < 1.2:
        errs.append(
            f"striping gained only {vals['striping_gain']:.3f}x over the "
            "best single route")
    if vals["stripe_count"] < 2:
        errs.append("the striped PUT never actually split across routes")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
