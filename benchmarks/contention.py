"""Link contention on the event-driven fabric timeline — the aggregate-
traffic regime of "APEnet+: high bandwidth 3D torus direct network"
(arXiv:1102.3796) and the P2P measurements of arXiv:1307.8276.

Four claims, all priced on ``fabric.FabricSim`` (per-link-direction FIFOs,
~40 KB credit windows, dimension-ordered packet walks):

1. **Aggregate-bandwidth curve shape**: concurrent flows forced through
   ONE shared link direction saturate its sustained payload bandwidth —
   aggregate goodput plateaus at ~2.2 GB/s while per-flow goodput falls
   ~1/k; the same flows on disjoint links scale aggregate ~k.  This is
   the curve shape the companion paper measures on the real machine.

2. **``contention_slowdown``** (gated, higher-is-better): a KV-page
   migration PUT (the 7B-class twin of ``benchmarks/migration.py``)
   issued while decode-step TP all-reduce traffic is in flight on the
   same torus is priced measurably slower than the sum-of-isolated
   closed-form models would claim.  Every pre-sim model in this repo
   made exactly that under-estimate.

3. **``congestion_route_gain``** (gated, higher-is-better): picking the
   migration route by *simulated completion time* against live traffic
   (``fabric.best_route``) beats the hop-count-minimal route when the
   direct link is hammered — the detour family comes from the same BFS
   machinery the fault rewriter uses.

4. **Differential validation**: on single-flow ring schedules the sim
   agrees with the analytic estimate (<= 10% — in practice exact), so
   the contention numbers come from a model that provably matches the
   closed-form one wherever the closed form is right.
"""
from __future__ import annotations

from repro.core import apelink, fabric
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus

# 7B-class serving twin (matches benchmarks/migration.py)
TORUS = Torus((4, 4, 4))
N_LAYERS = 32
N_KV_HEADS = 8
HEAD_DIM = 128
KV_ITEMSIZE = 2
PAGE_TOKENS = 32
CONTEXT = 2048
D_MODEL = 4096
DECODE_BATCH = 32     # running decode slots per node (serving load)

BYTES_PER_TOKEN = 2 * N_LAYERS * N_KV_HEADS * HEAD_DIM * KV_ITEMSIZE
PAGE_NBYTES = PAGE_TOKENS * BYTES_PER_TOKEN
TP_STEP_BYTES = N_LAYERS * DECODE_BATCH * D_MODEL * 2   # bf16 residual AR
# the migrate-under-decode scenario: a 4-node serving ring (the cluster
# tests' topology), every node running its TP twin over the shared ring,
# while a 512-token slot migrates 2 hops — the PUT's route rides exactly
# the links the +1-direction TP ring traffic saturates.  Decode steps are
# chained (step i+1's collectives wait on step i's — the engine's actual
# cadence), a continuous stream spanning the PUT.
CONT_TORUS = Torus((4,))
MIG_CONTEXT = 512
MIG_PAGES = -(-MIG_CONTEXT // PAGE_TOKENS)
MIG_DST = 2
DECODE_STEPS_IN_FLIGHT = 24
# coarse packets for the bulk scenarios: 40 KB = one credit window per
# packet, 9x fewer events than the 4 KB default at identical byte totals
BULK_PACKET = 40960

FLOW_NBYTES = 4 << 20


def _shared_link_sweep() -> list[dict]:
    """k concurrent flows through one shared link direction, and the same
    k on disjoint links."""
    rows = []
    ring = Torus((8,))
    sustained = apelink.sustained_bandwidth()
    for k in (1, 2, 3, 4):
        sim = fabric.FabricSim(ring)
        # dimension-ordered routes 0 -> d (d <= 4) all cross link (0, 1)
        fids = [sim.inject(0, d, FLOW_NBYTES) for d in range(1, k + 1)]
        makespan = max(sim.finish_s(f) for f in fids)
        agg = k * FLOW_NBYTES / makespan
        per_flow = min(sim.flow(f).bandwidth for f in fids)
        rows.append({"bench": "contention", "metric": f"aggregate_gbps_{k}",
                     "value": agg / 1e9,
                     "note": f"{k} flows sharing link (0,1); plateau "
                             f"{sustained / 1e9:.2f} GB/s"})
        rows.append({"bench": "contention", "metric": f"per_flow_gbps_{k}",
                     "value": per_flow / 1e9,
                     "note": "slowest flow's goodput (~1/k)"})
        # disjoint placement: i -> i+1 pairs never share a link direction
        sim2 = fabric.FabricSim(ring)
        fids2 = [sim2.inject(2 * i, 2 * i + 1, FLOW_NBYTES)
                 for i in range(k)]
        mk2 = max(sim2.finish_s(f) for f in fids2)
        rows.append({"bench": "contention",
                     "metric": f"disjoint_aggregate_gbps_{k}",
                     "value": k * FLOW_NBYTES / mk2 / 1e9,
                     "note": "same k flows on disjoint links (~k x)"})
    return rows


def _decode_traffic(sim: fabric.FabricSim) -> list[int]:
    """Inject the in-flight decode TP collectives of the serving ring:
    one tensor-parallel all-reduce per decode step, steps chained (the
    engine cannot issue step i+1's collectives before step i's are done)
    — a continuous stream spanning the migration window."""
    tp = fabric.lower_all_reduce(CONT_TORUS, ("x",))
    fids: list[int] = []
    tail: list[int] = []
    for _ in range(DECODE_STEPS_IN_FLIGHT):
        tail = fabric.inject_schedule(sim, tp, TP_STEP_BYTES, start_s=0.0,
                                      after=tuple(tail),
                                      granularity="phase")
        fids.extend(tail)
    return fids


def _migration_contention() -> tuple[float, float, float]:
    """(isolated_s, contended_s, decode_slowdown) for the migrate-under-
    decode scenario — the exact ``put_pages`` call the cluster makes."""

    def put(sim):
        ep = RdmaEndpoint(CONT_TORUS, 0, sim=sim)
        dst_ep = RdmaEndpoint(CONT_TORUS, MIG_DST, sim=sim)
        region = ep.register(MIG_PAGES * PAGE_NBYTES)
        dst_region = dst_ep.register(MIG_PAGES * PAGE_NBYTES)
        return ep.put_pages(MIG_DST, region, list(range(MIG_PAGES)),
                            page_nbytes=PAGE_NBYTES, dst_endpoint=dst_ep,
                            dst_region=dst_region), ep.last_put_report

    def ring_sim():
        return fabric.FabricSim(CONT_TORUS, packet_bytes=BULK_PACKET)

    # quiet fabric: the sim agrees with the sum-of-isolated price
    _, quiet_report = put(ring_sim())
    # live fabric: the decode stream in flight on the same links
    sim = ring_sim()
    decode_fids = _decode_traffic(sim)
    sim_idle = ring_sim()
    idle_fids = _decode_traffic(sim_idle)
    decode_alone = max(sim_idle.finish_s(f) for f in idle_fids)
    contended, _ = put(sim)
    decode_with_mig = max(sim.finish_s(f) for f in decode_fids)
    return quiet_report["isolated_s"], contended, \
        decode_with_mig / decode_alone


def _congestion_routing() -> tuple[float, float, int]:
    """(t_hops, t_congestion_aware, chosen_hops): route 0 -> +x neighbour
    while a bulk transfer hammers the direct link."""
    nbr = TORUS.rank((1, 0, 0))
    sim = fabric.FabricSim(TORUS, packet_bytes=BULK_PACKET)
    sim.inject(0, nbr, 64 << 20)          # background: 64 MB on the link
    direct = tuple(TORUS.route(0, nbr))
    t_hops = sim.probe_route(direct, MIG_PAGES * PAGE_NBYTES)
    route, t_best = fabric.best_route(sim, 0, nbr, MIG_PAGES * PAGE_NBYTES)
    return t_hops, t_best, len(route) - 1


def _sim_analytic_maxerr() -> float:
    worst = 0.0
    for dims, axes in (((8,), ("x",)), ((2, 4), ("a", "b")),
                       ((2, 2, 2), ("u", "v", "w"))):
        t = Torus(dims)
        sched = fabric.lower_all_reduce(t, axes)
        for nbytes in (4096, 1 << 20):
            a = fabric.estimate(sched, nbytes).total_s
            s = fabric.estimate(sched, nbytes, backend="sim").total_s
            worst = max(worst, abs(s - a) / a)
    return worst


def run() -> list[dict]:
    rows = _shared_link_sweep()
    isolated, contended, decode_slow = _migration_contention()
    rows += [
        {"bench": "contention", "metric": "migration_isolated_ms",
         "value": isolated * 1e3,
         "note": f"{MIG_PAGES * PAGE_NBYTES / 1e6:.1f} MB PUT ({MIG_CONTEXT}-token slot), quiet fabric "
                 "(= the old sum-of-isolated price)"},
        {"bench": "contention", "metric": "migration_contended_ms",
         "value": contended * 1e3,
         "note": f"same PUT under {DECODE_STEPS_IN_FLIGHT} decode steps "
                 "of TP all-reduce traffic"},
        {"bench": "contention", "metric": "contention_slowdown",
         "value": contended / isolated, "gate": "higher",
         "note": "concurrent migrate+decode vs sum-of-isolated (> 1 = "
                 "the isolated models under-priced it)"},
        {"bench": "contention", "metric": "decode_slowdown_under_migration",
         "value": decode_slow,
         "note": "decode TP comm stretch while the PUT is in flight "
                 "(contention cuts both ways)"},
    ]
    t_hops, t_best, hops = _congestion_routing()
    rows += [
        {"bench": "contention", "metric": "route_hopcount_ms",
         "value": t_hops * 1e3,
         "note": "hop-minimal route behind a 64 MB bulk transfer"},
        {"bench": "contention", "metric": "route_congestion_aware_ms",
         "value": t_best * 1e3,
         "note": f"best simulated-completion route ({hops} hops)"},
        {"bench": "contention", "metric": "congestion_route_gain",
         "value": t_hops / t_best, "gate": "higher",
         "note": "hop-count time / congestion-aware time (> 1 = the "
                 "detour won)"},
        {"bench": "contention", "metric": "congestion_route_hops",
         "value": hops, "note": "vs 1 direct hop"},
        {"bench": "contention", "metric": "sim_analytic_maxerr",
         "value": _sim_analytic_maxerr(),
         "note": "sim vs analytic on single-flow ring schedules "
                 "(differential validation, must be <= 0.10)"},
    ]
    return rows


def check(rows) -> list[str]:
    vals = {r["metric"]: r["value"] for r in rows}
    errs = []
    sustained = apelink.sustained_bandwidth() / 1e9
    for k in (1, 2, 3, 4):
        if vals[f"aggregate_gbps_{k}"] > sustained * 1.02:
            errs.append(f"aggregate bandwidth at k={k} exceeds the link's "
                        f"sustained rate ({sustained:.2f} GB/s)")
    per = [vals[f"per_flow_gbps_{k}"] for k in (1, 2, 3, 4)]
    if not all(a > b for a, b in zip(per, per[1:])):
        errs.append(f"per-flow goodput must fall with concurrency: {per}")
    if vals["disjoint_aggregate_gbps_4"] < 2.5 * vals["aggregate_gbps_4"]:
        errs.append("disjoint flows failed to scale aggregate bandwidth")
    if vals["contention_slowdown"] <= 1.10:
        errs.append(
            f"concurrent migrate+decode only {vals['contention_slowdown']:.3f}x "
            "the isolated price — contention not measurable")
    if vals["decode_slowdown_under_migration"] <= 1.0:
        errs.append("decode traffic saw no slowdown from the migration PUT")
    if vals["congestion_route_gain"] <= 1.05:
        errs.append(
            f"congestion-aware routing gained only "
            f"{vals['congestion_route_gain']:.3f}x over hop-count routing")
    if vals["congestion_route_hops"] <= 1:
        errs.append("congestion-aware router never took the detour")
    if vals["sim_analytic_maxerr"] > 0.10:
        errs.append(
            f"sim vs analytic differential {vals['sim_analytic_maxerr']:.3f} "
            "exceeds the 10% agreement bar on single-flow schedules")
    return errs


if __name__ == "__main__":
    for r in run():
        print(f"{r['bench']},{r['metric']},{r['value']}")
