"""Fault-tolerant trainer: LO|FA|MO watchdogs + checkpoint/restart +
elastic re-mesh + straggler detection.

Two communication modes:

  * ``comm="gspmd"`` — params/optimizer sharded by parallel.sharding specs,
    XLA inserts the collectives (production default; this is what the
    dry-run lowers);
  * ``comm="apex"``  — the paper-faithful path: the step runs inside
    shard_map over the DP axis, gradients are synchronised by the explicit
    bidirectional ring reduce-scatter / all-gather of core/collectives
    (first-neighbour torus RDMA, dual-DMA double buffering) with shard-local
    ZeRO-1 moments.  Model must fit per device (DP-pure).

Fault tolerance loop (per §4 of the paper):

  host watchdog ticks each step -> LofamoSim (the fabric model) diffuses
  any injected/host fault to neighbours -> the trainer's master view flags
  the rank -> trainer restores the last verified checkpoint onto the
  surviving mesh (elastic re-mesh: any device subset that still forms a
  torus) and replays the data stream from the checkpointed position.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointStore
from repro.core import collectives as C
from repro.core import fabric, hw, jaxcompat
from repro.core.lofamo import LofamoSim
from repro.core.rdma import RdmaEndpoint
from repro.core.topology import Torus
from repro.data import SyntheticTokens, make_batch_arrays
from repro.models import api
from repro.models.common import ArchCfg
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import apex_zero1_update
from repro.parallel import sharding


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/apex_ckpt"
    ckpt_every: int = 50
    keep_last: int = 3
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    batch: int = 8
    seq_len: int = 128
    # microbatch gradient accumulation: the global batch is split into
    # `grad_accum` sequential microbatches whose grads accumulate in fp32
    # before one optimizer step — on TPU the DP gradient reduction of
    # microbatch i overlaps the compute of i+1 (XLA async collectives),
    # and activation memory drops by the same factor
    grad_accum: int = 1
    remat: bool = True
    comm: str = "gspmd"            # or "apex"
    dp_axis: str = "data"
    # link-fault policy ("remesh" is the node-fault-only default: a dead
    # link loses no state, so it is logged and routing is left to the
    # runtime fabric); "reroute" (apex comm only) = rewrite the collective
    # schedules around the dead link and keep training — no restart, no
    # lost steps, just a higher predicted hop cost.  Node faults always
    # checkpoint-restart on an elastically re-meshed machine.
    fault_mode: str = "remesh"
    # overlap engine (apex comm only): bucket the gradient reduce-scatter
    # (fabric.plan_buckets) and issue each bucket's schedule inside the
    # backward pass via the fabric bucket grad hook, so the ppermute
    # rounds overlap the remaining backward compute — the schedule-level
    # analogue of the §2.1 dual-DMA prefetchable command queue.  Numerics
    # are identical to the sequential step (fp32 params: bitwise).
    overlap: bool = False
    # bucket size target (MB of fp32 grads).  The default (None) loads
    # the fabric autotuner's searched value from ``best_configs.json``
    # ("train" workload entry — see ``fabric.autotune``) and falls back
    # to the hand-tuned 4 MB when no artifact is pinned; passing any
    # explicit number always wins (the escape hatch).
    bucket_mb: float | None = None
    # fabric time-model backend for predicted_comm_s / the overlap
    # estimate: "analytic" (closed-form, the fast default) or "sim" (the
    # event-driven link-level FabricSim replay — same number on healthy
    # single-flow schedules, honest contention pricing under detours)
    cost_backend: str = "analytic"
    # sim-backend fidelity tier: "packet" (the bitwise oracle), "fluid"
    # (flow-level rate allocation — the fast path for big tori) or
    # "hybrid" (fluid with packet escalation of contended links).  The
    # analytic backend ignores it.
    cost_fidelity: str = "packet"
    wd_period: float = 0.5          # LO|FA|MO watchdog period (seconds)
    straggler_factor: float = 3.0   # step slower than this x median -> flag
    seed: int = 0
    # LO|FA|MO fabric shape override: the fault model may cover the full
    # cluster even when this process drives fewer devices (default: the
    # mesh's own torus twin)
    torus_dims: tuple | None = None

    def __post_init__(self) -> None:
        if self.bucket_mb is None:
            from repro.core.fabric import autotune
            self.bucket_mb = float(
                autotune.tuned_knob("train", "bucket_mb", 4.0))


class Trainer:
    def __init__(self, cfg: ArchCfg, tcfg: TrainerConfig,
                 mesh: Mesh | None = None,
                 telemetry: "object | None" = None) -> None:
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        # optional fabric Telemetry hub: step spans, fault-epoch events
        # and the RDMA twin's counters (None = zero telemetry code runs)
        self.telemetry = telemetry
        self.model = api.get_model(cfg)
        self.store = CheckpointStore(tcfg.ckpt_dir, keep_last=tcfg.keep_last)
        self.data = SyntheticTokens(cfg, tcfg.batch, tcfg.seq_len,
                                    seed=tcfg.seed)
        self.metrics_log: list[dict] = []
        self.events: list[str] = []
        self._step_times: list[float] = []
        # LO|FA|MO fabric model over the mesh's torus twin
        if tcfg.torus_dims is not None:
            dims = tuple(tcfg.torus_dims)
        elif mesh is not None:
            dims = tuple(mesh.shape[a] for a in mesh.axis_names)
        else:
            dims = (1,)
        self.torus = Torus(dims)
        self.lofamo = LofamoSim(self.torus, wd_period=tcfg.wd_period)
        # RDMA endpoint twin: its command-queue depth feeds the overlap
        # model (prefetchable queue = issue gaps hidden between buckets)
        self.rdma = RdmaEndpoint(self.torus, rank=0, telemetry=telemetry)
        self._handled_faults: set[int] = set()
        self._handled_links: set[tuple[int, int]] = set()
        self._fault_map = fabric.FaultMap()
        self.predicted_comm_s: float | None = None
        self.bucket_plan: fabric.BucketPlan | None = None
        self.overlap_estimate: fabric.OverlapEstimate | None = None
        self._overlap_baseline: dict | None = None
        self._build()

    # ------------------------------------------------------------------ build
    def _build(self) -> None:
        cfg, tcfg = self.cfg, self.tcfg
        key = jax.random.key(tcfg.seed)
        if self.mesh is None or tcfg.comm == "single":
            self.params = self.model.init(key)
            self.opt_state = adamw_init(self.params)
            self._make_single_step()
            return
        if tcfg.comm == "apex":
            self._build_apex(key)
        else:
            self._build_gspmd(key)

    def _loss_and_grads(self):
        """(params, batch) -> (loss, grads); microbatched when grad_accum>1
        (fp32 accumulation, one optimizer step per global batch)."""
        model, remat, accum = self.model, self.tcfg.remat, self.tcfg.grad_accum

        def single(params, batch):
            return jax.value_and_grad(
                lambda p: model.train_loss(p, batch, remat=remat))(params)

        if accum <= 1:
            return single

        def accumulated(params, batch):
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = single(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            inv = 1.0 / accum
            return loss * inv, jax.tree.map(lambda g: g * inv, grads)

        return accumulated

    def _make_single_step(self):
        opt = self.tcfg.opt
        loss_and_grads = self._loss_and_grads()

        @jax.jit
        def step_fn(params, opt_state, batch):
            loss, grads = loss_and_grads(params, batch)
            params, opt_state, metrics = adamw_update(opt, grads, opt_state,
                                                      params)
            return params, opt_state, {"loss": loss, **metrics}

        self._step_fn = step_fn
        self.batch_shardings = None

    def _build_gspmd(self, key) -> None:
        cfg, tcfg, mesh = self.cfg, self.tcfg, self.mesh
        shapes = api.param_shapes(cfg)
        pspecs = sharding.param_specs(cfg, shapes, mesh)
        self.param_shardings = sharding.named(mesh, pspecs)
        params = jax.jit(self.model.init,
                         out_shardings=self.param_shardings)(key)
        ostate_shapes = jax.eval_shape(adamw_init, shapes)
        ospecs = {"m": sharding.zero1_specs(cfg, shapes, mesh),
                  "v": sharding.zero1_specs(cfg, shapes, mesh),
                  "step": P()}
        self.opt_shardings = sharding.named(mesh, ospecs)
        opt_state = jax.jit(adamw_init,
                            out_shardings=self.opt_shardings)(params)
        batch_shapes = jax.eval_shape(
            lambda: jax.tree.map(
                jnp.zeros_like,
                make_batch_arrays(self.data.next_batch(), cfg)))
        self.data.step -= 1  # the eval_shape batch was a peek
        bspecs = sharding.batch_specs(cfg, batch_shapes, mesh)
        self.batch_shardings = sharding.named(mesh, bspecs)
        opt = tcfg.opt
        loss_and_grads = self._loss_and_grads()

        @jax.jit
        def step_fn(params, opt_state, batch):
            loss, grads = loss_and_grads(params, batch)
            params, opt_state, metrics = adamw_update(opt, grads, opt_state,
                                                      params)
            return params, opt_state, {"loss": loss, **metrics}

        self._step_fn = step_fn
        self.params, self.opt_state = params, opt_state

    # ------------------------------------------------------- apex (fabric)
    def _apex_schedules(self) -> dict:
        """Lower the apex step's collective schedules against the fabric
        torus, rewritten around the currently known fault map."""
        axis = self.tcfg.dp_axis
        dp = self.mesh.shape[axis]
        torus = self.torus if self.torus.dims == (dp,) else Torus((dp,))
        scheds = {
            "rs": fabric.lower_reduce_scatter(torus, (axis,), mean=True),
            "ag": fabric.lower_all_gather(torus, (axis,)),
            "loss": fabric.lower_all_reduce(torus, (axis,), mean=True),
        }
        if self._fault_map:
            scheds = {k: fabric.rewrite(s, self._fault_map)
                      for k, s in scheds.items()}
        return scheds

    def _predict_comm_s(self, scheds) -> float:
        """Predicted per-step gradient-sync time: every leaf's fp32 grad
        reduce-scatter plus updated-param all-gather, priced on the same
        schedules the step executes (fabric cost model)."""
        axis = self.tcfg.dp_axis
        dp = self.mesh.shape[axis]
        backend = self.tcfg.cost_backend
        # trainer collectives carry the COLLECTIVE traffic class.  Both
        # default backends price a quiet fabric where the tag is inert
        # (analytic ignores it; backend="sim" builds a single-class sim);
        # it matters when a caller prices these schedules on a QoS sim —
        # fabric.estimate(..., backend="sim", qos=QosPolicy()) or a
        # shared ServingCluster timeline — where the flows then ride the
        # COLLECTIVE virtual channel
        cls = fabric.TrafficClass.COLLECTIVE
        fid = self.tcfg.cost_fidelity
        total = fabric.estimate(scheds["loss"], 4, backend=backend,
                                fidelity=fid, cls=cls).total_s
        for p in jax.tree.leaves(self.params):
            chunk_bytes = -(-p.size // dp) * p.dtype.itemsize
            total += fabric.estimate(scheds["rs"], 4 * p.size,
                                     backend=backend, fidelity=fid,
                                     cls=cls).total_s
            total += fabric.estimate(scheds["ag"], chunk_bytes,
                                     backend=backend, fidelity=fid,
                                     cls=cls).total_s
        return total

    def _bwd_compute_model_s(self) -> float:
        """Modelled per-rank backward-compute seconds — the overlap model's
        compute trace (backward ~ 2x forward = 4 * P * T FLOPs, priced at a
        conservative 40% MFU on the target chip)."""
        dp = self.mesh.shape[self.tcfg.dp_axis]
        tokens = self.tcfg.batch * self.tcfg.seq_len / max(dp, 1)
        flops = 4.0 * self.n_params * tokens
        return flops / (hw.TPU_V5E.peak_flops_bf16 * 0.4)

    def _make_apex_step(self) -> None:
        """(Re)build the jitted apex step from the current schedules.

        With ``overlap=True`` the gradient reduce-scatter runs bucket by
        bucket *inside* the backward pass (fabric bucket grad hook) and the
        ZeRO-1 update consumes the pre-reduced shards; a sequential twin of
        the step is also built as the measured-overlap baseline."""
        tcfg, mesh = self.tcfg, self.mesh
        axis = tcfg.dp_axis
        model, opt, remat = self.model, tcfg.opt, tcfg.remat
        scheds = self._apex_schedules()
        self.apex_schedules = scheds
        self.predicted_comm_s = self._predict_comm_s(scheds)
        overlap = tcfg.overlap
        self._overlap_baseline = None
        if overlap:
            bucket_bytes = max(int(tcfg.bucket_mb * (1 << 20)), 1)
            self.bucket_plan = fabric.plan_buckets(self.params, bucket_bytes)
            self.overlap_estimate = fabric.estimate_overlapped(
                scheds["rs"], self.bucket_plan, self._bwd_compute_model_s(),
                queue_depth=self.rdma.queue_depth,
                backend=self.tcfg.cost_backend,
                fidelity=self.tcfg.cost_fidelity,
                cls=fabric.TrafficClass.COLLECTIVE)
        else:
            self.bucket_plan = None
            self.overlap_estimate = None

        def make_per_shard(bucketed: bool):
            hook = (fabric.make_bucket_grad_hook(self.bucket_plan,
                                                 scheds["rs"])
                    if bucketed else (lambda p: p))

            def per_shard(params, m, v, step, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: model.train_loss(hook(p), batch,
                                               remat=remat))(params)
                # mean loss across DP ranks over the torus ring
                loss = C.ring_all_reduce(loss[None], axis,
                                         schedule=scheds["loss"])[0]
                state = {"m": m, "v": v, "step": step}
                params, state = apex_zero1_update(opt, grads, state, params,
                                                  axis_name=axis,
                                                  rs_schedule=scheds["rs"],
                                                  ag_schedule=scheds["ag"],
                                                  pre_reduced=bucketed)
                return params, state["m"], state["v"], state["step"], loss

            return per_shard

        in_specs = (P(), P(axis), P(axis), P(), P(axis))
        out_specs = (P(), P(axis), P(axis), P(), P())
        # check_vma off: outputs ARE replicated (post all-gather), but the
        # ppermute chain hides that from the varying-axes checker.
        self._apex_step = jax.jit(jaxcompat.shard_map(
            make_per_shard(overlap), mesh=mesh, in_specs=in_specs,
            out_specs=out_specs, check_vma=False))
        self._apex_step_seq = None
        self._apex_compute_fn = None
        if overlap:
            self._apex_step_seq = jax.jit(jaxcompat.shard_map(
                make_per_shard(False), mesh=mesh, in_specs=in_specs,
                out_specs=out_specs, check_vma=False))

            def grads_only(params, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: model.train_loss(p, batch,
                                               remat=remat))(params)
                # the grads must be consumed in the output or XLA dead-code
                # eliminates the whole backward pass and this "compute
                # baseline" times the forward only
                keep = sum(jnp.sum(g.astype(jnp.float32))
                           for g in jax.tree.leaves(grads))
                return jnp.stack([loss, keep])[None]

            self._apex_compute_fn = jax.jit(jaxcompat.shard_map(
                grads_only, mesh=mesh, in_specs=(P(), P(axis)),
                out_specs=P(axis), check_vma=False))

        def step_fn(params, opt_state, batch):
            params, m, v, step, loss = self._apex_step(
                params, opt_state["m"], opt_state["v"], opt_state["step"],
                batch)
            return params, {"m": m, "v": v, "step": step}, {"loss": loss}

        self._step_fn = step_fn

    def _measure_overlap_baseline(self, batch) -> dict:
        """One-off calibration for measured overlap efficiency: wall-time
        the sequential (barrier) apex step and the compute-only backward on
        the live batch shapes (second run each, past jit compilation).
        Also warms the overlapped step itself, so the step times compared
        against these baselines never include its compile."""
        args = (self.params, self.opt_state["m"], self.opt_state["v"],
                self.opt_state["step"], batch)

        def timed(fn, *a):
            jax.block_until_ready(fn(*a))
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*a))
            return time.perf_counter() - t0

        seq_s = timed(self._apex_step_seq, *args)
        compute_s = timed(self._apex_compute_fn, self.params, batch)
        jax.block_until_ready(self._apex_step(*args))   # warm, discard
        return {"seq_s": seq_s, "compute_s": compute_s}

    def _build_apex(self, key) -> None:
        """Paper-faithful DP: shard_map + explicit torus ring collectives,
        every collective lowered through the fabric's CollectiveSchedule."""
        axis = self.tcfg.dp_axis
        dp = self.mesh.shape[axis]
        self.params = self.model.init(key)   # replicated
        self._make_apex_step()
        # global moment buffers: (dp * chunk,) per leaf
        m = jax.tree.map(
            lambda p: jnp.zeros((dp * (-(-p.size // dp)),), jnp.float32),
            self.params)
        self.opt_state = {"m": m, "v": jax.tree.map(jnp.copy, m),
                          "step": jnp.zeros((), jnp.int32)}
        self.batch_shardings = None
        self._batch_spec = P(axis)

    @property
    def n_params(self) -> int:
        return sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(self.params))

    def _place_tree(self, tree):
        """Re-place a restored host tree onto the current mesh shardings."""
        if getattr(self, "param_shardings", None) is not None \
                and self.tcfg.comm == "gspmd" and self.mesh is not None:
            return {"params": jax.device_put(tree["params"],
                                             self.param_shardings),
                    "opt": jax.device_put(tree["opt"], self.opt_shardings)}
        return jax.tree.map(jnp.asarray, tree)

    def resume(self) -> None:
        """Restore the latest checkpoint (raises FileNotFoundError if none)."""
        template = {"params": self.params, "opt": self.opt_state}
        tree, extra = self.store.restore_latest(
            jax.tree.map(np.asarray, template))
        placed = self._place_tree(tree)
        self.params, self.opt_state = placed["params"], placed["opt"]
        self.data = SyntheticTokens.from_state(
            self.cfg, self.tcfg.batch, self.tcfg.seq_len, extra["data"])
        self.events.append(f"resumed from checkpoint @ step {self.data.step}")

    # ------------------------------------------------------------------- loop
    def _place_batch(self, np_batch):
        batch = make_batch_arrays(np_batch, self.cfg, self.batch_shardings)
        if self.tcfg.comm == "apex" and self.mesh is not None:
            batch = {k: jax.device_put(
                v, NamedSharding(self.mesh, P(self.tcfg.dp_axis)))
                for k, v in batch.items()}
        return batch

    def train_step(self) -> dict:
        t0 = time.perf_counter()
        # models with explicit shard_map paths (ep_a2a MoE, manual_sp)
        # resolve the mesh through the registry at trace time
        sharding.set_runtime_mesh(self.mesh)
        np_batch = self.data.next_batch()
        batch = self._place_batch(np_batch)
        if self.tcfg.comm == "apex" and self.tcfg.overlap \
                and self._overlap_baseline is None \
                and self._apex_step_seq is not None:
            self._overlap_baseline = self._measure_overlap_baseline(batch)
            t0 = time.perf_counter()  # calibration is not step time
        self.params, self.opt_state, metrics = self._step_fn(
            self.params, self.opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        self._step_times.append(dt)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step_time_s"] = dt
        metrics["step"] = self.data.step
        if self.predicted_comm_s is not None:
            # fabric cost model vs wall clock: the schedule's predicted
            # gradient-sync time for this step (APEnet+ NetModel pricing)
            metrics["predicted_comm_s"] = self.predicted_comm_s
        if self.overlap_estimate is not None:
            # overlap engine: predicted overlap efficiency (fraction of
            # fabric time hidden behind backward compute, from the
            # bucketed timeline model) vs the measured one (wall clock of
            # the overlapped step against the sequential-step and
            # compute-only calibration baselines)
            est = self.overlap_estimate
            metrics["overlap_eff_pred"] = est.efficiency
            metrics["overlap_pred_reduction"] = est.reduction
            metrics["overlap_pred_total_s"] = est.total_s
            if self._overlap_baseline is not None:
                base = self._overlap_baseline
                comm_meas = max(base["seq_s"] - base["compute_s"], 1e-9)
                eff = (base["seq_s"] - dt) / comm_meas
                metrics["overlap_eff_measured"] = float(
                    np.clip(eff, 0.0, 1.0))
                metrics["seq_step_s"] = base["seq_s"]
        # straggler detection: this step vs the running median
        if len(self._step_times) >= 5:
            med = float(np.median(self._step_times[-20:]))
            if dt > self.tcfg.straggler_factor * med:
                metrics["straggler"] = True
                self.events.append(
                    f"straggler step={self.data.step} {dt:.3f}s vs median "
                    f"{med:.3f}s — would re-issue on hot spare")
        self.metrics_log.append(metrics)
        if self.telemetry is not None:
            self.telemetry.add("trainer.steps")
            self.telemetry.add("trainer.step_time_s", dt)
            # trainer spans ride a logical clock (cumulative step time):
            # the trainer has no fabric sim frontier to stamp against
            self.telemetry.event(
                ("trainer",), f"step{self.data.step}",
                sum(self._step_times[:-1]), dt,
                loss=metrics.get("loss", 0.0), step=self.data.step)
        return metrics

    def checkpoint(self) -> None:
        tree = {"params": self.params, "opt": self.opt_state}
        self.store.save_async(self.data.step, tree,
                              extra={"data": self.data.state(),
                                     "arch": self.cfg.name})
        self.events.append(f"checkpoint @ step {self.data.step}")

    def train(self, steps: int, *, fault_hook: Callable[[int], None] | None
              = None) -> list[dict]:
        out = []
        for i in range(steps):
            if fault_hook:
                fault_hook(i)
            # LO|FA|MO: one watchdog tick per step (the diagnostic traffic
            # rides the fabric; zero cost on the data path)
            self.lofamo.step()
            failed = self.lofamo.detected_at_master() - self._handled_faults
            if failed:
                self._recover(failed)
                self._handled_faults |= failed
            links = (self.lofamo.detected_links_at_master()
                     - self._handled_links)
            if links:
                self._handle_link_faults(links)
                self._handled_links |= links
            out.append(self.train_step())
            if self.tcfg.ckpt_every and \
                    self.data.step % self.tcfg.ckpt_every == 0:
                self.checkpoint()
        self.store.wait()
        return out

    # -------------------------------------------------------------- recovery
    def _handle_link_faults(self, links: set[tuple[int, int]]) -> None:
        """A torus link died but both endpoints live.  Under
        ``fault_mode="reroute"`` (apex comm) the collective schedules are
        rewritten around the dead link — same numerics, no restart, only a
        higher predicted hop cost; otherwise we just log the awareness."""
        self.events.append(
            f"LO|FA|MO: master aware of dead link(s) {sorted(links)}")
        if self.telemetry is not None:
            self.telemetry.add("fabric.fault_epochs")
            self.telemetry.event(
                ("trainer",), "link_fault", sum(self._step_times),
                links=sorted(links))
        if self.tcfg.fault_mode != "reroute" or self.tcfg.comm != "apex" \
                or self.mesh is None:
            return
        dp = self.mesh.shape[self.tcfg.dp_axis]
        if self.torus.dims != (dp,):
            # LofamoSim link pairs are ranks of self.torus; the apex
            # schedules are lowered on the dp ring — without a 1:1 match
            # the pair would be misread in the other rank space
            self.events.append(
                f"reroute unsupported: fault torus {self.torus.dims} is not "
                f"the dp ring ({dp},); routing left to the runtime fabric")
            return
        before = self.predicted_comm_s
        self._fault_map = fabric.FaultMap.normalized(
            self._fault_map.dead_nodes,
            set(self._fault_map.dead_links) | links)
        try:
            self._make_apex_step()
        except fabric.UnroutableError as e:
            self.events.append(f"reroute impossible ({e}); keeping schedule")
            return
        hops = max(s.max_hops for s in self.apex_schedules.values())
        self.events.append(
            f"rerouted collectives around {sorted(links)}: detour "
            f"max_hops={hops}, predicted grad-sync "
            f"{(before or 0) * 1e3:.2f} -> {self.predicted_comm_s * 1e3:.2f} ms"
            " (training continues, no restart)")

    def _recover(self, failed: set[int]) -> None:
        """Checkpoint-restart on the surviving mesh (elastic re-mesh)."""
        self.events.append(f"LO|FA|MO: master aware of faults {sorted(failed)}"
                           f" (Ta ~ {1.8 * self.tcfg.wd_period:.2f}s)")
        self.store.wait()
        survivors = [d for i, d in enumerate(self.mesh.devices.flat)
                     if i not in failed] if self.mesh is not None else []
        if self.mesh is not None and survivors \
                and len(self.mesh.axis_names) == 1:
            # largest power-of-two prefix that still forms a ring
            n = 1
            while n * 2 <= len(survivors):
                n *= 2
            from repro.launch.mesh import make_mesh
            new_mesh = make_mesh((n,), self.mesh.axis_names,
                                 devices=survivors[:n])
            self.events.append(
                f"elastic re-mesh: {self.mesh.devices.size} -> {n} devices")
            self.mesh = new_mesh
            self.torus = Torus(tuple(new_mesh.shape[a]
                                     for a in new_mesh.axis_names))
            self.lofamo = LofamoSim(self.torus,
                                    wd_period=self.tcfg.wd_period)
            # fresh fabric: the surviving devices' links are all healthy
            self._fault_map = fabric.FaultMap()
            self._handled_links = set()
        # restore model+opt+data from the last verified checkpoint
        template = {"params": self.params, "opt": self.opt_state}
        try:
            tree, extra = self.store.restore_latest(
                jax.tree.map(np.asarray, template))
        except FileNotFoundError:
            self.events.append("no checkpoint yet: restarting from init")
            self._build()
            return
        self._build()  # rebuild step fn / shardings for the new mesh
        placed = self._place_tree(tree)
        self.params, self.opt_state = placed["params"], placed["opt"]
        self.data = SyntheticTokens.from_state(
            self.cfg, self.tcfg.batch, self.tcfg.seq_len, extra["data"])
        self.events.append(
            f"restored step {self.data.step}; data stream replayed")
