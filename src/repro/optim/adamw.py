"""AdamW with global-norm clipping, cosine schedule, ZeRO-1-friendly state.

Moments are fp32 regardless of param dtype (bf16 training); their
PartitionSpecs come from parallel.sharding.zero1_specs, which shards them
further over the "data" axis — XLA then keeps the update fully sharded and
reduce-scatters gradients into it (ZeRO-1 under GSPMD).

Also provides the *explicit* APEX update used by the paper-faithful DP
trainer: gradients reduce-scattered with the torus ring collectives, the
shard-local moment update, and the parameter all-gather — the RDMA-fabric
version of the same math (runtime/trainer.py wires it into shard_map).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics


# ----------------------------------------------------------------------------
# APEX explicit ZeRO-1 update (inside shard_map over the DP axes):
#   RS(grads) -> shard-local AdamW on the 1/N state slice -> AG(params)
# All traffic is first-neighbour torus ppermutes (core/collectives).
# ----------------------------------------------------------------------------

def apex_zero1_init(params, dp: int):
    """Shard-local fp32 moment slices: each DP rank owns 1/dp of every
    (flattened, padded) parameter.  Run inside shard_map (out_specs P(dp))
    so the global representation is the concatenation of rank slices."""
    def shard_zeros(p):
        n = p.size
        chunk = -(-n // dp)  # ceil
        return jnp.zeros((chunk,), jnp.float32)

    zeros = jax.tree.map(shard_zeros, params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def apex_zero1_update(cfg: AdamWConfig, grads, state, params, *,
                      axis_name: str, rs_schedule=None, ag_schedule=None,
                      pre_reduced: bool = False):
    """Per-shard code (inside shard_map).  grads/params are the full
    (replicated w.r.t. the DP axis) values; moments are 1/N slices.

    ``rs_schedule``/``ag_schedule`` are optional pre-lowered (possibly
    fault-rewritten) ``fabric.CollectiveSchedule`` objects for the gradient
    reduce-scatter and parameter all-gather.

    ``pre_reduced=True`` is the overlap-engine contract: gradients were
    already reduce-scattered inside the backward pass by the fabric's
    bucket grad hook (``fabric.make_bucket_grad_hook``) — each leaf holds
    this rank's reduced chunk at its ring slot (zeros elsewhere), so the
    update only slices its shard out instead of running the collective
    again."""
    from repro.core import collectives as C

    step = state["step"] + 1
    # global grad norm: local full grads are identical only AFTER sync; here
    # grads are per-shard microbatch grads -> mean-reduce first (RS gives us
    # the mean shard directly).
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        if pre_reduced:
            # bucket hook already ran the ring RS inside backward: slice
            # this rank's chunk (the rest of the buffer is zeros)
            from repro.core import jaxcompat as _jc
            n_ = _jc.axis_size(axis_name)
            chunk_ = m.shape[0]
            gflat = g.reshape(-1).astype(jnp.float32)
            gshard = jax.lax.dynamic_slice(
                jnp.pad(gflat, (0, chunk_ * n_ - gflat.size)),
                (jax.lax.axis_index(axis_name) * chunk_,), (chunk_,))
        else:
            # mean gradient shard for this rank (ring reduce-scatter)
            gshard = C.ring_reduce_scatter(g.astype(jnp.float32), axis_name,
                                           mean=True, schedule=rs_schedule)
        pflat = p.reshape(-1)
        m = b1 * m + (1 - b1) * gshard
        v = b2 * v + (1 - b2) * gshard * gshard
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        # matching param shard
        from repro.core import jaxcompat
        n = jaxcompat.axis_size(axis_name)
        chunk = m.shape[0]
        r = jax.lax.axis_index(axis_name)
        pshard = jax.lax.dynamic_slice(
            jnp.pad(pflat, (0, chunk * n - pflat.size)), (r * chunk,),
            (chunk,)).astype(jnp.float32)
        if cfg.weight_decay and p.ndim >= 2:
            delta = delta + cfg.weight_decay * pshard
        new_shard = pshard - lr * delta
        # all-gather the updated parameter (bf16 on the wire)
        full = C.ring_all_gather(new_shard.astype(p.dtype), axis_name,
                                 schedule=ag_schedule)
        return full.reshape(-1)[: p.size].reshape(p.shape), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_state = {"m": treedef.unflatten([o[1] for o in out]),
                 "v": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_p, new_state
