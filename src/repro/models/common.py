"""Shared model building blocks: config, norms, embeddings, RoPE, MLPs.

Everything is a pure function over explicit parameter pytrees (no framework
modules): params are nested dicts of jnp arrays, layer stacks carry a
leading L axis and are walked with lax.scan so the HLO stays O(1) in depth
— essential for dry-run compiles of 80-layer configs on 512 devices.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Literal

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# configuration
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoeCfg:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SsmCfg:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    dt_min: float = 1e-3
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ArchCfg:
    """One architecture = one frozen config (see repro/configs/*)."""

    name: str
    family: Literal["dense", "moe", "mamba2", "rwkv6", "zamba2", "encdec",
                    "vlm"]
    n_layers: int
    d_model: int
    n_heads: int          # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0     # 0 -> d_model // n_heads
    norm: Literal["rms", "ln"] = "rms"
    mlp: Literal["swiglu", "gelu"] = "swiglu"
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    moe: MoeCfg | None = None
    ssm: SsmCfg | None = None
    # zamba2: one shared attention+MLP block applied every `attn_every`
    # mamba layers
    attn_every: int = 6
    # encdec: encoder depth (decoder gets n_layers); frontend emits frames
    n_enc_layers: int = 0
    n_frames: int = 1500
    # vlm: number of stub patch embeddings prepended to the text sequence
    n_patches: int = 256
    # dtypes
    dtype: Any = jnp.bfloat16       # activations / layer params
    # True for archs whose attention is quadratic in context (skip long_500k)
    full_attention: bool = True
    # recurrent-scan implementation: "auto" (pallas on TPU, chunked SSD
    # elsewhere) | "chunked" | "pertoken" (sequential oracle; the dry-run
    # baseline) — see kernels/ops.py and EXPERIMENTS.md §Perf
    scan_impl: str = "auto"
    # TP activation policy (§Perf H3): "free" lets GSPMD propagate whatever
    # sharding it likes through the residual stream; "megatron" pins layer
    # I/O replicated over 'model' (batch over DP), "sp" pins the sequence
    # dim over 'model' between blocks.  Needs a registered runtime mesh.
    tp_activations: str = "free"
    # MoE dispatch (§Perf H2): "global" = sort-based global-capacity
    # dispatch (GSPMD chooses the collectives); "ep_a2a" = shard_map
    # expert-parallel dispatch with explicit all-to-alls over 'model'.
    moe_impl: str = "global"
    # parallelism policy: "tp_dp" (default 16-way TP x 16-way DP on the
    # production mesh) or "dp_only" (params replicated, batch over every
    # mesh axis — right for models whose heads/d_ff don't split 16 ways;
    # see §Perf smollm study)
    parallelism: str = "tp_dp"
    # attention operand dtype: "f32" (baseline, exact) or "bf16" (operands
    # communicated/stored bf16, accumulation forced fp32 — MXU-native,
    # halves S^2 traffic and TP collective bytes; §Perf "attn_bf16")
    attn_dtype: str = "f32"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def reduced(self, **overrides) -> "ArchCfg":
        """A tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 if self.family != "zamba2" else 4),
            d_model=min(self.d_model, 64),
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=min(self.d_ff, 128),
            vocab=min(self.vocab, 512),
            head_dim=16 if self.n_heads else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_frames=min(self.n_frames, 8),
            n_patches=min(self.n_patches, 4),
            attn_every=2,
            dtype=jnp.float32,
        )
        if self.moe:
            small["moe"] = dataclasses.replace(self.moe, n_experts=4, top_k=2,
                                               d_expert=32)
        if self.ssm:
            small["ssm"] = dataclasses.replace(self.ssm, d_state=8,
                                               head_dim=8)
        # zamba2 kv heads = heads in the shared block
        if self.family == "zamba2":
            small["n_kv_heads"] = small["n_heads"]
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ----------------------------------------------------------------------------
# initialisation helpers
# ----------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def stacked(keys, fn):
    """Init one param per layer and stack along axis 0 (for lax.scan)."""
    return jax.vmap(fn)(keys)


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------

def init_norm(cfg: ArchCfg, dtype=None):
    dtype = dtype or cfg.dtype
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(cfg: ArchCfg, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rms":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True)
                               + cfg.norm_eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------------
# rotary position embeddings
# ----------------------------------------------------------------------------

def rope_freqs(cfg: ArchCfg) -> jax.Array:
    hd = cfg.resolved_head_dim
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32)
                                     / hd))


def apply_rope(x: jax.Array, positions: jax.Array,
               freqs: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------------

def init_mlp(cfg: ArchCfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp == "swiglu":
        return {"w_gate": dense_init(k1, (d, f), cfg.dtype),
                "w_up": dense_init(k2, (d, f), cfg.dtype),
                "w_down": dense_init(k3, (f, d), cfg.dtype)}
    return {"w_up": dense_init(k1, (d, f), cfg.dtype),
            "b_up": jnp.zeros((f,), cfg.dtype),
            "w_down": dense_init(k2, (f, d), cfg.dtype),
            "b_down": jnp.zeros((d,), cfg.dtype)}


def apply_mlp(cfg: ArchCfg, p, x):
    if cfg.mlp == "swiglu":
        g = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32))
        u = (x @ p["w_up"]).astype(jnp.float32)
        return ((g * u).astype(x.dtype)) @ p["w_down"]
    h = jax.nn.gelu((x @ p["w_up"] + p["b_up"]).astype(jnp.float32))
    return h.astype(x.dtype) @ p["w_down"] + p["b_down"]


# ----------------------------------------------------------------------------
# embeddings / head
# ----------------------------------------------------------------------------

def init_embed(cfg: ArchCfg, key):
    k1, k2 = jax.random.split(key)
    p = {"tok": dense_init(k1, (cfg.vocab, cfg.d_model), cfg.dtype,
                           scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, (cfg.d_model, cfg.vocab), cfg.dtype)
    return p


def embed_tokens(p, tokens):
    return p["tok"][tokens]


def lm_head(cfg: ArchCfg, p, h):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    return (h @ w).astype(jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """Mean token cross-entropy in fp32; labels == ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
