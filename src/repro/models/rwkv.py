"""RWKV6 (Finch) LM: token-shift time-mix with data-dependent decay +
squared-ReLU channel-mix.  Attention-free: decode state is O(1) in context
length (token-shift vectors + the (dh x dh) wkv state per head), so this
arch runs the long_500k shape at constant per-step cost.

The wkv recurrence runs through kernels/ops.rwkv6_scan (Pallas kernel on
TPU, jnp scan under GSPMD).  The decay LoRA (w = exp(-exp(w0 +
tanh(x A) B))) is kept — it is the architecture's headline feature; the
r/k/v/g token-shift mixes use static learned lerps (the ddlerp LoRA on
those is dropped for size — noted in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import common
from repro.models.common import ArchCfg, dense_init

DECAY_LORA = 64


def _heads(cfg: ArchCfg):
    hd = cfg.resolved_head_dim
    return cfg.d_model // hd, hd


def init_time_mix(cfg: ArchCfg, key):
    d = cfg.d_model
    H, hd = _heads(cfg)
    ks = jax.random.split(key, 8)
    return {
        "mu": 0.5 * jnp.ones((5, d), cfg.dtype),  # r,k,v,w,g lerps
        "w_r": dense_init(ks[0], (d, d), cfg.dtype),
        "w_k": dense_init(ks[1], (d, d), cfg.dtype),
        "w_v": dense_init(ks[2], (d, d), cfg.dtype),
        "w_g": dense_init(ks[3], (d, d), cfg.dtype),
        "w_o": dense_init(ks[4], (d, d), cfg.dtype),
        "w0": jnp.full((d,), -3.0, jnp.float32),
        "w_lora_a": dense_init(ks[5], (d, DECAY_LORA), jnp.float32),
        "w_lora_b": dense_init(ks[6], (DECAY_LORA, d), jnp.float32,
                               scale=0.01),
        "u": dense_init(ks[7], (H, hd), jnp.float32, scale=0.1),
        "gn_scale": jnp.ones((d,), cfg.dtype),
    }


def init_channel_mix(cfg: ArchCfg, key):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d), cfg.dtype),  # k, r lerps
        "w_k": dense_init(k1, (d, f), cfg.dtype),
        "w_v": dense_init(k2, (f, d), cfg.dtype),
        "w_r": dense_init(k3, (d, d), cfg.dtype),
    }


def _shift(x, prev=None):
    """x_{t-1} along seq; first step uses `prev` (decode) or zeros."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _lerp(x, xx, mu):
    return x + (xx - x) * mu


def _decay(p, xw):
    lo = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    return jnp.exp(-jnp.exp(p["w0"] + lo))


def _head_norm(cfg: ArchCfg, p, y):
    """Per-head RMS normalisation of the wkv output."""
    H, hd = _heads(cfg)
    shp = y.shape
    yf = y.astype(jnp.float32).reshape(shp[:-1] + (H, hd))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True)
                            + cfg.norm_eps)
    return (yf.reshape(shp) * p["gn_scale"].astype(jnp.float32))


def time_mix(cfg: ArchCfg, p, x, *, state=None, impl="auto",
             return_state=False):
    """x: (B, S, d).  state = (prev_token (B,d), wkv (B,H,dh,dh)) for decode."""
    H, hd = _heads(cfg)
    B, S, d = x.shape
    prev, wkv0 = (None, None) if state is None else state
    xx = _shift(x, prev)
    mr, mk, mv, mw, mg = p["mu"]
    r = (_lerp(x, xx, mr) @ p["w_r"]).reshape(B, S, H, hd)
    k = (_lerp(x, xx, mk) @ p["w_k"]).reshape(B, S, H, hd)
    v = (_lerp(x, xx, mv) @ p["w_v"]).reshape(B, S, H, hd)
    g = jax.nn.silu((_lerp(x, xx, mg) @ p["w_g"]).astype(jnp.float32))
    w = _decay(p, _lerp(x, xx, mw)).reshape(B, S, H, hd)

    if impl == "auto":
        impl = cfg.scan_impl
    if S == 1:
        impl = "pertoken"  # decode: one step, the oracle is exact + minimal
    if return_state or state is not None:
        y, wkv = ops.rwkv6_scan(r, k, v, w.astype(r.dtype), p["u"],
                                s0=wkv0, return_state=True, impl=impl)
    else:
        y = ops.rwkv6_scan(r, k, v, w.astype(r.dtype), p["u"], impl=impl)
        wkv = None
    y = _head_norm(cfg, p, y.reshape(B, S, d)) * g
    out = y.astype(x.dtype) @ p["w_o"]
    if return_state or state is not None:
        return out, (x[:, -1], wkv)
    return out


def channel_mix(cfg: ArchCfg, p, x, *, state=None, return_state=False):
    prev = None if state is None else state
    xx = _shift(x, prev)
    mk, mr = p["mu"]
    k = jnp.square(jax.nn.relu((_lerp(x, xx, mk) @ p["w_k"])
                               .astype(jnp.float32)))
    rgate = jax.nn.sigmoid((_lerp(x, xx, mr) @ p["w_r"]).astype(jnp.float32))
    out = (rgate * (k.astype(x.dtype) @ p["w_v"]).astype(jnp.float32))
    out = out.astype(x.dtype)
    if return_state or state is not None:
        return out, x[:, -1]
    return out


# ----------------------------------------------------------------------------
# LM stack
# ----------------------------------------------------------------------------

def init_lm(cfg: ArchCfg, key):
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)

    def one(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": common.init_norm(cfg), "ln2": common.init_norm(cfg),
                "tm": init_time_mix(cfg, k1),
                "cm": init_channel_mix(cfg, k2)}

    return {"embed": common.init_embed(cfg, ke),
            "layers": common.stacked(layer_keys, one),
            "final_norm": common.init_norm(cfg)}


def forward(cfg: ArchCfg, params, h, *, remat: bool = True):
    def body(h, lp):
        h = h + time_mix(cfg, lp["tm"], common.apply_norm(cfg, lp["ln1"], h))
        h = h + channel_mix(cfg, lp["cm"],
                            common.apply_norm(cfg, lp["ln2"], h))
        return h, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return common.apply_norm(cfg, params["final_norm"], h)


def train_loss(cfg: ArchCfg, params, batch, *, remat: bool = True):
    h = common.embed_tokens(params["embed"], batch["tokens"])
    h = forward(cfg, params, h, remat=remat)
    logits = common.lm_head(cfg, params["embed"], h)
    return common.cross_entropy(logits, batch["labels"])


def init_state(cfg: ArchCfg, batch: int, *, layers: int):
    H, hd = _heads(cfg)
    d = cfg.d_model
    return {
        "tm_shift": jnp.zeros((layers, batch, d), cfg.dtype),
        "cm_shift": jnp.zeros((layers, batch, d), cfg.dtype),
        "wkv": jnp.zeros((layers, batch, H, hd, hd), jnp.float32),
    }


def prefill(cfg: ArchCfg, params, batch, *, remat: bool = True):
    h = common.embed_tokens(params["embed"], batch["tokens"])

    def body(h, lp):
        x1 = common.apply_norm(cfg, lp["ln1"], h)
        y, (tms, wkv) = time_mix(cfg, lp["tm"], x1, return_state=True)
        h = h + y
        x2 = common.apply_norm(cfg, lp["ln2"], h)
        y, cms = channel_mix(cfg, lp["cm"], x2, return_state=True)
        return h + y, (tms, cms, wkv)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, (tms, cms, wkvs) = jax.lax.scan(body, h, params["layers"])
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = common.lm_head(cfg, params["embed"], h[:, -1:])
    return logits, {"tm_shift": tms, "cm_shift": cms, "wkv": wkvs}


def decode_step(cfg: ArchCfg, params, token, state, pos=None):
    h = common.embed_tokens(params["embed"], token)

    def body(h, xs):
        lp, tms, cms, wkv = xs
        x1 = common.apply_norm(cfg, lp["ln1"], h)
        y, (tms, wkv) = time_mix(cfg, lp["tm"], x1, state=(tms, wkv))
        h = h + y
        x2 = common.apply_norm(cfg, lp["ln2"], h)
        y, cms = channel_mix(cfg, lp["cm"], x2, state=cms)
        return h + y, (tms, cms, wkv)

    h, (tms, cms, wkvs) = jax.lax.scan(
        body, h, (params["layers"], state["tm_shift"], state["cm_shift"],
                  state["wkv"]))
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = common.lm_head(cfg, params["embed"], h)
    return logits, {"tm_shift": tms, "cm_shift": cms, "wkv": wkvs}
