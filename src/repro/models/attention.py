"""GQA attention with RoPE: train / prefill / decode paths.

The jnp implementation (kernels/ref.py) is used under jit so GSPMD can
shard it (heads over 'model', batch over 'data'/'pod'); the Pallas flash /
paged kernels are the per-shard fast path wired up through
kernels/ops.sharded_* in the serving engine.

Decode uses a dense ring-buffer KV cache (B, S_max, Hkv, hd) updated with
dynamic_update_slice at `pos`; attention masks positions >= pos+1.  The
paged variant (serving engine) stores the cache as a page pool + table —
the §2.2 TLB path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.models.common import ArchCfg, apply_rope, dense_init


def init_attn(cfg: ArchCfg, key, *, cross: bool = False):
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, (d, cfg.n_heads * hd), cfg.dtype),
        "wk": dense_init(kk, (d, cfg.n_kv_heads * hd), cfg.dtype),
        "wv": dense_init(kv, (d, cfg.n_kv_heads * hd), cfg.dtype),
        "wo": dense_init(ko, (cfg.n_heads * hd, d), cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.dtype)
    return p


def _project_qkv(cfg: ArchCfg, p, xq, xkv):
    hd = cfg.resolved_head_dim
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, Sq, cfg.n_heads, hd)
    k = k.reshape(B, Skv, cfg.n_kv_heads, hd)
    v = v.reshape(B, Skv, cfg.n_kv_heads, hd)
    return q, k, v


def _compute_dtype(cfg: ArchCfg):
    return jnp.bfloat16 if cfg.attn_dtype == "bf16" else jnp.float32


def attn_full(cfg: ArchCfg, p, x, *, freqs=None, causal=True,
              positions=None):
    """Full-sequence self-attention (training / encoder).

    Returns (out, (k, v)) so prefill can persist the cache."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, x)
    if freqs is not None:
        pos = positions if positions is not None else jnp.arange(S)[None]
        q = apply_rope(q, pos, freqs)
        k = apply_rope(k, pos, freqs)
    out = kref.mha_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=causal,
                             compute_dtype=_compute_dtype(cfg))
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return out @ p["wo"], (k, v)


def attn_cross(cfg: ArchCfg, p, x, kv_cache):
    """Cross-attention against precomputed (k, v) from the encoder."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k, v = kv_cache
    out = kref.mha_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=False,
                             compute_dtype=_compute_dtype(cfg))
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return out @ p["wo"]


def init_kv_cache(cfg: ArchCfg, batch: int, max_len: int, *, layers: int):
    hd = cfg.resolved_head_dim
    shape = (layers, batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def attn_decode(cfg: ArchCfg, p, x, k_cache, v_cache, pos, *, freqs=None):
    """One-token decode against a dense cache.

    x: (B, 1, d); k_cache/v_cache: (B, S_max, Hkv, hd); pos: scalar int —
    the index this token writes to (== current context length).
    Returns (out, k_cache, v_cache)."""
    B, _, _ = x.shape
    S_max = k_cache.shape[1]
    q, k, v = _project_qkv(cfg, p, x, x)            # (B,1,H,hd)/(B,1,Hkv,hd)
    if freqs is not None:
        posb = jnp.full((B, 1), pos)
        q = apply_rope(q, posb, freqs)
        k = apply_rope(k, posb, freqs)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, pos, 0, 0))
    group = cfg.n_heads // cfg.n_kv_heads
    if _compute_dtype(cfg) == jnp.bfloat16:
        # bf16 cache reads + grouped-query einsum (no repeat
        # materialization); accumulation forced fp32 — §Perf "attn_bf16"
        hd = cfg.resolved_head_dim
        qf = (q[:, 0].astype(jnp.float32) * hd ** -0.5).astype(jnp.bfloat16)
        q4 = qf.reshape(B, cfg.n_kv_heads, group, hd)
        logits = jnp.einsum("bkgd,bskd->bkgs", q4, k_cache,
                            preferred_element_type=jnp.float32)
        mask = jnp.arange(S_max)[None, None, None, :] <= pos
        logits = jnp.where(mask, logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(jnp.bfloat16),
                         v_cache, preferred_element_type=jnp.float32)
        out = out.astype(x.dtype).reshape(B, 1, -1)
        return out @ p["wo"], k_cache, v_cache
    qf = q[:, 0].astype(jnp.float32) * cfg.resolved_head_dim ** -0.5
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    if group > 1:
        kf = jnp.repeat(kf, group, axis=2)
        vf = jnp.repeat(vf, group, axis=2)
    logits = jnp.einsum("bhd,bshd->bhs", qf, kf)
    mask = jnp.arange(S_max)[None, None, :] <= pos
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, vf).astype(x.dtype)
    out = out.reshape(B, 1, -1)
    return out @ p["wo"], k_cache, v_cache
