"""Decoder-only transformer LM (dense + MoE) — also the VLM backbone.

Layer stacks are walked with lax.scan over stacked parameters (L leading
axis) and rematerialised per layer, so the lowered HLO is depth-independent:
an 80-layer dry-run compiles as fast as a 2-layer one, and activation
memory for train_4k stays at O(1 layer).

Three entry points per the assigned shape families:
  * train_loss  — full-sequence causal LM loss (train_4k)
  * prefill     — full forward that also returns the KV cache (prefill_32k)
  * decode_step — one token against the dense KV cache (decode_32k)
The paged decode path (the §2.2 TLB adaptation) lives in serving/engine.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import jaxcompat
from repro.kernels import ref as kref
from repro.models import attention as attn
from repro.models import common, moe
from repro.models.common import ArchCfg
from repro.parallel import sharding


def init_layer(cfg: ArchCfg, key):
    k1, k2 = jax.random.split(key)
    p = {"ln1": common.init_norm(cfg), "ln2": common.init_norm(cfg),
         "attn": attn.init_attn(cfg, k1)}
    if cfg.moe is not None:
        p["moe"] = moe.init_moe(cfg, k2)
    else:
        p["mlp"] = common.init_mlp(cfg, k2)
    return p


def init_lm(cfg: ArchCfg, key):
    ke, kl, kn = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": common.init_embed(cfg, ke),
        "layers": common.stacked(layer_keys,
                                 functools.partial(init_layer, cfg)),
        "final_norm": common.init_norm(cfg),
    }


def _constrain(cfg: ArchCfg, h):
    if cfg.tp_activations == "megatron":
        return sharding.constrain_activations(h)
    if cfg.tp_activations == "sp":
        return sharding.constrain_activations(h, seq_axis="model")
    return h


def _layer_fwd(cfg: ArchCfg, lp, h, freqs, causal):
    h = _constrain(cfg, h)
    a, _ = attn.attn_full(cfg, lp["attn"], common.apply_norm(cfg, lp["ln1"], h),
                          freqs=freqs, causal=causal)
    h = _constrain(cfg, h + a)
    if cfg.moe is not None:
        apply = moe.apply_moe_ep if cfg.moe_impl == "ep_a2a" else \
            moe.apply_moe
        m, aux = apply(cfg, lp["moe"], common.apply_norm(cfg, lp["ln2"], h))
    else:
        m = common.apply_mlp(cfg, lp["mlp"],
                             common.apply_norm(cfg, lp["ln2"], h))
        aux = jnp.zeros((), jnp.float32)
    return _constrain(cfg, h + m), aux


def forward(cfg: ArchCfg, params, h, *, causal: bool = True,
            remat: bool = True):
    """Run the layer stack over embeddings h: (B, S, d) -> (h, aux_loss)."""
    if cfg.tp_activations == "manual_sp" and causal \
            and _manual_sp_applicable(cfg):
        out = _stack_manual_sp(cfg, params["layers"], h, remat=remat)
        if out is not None:
            h, aux = out
            return common.apply_norm(cfg, params["final_norm"], h), aux
    freqs = common.rope_freqs(cfg)

    def body(carry, lp):
        h, aux = carry
        h, a = _layer_fwd(cfg, lp, h, freqs, causal)
        return (h, aux + a), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return common.apply_norm(cfg, params["final_norm"], h), aux


# ----------------------------------------------------------------------------
# §Perf "manual_sp": the dense layer stack hand-SPMD'd in shard_map —
# Megatron-style sequence parallelism with EXPLICIT collectives, so the
# wire dtype is the activation dtype (bf16) instead of the partitioner's
# post-upcast f32, and exactly one all-gather + one reduce-scatter of the
# (B, S, d) stream crosses 'model' per block:
#
#   h_loc --ln--> AG(seq) -> qkv (local heads) -> attn -> @wo (partial)
#         --RS(seq, summed)--> +residual --ln--> AG -> swiglu (f-sharded)
#         -> @w_down (partial) --RS--> +residual
#
# This is the same schedule the APEnet+ fabric would run as neighbour RDMA
# rings; autodiff of all_gather/psum_scatter gives the transposed
# collectives in the backward pass for free.
# ----------------------------------------------------------------------------


def _manual_sp_applicable(cfg: ArchCfg) -> bool:
    return cfg.moe is None and cfg.mlp == "swiglu" and cfg.n_heads > 0


def _manual_sp_ok(cfg: ArchCfg, mesh) -> bool:
    tp = mesh.shape.get("model", 1)
    return (tp > 1 and cfg.n_heads % tp == 0 and cfg.n_kv_heads % tp == 0
            and cfg.d_ff % tp == 0)


def _stack_manual_sp(cfg: ArchCfg, layers, h, *, remat: bool):
    from jax.sharding import PartitionSpec as P

    mesh = sharding.runtime_mesh()
    if mesh is None or not _manual_sp_ok(cfg, mesh):
        return None
    dpx = sharding.dp_axes(mesh)
    S = h.shape[1]
    if not dpx or S % mesh.shape["model"] or h.shape[0] % \
            sharding.dp_size(mesh):
        return None
    hd = cfg.resolved_head_dim
    freqs = common.rope_freqs(cfg)

    def layer(h_loc, lp):
        x = common.apply_norm(cfg, lp["ln1"], h_loc)
        xf = jax.lax.all_gather(x, "model", axis=1, tiled=True)  # (B,S,d)
        B, S_, _ = xf.shape
        q = xf @ lp["attn"]["wq"]
        k = xf @ lp["attn"]["wk"]
        v = xf @ lp["attn"]["wv"]
        if cfg.qkv_bias:
            q, k, v = q + lp["attn"]["bq"], k + lp["attn"]["bk"], \
                v + lp["attn"]["bv"]
        q = q.reshape(B, S_, -1, hd)
        k = k.reshape(B, S_, -1, hd)
        v = v.reshape(B, S_, -1, hd)
        pos = jnp.arange(S_)[None]
        q = common.apply_rope(q, pos, freqs)
        k = common.apply_rope(k, pos, freqs)
        out = kref.mha_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True,
            compute_dtype=jnp.bfloat16 if cfg.attn_dtype == "bf16"
            else jnp.float32)
        out = out.transpose(0, 2, 1, 3).reshape(B, S_, -1)
        part = (out @ lp["attn"]["wo"]).astype(h_loc.dtype)
        h_loc = h_loc + jax.lax.psum_scatter(part, "model",
                                             scatter_dimension=1, tiled=True)
        x2 = common.apply_norm(cfg, lp["ln2"], h_loc)
        x2f = jax.lax.all_gather(x2, "model", axis=1, tiled=True)
        mp = common.apply_mlp(cfg, lp["mlp"], x2f).astype(h_loc.dtype)
        h_loc = h_loc + jax.lax.psum_scatter(mp, "model",
                                             scatter_dimension=1, tiled=True)
        return h_loc

    def stack(h_loc, ls):
        def body(carry, lp):
            return layer(carry, lp), None

        b = body
        if remat:
            b = jax.checkpoint(
                b, policy=jax.checkpoint_policies.nothing_saveable)
        h_loc, _ = jax.lax.scan(b, h_loc, ls)
        return h_loc

    def leaf_spec(path, leaf):
        name = [getattr(kk, "key", None) for kk in path][-1]
        nd = leaf.ndim
        if name in ("wq", "wk", "wv"):
            return P(*([None] * (nd - 1) + ["model"]))
        if name in ("bq", "bk", "bv"):
            return P(None, "model")
        if name == "wo":
            return P(None, "model", None)
        if name in ("w_gate", "w_up"):
            return P(None, None, "model")
        if name == "w_down":
            return P(None, "model", None)
        return P(*([None] * nd))      # norms etc: replicated

    lspecs = jax.tree_util.tree_map_with_path(leaf_spec, layers)
    hspec = P(tuple(dpx), "model", None)
    mapped = jaxcompat.shard_map(stack, mesh=mesh, in_specs=(hspec, lspecs),
                                 out_specs=hspec, check_vma=False)
    return mapped(h, layers), jnp.zeros((), jnp.float32)


def embed_inputs(cfg: ArchCfg, params, batch) -> tuple[jax.Array, jax.Array]:
    """tokens (+ optional stub-frontend prefix embeddings) -> (h, labels)."""
    h = common.embed_tokens(params["embed"], batch["tokens"])
    labels = batch.get("labels")
    if "prefix_embeds" in batch:  # VLM: precomputed patch embeddings
        pre = batch["prefix_embeds"].astype(h.dtype)
        h = jnp.concatenate([pre, h], axis=1)
        if labels is not None:
            ignore = jnp.full(pre.shape[:2], -1, labels.dtype)
            labels = jnp.concatenate([ignore, labels], axis=1)
    return h, labels


def train_loss(cfg: ArchCfg, params, batch, *, remat: bool = True):
    h, labels = embed_inputs(cfg, params, batch)
    h, aux = forward(cfg, params, h, causal=True, remat=remat)
    logits = common.lm_head(cfg, params["embed"], h)
    return common.cross_entropy(logits, labels) + aux


# ----------------------------------------------------------------------------
# serving paths
# ----------------------------------------------------------------------------

def prefill(cfg: ArchCfg, params, batch, *, max_len: int | None = None,
            remat: bool = True, return_hidden: bool = False,
            moe_dropless: bool = False):
    """Forward + build the dense KV cache.  Returns (logits_last, cache)
    [+ final hidden states when return_hidden — serving engines pick their
    own logits position for padded prompts].  ``moe_dropless`` forces the
    capacity-free MoE dispatch serving requires (tokens must not depend on
    what else shares the forward)."""
    h, _ = embed_inputs(cfg, params, batch)
    B, S, _ = h.shape
    # VLM prefix embeddings extend S beyond the token budget: the cache must
    # cover the full (prefix + tokens) context
    max_len = max(max_len or S, S)
    freqs = common.rope_freqs(cfg)

    def body(h, lp):
        x = common.apply_norm(cfg, lp["ln1"], h)
        a, (k, v) = attn.attn_full(cfg, lp["attn"], x, freqs=freqs,
                                   causal=True)
        h = h + a
        if cfg.moe is not None:
            x2 = common.apply_norm(cfg, lp["ln2"], h)
            if moe_dropless:
                m, _ = moe.apply_moe(cfg, lp["moe"], x2, dropless=True)
            elif cfg.moe_impl == "ep_a2a":
                m, _ = moe.apply_moe_ep(cfg, lp["moe"], x2)
            else:
                m, _ = moe.apply_moe(cfg, lp["moe"], x2)
        else:
            m = common.apply_mlp(cfg, lp["mlp"],
                                 common.apply_norm(cfg, lp["ln2"], h))
        pad = max_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return h + m, (k, v)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = common.lm_head(cfg, params["embed"], h[:, -1:])
    if return_hidden:
        return logits, {"k": ks, "v": vs}, h
    return logits, {"k": ks, "v": vs}


def decode_step(cfg: ArchCfg, params, token, cache, pos):
    """token: (B, 1) int32; cache: {'k','v'}: (L,B,Smax,Hkv,hd); pos scalar.

    Returns (logits (B,1,V), new_cache)."""
    h = common.embed_tokens(params["embed"], token)
    freqs = common.rope_freqs(cfg)

    def body(h, xs):
        lp, kc, vc = xs
        x = common.apply_norm(cfg, lp["ln1"], h)
        a, kc, vc = attn.attn_decode(cfg, lp["attn"], x, kc, vc, pos,
                                     freqs=freqs)
        h = h + a
        if cfg.moe is not None:
            m, _ = moe.apply_moe(cfg, lp["moe"],
                                 common.apply_norm(cfg, lp["ln2"], h))
        else:
            m = common.apply_mlp(cfg, lp["mlp"],
                                 common.apply_norm(cfg, lp["ln2"], h))
        return h + m, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache["k"],
                                         cache["v"]))
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = common.lm_head(cfg, params["embed"], h)
    return logits, {"k": ks, "v": vs}
