"""Uniform model API: family dispatch + ShapeDtypeStruct input specs.

``get_model(cfg)`` returns a ``Model`` facade with the same five entry
points for every family; ``input_specs(cfg, shape)`` builds the exact
argument structures (as ShapeDtypeStructs — no allocation) for each of the
assigned input-shape families, which is what the multi-pod dry-run lowers
against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import attention, encdec, hybrid, rwkv, ssm, transformer
from repro.models.common import ArchCfg


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# the assigned LM shape set (applies to every arch; long_500k is gated on
# cfg.full_attention)
SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
    # reduced variants for smoke tests
    "smoke_train": ShapeCfg("smoke_train", 16, 2, "train"),
    "smoke_prefill": ShapeCfg("smoke_prefill", 16, 2, "prefill"),
    "smoke_decode": ShapeCfg("smoke_decode", 16, 2, "decode"),
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchCfg
    init: Callable[..., Any]
    train_loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_decode_state: Callable[..., Any] | None = None


def _transformer_model(cfg: ArchCfg) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_lm(cfg, key),
        train_loss=lambda p, b, **kw: transformer.train_loss(cfg, p, b, **kw),
        prefill=lambda p, b, **kw: transformer.prefill(cfg, p, b, **kw),
        decode_step=lambda p, t, s, pos: transformer.decode_step(
            cfg, p, t, s, pos),
        init_decode_state=lambda batch, max_len: attention.init_kv_cache(
            cfg, batch, max_len, layers=cfg.n_layers),
    )


def get_model(cfg: ArchCfg) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _transformer_model(cfg)
    if fam == "mamba2":
        return Model(
            cfg=cfg,
            init=lambda key: ssm.init_lm(cfg, key),
            train_loss=lambda p, b, **kw: ssm.train_loss(cfg, p, b, **kw),
            prefill=lambda p, b, **kw: ssm.prefill(cfg, p, b, **kw),
            decode_step=lambda p, t, s, pos: ssm.decode_step(cfg, p, t, s,
                                                             pos),
            init_decode_state=lambda batch, max_len: ssm.init_mamba_state(
                cfg, batch, layers=cfg.n_layers),
        )
    if fam == "rwkv6":
        return Model(
            cfg=cfg,
            init=lambda key: rwkv.init_lm(cfg, key),
            train_loss=lambda p, b, **kw: rwkv.train_loss(cfg, p, b, **kw),
            prefill=lambda p, b, **kw: rwkv.prefill(cfg, p, b, **kw),
            decode_step=lambda p, t, s, pos: rwkv.decode_step(cfg, p, t, s,
                                                              pos),
            init_decode_state=lambda batch, max_len: rwkv.init_state(
                cfg, batch, layers=cfg.n_layers),
        )
    if fam == "zamba2":
        return Model(
            cfg=cfg,
            init=lambda key: hybrid.init_lm(cfg, key),
            train_loss=lambda p, b, **kw: hybrid.train_loss(cfg, p, b, **kw),
            prefill=lambda p, b, **kw: hybrid.prefill(cfg, p, b, **kw),
            decode_step=lambda p, t, s, pos: hybrid.decode_step(cfg, p, t, s,
                                                                pos),
            init_decode_state=lambda batch, max_len: hybrid.init_state(
                cfg, batch, max_len),
        )
    if fam == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_lm(cfg, key),
            train_loss=lambda p, b, **kw: encdec.train_loss(cfg, p, b, **kw),
            prefill=lambda p, b, **kw: encdec.prefill(cfg, p, b, **kw),
            decode_step=lambda p, t, s, pos: encdec.decode_step(cfg, p, t, s,
                                                                pos),
        )
    raise ValueError(f"unknown family {fam}")


# ----------------------------------------------------------------------------
# input specs (ShapeDtypeStruct — shardable, no allocation)
# ----------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ArchCfg, shape: ShapeCfg) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32),
             "labels": _sds((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, cfg.n_frames, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = _sds((B, cfg.n_patches, cfg.d_model),
                                      cfg.dtype)
    return batch


def prefill_input_specs(cfg: ArchCfg, shape: ShapeCfg) -> dict:
    batch = train_input_specs(cfg, shape)
    del batch["labels"]
    return batch


def decode_input_specs(cfg: ArchCfg, shape: ShapeCfg) -> dict:
    """Specs for decode: one new token against a seq_len-deep cache."""
    B, S = shape.global_batch, shape.seq_len
    model = get_model(cfg)
    if cfg.family == "encdec":
        # state includes cross-attn caches; derive via eval_shape of prefill
        params_shapes = jax.eval_shape(model.init, jax.random.key(0))
        state = jax.eval_shape(
            lambda p, b: model.prefill(p, b, max_len=S, remat=False)[1],
            params_shapes, prefill_input_specs(cfg, shape))
    else:
        state = jax.eval_shape(lambda: model.init_decode_state(B, S))
    return {"token": _sds((B, 1), jnp.int32), "state": state,
            "pos": _sds((), jnp.int32)}


def input_specs(cfg: ArchCfg, shape_name: str) -> tuple[ShapeCfg, dict]:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return shape, train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return shape, prefill_input_specs(cfg, shape)
    return shape, decode_input_specs(cfg, shape)


def applicable_shapes(cfg: ArchCfg) -> list[str]:
    """The assigned shape cells for this arch (long_500k gated)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if not cfg.full_attention:
        out.append("long_500k")
    return out


def param_shapes(cfg: ArchCfg):
    model = get_model(cfg)
    return jax.eval_shape(model.init, jax.random.key(0))


def param_count(cfg: ArchCfg) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(param_shapes(cfg)))


def active_param_count(cfg: ArchCfg) -> int:
    """MoE: params touched per token (top_k of n_experts); else = total."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    expert = 0
    for path, x in jax.tree_util.tree_flatten_with_path(param_shapes(cfg))[0]:
        keys = [getattr(k, "key", None) for k in path]
        if "moe" in keys and keys[-1] in ("w_gate", "w_up", "w_down"):
            expert += int(np.prod(x.shape))
    # expert tensors carry the E axis; active fraction = top_k / n_experts
    return total - expert + int(expert * m.top_k / m.n_experts)
