"""Mamba2 (SSD) mixer block and LM stack.

The mixer follows the Mamba2 layout with n_groups = 1: a fused input
projection producing (z, x, B, C, dt), a short depthwise causal conv over
(x | B | C), softplus dt, the SSD scan (kernels: Pallas chunked kernel on
TPU, jnp oracle under GSPMD), a gated RMSNorm and the output projection.

Decode keeps O(1) state per layer — (conv tail, SSD state) — which is why
the ssm/hybrid archs are the only ones that run the long_500k shape: a
524288-token context costs the same per step as a 1-token one.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import common
from repro.models.common import ArchCfg, dense_init


def _dims(cfg: ArchCfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.d_state, s.conv_width


def init_mamba(cfg: ArchCfg, key):
    s = cfg.ssm
    d_inner, H, ds, cw = _dims(cfg)
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    conv_ch = d_inner + 2 * ds
    return {
        # packed projection: z | x | B | C | dt
        "w_in": dense_init(k1, (d, 2 * d_inner + 2 * ds + H), cfg.dtype),
        "conv_w": dense_init(k2, (cw, conv_ch), cfg.dtype, scale=cw ** -0.5),
        "conv_b": jnp.zeros((conv_ch,), cfg.dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), cfg.dtype),
        "w_out": dense_init(k4, (d_inner, d), cfg.dtype),
    }


def _split_proj(cfg: ArchCfg, proj):
    d_inner, H, ds, _ = _dims(cfg)
    z, x, b, c, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + ds, 2 * d_inner + 2 * ds],
        axis=-1)
    return z, x, b, c, dt


def _gated_norm(cfg: ArchCfg, p, y, z):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True)
                            + cfg.norm_eps)
    return (yf * p["norm_scale"].astype(jnp.float32)).astype(y.dtype)


def apply_mamba(cfg: ArchCfg, p, hx, *, impl="auto", return_state=False):
    """Full-sequence mixer: hx (B, S, d) -> (B, S, d).

    With return_state=True also returns (conv_tail, ssd_state) — the O(1)
    decode state after consuming the sequence (prefill path; uses the ref
    scan, which is the GSPMD-shardable implementation anyway)."""
    d_inner, H, ds, cw = _dims(cfg)
    s = cfg.ssm
    B, S, _ = hx.shape
    proj = hx @ p["w_in"]
    z, x, bm, cm, dt = _split_proj(cfg, proj)
    # depthwise causal conv over (x | B | C)
    xbc_raw = jnp.concatenate([x, bm, cm], axis=-1)
    pad = jnp.pad(xbc_raw, ((0, 0), (cw - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S] * p["conv_w"][i] for i in range(cw))
    xbc = jax.nn.silu((conv + p["conv_b"]).astype(jnp.float32)).astype(hx.dtype)
    x, bm, cm = jnp.split(xbc, [d_inner, d_inner + ds], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dtv = jnp.clip(dtv, s.dt_min, None)
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(B, S, H, s.head_dim)
    if impl == "auto":
        impl = cfg.scan_impl
    if return_state:
        y, ssd = ops.mamba2_scan(xh, dtv, A, bm, cm, p["D"], impl=impl,
                                 return_state=True)
    else:
        y = ops.mamba2_scan(xh, dtv, A, bm, cm, p["D"], impl=impl)
    y = y.reshape(B, S, d_inner)
    out = _gated_norm(cfg, p, y, z) @ p["w_out"]
    if return_state:
        conv_tail = pad[:, S:]   # last cw-1 raw (pre-activation) inputs
        return out, (conv_tail, ssd)
    return out


# -- decode (single step, O(1) state) -----------------------------------------

def init_mamba_state(cfg: ArchCfg, batch: int, *, layers: int):
    d_inner, H, ds, cw = _dims(cfg)
    conv_ch = d_inner + 2 * ds
    return {
        "conv": jnp.zeros((layers, batch, cw - 1, conv_ch), cfg.dtype),
        "ssd": jnp.zeros((layers, batch, H, ds, cfg.ssm.head_dim),
                         jnp.float32),
    }


def mamba_decode_step(cfg: ArchCfg, p, hx, conv_state, ssd_state):
    """hx: (B, 1, d); returns (out (B,1,d), conv_state, ssd_state)."""
    d_inner, H, ds, cw = _dims(cfg)
    s = cfg.ssm
    B = hx.shape[0]
    proj = hx[:, 0] @ p["w_in"]
    z, x, bm, cm, dt = _split_proj(cfg, proj)
    xbc = jnp.concatenate([x, bm, cm], axis=-1)      # (B, conv_ch)
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B,cw,ch)
    conv_state = window[:, 1:]
    conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv.astype(jnp.float32)).astype(hx.dtype)
    x, bm, cm = jnp.split(xbc, [d_inner, d_inner + ds], axis=-1)
    dtv = jnp.clip(jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]),
                   s.dt_min, None)                   # (B, H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(A[None] * dtv)                   # (B, H)
    xh = x.reshape(B, H, s.head_dim).astype(jnp.float32)
    inject = jnp.einsum("bs,bhd->bhsd", bm.astype(jnp.float32),
                        xh * dtv[..., None])
    ssd_state = ssd_state * decay[..., None, None] + inject
    y = jnp.einsum("bs,bhsd->bhd", cm.astype(jnp.float32), ssd_state)
    y = y.reshape(B, d_inner) + p["D"].repeat(s.head_dim) * x.astype(
        jnp.float32).reshape(B, d_inner)
    y = _gated_norm(cfg, p, y.astype(hx.dtype), z)
    return (y @ p["w_out"])[:, None], conv_state, ssd_state


# ----------------------------------------------------------------------------
# full LM stack (pure-mamba backbone, e.g. for ablations; Zamba2 hybrid is
# models/hybrid.py)
# ----------------------------------------------------------------------------

def init_lm(cfg: ArchCfg, key):
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)

    def one(k):
        kn, km = jax.random.split(k)
        return {"ln": common.init_norm(cfg), "mixer": init_mamba(cfg, km)}

    return {"embed": common.init_embed(cfg, ke),
            "layers": common.stacked(layer_keys, one),
            "final_norm": common.init_norm(cfg)}


def forward(cfg: ArchCfg, params, h, *, remat: bool = True):
    def body(h, lp):
        h = h + apply_mamba(cfg, lp["mixer"],
                            common.apply_norm(cfg, lp["ln"], h))
        return h, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return common.apply_norm(cfg, params["final_norm"], h)


def train_loss(cfg: ArchCfg, params, batch, *, remat: bool = True):
    h = common.embed_tokens(params["embed"], batch["tokens"])
    h = forward(cfg, params, h, remat=remat)
    logits = common.lm_head(cfg, params["embed"], h)
    return common.cross_entropy(logits, batch["labels"])


def prefill(cfg: ArchCfg, params, batch, *, remat: bool = True):
    """Returns (last-token logits, decode state) — state is O(1) in S."""
    h = common.embed_tokens(params["embed"], batch["tokens"])

    def body(h, lp):
        x = common.apply_norm(cfg, lp["ln"], h)
        y, (conv, ssd) = apply_mamba(cfg, lp["mixer"], x, return_state=True)
        return h + y, (conv, ssd)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, (convs, ssds) = jax.lax.scan(body, h, params["layers"])
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = common.lm_head(cfg, params["embed"], h[:, -1:])
    return logits, {"conv": convs, "ssd": ssds}


def decode_step(cfg: ArchCfg, params, token, state, pos=None):
    """token: (B,1); state {'conv','ssd'} leading L axis; pos unused (O(1))."""
    h = common.embed_tokens(params["embed"], token)

    def body(h, xs):
        lp, conv, ssd = xs
        x = common.apply_norm(cfg, lp["ln"], h)
        y, conv, ssd = mamba_decode_step(cfg, lp["mixer"], x, conv, ssd)
        return h + y, (conv, ssd)

    h, (convs, ssds) = jax.lax.scan(body, h, (params["layers"],
                                              state["conv"], state["ssd"]))
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = common.lm_head(cfg, params["embed"], h)
    return logits, {"conv": convs, "ssd": ssds}
