"""Zamba2-style hybrid LM: Mamba2 backbone + one *shared* attention block.

The backbone is a stack of Mamba2 mixer layers; a single shared
transformer block (full-attention + MLP, one parameter set) is applied
after every ``attn_every`` backbone layers — Zamba2's weight-sharing trick.
(The per-invocation LoRA adapters of the released checkpoints are omitted;
noted in DESIGN.md §Arch-applicability.)

Decode state = per-layer Mamba states (O(1)) + one KV cache per shared-
block *application* (same weights, different activations — so n_apps
caches).  Context length only grows the shared-block caches, which is why
this arch legitimately runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common, ssm
from repro.models.common import ArchCfg


def n_shared_applications(cfg: ArchCfg) -> int:
    return cfg.n_layers // cfg.attn_every


def init_lm(cfg: ArchCfg, key):
    ke, km, ks, kf = jax.random.split(key, 4)
    layer_keys = jax.random.split(km, cfg.n_layers)

    def one(k):
        return {"ln": common.init_norm(cfg),
                "mixer": ssm.init_mamba(cfg, k)}

    k1, k2 = jax.random.split(ks)
    shared = {"ln1": common.init_norm(cfg), "ln2": common.init_norm(cfg),
              "attn": attn.init_attn(cfg, k1),
              "mlp": common.init_mlp(cfg, k2)}
    return {"embed": common.init_embed(cfg, ke),
            "mamba": common.stacked(layer_keys, one),
            "shared": shared,
            "final_norm": common.init_norm(cfg)}


def _slice_layers(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def _mamba_span(cfg: ArchCfg, params, h, lo, hi, *, remat, collect_state=False):
    def body(h, lp):
        x = common.apply_norm(cfg, lp["ln"], h)
        if collect_state:
            y, (conv, ssd) = ssm.apply_mamba(cfg, lp["mixer"], x,
                                             return_state=True)
            return h + y, (conv, ssd)
        return h + ssm.apply_mamba(cfg, lp["mixer"], x), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.lax.scan(body, h, _slice_layers(params["mamba"], lo, hi))


def _shared_full(cfg: ArchCfg, sp, h, freqs, *, want_cache=False):
    a, kv = attn.attn_full(cfg, sp["attn"],
                           common.apply_norm(cfg, sp["ln1"], h),
                           freqs=freqs, causal=True)
    h = h + a
    h = h + common.apply_mlp(cfg, sp["mlp"],
                             common.apply_norm(cfg, sp["ln2"], h))
    return (h, kv) if want_cache else (h, None)


def _spans(cfg: ArchCfg):
    """[(lo, hi, shared_after), ...] covering all backbone layers."""
    napps = n_shared_applications(cfg)
    spans = [(g * cfg.attn_every, (g + 1) * cfg.attn_every, True)
             for g in range(napps)]
    if napps * cfg.attn_every < cfg.n_layers:
        spans.append((napps * cfg.attn_every, cfg.n_layers, False))
    return spans


def forward(cfg: ArchCfg, params, h, *, remat: bool = True):
    freqs = common.rope_freqs(cfg)
    for lo, hi, shared in _spans(cfg):
        h, _ = _mamba_span(cfg, params, h, lo, hi, remat=remat)
        if shared:
            h, _ = _shared_full(cfg, params["shared"], h, freqs)
    return common.apply_norm(cfg, params["final_norm"], h)


def train_loss(cfg: ArchCfg, params, batch, *, remat: bool = True):
    h = common.embed_tokens(params["embed"], batch["tokens"])
    h = forward(cfg, params, h, remat=remat)
    logits = common.lm_head(cfg, params["embed"], h)
    return common.cross_entropy(logits, batch["labels"])


# ----------------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------------

def init_state(cfg: ArchCfg, batch: int, max_len: int):
    st = ssm.init_mamba_state(cfg, batch, layers=cfg.n_layers)
    napps = n_shared_applications(cfg)
    kv = attn.init_kv_cache(cfg, batch, max_len, layers=napps)
    return {"mamba": st, "kv": kv}


def prefill(cfg: ArchCfg, params, batch, *, max_len: int | None = None,
            remat: bool = True):
    h = common.embed_tokens(params["embed"], batch["tokens"])
    B, S, _ = h.shape
    max_len = max_len or S
    freqs = common.rope_freqs(cfg)
    convs, ssds, kvs = [], [], []
    for lo, hi, shared in _spans(cfg):
        h, (conv, ssd) = _mamba_span(cfg, params, h, lo, hi, remat=remat,
                                     collect_state=True)
        convs.append(conv)
        ssds.append(ssd)
        if shared:
            h, (k, v) = _shared_full(cfg, params["shared"], h, freqs,
                                     want_cache=True)
            pad = max_len - S
            kvs.append((jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                        jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))))
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = common.lm_head(cfg, params["embed"], h[:, -1:])
    state = {
        "mamba": {"conv": jnp.concatenate(convs, 0),
                  "ssd": jnp.concatenate(ssds, 0)},
        "kv": {"k": jnp.stack([k for k, _ in kvs]),
               "v": jnp.stack([v for _, v in kvs])},
    }
    return logits, state


def decode_step(cfg: ArchCfg, params, token, state, pos):
    h = common.embed_tokens(params["embed"], token)
    freqs = common.rope_freqs(cfg)
    mamba = state["mamba"]
    kvs = state["kv"]
    new_conv = mamba["conv"]
    new_ssd = mamba["ssd"]
    new_k, new_v = kvs["k"], kvs["v"]
    app = 0
    for lo, hi, shared in _spans(cfg):
        def body(h, xs):
            lp, conv, ssd = xs
            x = common.apply_norm(cfg, lp["ln"], h)
            y, conv, ssd = ssm.mamba_decode_step(cfg, lp["mixer"], x, conv,
                                                 ssd)
            return h + y, (conv, ssd)

        h, (conv, ssd) = jax.lax.scan(
            body, h, (_slice_layers(params["mamba"], lo, hi),
                      mamba["conv"][lo:hi], mamba["ssd"][lo:hi]))
        new_conv = jax.lax.dynamic_update_slice_in_dim(new_conv, conv, lo, 0)
        new_ssd = jax.lax.dynamic_update_slice_in_dim(new_ssd, ssd, lo, 0)
        if shared:
            sp = params["shared"]
            x = common.apply_norm(cfg, sp["ln1"], h)
            a, kc, vc = attn.attn_decode(cfg, sp["attn"], x,
                                         kvs["k"][app], kvs["v"][app], pos,
                                         freqs=freqs)
            h = h + a
            h = h + common.apply_mlp(cfg, sp["mlp"],
                                     common.apply_norm(cfg, sp["ln2"], h))
            new_k = new_k.at[app].set(kc)
            new_v = new_v.at[app].set(vc)
            app += 1
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = common.lm_head(cfg, params["embed"], h)
    return logits, {"mamba": {"conv": new_conv, "ssd": new_ssd},
                    "kv": {"k": new_k, "v": new_v}}
