"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

The conv1d mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, n_frames, d_model).  The encoder
is a non-causal transformer over frames with a learned positional table;
the decoder is a causal transformer with cross-attention to the encoder
output.  Decoder positions use RoPE instead of Whisper's learned absolute
table so the assigned 32k-token decode shapes are well-defined (deviation
noted in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common
from repro.models.common import ArchCfg, dense_init


def init_enc_layer(cfg: ArchCfg, key):
    k1, k2 = jax.random.split(key)
    return {"ln1": common.init_norm(cfg), "ln2": common.init_norm(cfg),
            "attn": attn.init_attn(cfg, k1),
            "mlp": common.init_mlp(cfg, k2)}


def init_dec_layer(cfg: ArchCfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": common.init_norm(cfg), "ln2": common.init_norm(cfg),
            "ln3": common.init_norm(cfg),
            "self_attn": attn.init_attn(cfg, k1),
            "cross_attn": attn.init_attn(cfg, k2),
            "mlp": common.init_mlp(cfg, k3)}


def init_lm(cfg: ArchCfg, key):
    ke, kp, kenc, kdec, kn = jax.random.split(key, 5)
    return {
        "embed": common.init_embed(cfg, ke),
        "enc_pos": dense_init(kp, (cfg.n_frames, cfg.d_model), cfg.dtype,
                              scale=0.02),
        "enc_layers": common.stacked(jax.random.split(kenc, cfg.n_enc_layers),
                                     functools.partial(init_enc_layer, cfg)),
        "dec_layers": common.stacked(jax.random.split(kdec, cfg.n_layers),
                                     functools.partial(init_dec_layer, cfg)),
        "enc_norm": common.init_norm(cfg),
        "final_norm": common.init_norm(cfg),
    }


def encode(cfg: ArchCfg, params, frames, *, remat: bool = True):
    """frames: (B, n_frames, d) stub embeddings -> encoder output."""
    h = frames.astype(cfg.dtype) + params["enc_pos"][None]

    def body(h, lp):
        a, _ = attn.attn_full(cfg, lp["attn"],
                              common.apply_norm(cfg, lp["ln1"], h),
                              freqs=None, causal=False)
        h = h + a
        h = h + common.apply_mlp(cfg, lp["mlp"],
                                 common.apply_norm(cfg, lp["ln2"], h))
        return h, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return common.apply_norm(cfg, params["enc_norm"], h)


def _cross_kv(cfg: ArchCfg, lp, enc_out):
    """Precompute cross-attention K/V for one decoder layer."""
    B, F, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    p = lp["cross_attn"]
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return (k.reshape(B, F, cfg.n_kv_heads, hd),
            v.reshape(B, F, cfg.n_kv_heads, hd))


def decode_stack(cfg: ArchCfg, params, h, enc_out, *, remat: bool = True):
    freqs = common.rope_freqs(cfg)

    def body(h, lp):
        a, _ = attn.attn_full(cfg, lp["self_attn"],
                              common.apply_norm(cfg, lp["ln1"], h),
                              freqs=freqs, causal=True)
        h = h + a
        kv = _cross_kv(cfg, lp, enc_out)
        h = h + attn.attn_cross(cfg, lp["cross_attn"],
                                common.apply_norm(cfg, lp["ln2"], h), kv)
        h = h + common.apply_mlp(cfg, lp["mlp"],
                                 common.apply_norm(cfg, lp["ln3"], h))
        return h, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    return common.apply_norm(cfg, params["final_norm"], h)


def train_loss(cfg: ArchCfg, params, batch, *, remat: bool = True):
    enc_out = encode(cfg, params, batch["frames"], remat=remat)
    h = common.embed_tokens(params["embed"], batch["tokens"])
    h = decode_stack(cfg, params, h, enc_out, remat=remat)
    logits = common.lm_head(cfg, params["embed"], h)
    return common.cross_entropy(logits, batch["labels"])


# ----------------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------------

def prefill(cfg: ArchCfg, params, batch, *, max_len: int | None = None,
            remat: bool = True):
    """Encode frames + prefill decoder tokens.  Returns (logits, state)."""
    enc_out = encode(cfg, params, batch["frames"], remat=remat)
    h = common.embed_tokens(params["embed"], batch["tokens"])
    B, S, _ = h.shape
    max_len = max_len or S
    freqs = common.rope_freqs(cfg)

    def body(h, lp):
        a, (k, v) = attn.attn_full(cfg, lp["self_attn"],
                                   common.apply_norm(cfg, lp["ln1"], h),
                                   freqs=freqs, causal=True)
        h = h + a
        ckv = _cross_kv(cfg, lp, enc_out)
        h = h + attn.attn_cross(cfg, lp["cross_attn"],
                                common.apply_norm(cfg, lp["ln2"], h), ckv)
        h = h + common.apply_mlp(cfg, lp["mlp"],
                                 common.apply_norm(cfg, lp["ln3"], h))
        pad = max_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return h, (k, v, ckv[0], ckv[1])

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, (ks, vs, cks, cvs) = jax.lax.scan(body, h, params["dec_layers"])
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = common.lm_head(cfg, params["embed"], h[:, -1:])
    return logits, {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs}


def decode_step(cfg: ArchCfg, params, token, state, pos):
    h = common.embed_tokens(params["embed"], token)
    freqs = common.rope_freqs(cfg)

    def body(h, xs):
        lp, kc, vc, ck, cv = xs
        x = common.apply_norm(cfg, lp["ln1"], h)
        a, kc, vc = attn.attn_decode(cfg, lp["self_attn"], x, kc, vc, pos,
                                     freqs=freqs)
        h = h + a
        h = h + attn.attn_cross(cfg, lp["cross_attn"],
                                common.apply_norm(cfg, lp["ln2"], h),
                                (ck, cv))
        h = h + common.apply_mlp(cfg, lp["mlp"],
                                 common.apply_norm(cfg, lp["ln3"], h))
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, h, (params["dec_layers"], state["k"],
                                         state["v"], state["cross_k"],
                                         state["cross_v"]))
    h = common.apply_norm(cfg, params["final_norm"], h)
    logits = common.lm_head(cfg, params["embed"], h)
    return logits, {**state, "k": ks, "v": vs}
