"""Mixture-of-Experts FFN with sort-based (dropless-style) dispatch.

Dispatch is the sorted-scatter formulation: expand each token k times,
stable-sort by expert id, place into an (E, C, d) capacity buffer, run the
batched expert FFN as (E, C, d) x (E, d, f) einsums (MXU-friendly), then
combine back with the router probabilities.  No (T, E, C) one-hot tensor is
ever materialised — peak extra memory is the k-expanded token buffer.

Under GSPMD the expert axis shards over 'model' (EP): the scatter/gather
pair lowers to the expert all-to-all, which on the torus fabric is exactly
the dimension-ordered A2A of core/collectives (cf. benchmarks/roofline —
the MoE cells are the most collective-bound of the pool).

Overflowed tokens (per-expert demand beyond capacity) are dropped by the
scatter's OOB semantics and contribute zero to the combine — the standard
capacity-factor trade-off; tests cover both the no-drop and drop regimes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import jaxcompat
from repro.models.common import ArchCfg, dense_init


def init_moe(cfg: ArchCfg, key):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_expert, m.n_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(k1, (e, d, f), cfg.dtype),
        "w_up": dense_init(k2, (e, d, f), cfg.dtype),
        "w_down": dense_init(k3, (e, f, d), cfg.dtype),
    }


def capacity(cfg: ArchCfg, n_tokens: int) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k / m.n_experts * m.capacity_factor)
    return max(c, m.top_k)


def apply_moe(cfg: ArchCfg, p, x, *, dropless: bool = False):
    """x: (B, S, d) -> (y: (B, S, d), aux_loss: scalar fp32).

    ``dropless=True`` sizes every expert's buffer to the full token count,
    so no token is ever capacity-dropped.  Serving paths require this:
    with capacity drops a token's output depends on which other tokens
    share the forward (C scales with T), which would make decode results
    vary with batching and prefill chunking.  Training keeps the capacity
    model (the paper-relevant comm-bounded dispatch)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    C = T if dropless else capacity(cfg, T)
    xt = x.reshape(T, d)

    # --- routing (fp32 for a stable softmax) ---------------------------------
    logits = xt.astype(jnp.float32) @ p["router"]           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                  # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(0)                                       # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (T * K))
    aux = m.router_aux_weight * E * jnp.sum(me * ce)

    # --- sort-based dispatch ---------------------------------------------------
    flat_e = top_e.reshape(-1)                               # (T*K,)
    flat_p = top_p.reshape(-1)
    tok_id = jnp.arange(T * K, dtype=jnp.int32) // K
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)   # OOB -> dropped

    buf = jnp.zeros((E * C, d), x.dtype)
    buf = buf.at[dest].set(xt[tok_id[order]], mode="drop")
    buf = buf.reshape(E, C, d)

    # --- expert FFN (batched over E) --------------------------------------------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                               p["w_gate"]).astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"]).astype(jnp.float32)
    h = (g * u).astype(x.dtype)
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, d)

    # --- combine -------------------------------------------------------------------
    gathered = jnp.where(keep[:, None],
                         jnp.take(out, jnp.minimum(dest, E * C - 1), axis=0),
                         0.0)
    weighted = gathered.astype(jnp.float32) * flat_p[order][:, None]
    y = jnp.zeros((T, d), jnp.float32).at[tok_id[order]].add(weighted)
    return y.reshape(B, S, d).astype(x.dtype), aux


# ----------------------------------------------------------------------------
# Expert-parallel dispatch with explicit all-to-alls (§Perf H2).
#
# The global sort-based dispatch above is a single data-dependent scatter
# over a (T*K, d) buffer: GSPMD cannot see an all-to-all in it, so at 256
# chips it all-gathers the expanded token buffer (the olmoe/moonshot train
# cells were ~50x collective-bound at baseline).  Here the routing runs
# *locally* per (data x model) shard inside shard_map and only the
# capacity-bounded expert buffers cross the 'model' axis — two explicit
# lax.all_to_all ops (dispatch + return), which is exactly the
# dimension-ordered torus A2A of the paper's fabric.
# ----------------------------------------------------------------------------


def _local_dispatch(cfg: ArchCfg, xt, router, K, E, C):
    """Route a local token block: returns (buf (E*C, d), combine closure)."""
    T, d = xt.shape
    m = cfg.moe
    logits = xt.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(-1)
    flat_p = top_p.reshape(-1)
    tok_id = jnp.arange(T * K, dtype=jnp.int32) // K
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)
    buf = jnp.zeros((E * C, d), xt.dtype)
    buf = buf.at[dest].set(xt[tok_id[order]], mode="drop")

    def combine(outbuf):
        gathered = jnp.where(
            keep[:, None],
            jnp.take(outbuf, jnp.minimum(dest, E * C - 1), axis=0), 0.0)
        weighted = gathered.astype(jnp.float32) * flat_p[order][:, None]
        return jnp.zeros((T, d), jnp.float32).at[tok_id[order]].add(weighted)

    return buf, combine, probs, flat_e


def apply_moe_ep(cfg: ArchCfg, p, x):
    """shard_map EP MoE: x (B, S, d) -> (y, aux).  Tokens are sharded over
    (DP x 'model') for routing; capacity buffers cross 'model' via two
    explicit all_to_alls; experts stay sharded over 'model' (EP)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel import sharding as shd

    mesh = shd.runtime_mesh()
    m = cfg.moe
    tp = 1 if mesh is None else shd.tp_size(mesh)
    B, S, d = x.shape
    if mesh is None or tp <= 1 or m.n_experts % tp or S % tp \
            or (B % max(shd.dp_size(mesh), 1)):
        return apply_moe(cfg, p, x)   # graceful fallback: global dispatch
    dpx = shd.dp_axes(mesh)
    E, K = m.n_experts, m.top_k
    E_loc = E // tp
    T_loc = (B // max(shd.dp_size(mesh), 1)) * (S // tp)
    C = max(int(T_loc * K / E * m.capacity_factor), K)
    all_axes = tuple(dpx) + ("model",)

    def local(xs, router, wg, wu, wd):
        Bl, Sl, _ = xs.shape
        xt = xs.reshape(Bl * Sl, d)
        buf, combine, probs, flat_e = _local_dispatch(cfg, xt, router, K, E,
                                                      C)
        # Switch-style aux loss from globally-averaged router stats
        me = jax.lax.pmean(probs.mean(0), all_axes)
        ce = jax.lax.pmean(
            jnp.zeros((E,), jnp.float32).at[flat_e].add(
                1.0 / flat_e.shape[0]), all_axes)
        aux = m.router_aux_weight * E * jnp.sum(me * ce)
        # dispatch A2A: (tp, E_loc*C, d) -> dim0 becomes the sender rank
        send = buf.reshape(tp, E_loc * C, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0)
        toks = recv.reshape(tp, E_loc, C, d).transpose(1, 0, 2, 3) \
            .reshape(E_loc, tp * C, d)
        # local expert FFN (E_loc experts on this shard)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", toks,
                                   wg).astype(jnp.float32))
        u = jnp.einsum("ecd,edf->ecf", toks, wu).astype(jnp.float32)
        hmid = (g * u).astype(xs.dtype)
        out = jnp.einsum("ecf,efd->ecd", hmid, wd)
        # return A2A: route expert outputs back to their senders
        back = out.reshape(E_loc, tp, C, d).transpose(1, 0, 2, 3) \
            .reshape(tp, E_loc * C, d)
        ret = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0)
        y = combine(ret.reshape(E * C, d))
        return y.reshape(Bl, Sl, d).astype(x.dtype), aux

    in_specs = (P(tuple(dpx), "model", None), P(), P("model", None, None),
                P("model", None, None), P("model", None, None))
    out_specs = (P(tuple(dpx), "model", None), P())
    mapped = jaxcompat.shard_map(local, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
    y, aux = mapped(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, jnp.mean(aux)
