"""Sharding rules: PartitionSpecs for params, batches and decode state.

Axes: DP over ("pod", "data") [batch], TP over "model" [heads / hidden /
vocab / experts], ZeRO-1 optimizer-state sharding over "data".  PP is not
enabled for the assigned shapes (every config fits TP x DP at 512 chips);
the natural hook is a leading "stage" mesh axis plus a stage-sliced layer
scan — documented here, implemented when depth x batch demands it.

Parameter rules are path+shape driven so one rule set covers all ten arch
families (stacked layer params carry a leading L axis that is never
sharded):

  * MoE expert tensors (E, d, f): E -> "model"  (expert parallelism; the
    token dispatch then lowers to the torus all-to-all)
  * other >=2D weights: shard the last dim whose size divides |model| and
    that is not d_model; fall back to any divisible dim; else replicate
    (e.g. GQA kv projections with 2 kv heads < 16-way TP stay replicated)
  * 1D tensors: shard iff not d_model-sized and divisible (biases of
    sharded projections follow their matrix)
  * norms / scalars / tiny leaves: replicated

Batch rule: batch dim over DP axes when divisible (long_500k has batch 1 —
the KV-cache sequence dim shards over "data" instead: SP-style decode).
"""
from __future__ import annotations


import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.jaxcompat import abstract_mesh  # noqa: F401  (re-export:
# spec-level tests build device-less production meshes through here)
from repro.models.common import ArchCfg

STACKED_KEYS = {"layers", "mamba", "enc_layers", "dec_layers"}
MOE_EXPERT_KEYS = {"w_gate", "w_up", "w_down"}


def dp_axes(mesh: Mesh, cfg: ArchCfg | None = None) -> tuple[str, ...]:
    names = ["pod", "data"]
    if cfg is not None and cfg.parallelism == "dp_only":
        names.append("model")   # batch over every axis, params replicated
    return tuple(a for a in names if a in mesh.axis_names)


def dp_size(mesh: Mesh, cfg: ArchCfg | None = None) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh, cfg)],
                       initial=1))


def tp_size(mesh: Mesh, cfg: ArchCfg | None = None) -> int:
    if cfg is not None and cfg.parallelism == "dp_only":
        return 1
    return mesh.shape.get("model", 1)


def _param_spec(path, shape, cfg: ArchCfg, tp: int) -> P:
    keys = [getattr(k, "key", None) for k in path]
    name = keys[-1]
    stacked = any(k in STACKED_KEYS for k in keys)
    dims = list(shape[1:]) if stacked else list(shape)
    offset = 1 if stacked else 0

    def lift(spec_dims):
        return P(*([None] * offset + spec_dims))

    if not dims:
        return P()
    if tp <= 1:  # no TP axis in this mesh: everything replicated
        return lift([None] * len(dims))
    # MoE expert tensors: expert-parallel on the leading E axis
    if "moe" in keys and name in MOE_EXPERT_KEYS and len(dims) == 3:
        if dims[0] % tp == 0:
            return lift(["model", None, None])
        return lift([None, None, None])
    if len(dims) == 1:
        n = dims[0]
        if n != cfg.d_model and n % tp == 0 and n >= tp:
            return lift(["model"])
        return lift([None])
    # >= 2D: prefer last non-d_model divisible dim, then any divisible dim
    spec = [None] * len(dims)
    candidates = [i for i in reversed(range(len(dims)))
                  if dims[i] % tp == 0 and dims[i] >= tp]
    preferred = [i for i in candidates if dims[i] != cfg.d_model]
    pick = (preferred or candidates)
    if pick:
        spec[pick[0]] = "model"
    return lift(spec)


def param_specs(cfg: ArchCfg, shapes, mesh: Mesh):
    """PartitionSpec pytree matching the param-shape pytree."""
    tp = tp_size(mesh, cfg)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(path, leaf.shape, cfg, tp), shapes)


def zero1_specs(cfg: ArchCfg, shapes, mesh: Mesh):
    """Optimizer-moment specs: params' specs + the largest remaining dim
    sharded over "data" (ZeRO-1: moments never need to be re-gathered for
    the forward pass, so they can shard further than params)."""
    base = param_specs(cfg, shapes, mesh)
    nd = mesh.shape.get("data", 1)
    if nd <= 1:  # no data axis: ZeRO-1 degenerates to plain param specs
        return base

    # dp_only: params are replicated, so moments can shard over the whole
    # (data x model) device grid
    zaxes = ("data", "model") if cfg.parallelism == "dp_only" \
        and "model" in mesh.axis_names else ("data",)
    nz = int(np.prod([mesh.shape[a] for a in zaxes]))

    def extend(path, leaf, spec):
        dims = list(leaf.shape)
        used = list(spec) + [None] * (len(dims) - len(spec))
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if used[i] is None and dims[i] % nz == 0 and dims[i] >= nz:
                used[i] = zaxes if len(zaxes) > 1 else "data"
                break
        else:
            for i in order:
                if used[i] is None and dims[i] % nd == 0 and dims[i] >= nd:
                    used[i] = "data"
                    break
        return P(*used)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf, spec: extend(path, leaf, spec), shapes, base)


# ----------------------------------------------------------------------------
# batch / state specs
# ----------------------------------------------------------------------------

def _dp_prefix(mesh: Mesh, cfg: ArchCfg | None, n: int) \
        -> tuple[tuple[str, ...], int]:
    """Longest prefix of the DP axes whose size product divides n."""
    out: list[str] = []
    prod = 1
    for a in dp_axes(mesh, cfg):
        if n > 0 and n % (prod * mesh.shape[a]) == 0:
            out.append(a)
            prod *= mesh.shape[a]
        else:
            break
    return tuple(out), prod


def batch_specs(cfg: ArchCfg, batch_shapes, mesh: Mesh):
    """Shard the leading (batch) dim of every input over the largest
    dividing DP-axis prefix; under dp_only an idle 'model' axis picks up
    the sequence dim instead (SP) — a global batch smaller than the
    device grid must never silently replicate the whole computation."""
    def one(leaf):
        dims = list(leaf.shape)
        if not dims:
            return P()
        axes_used, _ = _dp_prefix(mesh, cfg, dims[0])
        spec: list = [None] * len(dims)
        if axes_used:
            spec[0] = axes_used
        if cfg.parallelism == "dp_only" and "model" not in axes_used \
                and "model" in mesh.axis_names and len(dims) >= 2 \
                and dims[1] % mesh.shape["model"] == 0 and dims[1] > 1:
            spec[1] = "model"
        return P(*spec)

    return jax.tree.map(one, batch_shapes)


def decode_state_specs(cfg: ArchCfg, state_shapes, mesh: Mesh,
                       global_batch: int):
    """Decode caches: batch over the largest dividing DP-axis prefix;
    head-indexed dims shard over "model" under TP; a leftover axis
    ("model" under dp_only, "data" at batch 1) picks up the cache
    *sequence* dim (sequence-parallel decode)."""
    axes_used, nprod = _dp_prefix(mesh, cfg, global_batch)
    tp = tp_size(mesh, cfg)

    def _seq_shard(spec, dims, axis_name, min_dim=1024):
        m = mesh.shape[axis_name]
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if spec[i] is None and dims[i] % m == 0 and dims[i] > min_dim:
                spec[i] = axis_name
                return

    def one(path, leaf):
        dims = list(leaf.shape)
        spec = [None] * len(dims)
        # find the batch dim (== global_batch); caches carry leading L axis
        if axes_used:
            for i, d in enumerate(dims):
                if d == global_batch:
                    spec[i] = axes_used
                    break
        # shard one more dim over model: prefer head-count / feature dims
        if tp > 1:
            for i in reversed(range(len(dims))):
                if spec[i] is None and dims[i] % tp == 0 and dims[i] >= tp \
                        and i != len(dims) - 1:  # keep head_dim/lane dim whole
                    spec[i] = "model"
                    break
        elif cfg.parallelism == "dp_only" and "model" not in axes_used \
                and "model" in mesh.axis_names:
            _seq_shard(spec, dims, "model")      # SP decode over 'model'
        if not axes_used and "data" in mesh.axis_names:
            _seq_shard(spec, dims, "data")       # long_500k: seq over data
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, state_shapes)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


# ----------------------------------------------------------------------------
# runtime mesh registry: models are pure functions of (cfg, params, batch),
# but two §Perf optimizations need the ambient mesh while tracing —
# activation sharding constraints (Megatron TP) and the shard_map EP
# all-to-all.  The launcher/trainer registers its mesh here; with no mesh
# registered both helpers are no-ops and the model stays mesh-agnostic.
# ----------------------------------------------------------------------------

_RUNTIME_MESH: Mesh | None = None


def set_runtime_mesh(mesh: Mesh | None) -> None:
    global _RUNTIME_MESH
    _RUNTIME_MESH = mesh


def runtime_mesh() -> Mesh | None:
    return _RUNTIME_MESH


def constrain_activations(x, *, seq_axis: str | None = None):
    """Pin a (B, S, d) activation to batch-over-DP [,seq-over-model].

    Megatron-style TP keeps the residual stream replicated over 'model'
    (seq_axis=None); sequence parallelism shards S over 'model' instead
    (seq_axis='model').  No-op without a registered mesh."""
    mesh = _RUNTIME_MESH
    if mesh is None:
        return x
    axes = dp_axes(mesh)  # constraint path: cfg-independent (tp modes only)
    if not axes:
        return x
    spec = [axes] + [None] * (x.ndim - 1)
    if seq_axis and seq_axis in mesh.axis_names and x.ndim >= 2 \
            and x.shape[1] % mesh.shape[seq_axis] == 0:
        spec[1] = seq_axis
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
