"""Data pipeline: deterministic synthetic LM stream + threaded prefetch.

The stream is a seeded Markov-ish token process (so losses actually go
*down* during the e2e examples — pure-uniform tokens would pin the loss at
log V).  Batches are resumable: the generator state is just (seed, step),
checkpointed alongside the model, so a restarted run replays the exact
stream — a fault-tolerance requirement (LO|FA|MO restart), tested in
tests/test_runtime.py.

``Prefetcher`` double-buffers host batch construction behind device compute
on a background thread (the host-side analogue of the §2.1 prefetchable
command queue).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.common import ArchCfg


class SyntheticTokens:
    """Deterministic, resumable synthetic token batches."""

    def __init__(self, cfg: ArchCfg, batch: int, seq_len: int, *,
                 seed: int = 0, step: int = 0) -> None:
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = step
        # fixed per-seed Markov transition "template" to give structure
        rng = np.random.default_rng(seed)
        self._mod = min(cfg.vocab, 257)
        self._shift = rng.integers(1, self._mod - 1)

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, cfg: ArchCfg, batch: int, seq_len: int,
                   state: dict) -> "SyntheticTokens":
        return cls(cfg, batch, seq_len, seed=int(state["seed"]),
                   step=int(state["step"]))

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        start = rng.integers(0, self._mod, size=(self.batch, 1))
        idx = np.arange(self.seq_len)[None, :]
        # affine-progression tokens: next token is predictable from previous
        tokens = ((start + idx * self._shift) % self._mod).astype(np.int32)
        noise = rng.random(size=tokens.shape) < 0.05
        tokens = np.where(noise,
                          rng.integers(0, self._mod, size=tokens.shape),
                          tokens).astype(np.int32)
        labels = np.concatenate([tokens[:, 1:],
                                 np.full((self.batch, 1), -1, np.int32)], 1)
        batch = {"tokens": tokens, "labels": labels}
        cfg = self.cfg
        if cfg.family == "encdec":
            batch["frames"] = rng.normal(
                size=(self.batch, cfg.n_frames, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            batch["prefix_embeds"] = rng.normal(
                size=(self.batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
        self.step += 1
        return batch

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()


def make_batch_arrays(batch: dict, cfg: ArchCfg, shardings=None) -> dict:
    """Host numpy batch -> device arrays (optionally with NamedShardings)."""
    out = {}
    for k, v in batch.items():
        dtype = jnp.int32 if v.dtype.kind == "i" else cfg.dtype
        arr = jnp.asarray(v, dtype)
        if shardings is not None and k in shardings:
            arr = jax.device_put(arr, shardings[k])
        out[k] = arr
    return out


class Prefetcher:
    """Background-thread double buffering of host batch construction."""

    def __init__(self, it: Iterator[dict], depth: int = 2) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        except Exception as e:  # surface errors to the consumer
            self._q.put(e)

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self) -> None:
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
