from repro.data.pipeline import (Prefetcher, SyntheticTokens,  # noqa: F401
                                 make_batch_arrays)
