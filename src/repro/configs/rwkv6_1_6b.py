"""RWKV6 "Finch" 1.6B [arXiv:2404.05892]: attention-free, 24L
d_model=2048, channel-mix d_ff=7168, vocab 65536; 32 wkv heads of 64."""
from repro.models.common import ArchCfg

CONFIG = ArchCfg(
    name="rwkv6-1_6b",
    family="rwkv6",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # wkv heads
    n_kv_heads=0,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    norm="ln",
    full_attention=False,  # O(1) state: runs long_500k
)
