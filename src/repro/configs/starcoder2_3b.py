"""StarCoder2-3B [arXiv:2402.19173]: 30L d_model=3072 24H (GQA kv=2)
d_ff=12288, vocab 49152; RoPE, LayerNorm + GeLU MLP, biasful QKV."""
from repro.models.common import ArchCfg

CONFIG = ArchCfg(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    norm="ln",
    mlp="gelu",
    qkv_bias=True,
    full_attention=True,
    parallelism="dp_only",       # §Perf H4: 24H/2KV do not split 16-way
)
