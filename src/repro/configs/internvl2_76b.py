"""InternVL2-76B LM backbone [arXiv:2404.16821]: 80L d_model=8192 64H
(GQA kv=8) d_ff=28672, vocab 128256.  InternViT frontend is a STUB:
input_specs supply precomputed patch embeddings."""
from repro.models.common import ArchCfg

CONFIG = ArchCfg(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    n_patches=256,
    norm="rms",
    mlp="swiglu",
    full_attention=True,  # long_500k skipped
    attn_dtype="bf16",           # decode: bf16 cache ops, no GQA repeat
)
