"""Assigned architecture configs (exact public-literature settings).

``get_config(name)`` returns the full ArchCfg; ``get_reduced(name)`` the
same-family tiny variant used by CPU smoke tests.  ``ALL_ARCHS`` is the
assignment's 10-arch pool.
"""
from __future__ import annotations

import importlib

from repro.models.common import ArchCfg

ALL_ARCHS = [
    "olmoe-1b-7b",
    "moonshot-v1-16b-a3b",
    "starcoder2-3b",
    "qwen2-0_5b",
    "deepseek-7b",
    "smollm-135m",
    "zamba2-1_2b",
    "rwkv6-1_6b",
    "whisper-large-v3",
    "internvl2-76b",
]

# accept both the assignment spelling (dots) and module-safe underscores
_ALIASES = {
    "qwen2-0.5b": "qwen2-0_5b",
    "zamba2-1.2b": "zamba2-1_2b",
    "rwkv6-1.6b": "rwkv6-1_6b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name)


def get_config(name: str) -> ArchCfg:
    mod = importlib.import_module(
        f"repro.configs.{canonical(name).replace('-', '_')}")
    return mod.CONFIG


def get_reduced(name: str) -> ArchCfg:
    return get_config(name).reduced()
