"""Zamba2-1.2B [arXiv:2411.15242]: hybrid — 38 Mamba2 backbone layers,
one shared full-attention block (32H, MHA) applied periodically,
d_model=2048, shared-MLP d_ff=8192, ssm_state=64, vocab 32000."""
from repro.models.common import ArchCfg, SsmCfg

CONFIG = ArchCfg(
    name="zamba2-1_2b",
    family="zamba2",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm=SsmCfg(d_state=64, head_dim=64, expand=2, conv_width=4),
    attn_every=6,
    norm="rms",
    mlp="gelu",
    full_attention=False,   # runs long_500k: state is O(1); shared-attn KV
                            # is the only context-linear memory
)
