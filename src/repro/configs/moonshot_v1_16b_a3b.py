"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: 48L d_model=2048
16H (GQA kv=16), MoE 64 experts top-6, expert d_ff=1408, vocab 163840."""
from repro.models.common import ArchCfg, MoeCfg

CONFIG = ArchCfg(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,          # per-expert hidden
    vocab=163840,
    moe=MoeCfg(n_experts=64, top_k=6, d_expert=1408),
    norm="rms",
    mlp="swiglu",
    full_attention=True,
    moe_impl="ep_a2a",           # §Perf H2: explicit EP all-to-all
)
