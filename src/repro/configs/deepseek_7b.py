"""DeepSeek-7B [arXiv:2401.02954]: llama-arch, 30L d_model=4096 32H
(MHA kv=32) d_ff=11008, vocab 102400."""
from repro.models.common import ArchCfg

CONFIG = ArchCfg(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    norm="rms",
    mlp="swiglu",
    full_attention=True,
    tp_activations="manual_sp",  # §Perf H3: hand-SPMD Megatron-SP
    attn_dtype="bf16",           # bf16 wire/operands, fp32 accumulation
)
