"""Qwen2-0.5B [arXiv:2407.10671]: 24L d_model=896 14H (GQA kv=2)
d_ff=4864, vocab 151936; QKV bias, tied embeddings."""
from repro.models.common import ArchCfg

CONFIG = ArchCfg(
    name="qwen2-0_5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    norm="rms",
    mlp="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    full_attention=True,
    parallelism="dp_only",       # §Perf H4: 14H/2KV do not split 16-way
)
