"""Whisper-large-v3 backbone [arXiv:2212.04356]: enc-dec, 32+32L,
d_model=1280 20H (MHA) d_ff=5120, vocab 51866.  Conv/mel frontend is a
STUB: input_specs supply precomputed 1500-frame embeddings."""
from repro.models.common import ArchCfg

CONFIG = ArchCfg(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,          # decoder
    n_enc_layers=32,
    n_frames=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    norm="ln",
    mlp="gelu",
    full_attention=True,  # long_500k skipped
)
