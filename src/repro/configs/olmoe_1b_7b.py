"""OLMoE-1B-7B [arXiv:2409.02060; hf]: 16L d_model=2048 16H (GQA kv=16)
MoE 64 experts top-8, expert d_ff=1024, vocab 50304."""
from repro.models.common import ArchCfg, MoeCfg

CONFIG = ArchCfg(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,          # per-expert hidden
    vocab=50304,
    moe=MoeCfg(n_experts=64, top_k=8, d_expert=1024),
    norm="rms",
    mlp="swiglu",
    full_attention=True,
    moe_impl="ep_a2a",           # §Perf H2: explicit EP all-to-all
)
