"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: llama-arch small,
30L d_model=576 9H (GQA kv=3) d_ff=1536, vocab 49152."""
from repro.models.common import ArchCfg

CONFIG = ArchCfg(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    norm="rms",
    mlp="swiglu",
    tie_embeddings=True,
    full_attention=True,
    parallelism="dp_only",       # §Perf H4: 9H/3KV do not split 16-way
)
