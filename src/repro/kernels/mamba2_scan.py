"""Mamba2 (SSD) chunked selective-scan Pallas kernel.

The SSD recurrence  h_t = exp(A dt_t) h_{t-1} + dt_t B_t (x) x_t,
y_t = C_t . h_t + D x_t  is evaluated chunk-parallel: within a chunk of L
steps everything is expressed as (L x L) / (L x ds) matmuls (MXU work), and
only the (ds x dh) state crosses chunk boundaries, carried in VMEM scratch
across the sequential innermost grid axis.

Because A < 0 and dt > 0, every decay factor exp(.) used below is <= 1, so
the closed form is numerically stable without max-subtraction.

Grid: (B, H, S/L).  n_groups = 1 (B/C shared across heads), the Zamba2
configuration.  Validated vs kernels/ref.py::mamba2_scan in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_ref, *,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # (L, dh)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (L,)
    a = a_ref[0].astype(jnp.float32)                # ()
    bm = b_ref[0].astype(jnp.float32)               # (L, ds)
    cm = c_ref[0].astype(jnp.float32)               # (L, ds)
    dskip = d_ref[0].astype(jnp.float32)            # ()

    la = a * dt                                     # (L,) log-decays, <= 0
    s = jnp.cumsum(la)                              # inclusive cumulative
    # state contribution: y_state[t] = (exp(s_t) C_t) . h_in
    y_state = jax.lax.dot_general(cm * jnp.exp(s)[:, None], h_ref[...],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # intra-chunk: att[t,tau] = exp(s_t - s_tau) (C_t.B_tau) dt_tau, tau <= t
    gram = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (L, L)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tau_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(s[:, None] - s[None, :])
    att = jnp.where(tau_idx <= t_idx, gram * decay * dt[None, :], 0.0)
    y = y_state + jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = (y + dskip * x).astype(y_ref.dtype)

    # state update: h_out = exp(s_L) h_in + sum_tau exp(s_L - s_tau) dt_tau
    #               B_tau (x) x_tau
    s_last = s[chunk - 1]
    w = jnp.exp(s_last - s) * dt                    # (L,)
    inject = jax.lax.dot_general(bm * w[:, None], x,
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    h_ref[...] = h_ref[...] * jnp.exp(s_last) + inject


def mamba2_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bmat: jax.Array,
                Cmat: jax.Array, D: jax.Array, *,
                chunk: int = DEFAULT_CHUNK,
                interpret: bool = False) -> jax.Array:
    """x: (B,S,H,dh), dt: (B,S,H), A/D: (H,), Bmat/Cmat: (B,S,ds) -> like x."""
    Bsz, S, H, dh = x.shape
    ds = Bmat.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (Bsz, H, S // chunk)

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, ds), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, dh), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((ds, dh), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bmat, Cmat, D)
