"""RWKV6 (Finch) wkv recurrence Pallas kernel.

S_t = diag(w_t) S_{t-1} + k_t (x) v_t ;   y_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)

w_t is a *data-dependent per-channel* decay (the paper-series' headline
feature), so unlike Mamba2's scalar-decay SSD there is no cheap chunk-level
closed form; the kernel walks the chunk with an in-register fori_loop and
carries the (dh x dh) state across chunks in VMEM scratch (sequential
innermost grid axis).  dh is the vector-lane dimension, so each step is a
rank-1 update + matvec on the VPU; the chunk loop amortises the state
load/store to once per L steps.

Grid: (B, H, S/L).  Validated vs kernels/ref.py::rwkv6_scan in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)   # (L, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)            # (dh,)

    def step(t, carry):
        s, y = carry
        rt = jax.lax.dynamic_index_in_dim(r, t, 0, keepdims=False)
        kt = jax.lax.dynamic_index_in_dim(k, t, 0, keepdims=False)
        vt = jax.lax.dynamic_index_in_dim(v, t, 0, keepdims=False)
        wt = jax.lax.dynamic_index_in_dim(w, t, 0, keepdims=False)
        kv = kt[:, None] * vt[None, :]                     # (dh, dh)
        yt = (rt[None, :] @ (s + u[:, None] * kv))[0]      # (dh,)
        s = s * wt[:, None] + kv
        y = jax.lax.dynamic_update_index_in_dim(y, yt, t, 0)
        return s, y

    s0 = s_ref[...]
    y0 = jnp.zeros((chunk, r.shape[-1]), jnp.float32)
    s_out, y = jax.lax.fori_loop(0, chunk, step, (s0, y0))
    s_ref[...] = s_out
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, *, chunk: int = DEFAULT_CHUNK,
               interpret: bool = False) -> jax.Array:
    """r/k/v/w: (B,S,H,dh), u: (H,dh) -> (B,S,H,dh)."""
    B, S, H, dh = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (B, H, S // chunk)

    seq_spec = pl.BlockSpec((1, chunk, 1, dh), lambda b, h, c: (b, c, h, 0))
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, dh), lambda b, h, c: (h, 0))],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct(r.shape, r.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
