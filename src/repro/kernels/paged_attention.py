"""Paged decode attention — the §2.2 hardware-TLB idea as a Pallas kernel.

APEnet+ §2.2 moved virtual->physical address translation out of the Nios II
soft-CPU into an FPGA TLB sitting directly in the RX datapath (+60% RX
bandwidth).  The TPU-native analogue: during decode, the KV cache is *paged*
(virtual per-sequence pages scattered over a physical page pool), and the
translation happens **inside the kernel's BlockSpec index_map** via scalar
prefetch — the DMA engine that streams K/V pages from HBM into VMEM is
programmed directly with translated physical page indices, with no
XLA-level gather materialising the sequence first.

  * fast path (this kernel): translation in the index_map = "hardware TLB";
  * slow path (kernels/ref.py::paged_attention): gather pages with XLA ops,
    then dense attention = "Nios II software walk".

benchmarks/tlb.py quantifies the byte-traffic gap between the two paths
(the gather path writes the gathered copy back to HBM before attending).

Grid: (B, H, max_pages), page axis innermost/sequential; online-softmax
running stats in VMEM scratch; pages past a sequence's length are skipped
(pl.when), so ragged batches pay only for resident pages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_table_ref, seq_lens_ref,      # scalar-prefetch operands
            q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *,
            scale: float, page: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    npages = pl.num_programs(2)
    seq_len = seq_lens_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The page is resident iff it holds any position < seq_len.
    @pl.when(j * page < seq_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (D,)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (page, D)
        s = jnp.einsum("d,pd->p", q, k)                      # (page,)
        pos = j * page + jax.lax.iota(jnp.int32, page)
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = alpha * l_ref[0] + p.sum()
        m_ref[0] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jnp.einsum("p,pd->d", p, v)[None]

    @pl.when(j == npages - 1)
    def _flush():
        denom = jnp.where(l_ref[0] == 0.0, 1.0, l_ref[0])
        o_ref[0, 0, :] = (acc_ref[0] / denom).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, seq_lens: jax.Array, *,
                    scale: float | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B,H,D); k_pages/v_pages: (P,page,Hkv,D);
    page_table: (B,max_pages) int32; seq_lens: (B,) int32 -> (B,H,D)."""
    B, H, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    max_pages = page_table.shape[1]
    assert H % Hkv == 0
    group = H // Hkv
    scale = scale if scale is not None else D ** -0.5

    kernel = functools.partial(_kernel, scale=scale, page=page)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, j, pt, sl: (b, h, 0)),
            # THE TLB: physical page id comes from the prefetched page table.
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, pt, sl: (pt[b, j], 0, h // group, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, pt, sl: (pt[b, j], 0, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j, pt, sl: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(page_table, seq_lens, q, k_pages, v_pages)
