"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for kernel tests (interpret=True vs. ref, swept
over shapes/dtypes) AND the GSPMD-shardable implementations used by the
model zoo under jit (XLA partitions them across the mesh; the Pallas
kernels run per-shard inside shard_map — see kernels/ops.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# attention (training / prefill): GQA + causal
# ----------------------------------------------------------------------------

def mha_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: float | None = None,
                  logits_soft_cap: float | None = None,
                  compute_dtype=jnp.float32) -> jax.Array:
    """Multi-head attention with grouped KV heads.

    q: (B, H, Sq, D);  k, v: (B, Hkv, Skv, D) with H % Hkv == 0.
    Returns (B, H, Sq, D) in q.dtype; softmax in fp32.

    ``compute_dtype`` is the *storage/communication* dtype of the matmul
    operands; accumulation is forced to fp32 either way
    (preferred_element_type), which is the TPU-MXU-native arrangement —
    bf16 operands halve the S^2 intermediate traffic and the TP collective
    bytes (§Perf "attn_bf16").
    """
    B, H, Sq, D = q.shape
    Hkv = k.shape[1]
    Skv = k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).astype(compute_dtype)
    kf = k.astype(compute_dtype)
    vf = v.astype(compute_dtype)
    if group > 1:
        kf = jnp.repeat(kf, group, axis=1)
        vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf,
                        preferred_element_type=jnp.float32)
    if logits_soft_cap is not None:
        logits = logits_soft_cap * jnp.tanh(logits / logits_soft_cap)
    if causal:
        qi = jnp.arange(Sq)[:, None] + (Skv - Sq)
        ki = jnp.arange(Skv)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs.astype(compute_dtype), vf,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ----------------------------------------------------------------------------
# paged decode attention ("Nios II" software path: gather pages with XLA,
# then dense attention).  The Pallas kernel translates pages *inside* the
# kernel instead (the §2.2 hardware-TLB analogue).
# ----------------------------------------------------------------------------

def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, seq_lens: jax.Array, *,
                    scale: float | None = None) -> jax.Array:
    """Decode attention for one new token per sequence over a paged KV cache.

    q:          (B, H, D)      — current-step queries
    k_pages:    (P, page, Hkv, D) — physical page pool
    v_pages:    (P, page, Hkv, D)
    page_table: (B, max_pages) int32 — virtual->physical translation
    seq_lens:   (B,) int32     — valid tokens per sequence (cache length)
    Returns (B, H, D).
    """
    B, H, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    max_pages = page_table.shape[1]
    group = H // Hkv
    scale = scale if scale is not None else D ** -0.5

    # XLA-level gather: materialise each sequence's K/V (the slow path).
    k_seq = k_pages[page_table]  # (B, max_pages, page, Hkv, D)
    v_seq = v_pages[page_table]
    k_seq = k_seq.reshape(B, max_pages * page, Hkv, D)
    v_seq = v_seq.reshape(B, max_pages * page, Hkv, D)

    qf = q.astype(jnp.float32) * scale
    kf = k_seq.astype(jnp.float32)
    vf = v_seq.astype(jnp.float32)
    if group > 1:
        kf = jnp.repeat(kf, group, axis=2)
        vf = jnp.repeat(vf, group, axis=2)
    logits = jnp.einsum("bhd,bshd->bhs", qf, kf)
    mask = jnp.arange(max_pages * page)[None, :] < seq_lens[:, None]
    logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, vf)
    return out.astype(q.dtype)


# ----------------------------------------------------------------------------
# Mamba2 / SSD selective scan
# ----------------------------------------------------------------------------

def mamba2_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bmat: jax.Array,
                Cmat: jax.Array, D: jax.Array,
                h0: jax.Array | None = None,
                return_state: bool = False):
    """Sequential oracle of the Mamba2 SSD recurrence (n_groups = 1).

    x:  (B, S, H, dh)   inputs per head
    dt: (B, S, H)       softplus-ed step sizes (> 0)
    A:  (H,)            negative decay rates
    Bmat, Cmat: (B, S, ds)
    D:  (H,)            skip gain
    h0: (B, H, ds, dh)  initial state (zeros if None)

    h_t = exp(A dt_t) h_{t-1} + dt_t * B_t (x) x_t ;  y_t = C_t . h_t + D x_t
    Returns y (B, S, H, dh) [and optionally final state].
    """
    Bsz, S, H, dh = x.shape
    ds = Bmat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = (jnp.zeros((Bsz, H, ds, dh), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,dh), (B,H), (B,ds), (B,ds)
        decay = jnp.exp(Af[None, :] * dtt)            # (B,H)
        inject = jnp.einsum("bs,bhd->bhsd", bt, xt * dtt[..., None])
        h = h * decay[..., None, None] + inject
        y = jnp.einsum("bs,bhsd->bhd", ct, h)
        return h, y

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h, xs)
    y = ys.transpose(1, 0, 2, 3) + D[None, None, :, None] * xf
    y = y.astype(x.dtype)
    if return_state:
        return y, h
    return y


# ----------------------------------------------------------------------------
# RWKV6 (Finch) — data-dependent decay linear attention
# ----------------------------------------------------------------------------

def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, s0: jax.Array | None = None,
               return_state: bool = False):
    """Sequential oracle of the RWKV6 wkv recurrence.

    r, k, v: (B, S, H, dh);  w: (B, S, H, dh) decay in (0,1) (already
    exp(-exp(.)) transformed);  u: (H, dh) bonus.
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)
    Returns y (B, S, H, dh) [and optionally final state (B, H, dh, dh)].
    """
    B, S, H, dh = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)
    state = (jnp.zeros((B, H, dh, dh), jnp.float32) if s0 is None
             else s0.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp  # each (B, H, dh)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[None, :, :, None] * kv)
        s = s * wt[..., None] + kv
        return s, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))
    state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3).astype(r.dtype)
    if return_state:
        return y, state
    return y


# ----------------------------------------------------------------------------
# Chunked (SSD-style) formulations — the GSPMD performance path.
#
# The per-token scans above lower to S-trip while loops whose bodies move
# the full recurrent state through HBM every token: the dry-run roofline
# showed memory terms ~100x above everything else for the ssm/hybrid train
# cells.  The block decomposition below processes C tokens per loop trip
# with dense (MXU-shaped) intra-chunk matmuls and an inter-chunk state
# carry, cutting loop trips and state traffic by C while staying pure jnp
# (so XLA/GSPMD still shards batch/heads across the mesh).  Both are
# validated against the sequential oracles over shapes, chunk sizes and
# carried state in tests/test_kernels.py.
# ----------------------------------------------------------------------------

DEFAULT_SCAN_CHUNK = 64


def _pad_to_chunks(t, chunk, axis=1):
    s = t.shape[axis]
    pad = (-s) % chunk
    if pad == 0:
        return t, 0
    widths = [(0, 0)] * t.ndim
    widths[axis] = (0, pad)
    return jnp.pad(t, widths), pad


def mamba2_scan_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                        Bmat: jax.Array, Cmat: jax.Array, D: jax.Array,
                        h0: jax.Array | None = None,
                        return_state: bool = False,
                        chunk: int = DEFAULT_SCAN_CHUNK):
    """Chunked SSD: same contract as mamba2_scan, O(S/chunk) loop trips.

    Per chunk (decay is a scalar per head+step, so everything is matmuls):
      G[t,j] = exp(cum_t - cum_j)            (bounded <= 1 for j <= t)
      y_t    = sum_{j<=t} G[t,j] (C_t.B_j) dt_j x_j      (intra)
             + exp(cum_t) C_t . h_in + D x_t             (inter + skip)
      h_out  = exp(cum_C) h_in + sum_j exp(cum_C - cum_j) dt_j B_j (x) x_j
    """
    Bsz, S, H, dh = x.shape
    ds = Bmat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    # dt = 0 on padded steps -> decay 1, inject 0: state passes through
    xf, _ = _pad_to_chunks(xf, chunk)
    dtf, _ = _pad_to_chunks(dtf, chunk)
    Bf, _ = _pad_to_chunks(Bf, chunk)
    Cf, _ = _pad_to_chunks(Cf, chunk)
    nC = xf.shape[1] // chunk

    def to_chunks(t):
        return t.reshape((Bsz, nC, chunk) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    xs = (to_chunks(xf), to_chunks(dtf), to_chunks(Bf), to_chunks(Cf))
    h_init = (jnp.zeros((Bsz, H, ds, dh), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def step(h, inp):
        xc, dtc, bc, cc = inp     # (B,C,H,dh) (B,C,H) (B,C,ds) (B,C,ds)
        a = Af[None, None, :] * dtc              # (B,C,H), <= 0
        cum = jnp.cumsum(a, axis=1)              # inclusive
        cum_h = cum.transpose(0, 2, 1)           # (B,H,C)
        # mask the exponent BEFORE exp: the upper triangle is positive and
        # exp(+big) * 0-mask would be inf * 0 = NaN
        diff = cum_h[:, :, :, None] - cum_h[:, :, None, :]
        diff = jnp.where(mask[None, None] > 0, diff, jnp.float32(-1e30))
        G = jnp.exp(diff)                        # (B,H,C,C), j<=t
        CB = jnp.einsum("bts,bjs->btj", cc, bc)  # (B,C,C)
        xdt = xf_mul = xc * dtc[..., None]       # (B,C,H,dh)
        y = jnp.einsum("bhtj,btj,bjhd->bthd", G, CB, xdt)
        y += jnp.einsum("bts,bhsd->bthd", cc, h) \
            * jnp.exp(cum)[..., None]
        y += D[None, None, :, None] * xc
        decay_end = jnp.exp(cum_h[:, :, -1:] - cum_h)   # (B,H,C) <= 1
        h = h * jnp.exp(cum_h[:, :, -1])[..., None, None] \
            + jnp.einsum("bhj,bjs,bjhd->bhsd", decay_end, bc, xf_mul)
        return h, y

    h, ys = jax.lax.scan(step, h_init, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, nC * chunk, H, dh)[:, :S]
    y = y.astype(x.dtype)
    if return_state:
        return y, h
    return y


RWKV_SCAN_CHUNK = 32


def rwkv6_scan_chunked(r: jax.Array, k: jax.Array, v: jax.Array,
                       w: jax.Array, u: jax.Array,
                       s0: jax.Array | None = None,
                       return_state: bool = False,
                       chunk: int = RWKV_SCAN_CHUNK):
    """Chunked RWKV6 wkv: same contract as rwkv6_scan, O(S/chunk) trips.

    Decay is per k-channel, so the intra-chunk term keeps the channel sum:
      y_t = sum_{j<t} sum_c r_t[c] exp(cum_{t-1}[c] - cum_j[c]) k_j[c] v_j
          + (r_t . u k_t) v_t + (r_t * exp(cum_{t-1})) . S_in
    The pairwise exponent cum_{t-1} - cum_j is <= 0 wherever j < t, so it
    is exponentiated directly (exact and bounded; a factored
    r*exp(cum) @ (k*exp(-cum))^T form saturates under strong decay).  The
    (C, C, dh) pairwise tensor bounds the chunk size; 32 keeps it ~100 MB
    at the production per-device batch while still cutting loop trips and
    state HBM traffic by 32x.
    """
    Bsz, S, H, dh = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)
    # padded steps: w = 1 (identity decay), k = v = 0 (no injection)
    rf, _ = _pad_to_chunks(rf, chunk)
    kf, _ = _pad_to_chunks(kf, chunk)
    vf, _ = _pad_to_chunks(vf, chunk)
    wf, pad = _pad_to_chunks(wf, chunk)
    if pad:
        wf = wf.at[:, S:].set(1.0)
    nC = rf.shape[1] // chunk

    def to_chunks(t):
        return t.reshape(Bsz, nC, chunk, H, dh).transpose(1, 0, 2, 3, 4)

    xs = tuple(to_chunks(t) for t in (rf, kf, vf, wf))
    s_init = (jnp.zeros((Bsz, H, dh, dh), jnp.float32) if s0 is None
              else s0.astype(jnp.float32))
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)  # j < t

    neg_inf = jnp.float32(-1e30)

    def step(s, inp):
        rc, kc, vc, wc = inp                     # (B,C,H,dh)
        # floor must stay in the fp32 *normal* range: subnormals flush to
        # zero on-device and log(0) = -inf poisons cum_prev = cum - lw
        lw = jnp.log(jnp.maximum(wc, 1e-30))
        cum = jnp.cumsum(lw, axis=1)             # inclusive, <= 0
        cum_prev = cum - lw                      # exclusive
        r2 = rc * jnp.exp(cum_prev)              # bounded by |r|
        # exact pairwise decay: exponent <= 0 on the masked (j < t) region
        expo = cum_prev[:, :, None] - cum[:, None, :]     # (B,C,C,H,dh)
        expo = jnp.where(mask[None, :, :, None, None] > 0, expo, neg_inf)
        att = jnp.einsum("bihd,bijhd,bjhd->bhij", rc, jnp.exp(expo), kc)
        y = jnp.einsum("bhij,bjhd->bihd", att, vc)
        bonus = jnp.einsum("bihd,hd,bihd->bih", rc, uf, kc)
        y += bonus[..., None] * vc
        y += jnp.einsum("bihk,bhkv->bihv", r2, s)
        decay_end = jnp.exp(cum[:, -1:] - cum)   # (B,C,H,dh) <= 1
        s = s * jnp.exp(cum[:, -1])[..., None] \
            + jnp.einsum("bjhk,bjhv->bhkv", kc * decay_end, vc)
        return s, y

    s, ys = jax.lax.scan(step, s_init, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, nC * chunk, H, dh)[:, :S]
    y = y.astype(r.dtype)
    if return_state:
        return y, s
    return y
