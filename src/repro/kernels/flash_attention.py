"""Blocked (flash) attention Pallas kernel for TPU.

Tiling: grid (B, H, Sq/bq, Skv/bk) with the KV axis innermost — on TPU the
grid is executed sequentially over the last axis, so the output block and
the online-softmax running statistics live in VMEM scratch across KV steps
and are flushed once at the final step.  Block sizes are multiples of 128 on
the lane dimension to keep the MXU fed; K/V blocks for grouped queries are
selected in the index_map (h // group), so GQA costs no extra copies.

Causal skipping: KV blocks strictly above the diagonal are skipped via
pl.when (their compute would be fully masked), which halves FLOPs for long
sequences — the standard flash-attention triangle walk.

Validated on CPU with interpret=True against kernels/ref.py::mha_attention
(see tests/test_kernels.py); the TPU path compiles the same kernel with the
same BlockSpecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            seq_q: int, seq_kv: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: query block [iq*bq, iq*bq+bq) can only attend to kv blocks with
    # start <= last query position (+ offset when Sq != Skv: right-aligned).
    offs = seq_kv - seq_q
    q_last = iq * block_q + block_q - 1 + offs
    visible = jnp.logical_or(jnp.logical_not(causal),
                             jk * block_k <= q_last)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qi = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0) + offs
            ki = jk * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(ki <= qi, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + p.sum(axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(jk == nk - 1)
    def _flush():
        l = l_ref[...]
        # rows that saw nothing (can't happen for causal diag) keep 0
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, ...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B,H,Sq,D), k/v: (B,Hkv,Skv,D); returns (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert H % Hkv == 0
    group = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, block_q, Skv, block_k)
    grid = (B, H, Sq // block_q, Skv // block_k)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_q=Sq, seq_kv=Skv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
