"""Public kernel API: jit'd wrappers that dispatch Pallas vs. reference.

Dispatch policy (``impl=`` argument, default "auto"):

  * "pallas"   — the Pallas kernel, compiled for TPU (or interpret=True when
                 the backend is CPU, so CI on this container still exercises
                 the kernel body);
  * "ref"      — the pure-jnp sequential oracle ("pertoken" for the scans).
                 GSPMD-shardable but per-token state traffic (the dry-run
                 baseline);
  * "chunked"  — the pure-jnp chunked/SSD formulation (scans only):
                 GSPMD-shardable AND block-parallel — the optimized GSPMD
                 path (see EXPERIMENTS.md §Perf);
  * "auto"     — "pallas" on TPU backends, best jnp path elsewhere
                 ("chunked" for the scans, "ref" for attention).

Every wrapper is shape/dtype-polymorphic and jit-compatible.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax

from repro.core import jaxcompat

from repro.kernels import flash_attention as _fa
from repro.kernels import mamba2_scan as _m2
from repro.kernels import paged_attention as _pa
from repro.kernels import ref
from repro.kernels import rwkv6_scan as _rw

Impl = Literal["auto", "pallas", "ref", "pertoken", "chunked"]


def _use_pallas(impl: Impl) -> tuple[bool, bool]:
    """Returns (use_pallas, interpret)."""
    if impl in ("ref", "pertoken", "chunked"):
        return False, False
    on_tpu = jax.default_backend() == "tpu"
    if impl == "pallas":
        return True, not on_tpu
    return (True, False) if on_tpu else (False, False)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    impl: Impl = "auto", block_q: int = _fa.DEFAULT_BLOCK_Q,
                    block_k: int = _fa.DEFAULT_BLOCK_K):
    use, interp = _use_pallas(impl)
    if use:
        return _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interp)
    return ref.mha_attention(q, k, v, causal=causal, scale=scale)


def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *, scale=None,
                    impl: Impl = "auto"):
    use, interp = _use_pallas(impl)
    if use:
        return _pa.paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                                   scale=scale, interpret=interp)
    return ref.paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                               scale=scale)


def mamba2_scan(x, dt, A, Bmat, Cmat, D, *, impl: Impl = "auto",
                chunk: int = _m2.DEFAULT_CHUNK, h0=None,
                return_state: bool = False):
    use, interp = _use_pallas(impl)
    if use and not return_state and h0 is None:
        return _m2.mamba2_scan(x, dt, A, Bmat, Cmat, D, chunk=chunk,
                               interpret=interp)
    if impl in ("ref", "pertoken"):
        return ref.mamba2_scan(x, dt, A, Bmat, Cmat, D, h0=h0,
                               return_state=return_state)
    return ref.mamba2_scan_chunked(x, dt, A, Bmat, Cmat, D, h0=h0,
                                   return_state=return_state)


def rwkv6_scan(r, k, v, w, u, *, impl: Impl = "auto",
               chunk: int = _rw.DEFAULT_CHUNK, s0=None,
               return_state: bool = False):
    use, interp = _use_pallas(impl)
    if use and not return_state and s0 is None:
        return _rw.rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=interp)
    if impl in ("ref", "pertoken"):
        return ref.rwkv6_scan(r, k, v, w, u, s0=s0,
                              return_state=return_state)
    return ref.rwkv6_scan_chunked(r, k, v, w, u, s0=s0,
                                  return_state=return_state)


# ----------------------------------------------------------------------------
# shard_map'd distributed wrappers: batch over 'data', heads over 'model'.
# These are how the Pallas kernels run on a real mesh (each shard executes
# the kernel on its local (B/dp, H/tp) slice; no cross-shard attention state
# is needed because heads are independent).
# ----------------------------------------------------------------------------

def sharded_flash_attention(mesh, *, data_axes=("data",), model_axis="model",
                            **kw):
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(data_axes), model_axis, None, None)

    fn = functools.partial(flash_attention, **kw)
    return jaxcompat.shard_map(lambda q, k, v: fn(q, k, v), mesh=mesh,
                               in_specs=(spec, spec, spec), out_specs=spec)


def sharded_paged_attention(mesh, *, data_axes=("data",), model_axis="model",
                            **kw):
    from jax.sharding import PartitionSpec as P
    qspec = P(tuple(data_axes), model_axis, None)
    kvspec = P(None, None, model_axis, None)   # page pool sharded over heads
    tspec = P(tuple(data_axes), None)
    lspec = P(tuple(data_axes))

    fn = functools.partial(paged_attention, **kw)
    return jaxcompat.shard_map(
        lambda q, kp, vp, pt, sl: fn(q, kp, vp, pt, sl), mesh=mesh,
        in_specs=(qspec, kvspec, kvspec, tspec, lspec), out_specs=qspec)
