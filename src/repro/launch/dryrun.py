import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script builds the real step function (train / prefill /
serve), assigns the production shardings from parallel.sharding, lowers it
against ShapeDtypeStruct inputs (no allocation), compiles it for the
production mesh and extracts:

  * memory_analysis()      -> bytes/device (proves the cell fits HBM)
  * cost_analysis()        -> HLO FLOPs / HLO bytes (roofline compute+memory)
  * the partitioned HLO    -> per-kind collective byte counts (roofline
                              collective term; parsed from as_text())

Results are cached as JSON under benchmarks/out/dryrun/ — one file per
(arch, shape, mesh, variant) — and consumed by benchmarks/roofline.py and
EXPERIMENTS.md. (No ``from __future__`` here: the XLA_FLAGS lines above
must stay the first statements in the file.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
      --shape train_4k --mesh pod --variant baseline
  PYTHONPATH=src python -m repro.launch.dryrun --list
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import hw
from repro.core.apelink import protocol_efficiency
from repro.launch import hlo_analysis
from repro.models import api
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel import sharding

OUT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "out" / "dryrun"

MESHES = {
    "pod": dict(multi_pod=False, chips=256),
    "multipod": dict(multi_pod=True, chips=512),
}

# ----------------------------------------------------------------------------
# variants (perf hillclimbing) — "baseline" is the paper-faithful default
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Variant:
    name: str = "baseline"
    remat: bool = True           # activation checkpointing in train_loss
    donate: bool = True          # donate params/opt buffers (in-place update)
    # pin jit out_shardings to the in_shardings (params/opt state keep
    # their layout through the update — stops the partitioner from
    # round-tripping f32 full-weight copies; §Perf "outsharded")
    out_shardings: bool = False
    # microbatch gradient accumulation (activation memory / overlap knob)
    grad_accum: int = 1
    # ArchCfg field overrides (dataclasses.replace) — the hillclimb knobs
    cfg_overrides: tuple = ()    # (("field", value), ...)
    extra: dict | None = None    # free-form notes, recorded in the JSON


_FAITHFUL = (("scan_impl", "pertoken"), ("moe_impl", "global"),
             ("tp_activations", "free"), ("parallelism", "tp_dp"),
             ("attn_dtype", "f32"))

VARIANTS: dict[str, Variant] = {
    # the paper-faithful baseline pins every §Perf knob to the naive
    # setting (sequential scans, global MoE dispatch, free activation
    # sharding, TPxDP for all archs, f32 attention) — matches the
    # recorded baseline sweep regardless of the per-arch config defaults
    "baseline": Variant(cfg_overrides=_FAITHFUL),
    # per-arch production defaults (the optimized configuration each
    # config file ships with; see EXPERIMENTS.md §Perf)
    "production": Variant(name="production", out_shardings=True),
    "noremat": Variant(name="noremat", remat=False,
                       cfg_overrides=_FAITHFUL),
    "nodonate": Variant(name="nodonate", donate=False,
                        cfg_overrides=_FAITHFUL),
    # §Perf hillclimb variants
    "chunked_ssm": Variant(name="chunked_ssm",
                           cfg_overrides=(("scan_impl", "chunked"),)),
    "ep_a2a": Variant(name="ep_a2a",
                      cfg_overrides=(("moe_impl", "ep_a2a"),)),
    "tp_megatron": Variant(name="tp_megatron",
                           cfg_overrides=(("tp_activations", "megatron"),)),
    "tp_sp": Variant(name="tp_sp",
                     cfg_overrides=(("tp_activations", "sp"),)),
    "ep_a2a_megatron": Variant(
        name="ep_a2a_megatron",
        cfg_overrides=(("moe_impl", "ep_a2a"),
                       ("tp_activations", "megatron"))),
    "dp_only": Variant(name="dp_only",
                       cfg_overrides=(("parallelism", "dp_only"),)),
    # attribution singles
    "attn_bf16": Variant(name="attn_bf16",
                         cfg_overrides=(("attn_dtype", "bf16"),)),
    "outsharded": Variant(name="outsharded", out_shardings=True),
    # combined per-cell winners (§Perf)
    "sp_fast": Variant(name="sp_fast", out_shardings=True,
                       cfg_overrides=(("tp_activations", "sp"),
                                      ("attn_dtype", "bf16"))),
    "ep_fast": Variant(name="ep_fast", out_shardings=True,
                       cfg_overrides=(("moe_impl", "ep_a2a"),
                                      ("attn_dtype", "bf16"))),
    "ssm_fast": Variant(name="ssm_fast", out_shardings=True,
                        cfg_overrides=(("scan_impl", "chunked"),
                                       ("attn_dtype", "bf16"))),
    "dp_fast": Variant(name="dp_fast", out_shardings=True,
                       cfg_overrides=(("parallelism", "dp_only"),
                                      ("attn_dtype", "bf16"))),
    # microbatch gradient accumulation (activation memory knob)
    "accum4": Variant(name="accum4", grad_accum=4),
    "accum8": Variant(name="accum8", grad_accum=8),
    # hand-SPMD Megatron-SP dense layer (explicit bf16 AG/RS in shard_map)
    "manual_sp": Variant(name="manual_sp",
                         cfg_overrides=(("tp_activations", "manual_sp"),)),
    "manual_sp_bf16": Variant(
        name="manual_sp_bf16",
        cfg_overrides=(("tp_activations", "manual_sp"),
                       ("attn_dtype", "bf16"))),
}


def get_variant(name: str) -> Variant:
    return VARIANTS[name]


def apply_variant(cfg, variant: Variant):
    if not variant.cfg_overrides:
        return cfg
    return dataclasses.replace(cfg, **dict(variant.cfg_overrides))


# ----------------------------------------------------------------------------
# useful attention flops (causal-masked QK^T + AV, one forward pass)
# ----------------------------------------------------------------------------


def model_attn_flops(cfg, shape, *, decode: bool = False) -> float:
    """Useful attention-matmul FLOPs for one forward pass (global).

    Causal attention does 2*0.5*S^2*H*hd flops for each of QK^T and AV per
    sequence; a decode step attends one query against a seq_len cache.
    Recurrent families (rwkv6, mamba2) have no S^2 term; zamba2 has one
    shared attention block applied every ``attn_every`` mamba layers;
    whisper adds the non-causal encoder and cross-attention.
    """
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    H = max(cfg.n_heads, 1)

    def causal(n_layers, s):
        per_seq = 2 * 0.5 * s * s * H * hd * 2  # QK + AV, causal half
        return n_layers * B * per_seq

    def one_step(n_layers, cache):
        return n_layers * B * (2 * cache * H * hd * 2)

    fam = cfg.family
    if fam in ("rwkv6", "mamba2"):
        return 0.0
    if fam == "zamba2":
        n_attn = max(cfg.n_layers // max(cfg.attn_every, 1), 1)
        return one_step(n_attn, S) if decode else causal(n_attn, S)
    if fam == "encdec":
        enc = cfg.n_enc_layers * B * (2 * cfg.n_frames ** 2 * H * hd * 2)
        if decode:
            dec = one_step(cfg.n_layers, S)
            cross = cfg.n_layers * B * (2 * cfg.n_frames * H * hd * 2)
            return dec + cross  # encoder ran at prefill
        dec = causal(cfg.n_layers, S)
        cross = cfg.n_layers * B * (2 * S * cfg.n_frames * H * hd * 2)
        return enc + dec + cross
    # dense / moe / vlm decoder stacks
    s_eff = S + (cfg.n_patches if fam == "vlm" else 0)
    if decode:
        return one_step(cfg.n_layers, s_eff)
    return causal(cfg.n_layers, s_eff)


# ----------------------------------------------------------------------------
# step builders: (jitted_fn, arg_specs_with_shardings)
# ----------------------------------------------------------------------------


def build_train(cfg, mesh, variant: Variant):
    model = api.get_model(cfg)
    shapes = api.param_shapes(cfg)
    psh = sharding.named(mesh, sharding.param_specs(cfg, shapes, mesh))
    ost_shapes = jax.eval_shape(adamw_init, shapes)
    osp = {"m": sharding.zero1_specs(cfg, shapes, mesh),
           "v": sharding.zero1_specs(cfg, shapes, mesh), "step": P()}
    osh = sharding.named(mesh, osp)
    opt = AdamWConfig()
    remat = variant.remat
    accum = variant.grad_accum

    def specs(shape_name):
        shape, batch = api.input_specs(cfg, shape_name)
        bspecs = sharding.batch_specs(cfg, batch, mesh)
        bsh = sharding.named(mesh, bspecs)
        # the (accum, B/accum, ...) reshape must keep the DP sharding on
        # the per-microbatch dim (dim 1) — left free, GSPMD replicates
        micro_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, P(None, *s)), bspecs)

        def single(p, b):
            return jax.value_and_grad(
                lambda q: model.train_loss(q, b, remat=remat))(p)

        def loss_and_grads(params, batch):
            if accum <= 1:
                return single(params, batch)
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            micro = jax.lax.with_sharding_constraint(micro, micro_sh)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)

            def body(carry, mb):
                la, ga = carry
                loss, g = single(params, mb)
                return (la + loss, jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), ga, g)), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            inv = 1.0 / accum
            return loss * inv, jax.tree.map(lambda g: g * inv, grads)

        def train_step(params, opt_state, batch):
            loss, grads = loss_and_grads(params, batch)
            params, opt_state, metrics = adamw_update(opt, grads, opt_state,
                                                      params)
            return params, opt_state, {"loss": loss, **metrics}

        in_sh = (psh, osh, bsh)
        donate = (0, 1) if variant.donate else ()
        kw = {}
        if variant.out_shardings:
            kw["out_shardings"] = (psh, osh, None)
        fn = jax.jit(train_step, in_shardings=in_sh,
                     donate_argnums=donate, **kw)
        args = (shapes, ost_shapes, batch)
        return fn, args

    return specs


def build_prefill(cfg, mesh, variant: Variant):
    model = api.get_model(cfg)
    shapes = api.param_shapes(cfg)
    psh = sharding.named(mesh, sharding.param_specs(cfg, shapes, mesh))

    def specs(shape_name):
        shape, batch = api.input_specs(cfg, shape_name)
        bsh = sharding.named(mesh, sharding.batch_specs(cfg, batch, mesh))

        def prefill_step(params, batch):
            return model.prefill(params, batch, remat=False) \
                if cfg.family in ("rwkv6", "mamba2") else \
                model.prefill(params, batch, max_len=shape.seq_len,
                              remat=False)

        fn = jax.jit(prefill_step, in_shardings=(psh, bsh))
        return fn, (shapes, batch)

    return specs


def build_decode(cfg, mesh, variant: Variant):
    # decode is weight-read-bound: replicating params (dp_only) doubles the
    # per-step HBM traffic (measured on starcoder2 decode_32k), so serving
    # always uses TP-sharded params even for dp_only-trained archs
    if cfg.parallelism == "dp_only":
        cfg = dataclasses.replace(cfg, parallelism="tp_dp")
    model = api.get_model(cfg)
    shapes = api.param_shapes(cfg)
    psh = sharding.named(mesh, sharding.param_specs(cfg, shapes, mesh))

    def specs(shape_name):
        shape, spec = api.input_specs(cfg, shape_name)
        tok_sh = sharding.named(
            mesh, sharding.batch_specs(cfg, {"t": spec["token"]}, mesh))["t"]
        st_sh = sharding.named(mesh, sharding.decode_state_specs(
            cfg, spec["state"], mesh, shape.global_batch))
        pos_sh = NamedSharding(mesh, P())

        def serve_step(params, token, state, pos):
            return model.decode_step(params, token, state, pos)

        fn = jax.jit(serve_step, in_shardings=(psh, tok_sh, st_sh, pos_sh),
                     donate_argnums=(2,))
        return fn, (shapes, spec["token"], spec["state"], spec["pos"])

    return specs


def build_cell(cfg, mesh, shape_name: str, variant: Variant):
    kind = api.SHAPES[shape_name].kind
    builder = {"train": build_train, "prefill": build_prefill,
               "decode": build_decode}[kind]
    return builder(cfg, mesh, variant)(shape_name)


# ----------------------------------------------------------------------------
# per-cell dry run
# ----------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_name: str,
             variant: Variant) -> dict:
    from repro.launch.mesh import make_production_mesh
    cfg = apply_variant(configs.get_config(arch), variant)
    chips = MESHES[mesh_name]["chips"]
    mesh = make_production_mesh(multi_pod=MESHES[mesh_name]["multi_pod"])
    t0 = time.time()
    try:
        sharding.set_runtime_mesh(mesh)
        with mesh:
            fn, args = build_cell(cfg, mesh, shape_name, variant)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
    finally:
        sharding.set_runtime_mesh(None)

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older JAX returns [dict]
        cost = cost[0] if cost else {}
    cost = {k: v for k, v in cost.items()
            if k in ("flops", "bytes accessed", "transcendentals",
                     "optimal_seconds")}
    try:
        mem = compiled.memory_analysis()
        mem_d = {k: int(getattr(mem, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes") if hasattr(mem, k)}
        # live bytes/device ~ args + outputs + temps - aliased(donated)
        live = (mem_d.get("argument_size_in_bytes", 0)
                + mem_d.get("output_size_in_bytes", 0)
                + mem_d.get("temp_size_in_bytes", 0)
                - mem_d.get("alias_size_in_bytes", 0))
        mem_d["live_bytes_per_device"] = live
        mem_d["fits_hbm"] = bool(live <= hw.TPU_V5E.hbm_bytes)
    except Exception as e:  # CPU backend may not implement it
        mem_d = {"error": str(e)}
    hlo = compiled.as_text()
    # trip-count-aware analysis (XLA's cost_analysis counts scan bodies once)
    ana = hlo_analysis.analyze(hlo)
    colls = ana.collectives
    link_bytes = ana.link_bytes

    chip = hw.TPU_V5E
    flops_dev = float(ana.flops)
    bytes_dev = float(ana.bytes)
    eta = protocol_efficiency()  # APElink-style link derate (paper §2.3)
    terms = {
        "compute_s": flops_dev / chip.peak_flops_bf16,
        "memory_s": bytes_dev / chip.hbm_bandwidth,
        "collective_s": link_bytes / chip.ici_link_bandwidth,
        "collective_derated_s":
            link_bytes / (chip.ici_link_bandwidth * eta),
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])

    # model FLOPs: 6*N_active*D for train (fwd+bwd), 2*N_active*D for
    # inference, per chip; the _attn variant adds the useful causal
    # attention-matmul flops (QK^T + AV), which dominate small-d_model
    # archs at seq 4096+ and are invisible to the parameter-count formula
    n_active = api.active_param_count(cfg)
    shape = api.SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
        attn_flops = 3.0 * model_attn_flops(cfg, shape)
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
        attn_flops = model_attn_flops(cfg, shape)
    else:  # decode: one token per sequence against a seq_len cache
        model_flops = 2.0 * n_active * shape.global_batch
        attn_flops = model_attn_flops(cfg, shape, decode=True)
    model_flops_dev = model_flops / chips
    attn_flops_dev = attn_flops / chips

    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant.name, "chips": chips,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_d,
        "collectives": colls,
        "top_collective_buffers": ana.top_buffers(12),
        "link_bytes_per_device": link_bytes,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "n_while": ana.n_while,
        "max_trip": ana.max_trip,
        "model_flops_per_device": model_flops_dev,
        "attn_model_flops_per_device": attn_flops_dev,
        "useful_flop_ratio":
            model_flops_dev / flops_dev if flops_dev else None,
        "useful_flop_ratio_attn":
            (model_flops_dev + attn_flops_dev) / flops_dev
            if flops_dev else None,
        "roofline": terms,
        "n_params": api.param_count(cfg),
        "n_active_params": n_active,
        "hlo_bytes": len(hlo),
    }
    return out


def cell_path(arch, shape, mesh_name, variant, out_dir=None) -> Path:
    v = "" if variant == "baseline" else f"_{variant}"
    return (out_dir or OUT_DIR) / f"{arch}_{shape}_{mesh_name}{v}.json"


def all_cells(archs, shapes_filter, mesh_names):
    for arch in archs:
        cfg = configs.get_config(arch)
        for shape in api.applicable_shapes(cfg):
            if shapes_filter and shape not in shapes_filter:
                continue
            for mesh_name in mesh_names:
                yield arch, shape, mesh_name


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=None, help="output dir override")
    args = ap.parse_args(argv)
    out_dir = Path(args.out) if args.out else OUT_DIR

    archs = [configs.canonical(a) for a in (args.arch or configs.ALL_ARCHS)]
    mesh_names = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    variant = get_variant(args.variant)
    cells = list(all_cells(archs, args.shape, mesh_names))
    if args.list:
        for c in cells:
            print(*c)
        print(f"{len(cells)} cells")
        return 0

    out_dir.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch, shape, mesh_name in cells:
        path = cell_path(arch, shape, mesh_name, variant.name, out_dir)
        if path.exists() and not args.force:
            print(f"[skip] {path.name}")
            continue
        print(f"[cell] {arch} x {shape} x {mesh_name} ({variant.name}) ...",
              flush=True)
        try:
            out = run_cell(arch, shape, mesh_name, variant)
        except Exception:
            traceback.print_exc()
            failures.append((arch, shape, mesh_name))
            continue
        path.write_text(json.dumps(out, indent=1))
        r = out["roofline"]
        print(f"   ok: compile {out['t_compile_s']}s  "
              f"flops/dev {out['flops_per_device']:.3e}  "
              f"bytes/dev {out['bytes_per_device']:.3e}  "
              f"link/dev {out['link_bytes_per_device']:.3e}  "
              f"bottleneck {r['bottleneck']}", flush=True)
    if failures:
        print("FAILED CELLS:", failures)
        return 1
    print("all requested cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
