"""Trip-count-aware roofline analysis of compiled (partitioned) HLO text.

``compiled.cost_analysis()`` on the CPU backend counts each ``while`` body
ONCE — but our models walk layers with ``lax.scan``, so flops/bytes/
collective traffic inside the loop must be multiplied by the trip count
(x30..x80 for the assigned archs).  This module re-derives the three
roofline quantities directly from ``compiled.as_text()``:

  * flops       — 2 x |result| x K for every ``dot`` (K = product of the
                  lhs contracting dims), recursing into fusions/calls and
                  multiplying while bodies by their trip counts;
  * bytes       — HBM-traffic estimate at fusion boundaries: every
                  non-bookkeeping op contributes operand+result bytes, a
                  fusion counts only at its boundary (its internals are
                  register/VMEM-resident on a TPU-like target), while
                  bodies multiplied by trips;
  * collectives — per-kind counts and link-traffic bytes (ring-schedule
                  multipliers), while bodies multiplied by trips.

Trip counts: jax scans lower to ``while`` whose condition compares the
induction variable against a literal ``constant(N)`` placed in the
condition computation — we take the max integer constant found there
(recursing through called computations), falling back to 1.

The parser is deliberately tolerant: unknown ops cost 0 flops and
operand+result bytes, tuple-shuffling ops cost nothing.
"""
from __future__ import annotations

import dataclasses
import re

# ---------------------------------------------------------------------------
# array-literal parsing
# ---------------------------------------------------------------------------

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4,
    "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
ARRAY_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s4|s8|s16|s32|s64"
    r"|u4|u8|u16|u32|u64|c64|c128)\[([0-9,]*)\]")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")
_REF_RE = re.compile(r"%([\w\.\-]+)")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\{\s*$")
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|condition=|body=)%([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TFCOMP_RE = re.compile(
    r"(?:true_computation|false_computation)=%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT_RE = re.compile(r"\bconstant\((\d+)\)")

# ops that move no HBM bytes of their own
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-get-and-update-state",
}

# link-traffic multiplier per collective kind (ring schedule, large groups):
#   all-reduce      ~ 2x buffer (reduce-scatter + all-gather phases)
#   all-gather      ~ 1x full result
#   reduce-scatter  ~ 1x full operand
#   all-to-all      ~ 1x buffer
#   collective-permute ~ 1x buffer (one hop)
COLLECTIVE_TRAFFIC = {
    "all-reduce": ("res", 2.0),
    "all-gather": ("res", 1.0),
    "reduce-scatter": ("arg", 1.0),
    "all-to-all": ("res", 1.0),
    "collective-permute": ("res", 1.0),
}
_COLL_BASE = {k.rstrip("-start"): k for k in COLLECTIVE_TRAFFIC}


def array_bytes(text: str) -> int:
    total = 0
    for m in ARRAY_RE.finditer(text):
        n = 1
        dims = m.group(2)
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[m.group(1)]
    return total


def _first_array_dims(text: str) -> list[int] | None:
    m = ARRAY_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def _array_elems(text: str) -> int:
    total = 0
    for m in ARRAY_RE.finditer(text):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n
    return total


# ---------------------------------------------------------------------------
# module parsing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Op:
    rname: str           # result value name (without the %)
    name: str            # op kind, e.g. "dot", "while", "fusion"
    result: str          # result type text
    operands: str        # text inside the top-level parens (name refs)
    attrs: str           # text after the closing paren


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    types: dict[str, str]  # value name -> result type text

    def operand_types(self, op: Op) -> str:
        """Resolve %refs in an op's operand list to their result types."""
        return " ".join(self.types.get(r, "")
                        for r in _REF_RE.findall(op.operands))


def _split_op_line(line: str) -> Op | None:
    m = _OP_RE.match(line)
    if not m:
        return None
    rname, result, opname, rest = m.groups()
    # find the matching close paren for the operand list
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return Op(rname, opname, result, rest[:i], rest[i + 1:])
    return Op(rname, opname, result, rest, "")


def parse_module(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry: str | None = None
    cur: Computation | None = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr is not None:
            cur = Computation(hdr.group(2), [], {})
            comps[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        op = _split_op_line(line)
        if op is not None:
            cur.ops.append(op)
            cur.types[op.rname] = op.result
    return comps, entry


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------


def _trip_count(op: Op, comps: dict[str, Computation]) -> int:
    """Max integer literal in the while condition (recursively)."""
    m = re.search(r"condition=%([\w\.\-]+)", op.attrs)
    if not m:
        return 1
    best = 0
    stack = [m.group(1)]
    seen = set()
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in comps:
            continue
        seen.add(cname)
        for o in comps[cname].ops:
            if o.name == "constant":
                c = _CONST_INT_RE.search("constant(" + o.operands + ")")
                if c:
                    best = max(best, int(c.group(1)))
            stack.extend(_CALLED_RE.findall(o.attrs))
    return best or 1


def _called(op: Op) -> list[str]:
    names = []
    if op.name in ("fusion", "call", "map", "reduce", "reduce-window",
                   "sort", "scatter", "select-and-scatter"):
        names += _CALLED_RE.findall(op.attrs)
    if op.name == "conditional":
        b = _BRANCHES_RE.search(op.attrs)
        if b:
            names += [x.strip().lstrip("%") for x in b.group(1).split(",")]
        names += _TFCOMP_RE.findall(op.attrs)
    return names


def _dot_flops(op: Op, operand_types: str) -> float:
    lhs = _first_array_dims(operand_types)
    res_elems = _array_elems(op.result)
    if lhs is None:
        return 0.0
    k = 1
    m = _CONTRACT_RE.search(op.attrs)
    if m and m.group(1):
        for d in m.group(1).split(","):
            k *= lhs[int(d)]
    return 2.0 * res_elems * k


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    by_buffer: dict = dataclasses.field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1

    @property
    def link_bytes(self) -> float:
        return sum(d["link_bytes"] for d in self.collectives.values())

    def top_buffers(self, n: int = 10) -> list[tuple[str, float, int]]:
        """Largest collective contributors: (kind+type, link_bytes, count)."""
        rows = [(k, v["link_bytes"], v["count"])
                for k, v in self.by_buffer.items()]
        return sorted(rows, key=lambda r: -r[1])[:n]

    def merge_scaled(self, other: "Analysis", scale: float) -> None:
        self.flops += scale * other.flops
        self.bytes += scale * other.bytes
        self.n_while += other.n_while
        self.max_trip = max(self.max_trip, other.max_trip)
        for k, d in other.collectives.items():
            acc = self.collectives.setdefault(
                k, {"count": 0, "result_bytes": 0, "operand_bytes": 0,
                    "link_bytes": 0.0})
            acc["count"] += int(scale * d["count"])
            acc["result_bytes"] += int(scale * d["result_bytes"])
            acc["operand_bytes"] += int(scale * d["operand_bytes"])
            acc["link_bytes"] += scale * d["link_bytes"]
        for k, d in other.by_buffer.items():
            acc = self.by_buffer.setdefault(k, {"count": 0, "link_bytes": 0.0})
            acc["count"] += int(scale * d["count"])
            acc["link_bytes"] += scale * d["link_bytes"]


def _collective_kind(opname: str) -> str | None:
    base = opname[:-6] if opname.endswith("-start") else opname
    return base if base in COLLECTIVE_TRAFFIC else None


def _analyze_comp(cname: str, comps: dict[str, Computation],
                  cache: dict[str, Analysis], flops_stack: tuple = ()) \
        -> Analysis:
    if cname in cache:
        return cache[cname]
    comp = comps.get(cname)
    out = Analysis()
    if comp is None:
        cache[cname] = out
        return out
    for op in comp.ops:
        arg_types = comp.operand_types(op)
        kind = _collective_kind(op.name)
        if op.name.endswith("-done"):
            continue  # paired with a -start that carried the buffers
        if kind is not None:
            res_b = array_bytes(op.result)
            arg_b = array_bytes(arg_types)
            if op.name.endswith("-start"):
                # result tuple of a -start includes the operand buffers
                res_b = max(res_b - arg_b, 0)
            d = out.collectives.setdefault(
                kind, {"count": 0, "result_bytes": 0, "operand_bytes": 0,
                       "link_bytes": 0.0})
            d["count"] += 1
            d["result_bytes"] += res_b
            d["operand_bytes"] += arg_b
            which, mult = COLLECTIVE_TRAFFIC[kind]
            link = mult * (res_b if which == "res" else arg_b)
            d["link_bytes"] += link
            key = f"{kind} {ARRAY_RE.search(op.result).group(0) if ARRAY_RE.search(op.result) else '?'}"
            bb = out.by_buffer.setdefault(key, {"count": 0,
                                                "link_bytes": 0.0})
            bb["count"] += 1
            bb["link_bytes"] += link
            out.bytes += res_b + arg_b
            continue
        if op.name == "while":
            trips = _trip_count(op, comps)
            out.n_while += 1
            out.max_trip = max(out.max_trip, trips)
            body = re.search(r"body=%([\w\.\-]+)", op.attrs)
            cond = re.search(r"condition=%([\w\.\-]+)", op.attrs)
            for sub in (body, cond):
                if sub:
                    a = _analyze_comp(sub.group(1), comps, cache)
                    out.merge_scaled(a, trips)
            continue
        if op.name == "dot":
            out.flops += _dot_flops(op, arg_types)
            out.bytes += array_bytes(op.result) + array_bytes(arg_types)
            continue
        if op.name == "fusion":
            # flops: recurse (a dot may be fused); bytes: boundary only
            for sub in _called(op):
                a = _analyze_comp(sub, comps, cache)
                out.flops += a.flops
                out.merge_scaled(
                    Analysis(collectives=a.collectives), 1.0)
            out.bytes += array_bytes(op.result) + array_bytes(arg_types)
            continue
        if op.name in ("call", "conditional", "custom-call", "reduce",
                       "scatter", "map", "sort", "reduce-window",
                       "select-and-scatter"):
            for sub in _called(op):
                a = _analyze_comp(sub, comps, cache)
                out.merge_scaled(a, 1.0)
            out.bytes += array_bytes(op.result) + array_bytes(arg_types)
            continue
        if op.name in _FREE_OPS:
            continue
        # default: an unfused elementwise/data-movement op
        out.bytes += array_bytes(op.result) + array_bytes(arg_types)
    cache[cname] = out
    return out


def analyze(hlo_text: str) -> Analysis:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        return Analysis()
    # cache shared across the module: computations reached multiple times
    # are (correctly) charged at each reaching site via merge_scaled
    return _analyze_comp(entry, comps, {})


def analysis_dict(a: Analysis) -> dict:
    return {"flops": a.flops, "bytes": a.bytes, "link_bytes": a.link_bytes,
            "collectives": a.collectives, "n_while": a.n_while,
            "max_trip": a.max_trip}
