"""Training launcher.

On a real TPU pod this is the per-host entrypoint (jax.distributed
initialises from the TPU runtime; the GSPMD step then spans the full mesh).
On CPU it runs the same code path over forced host devices, which is how
the examples and integration tests exercise it end-to-end.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 20 --batch 8 --seq 128 --devices 8

``--comm apex`` selects the paper-faithful explicit torus-collective data
parallelism (shard_map + bidirectional ring reduce-scatter/all-gather);
``--comm gspmd`` (default) lets XLA place the collectives from the
parallel.sharding specs.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = real devices)")
    ap.add_argument("--mesh", default="",
                    help="mesh as 'dp,tp' (e.g. '4,2'); default: all-DP")
    ap.add_argument("--comm", choices=["gspmd", "apex", "single"],
                    default="gspmd")
    ap.add_argument("--ckpt-dir", default="/tmp/apex_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="microbatches per optimizer step")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax  # noqa: E402  (after XLA_FLAGS)
    import numpy as np  # noqa: E402

    from repro import configs  # noqa: E402
    from repro.launch.mesh import make_mesh  # noqa: E402
    from repro.optim import AdamWConfig  # noqa: E402
    from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: E402

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    n = len(jax.devices())
    if args.comm == "single" or n == 1:
        mesh = None
        args.comm = "single"
    elif args.mesh:
        dp, tp = (int(x) for x in args.mesh.split(","))
        mesh = make_mesh((dp, tp), ("data", "model"))
    elif args.comm == "apex":
        mesh = make_mesh((n,), ("data",))
    else:
        mesh = make_mesh((n, 1), ("data", "model"))

    opt = AdamWConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10),
                      total_steps=max(args.steps, 1))
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         opt=opt, batch=args.batch, seq_len=args.seq,
                         comm=args.comm, dp_axis="data", seed=args.seed,
                         grad_accum=args.grad_accum)
    tr = Trainer(cfg, tcfg, mesh=mesh)
    if args.resume:
        try:
            tr.resume()
        except FileNotFoundError:
            print("[train] no checkpoint found; starting fresh")
    print(f"[train] arch={cfg.name} params={tr.n_params:,} "
          f"devices={n} comm={args.comm}")
    for m in tr.train(args.steps):
        print(f"  step {m['step']:>5d}  loss {m['loss']:.4f}  "
              f"{m['step_time_s']*1e3:7.1f} ms")
    if tr.events:
        print("[events]")
        for e in tr.events:
            print("  ", e)
    losses = [m["loss"] for m in tr.metrics_log]
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
