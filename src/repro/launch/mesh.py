"""Production mesh definitions.

The production target is a TPU v5e pod: 256 chips in a 16x16 ICI torus, and
two such pods linked over the "pod" axis for the multi-pod configuration —
the same 3D-torus shape APEnet+ builds out of 6-link FPGA NICs (Z = pod,
Y = data, X = model).

Everything here is a FUNCTION (never module-level device state) so importing
this module does not initialise the JAX backend — critical because the
dry-run must set XLA_FLAGS before first jax use, while smoke tests must see
the real single-CPU device.
"""
from __future__ import annotations

import jax

from repro.core import jaxcompat
from repro.core.topology import Torus

POD_AXES = ("data", "model")
MULTIPOD_AXES = ("pod", "data", "model")


def make_mesh(shape, axes, *, devices=None) -> jax.sharding.Mesh:
    """jax.make_mesh with explicit Auto axis types (GSPMD sharding).

    Uses the first prod(shape) devices when more are available (the dry-run
    forces 512 host devices but the single-pod mesh needs only 256)."""
    import numpy as np
    need = int(np.prod(tuple(shape)))
    if devices is None and len(jax.devices()) > need:
        devices = jax.devices()[:need]
    return jaxcompat.make_mesh(tuple(shape), tuple(axes), devices=devices)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The graded production mesh: 16x16 single pod / 2x16x16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = MULTIPOD_AXES if multi_pod else POD_AXES
    return make_mesh(shape, axes)


def production_torus(*, multi_pod: bool = False) -> Torus:
    """Topology-model twin of the production mesh (LO|FA|MO, routing math).

    Rank i of the torus is device i of the mesh (both row-major)."""
    return Torus((2, 16, 16) if multi_pod else (16, 16))


def host_test_mesh(shape=(8,), axes=("x",)) -> jax.sharding.Mesh:
    """Small mesh over forced host devices (tests / demos only)."""
    return make_mesh(shape, axes)
