"""Serving launcher: continuous-batching decode over the paged-KV engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 12 --max-new 24

The engine exercises the paper's §2.2 path end-to-end: page allocation goes
through RDMA buffer registration, virtual->physical page translation hits
the (software) TLB, and decode attention dispatches through the paged-
attention kernel whose in-kernel page-table lookup is the hardware-TLB
analogue.  Engine stats report the TLB hit rate and translation cost next
to throughput.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import numpy as np  # noqa: E402
    import jax  # noqa: E402

    from repro import configs  # noqa: E402
    from repro.models import api  # noqa: E402
    from repro.serving.engine import Engine, PagedLM, Request  # noqa: E402

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family not in ("dense", "moe", "vlm"):
        print(f"[serve] family {cfg.family} has no paged-KV decode "
              "(O(1) recurrent state) — engine targets transformer archs")
        return 2

    model = api.get_model(cfg)
    params = model.init(jax.random.key(args.seed))
    max_seq = args.prompt_len + args.max_new + args.page_tokens
    lm = PagedLM(cfg, params, max_batch=args.max_batch, max_seq=max_seq,
                 page_tokens=args.page_tokens)
    eng = Engine(lm)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        prompt = rng.integers(0, cfg.vocab, size=(plen,)).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    stats = eng.stats()
    toks = sum(len(r.out_tokens) for r in eng.finished)
    print(f"[serve] arch={cfg.name} requests={len(eng.finished)} "
          f"tokens={toks} wall={dt:.2f}s ({toks/dt:.1f} tok/s)")
    print(f"[serve] decode_steps={stats['decode_steps']} "
          f"tlb_hit_rate={stats['tlb_hit_rate']:.3f} "
          f"translation_cost={stats['translation_cost_s']*1e6:.1f} us")
    assert len(eng.finished) == args.requests
    return 0


if __name__ == "__main__":
    sys.exit(main())
