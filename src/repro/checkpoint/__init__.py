from repro.checkpoint.store import (CheckpointStore, latest_step,  # noqa: F401
                                    load_checkpoint, save_checkpoint)
