"""Checkpoint/restart substrate for the fault-tolerant trainer.

Design points that matter at cluster scale (and are all exercised here):

  * **integrity** — every tensor is CRC32-checksummed into a manifest; a
    corrupted/truncated file is *detected* at restore, never silently
    loaded (LO|FA|MO flags the node, the trainer restores the previous
    step);
  * **atomicity** — writes go to a temp dir + os.rename, so a node dying
    mid-save (the §4 scenario) can never leave a half-written checkpoint
    that masquerades as valid;
  * **async** — saving runs on a background thread off the training path
    (double-buffered, like the DMA queue in §2.1); ``wait()`` joins before
    the next save or exit;
  * **resharding restore** — tensors are loaded to host then device_put
    against the *target* NamedShardings, so a restart may use a different
    mesh (elastic re-mesh after a fault kills a pod slice).

Storage is .npz per checkpoint (this container is single-host; at real
scale each host writes its address-range slice — the format keeps a
per-tensor manifest precisely so that extension is mechanical).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(directory: str, step: int, tree, *, extra: dict | None
                    = None) -> str:
    """Atomic synchronous save.  Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    manifest = {"step": int(step), "extra": extra or {}, "tensors": {}}
    for k, a in arrays.items():
        manifest["tensors"][k] = {
            "shape": list(a.shape), "dtype": str(a.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(a).tobytes()),
        }
    np.savez(os.path.join(tmp, "tensors.npz"),
             **{k.replace("/", "__"): a for k, a in arrays.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int | None = None, *,
                    template=None, shardings=None):
    """Verified restore.  Returns (tree_or_flatdict, extra).

    With ``template`` (a pytree of like-structured leaves) the result is a
    pytree; otherwise a flat {path: array} dict.  ``shardings`` (matching
    pytree of NamedShardings) re-lays tensors onto the current mesh.
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "tensors.npz")) as z:
        arrays = {k.replace("__", "/"): z[k] for k in z.files}
    for k, meta in manifest["tensors"].items():
        if k not in arrays:
            raise ValueError(f"checkpoint missing tensor {k}")
        a = arrays[k]
        if list(a.shape) != meta["shape"] or str(a.dtype) != meta["dtype"]:
            raise ValueError(f"checkpoint tensor {k} shape/dtype mismatch")
        if zlib.crc32(np.ascontiguousarray(a).tobytes()) != meta["crc32"]:
            raise ValueError(f"checkpoint tensor {k} failed CRC check")
    if template is None:
        return arrays, manifest["extra"]
    flat_t, _ = _flatten(template)
    missing = set(flat_t) - set(arrays)
    if missing:
        raise ValueError(f"checkpoint missing tensors: {sorted(missing)[:5]}")
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in leaves:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        a = arrays[key]
        if flat_s:
            out.append(jax.device_put(a, flat_s[key]))
        else:
            out.append(jax.numpy.asarray(a, getattr(leaf, "dtype", None)))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


class CheckpointStore:
    """Async, GC'd checkpoint manager for the trainer."""

    def __init__(self, directory: str, *, keep_last: int = 3) -> None:
        self.directory = directory
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save_async(self, step: int, tree, *, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device

        def run():
            try:
                save_checkpoint(self.directory, step, host_tree, extra=extra)
                self._gc()
            except Exception as e:  # pragma: no cover - surfaced via wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, template, shardings=None):
        return load_checkpoint(self.directory, template=template,
                               shardings=shardings)
