"""Batched serving engine with a paged KV cache — the §2.2 TLB in action.

The engine owns a physical page pool per layer; each request's logical
(virtual) cache pages are mapped to physical pages through a page table.
Page allocation goes through buffer *registration* on an RdmaEndpoint
(core/rdma): the first touch of a page walks the "Nios II" path, later
accesses hit the hardware TLB — the engine reports the measured hit rate
and the modelled Fig 2 bandwidth gain alongside throughput.

Decode attention dispatches through kernels/ops.paged_attention: on TPU
the Pallas kernel translates pages inside its BlockSpec index_map (the
hardware TLB); under GSPMD/CPU the XLA gather path runs (the software
walk).  Continuous batching: finished requests free their pages; admitted
requests prefill into freshly mapped ones.

Engine scope: decoder-only transformer families (dense/moe/vlm).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import fabric
from repro.core.apelink import NetModel
from repro.core.rdma import RdmaEndpoint
from repro.core.tlb import PAGE_BYTES
from repro.core.topology import Torus
from repro.kernels import ops
from repro.models import attention as attn_mod
from repro.models import common
from repro.models import moe as moe_mod
from repro.models import transformer
from repro.models.common import ArchCfg


class TruncatedRunError(RuntimeError):
    """``run_to_completion`` exhausted ``max_steps`` with requests still
    in flight.  Returning silently here would quietly truncate exactly
    the tail of a long replay — the p99 requests are the ones still in
    flight — so the driver raises and carries the evidence."""

    def __init__(self, steps: int, in_flight: int) -> None:
        super().__init__(
            f"run_to_completion truncated after {steps} steps with "
            f"{in_flight} request(s) still in flight (raise max_steps, "
            "or drain the admission queue)")
        self.steps = steps
        self.in_flight = in_flight


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    slot: int | None = None
    pos: int = 0                 # current context length
    # -- trace-replay / SLO surface (all optional; the engine never
    #    requires them).  Times are on the cluster's shared fabric
    #    timeline (seconds); ``warm_tokens`` is the prefix the node's
    #    modelled prefix cache already holds (a session follow-up routed
    #    to its home node skips that much prefill compute — modelled
    #    accounting only, the real prefill path ignores it).
    arrival_s: float | None = None
    admit_s: float | None = None       # left the admission queue
    first_token_s: float | None = None  # end of the window that produced
    #                                     the first output token (TTFT)
    finish_s: float | None = None
    shed_s: float | None = None        # admission gave up (SLO shed)
    warm_tokens: int = 0
    session: int = -1                  # trace session id (-1: none)

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class PageAllocator:
    """Free-list page allocator whose pages are TLB-registered buffers."""

    def __init__(self, n_pages: int, page_tokens: int, bytes_per_token: int,
                 endpoint: RdmaEndpoint) -> None:
        self.free = list(range(n_pages - 1, -1, -1))
        self.page_tokens = page_tokens
        self.endpoint = endpoint
        self.region = endpoint.register(
            max(n_pages * page_tokens * bytes_per_token, PAGE_BYTES))
        self.translation_cost = 0.0

    def alloc(self) -> int:
        if not self.free:
            raise RuntimeError("page pool exhausted")
        page = self.free.pop()
        # translating the page's address range = registration fast/slow path
        vaddr = self.region.vaddr + page * PAGE_BYTES
        _, cost = self.endpoint.tlb.translate(vaddr)
        self.translation_cost += cost
        return page

    def release(self, pages: list[int]) -> None:
        self.free.extend(pages)

    @property
    def hit_rate(self) -> float:
        return self.endpoint.tlb.stats.hit_rate


@dataclasses.dataclass
class SlotState:
    """A running slot's exportable KV state — what a migration moves.

    ``k``/``v`` hold only the slot's LIVE pages (the ones covering
    ``seq_len`` tokens) in page-table (logical) order, shaped
    (L, n_pages, page_tokens, n_kv_heads, head_dim) — the zero/stale
    ``max_new`` headroom pages never touch the wire; the importer claims
    all ``n_alloc`` pages fresh from its own pool (physical page ids are
    a node-local detail and do NOT travel).

    A *modelled* node (``PagedLM(modelled=True)``) exports ``k = v =
    None`` with ``n_live`` carrying the page count: the wire payload is
    priced identically, only the tensor contents are absent.
    """

    k: jax.Array | None
    v: jax.Array | None
    seq_len: int
    page_tokens: int
    n_alloc: int                 # total pages the importer must claim
    nbytes: int                  # wire payload (live page contents only)
    n_live: int = -1             # live page count when k is None

    @property
    def n_pages(self) -> int:
        """Live pages on the wire (<= n_alloc)."""
        if self.k is None:
            return int(self.n_live)
        return int(self.k.shape[1])


class PagedLM:
    """Decode wrapper holding paged K/V pools for every layer.

    ``torus``/``rank`` place this node's fabric twin at its real torus
    coordinate (a serving cluster passes the shared cluster fabric);
    ``tp_axes`` are the mesh axes of the modelled tensor-parallel
    deployment — default: one axis per torus dimension; pass ``()`` for a
    single-card replica whose fabric traffic is only inter-node
    (migration) RDMA.

    ``modelled=True`` keeps the whole control plane — slots, page
    allocator, TLB registration, export/import, RDMA endpoint — but
    allocates no K/V tensors and compiles no kernels: decode/prefill
    become pure accounting (tokens are placeholders, compute is priced
    analytically by the window owner).  This is what lets a 512-node
    trace replay drive the real router/admission/migration machinery
    without 512 live model replicas.
    """

    def __init__(self, cfg: ArchCfg, params, *, max_batch: int,
                 max_seq: int, page_tokens: int = 16,
                 pool_pages: int | None = None,
                 torus: Torus | None = None,
                 tp_axes: tuple[str, ...] | None = None,
                 rank: int = 0, net: NetModel | None = None,
                 sim: fabric.FabricSim | None = None,
                 cost_backend: str = "analytic",
                 cost_fidelity: str = "packet",
                 descriptor_bytes: float | None = None,
                 modelled: bool = False) -> None:
        assert cfg.family in ("dense", "moe", "vlm")
        self.cfg = cfg
        self.params = params
        self.modelled = modelled
        self.page = page_tokens
        self.max_batch = max_batch
        self.pages_per_seq = -(-max_seq // page_tokens)
        need = max_batch * self.pages_per_seq
        self.n_pages = pool_pages or int(need * 1.25)
        hd = cfg.resolved_head_dim
        L = cfg.n_layers
        if modelled:
            self.k_pool = None
            self.v_pool = None
        else:
            self.k_pool = jnp.zeros((L, self.n_pages, page_tokens,
                                     cfg.n_kv_heads, hd), cfg.dtype)
            self.v_pool = jnp.zeros_like(self.k_pool)
        self.page_table = np.zeros((max_batch, self.pages_per_seq), np.int32)
        self.seq_lens = np.zeros((max_batch,), np.int32)
        self.torus = torus or Torus((4, 4))
        self.rank = rank
        if not 0 <= rank < self.torus.size:
            raise ValueError(f"rank {rank} out of range for torus "
                             f"{self.torus.dims}")
        self.net = net or NetModel()
        self.bytes_per_token = 2 * L * cfg.n_kv_heads * hd * 2
        # shared fabric timeline: a serving cluster passes ONE simulator
        # (any fidelity tier of ``fabric.make_sim`` — packet ``FabricSim``,
        # ``FluidSim`` or ``HybridSim``; the surface is duck-typed) so
        # this node's migration PUTs and decode-step TP collectives contend
        # with every other node's traffic on the same torus links
        self.sim = sim
        self.endpoint = RdmaEndpoint(self.torus, rank=rank, net=self.net,
                                     sim=sim,
                                     descriptor_bytes=descriptor_bytes)
        self.allocator = PageAllocator(
            self.n_pages, page_tokens,
            bytes_per_token=self.bytes_per_token, endpoint=self.endpoint)
        # Fabric twin of a TP deployment of this model on the torus: one
        # residual-stream all-reduce per layer per decode step, priced by
        # the same CollectiveSchedule the trainer executes.  Reported in
        # stats() against the measured decode step time.
        if tp_axes is None:   # one TP axis per torus dim, whatever its rank
            names = ("x", "y", "z")
            tp_axes = tuple(names[i] if i < len(names) else f"d{i}"
                            for i in range(self.torus.ndims))
        self.tp_axes = tuple(tp_axes)
        self._cost_backend = cost_backend
        self._cost_fidelity = cost_fidelity
        if self.tp_axes:
            self.tp_schedule = fabric.lower_all_reduce(self.torus,
                                                       self.tp_axes)
            ar_bytes = max_batch * cfg.d_model * jnp.dtype(cfg.dtype).itemsize
            # per-decode-step TP wire bytes: one residual all-reduce per
            # layer (the per-step traffic a shared sim injects as flows)
            self.tp_step_bytes = L * ar_bytes
            self._tp_base = self.tp_schedule   # healthy-fabric lowering
            self._tp_ar_bytes = ar_bytes
            self.predicted_tp_comm_s = L * fabric.estimate(
                self.tp_schedule, ar_bytes, self.net,
                backend=cost_backend, fidelity=cost_fidelity).total_s
        else:
            self.tp_schedule = None
            self._tp_base = None
            self._tp_ar_bytes = 0
            self.tp_step_bytes = 0
            self.predicted_tp_comm_s = 0.0
        self.slot_pages: dict[int, list[int]] = {}
        if modelled:
            self._decode = None
            self._prefill = None
            self._prefill_chunk = None
        else:
            self._decode = jax.jit(self._decode_impl)
            self._prefill = jax.jit(self._prefill_impl)
            self._prefill_chunk = jax.jit(self._prefill_chunk_impl)

    # -- fault feed -------------------------------------------------------------
    def relower_tp(self, faults) -> bool:
        """Re-lower the decode TP twin through ``fabric.rewrite`` against
        the cluster's fault map, so the per-step TP flows the engine
        injects price shrunk/detoured rings honestly (a dead link on the
        TP ring becomes explicit detour hops in the schedule, not just a
        sim-side route resolution).  Returns True when the twin changed.

        A fault map that partitions the TP ring is unroutable; the last
        routable twin is kept — the sim's own BFS keeps detouring what it
        can, and the cluster surfaces the partition on the paths that
        genuinely need the dead links."""
        if self._tp_base is None:
            return False
        try:
            sched = fabric.rewrite(self._tp_base, faults) if faults \
                else self._tp_base
        except fabric.UnroutableError:
            return False
        if sched == self.tp_schedule:
            return False
        self.tp_schedule = sched
        self.predicted_tp_comm_s = self.cfg.n_layers * fabric.estimate(
            sched, self._tp_ar_bytes, self.net,
            backend=self._cost_backend,
            fidelity=self._cost_fidelity).total_s
        return True

    # -- slot management --------------------------------------------------------
    def _claim(self, npages: int) -> int:
        """Claim a free slot holding ``npages`` freshly allocated pages."""
        if npages > self.pages_per_seq:
            # ValueError, NOT RuntimeError: admission retries RuntimeError
            # (transient exhaustion), but an oversize request can never
            # fit and must fail loudly instead of re-queueing forever
            raise ValueError(
                f"request needs {npages} pages > pages_per_seq "
                f"{self.pages_per_seq} (raise max_seq or shorten it)")
        used = set(self.slot_pages)
        slot = next((i for i in range(self.max_batch) if i not in used),
                    None)
        if slot is None:
            raise RuntimeError("no free decode slot")
        pages: list[int] = []
        try:
            for _ in range(npages):
                pages.append(self.allocator.alloc())
        except Exception:
            # pool exhausted mid-claim: hand the partial allocation back so
            # admission can retry cleanly once pages free up (a leak here
            # permanently shrinks the pool)
            self.allocator.release(pages)
            raise
        self.slot_pages[slot] = pages
        self.page_table[slot, :npages] = pages
        self.seq_lens[slot] = 0
        return slot

    def claim_slot(self, prompt_len: int, max_new: int) -> int:
        return self._claim(-(-(prompt_len + max_new) // self.page))

    def free_slot(self, slot: int) -> None:
        self.allocator.release(self.slot_pages.pop(slot))
        self.seq_lens[slot] = 0

    # -- slot migration (export/import) -----------------------------------------
    def live_pages(self, slot: int) -> list[int]:
        """The slot's pages actually covering its ``seq_len`` tokens — the
        only ones a migration must move (headroom pages hold no state the
        decode can ever read: positions past seq_len are masked)."""
        seq_len = int(self.seq_lens[slot])
        n_live = min(len(self.slot_pages[slot]), -(-seq_len // self.page))
        return self.slot_pages[slot][:n_live]

    def export_slot(self, slot: int) -> SlotState:
        """Snapshot a slot's live KV pages (logical order) + seq_len."""
        live = np.asarray(self.live_pages(slot), np.int32)
        if self.modelled:
            # no tensor contents to snapshot — the wire payload (and the
            # importer's page claim) are priced from the counts alone
            return SlotState(
                k=None, v=None,
                seq_len=int(self.seq_lens[slot]), page_tokens=self.page,
                n_alloc=len(self.slot_pages[slot]), n_live=len(live),
                nbytes=len(live) * self.page * self.bytes_per_token)
        return SlotState(
            k=self.k_pool[:, live], v=self.v_pool[:, live],
            seq_len=int(self.seq_lens[slot]), page_tokens=self.page,
            n_alloc=len(self.slot_pages[slot]),
            nbytes=len(live) * self.page * self.bytes_per_token)

    def import_slot(self, state: SlotState) -> int:
        """Land a migrated slot: claim ``n_alloc`` local pages, write the
        live KV contents, restore the sequence length.  Decode resumes
        bitwise-identically — the live page contents and seq_len are the
        whole decode state (headroom content is never read before being
        written)."""
        if state.page_tokens != self.page:
            raise ValueError(
                f"page_tokens mismatch: exported {state.page_tokens}, "
                f"local {self.page}")
        if state.n_pages > state.n_alloc:
            raise ValueError(f"corrupt slot state: {state.n_pages} live "
                             f"pages > {state.n_alloc} allocated")
        slot = self._claim(state.n_alloc)
        if state.n_pages and not self.modelled and state.k is not None:
            live = jnp.asarray(self.slot_pages[slot][:state.n_pages],
                               jnp.int32)
            self.k_pool = self.k_pool.at[:, live].set(state.k)
            self.v_pool = self.v_pool.at[:, live].set(state.v)
        self.seq_lens[slot] = state.seq_len
        return slot

    # -- jitted compute ----------------------------------------------------------
    def _prefill_impl(self, params, tokens, k_pool, v_pool, page_table,
                      slot, true_len):
        """Prefill one request's prompt into its pages (batch of 1).

        tokens are right-padded to a page multiple; the returned logits are
        taken at the *true* last prompt position."""
        cfg = self.cfg
        _, cache, h = transformer.prefill(cfg, params, {"tokens": tokens},
                                          max_len=tokens.shape[1],
                                          remat=False, return_hidden=True,
                                          moe_dropless=True)
        S = tokens.shape[1]
        last_h = jax.lax.dynamic_slice_in_dim(h, true_len - 1, 1, axis=1)
        logits = common.lm_head(cfg, params["embed"], last_h)
        k = cache["k"][:, 0]   # (L, S, Hkv, hd)
        v = cache["v"][:, 0]
        npage_prompt = S // self.page   # S is padded to page multiple
        kp = k.reshape(cfg.n_layers, npage_prompt, self.page,
                       cfg.n_kv_heads, -1)
        vp = v.reshape(cfg.n_layers, npage_prompt, self.page,
                       cfg.n_kv_heads, -1)
        dest = jax.lax.dynamic_slice(page_table, (slot, 0),
                                     (1, self.pages_per_seq))[0]
        k_pool = k_pool.at[:, dest[:npage_prompt]].set(kp)
        v_pool = v_pool.at[:, dest[:npage_prompt]].set(vp)
        return logits[:, -1], k_pool, v_pool

    def _prefill_chunk_impl(self, params, tokens, k_pool, v_pool,
                            page_table, slot, start_pos, n_alloc):
        """Prefill ONE page-aligned chunk of a prompt (batch of 1).

        The overlap engine's serving analogue: instead of one monolithic
        prompt forward stalling the running decode batch, the prompt is
        admitted in page-sized chunks interleaved with decode steps.  Each
        chunk writes its K/V into the slot's pages and attends all cached
        positions <= its own (causal over the page span), so the math per
        query is identical to the whole-prompt prefill.

        tokens: (1, T) with T a page multiple (final chunk right-padded);
        start_pos: absolute position of tokens[0, 0] (page-aligned);
        n_alloc: pages claimed for the slot — padded-chunk writes past the
        allocation are dropped (their queries are padding, never read).
        Returns (logits (1, T, V), k_pool, v_pool)."""
        cfg = self.cfg
        T = tokens.shape[1]
        npage = T // self.page
        hd = cfg.resolved_head_dim
        group = cfg.n_heads // cfg.n_kv_heads
        S_all = self.pages_per_seq * self.page
        h = common.embed_tokens(params["embed"], tokens)
        freqs = common.rope_freqs(cfg)
        pos = start_pos + jnp.arange(T)
        page0 = start_pos // self.page
        rows = jax.lax.dynamic_slice(page_table, (slot, 0),
                                     (1, self.pages_per_seq))[0]

        def body(h, xs):
            lp, kp, vp = xs
            x = common.apply_norm(cfg, lp["ln1"], h)
            q, k, v = attn_mod._project_qkv(cfg, lp["attn"], x, x)
            q = common.apply_rope(q, pos[None], freqs)
            k = common.apply_rope(k, pos[None], freqs)
            dest = jax.lax.dynamic_slice(rows, (page0,), (npage,))
            dest = jnp.where(page0 + jnp.arange(npage) < n_alloc, dest,
                             kp.shape[0])
            kp = kp.at[dest].set(
                k[0].reshape(npage, self.page, cfg.n_kv_heads, hd),
                mode="drop")
            vp = vp.at[dest].set(
                v[0].reshape(npage, self.page, cfg.n_kv_heads, hd),
                mode="drop")
            kd = kp[rows].reshape(S_all, cfg.n_kv_heads, hd)
            vd = vp[rows].reshape(S_all, cfg.n_kv_heads, hd)
            qf = q[0].astype(jnp.float32) * hd ** -0.5
            kf = kd.astype(jnp.float32)
            vf = vd.astype(jnp.float32)
            if group > 1:
                kf = jnp.repeat(kf, group, axis=1)
                vf = jnp.repeat(vf, group, axis=1)
            logits = jnp.einsum("qhd,khd->hqk", qf, kf)
            mask = jnp.arange(S_all)[None, :] <= pos[:, None]
            logits = jnp.where(mask[None], logits, -jnp.inf)
            probs = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("hqk,khd->qhd", probs, vf)
            a = out.astype(h.dtype).reshape(1, T, -1) @ lp["attn"]["wo"]
            h = h + a
            x2 = common.apply_norm(cfg, lp["ln2"], h)
            if cfg.moe is not None:
                m, _ = moe_mod.apply_moe(cfg, lp["moe"], x2, dropless=True)
            else:
                m = common.apply_mlp(cfg, lp["mlp"], x2)
            return h + m, (kp, vp)

        h, (k_pool, v_pool) = jax.lax.scan(body, h, (params["layers"],
                                                     k_pool, v_pool))
        h = common.apply_norm(cfg, params["final_norm"], h)
        logits = common.lm_head(cfg, params["embed"], h)
        return logits, k_pool, v_pool

    def _decode_impl(self, params, tokens, k_pool, v_pool, page_table,
                     seq_lens, active):
        """One batched decode step over all active slots.

        tokens: (B, 1); seq_lens: (B,) current context length per slot;
        active: (B,) bool mask."""
        cfg = self.cfg
        B = tokens.shape[0]
        hd = cfg.resolved_head_dim
        h = common.embed_tokens(params["embed"], tokens)
        freqs = common.rope_freqs(cfg)
        pos = seq_lens  # (B,)

        def body(h, xs):
            lp, kp, vp = xs
            x = common.apply_norm(cfg, lp["ln1"], h)
            q, k, v = attn_mod._project_qkv(cfg, lp["attn"], x, x)
            q = common.apply_rope(q, pos[:, None], freqs)
            k = common.apply_rope(k, pos[:, None], freqs)
            # scatter this step's K/V into each slot's current page;
            # inactive slots scatter out-of-bounds (dropped — their pages
            # may already belong to a newly admitted request)
            page_idx = pos // self.page
            page_off = pos % self.page
            phys = jnp.take_along_axis(page_table, page_idx[:, None],
                                       axis=1)[:, 0]
            phys = jnp.where(active, phys, kp.shape[0])
            kp = kp.at[phys, page_off].set(k[:, 0], mode="drop")
            vp = vp.at[phys, page_off].set(v[:, 0], mode="drop")
            out = ops.paged_attention(q[:, 0], kp, vp, page_table,
                                      seq_lens + 1)
            a = out.reshape(B, 1, -1) @ lp["attn"]["wo"]
            h = h + a
            x2 = common.apply_norm(cfg, lp["ln2"], h)
            if cfg.moe is not None:
                m, _ = moe_mod.apply_moe(cfg, lp["moe"], x2, dropless=True)
            else:
                m = common.apply_mlp(cfg, lp["mlp"], x2)
            return h + m, (kp, vp)

        h, (k_pool, v_pool) = jax.lax.scan(body, h,
                                           (params["layers"], k_pool,
                                            v_pool))
        h = common.apply_norm(cfg, params["final_norm"], h)
        logits = common.lm_head(cfg, params["embed"], h)[:, 0]
        logits = jnp.where(active[:, None], logits, 0.0)
        return logits, k_pool, v_pool

    # -- public API ---------------------------------------------------------------
    def prefill_slot(self, slot: int, prompt: np.ndarray) -> int:
        pad = (-len(prompt)) % self.page
        tokens = jnp.asarray(
            np.pad(prompt, (0, pad))[None].astype(np.int32))
        # NOTE: padded prompt tokens are attended (right padding); the
        # first generated token comes from the true last prompt position,
        # so we prefill only up to len(prompt) and ignore tail positions by
        # setting seq_len to the true length.
        logits, self.k_pool, self.v_pool = self._prefill(
            self.params, tokens, self.k_pool, self.v_pool,
            jnp.asarray(self.page_table), slot, len(prompt))
        self.seq_lens[slot] = len(prompt)
        return int(jnp.argmax(logits[0]))

    def prefill_slot_chunk(self, slot: int, prompt: np.ndarray, start: int,
                           chunk_tokens: int) -> int | None:
        """Prefill ``prompt[start:start+chunk_tokens]`` into the slot.

        ``start`` and ``chunk_tokens`` must be page multiples.  Returns the
        first generated token when the chunk covers the prompt tail (the
        request is then decode-ready), else None."""
        if start % self.page or chunk_tokens % self.page:
            raise ValueError("chunk boundaries must be page-aligned")
        end = min(start + chunk_tokens, len(prompt))
        toks = np.zeros((chunk_tokens,), np.int32)
        toks[:end - start] = prompt[start:end]
        logits, self.k_pool, self.v_pool = self._prefill_chunk(
            self.params, jnp.asarray(toks[None]), self.k_pool, self.v_pool,
            jnp.asarray(self.page_table), slot, start,
            len(self.slot_pages[slot]))
        if end < len(prompt):
            return None
        self.seq_lens[slot] = len(prompt)
        return int(jnp.argmax(logits[0, len(prompt) - 1 - start]))

    def decode_batch(self, tokens: np.ndarray, active: np.ndarray):
        logits, self.k_pool, self.v_pool = self._decode(
            self.params, jnp.asarray(tokens[:, None].astype(np.int32)),
            self.k_pool, self.v_pool, jnp.asarray(self.page_table),
            jnp.asarray(self.seq_lens), jnp.asarray(active))
        self.seq_lens = self.seq_lens + active.astype(np.int32)
        return np.asarray(jnp.argmax(logits, -1))


class Engine:
    """Continuous-batching loop over a PagedLM.

    ``chunked_prefill=True`` admits prompts in page-sized chunks
    interleaved with decode steps (one chunk per prefilling request per
    engine step), so a long prompt no longer stalls the running batch for
    its whole forward — the serving-side overlap engine.  Tokens are
    identical to whole-prompt prefill (same per-query attention math).
    """

    def __init__(self, lm: PagedLM, *, chunked_prefill: bool = False,
                 prefill_chunk_pages: int = 1) -> None:
        self.lm = lm
        self.chunked_prefill = chunked_prefill
        self.chunk_tokens = max(prefill_chunk_pages, 1) * lm.page
        self.pending: list[Request] = []
        self.prefilling: dict[int, Request] = {}
        self.running: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.steps = 0
        self.prefill_chunks = 0
        self.decode_stall_s = 0.0   # non-decode work while a batch waited
        self._step_times: list[float] = []
        # shared-timeline accounting (lm.sim attached): each decode step
        # injects the node's TP collective traffic as flows; the timeline
        # owner (the serving cluster) settles them per logical window
        self.pending_comm_fids: list[int] = []
        self.sim_tp_comm_s = 0.0    # settled, contention-priced TP comm
        self.sim_comm_steps = 0
        # per-window SLO accounting, consumed (and cleared) by the
        # cluster's window close: which requests produced their first
        # token / finished this window, and how much compute the window
        # carried (decode tokens everywhere; cold prefill tokens only on
        # a modelled lm — the real prefill path measures itself)
        self.window_first: list[Request] = []
        self.window_finished: list[Request] = []
        self.window_decode_tokens = 0
        self.window_cold_prefill_tokens = 0

    @property
    def load(self) -> int:
        """Requests this engine is responsible for (the router's metric)."""
        return len(self.pending) + len(self.prefilling) + len(self.running)

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    # -- migration hooks (ServingCluster) ---------------------------------------
    def detach(self, slot: int) -> Request:
        """Hand a running request over to a migration (its pages stay
        claimed until the cluster frees them after the PUT)."""
        return self.running.pop(slot)

    def attach(self, req: Request) -> None:
        """Adopt a migrated request whose slot was already imported."""
        if req.slot is None or req.slot in self.running:
            raise ValueError(f"cannot attach request {req.rid} at slot "
                             f"{req.slot}")
        self.running[req.slot] = req

    def _admit(self) -> int:
        admitted = 0
        while self.pending and len(self.running) + len(self.prefilling) \
                < self.lm.max_batch:
            req = self.pending.pop(0)
            try:
                slot = self.lm.claim_slot(len(req.prompt),
                                          req.max_new_tokens)
            except (RuntimeError, StopIteration):
                self.pending.insert(0, req)
                return admitted
            except ValueError:
                # oversize request: surface the error, but keep the request
                # addressable (it must not vanish from every queue)
                self.pending.insert(0, req)
                raise
            req.slot = slot
            admitted += 1
            if self.chunked_prefill:
                req.pos = 0
                self.prefilling[slot] = req
            else:
                if self.lm.modelled:
                    # accounting-only prefill: a session follow-up on its
                    # home node skips the warm prefix (modelled prefix
                    # cache); the cold remainder is charged to the window
                    warm = min(max(req.warm_tokens, 0), len(req.prompt))
                    self.window_cold_prefill_tokens += \
                        len(req.prompt) - warm
                    self.lm.seq_lens[slot] = len(req.prompt)
                    first = 0
                else:
                    first = self.lm.prefill_slot(slot, req.prompt)
                req.out_tokens.append(first)
                req.pos = len(req.prompt)
                self.running[slot] = req
                self.window_first.append(req)
        return admitted

    def _advance_prefills(self) -> int:
        """One page-sized chunk per prefilling request per engine step."""
        chunks = 0
        if self.lm.modelled:
            return self._advance_prefills_modelled()
        for slot, req in list(self.prefilling.items()):
            tok = self.lm.prefill_slot_chunk(slot, req.prompt, req.pos,
                                             self.chunk_tokens)
            self.prefill_chunks += 1
            chunks += 1
            req.pos = min(req.pos + self.chunk_tokens, len(req.prompt))
            if tok is not None:
                req.out_tokens.append(tok)
                req.pos = len(req.prompt)
                del self.prefilling[slot]
                self.running[slot] = req
                self.window_first.append(req)
        return chunks

    def _advance_prefills_modelled(self) -> int:
        """Accounting-only chunked prefill: the warm prefix (home-node
        prefix-cache hit) is skipped outright, each step charges one
        chunk of the cold remainder to ``window_cold_prefill_tokens``,
        and the request goes decode-ready when the cursor covers the
        prompt — same admission cadence as the real chunked path."""
        chunks = 0
        for slot, req in list(self.prefilling.items()):
            if req.pos == 0 and req.warm_tokens > 0:
                req.pos = min(req.warm_tokens, len(req.prompt))
            end = min(req.pos + self.chunk_tokens, len(req.prompt))
            self.window_cold_prefill_tokens += end - req.pos
            req.pos = end
            self.prefill_chunks += 1
            chunks += 1
            if req.pos >= len(req.prompt):
                self.lm.seq_lens[slot] = len(req.prompt)
                req.out_tokens.append(0)
                req.pos = len(req.prompt)
                del self.prefilling[slot]
                self.running[slot] = req
                self.window_first.append(req)
        return chunks

    def step(self) -> None:
        t0 = time.perf_counter()
        # fresh window accounting: the cluster steps each engine exactly
        # once per logical window and reads these at window close
        self.window_first = []
        self.window_finished = []
        self.window_decode_tokens = 0
        self.window_cold_prefill_tokens = 0
        had_batch = bool(self.running)
        worked = self._admit()
        if self.chunked_prefill:
            worked += self._advance_prefills()
        if had_batch and worked:
            # whole-prompt prefill (or the per-step chunk) ran while the
            # decode batch sat idle: that gap is the admission stall the
            # chunked path bounds at one chunk.  Steps that admitted or
            # prefilled nothing did no non-decode work — the _admit walk
            # itself is not a stall.
            self.decode_stall_s += time.perf_counter() - t0
        if not self.running:
            return
        if self.lm.modelled:
            self._step_modelled(t0)
            return
        B = self.lm.max_batch
        tokens = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        for slot, req in self.running.items():
            tokens[slot] = req.out_tokens[-1]
            active[slot] = not req.done
        self.window_decode_tokens += int(active.sum())
        nxt = self.lm.decode_batch(tokens, active)
        if self.lm.sim is not None and self.lm.tp_schedule is not None:
            # this step's TP collectives enter the shared timeline at the
            # current window start, tagged DECODE: on a QoS fabric the
            # link arbiter protects them from concurrent BULK migrations;
            # they are settled (and priced, WITH whatever traffic they
            # contended against) by settle_comm
            self.pending_comm_fids.extend(fabric.inject_schedule(
                self.lm.sim, self.lm.tp_schedule, self.lm.tp_step_bytes,
                start_s=self.lm.sim.now, granularity="phase",
                cls=fabric.TrafficClass.DECODE))
            self.sim_comm_steps += 1
        self.steps += 1
        self._step_times.append(time.perf_counter() - t0)
        for slot, req in list(self.running.items()):
            if active[slot]:
                req.out_tokens.append(int(nxt[slot]))
                req.pos += 1
            if req.done:
                self.lm.free_slot(slot)
                self.finished.append(self.running.pop(slot))
                self.window_finished.append(req)

    def _step_modelled(self, t0: float) -> None:
        """Decode step on a modelled lm: token bookkeeping only (the
        placeholder token is 0), same batch/finish semantics as the real
        path; the window owner prices ``window_decode_tokens`` of compute
        analytically.  TP flows still enter the shared timeline — the
        fabric twin is real even when the FLOPs are modelled."""
        for slot, req in list(self.running.items()):
            if not req.done:
                req.out_tokens.append(0)
                req.pos += 1
                self.lm.seq_lens[slot] += 1
                self.window_decode_tokens += 1
            if req.done:
                self.lm.free_slot(slot)
                self.finished.append(self.running.pop(slot))
                self.window_finished.append(req)
        if self.lm.sim is not None and self.lm.tp_schedule is not None:
            self.pending_comm_fids.extend(fabric.inject_schedule(
                self.lm.sim, self.lm.tp_schedule, self.lm.tp_step_bytes,
                start_s=self.lm.sim.now, granularity="phase",
                cls=fabric.TrafficClass.DECODE))
            self.sim_comm_steps += 1
        self.steps += 1
        self._step_times.append(time.perf_counter() - t0)

    def settle_comm(self, window_start: float) -> float:
        """Resolve this window's injected TP flows against the shared
        timeline; accrues their contention-priced wall time and returns
        the window's comm end (``window_start`` when idle).  Called by
        the timeline owner (the serving cluster) once per logical window."""
        if not self.pending_comm_fids:
            return window_start
        sim = self.lm.sim
        sim.run()
        end = max(sim.finish_s(f) for f in self.pending_comm_fids)
        self.pending_comm_fids = []
        self.sim_tp_comm_s += max(end - window_start, 0.0)
        return end

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.pending or self.prefilling or self.running) \
                and steps < max_steps:
            self.step()
            steps += 1
        if self.pending or self.prefilling or self.running:
            raise TruncatedRunError(steps, self.load)

    def stats(self) -> dict:
        alloc = self.lm.allocator
        # median, not mean: the first decode step carries jit compilation
        measured = (float(np.median(self._step_times))
                    if self._step_times else 0.0)
        return {
            "decode_steps": self.steps,
            "finished": len(self.finished),
            "tlb_hit_rate": alloc.hit_rate,
            "translation_cost_s": alloc.translation_cost,
            # fabric CollectiveSchedule prediction vs wall clock: the
            # per-step TP all-reduce cost a torus deployment would add
            "predicted_tp_comm_s": self.lm.predicted_tp_comm_s,
            "measured_step_s": measured,
            # overlap engine (serving side): chunked-prefill admission
            "chunked_prefill": self.chunked_prefill,
            "prefill_chunks": self.prefill_chunks,
            "decode_stall_s": self.decode_stall_s,
            # shared-timeline contention pricing (0.0 without a sim): TP
            # comm as actually experienced against concurrent traffic,
            # vs predicted_tp_comm_s which prices a quiet fabric
            "sim_tp_comm_s": self.sim_tp_comm_s,
            "sim_comm_steps": self.sim_comm_steps,
        }
