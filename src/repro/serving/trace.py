"""Seeded heavy-tailed synthetic traces + the cluster replay driver.

This is the north-star workload: the paper's fabric exists to keep
latency low *under sustained real traffic* (arXiv:1311.1741 §1), and a
serving deployment's real traffic is not a uniform stream — it has a
diurnal swing, Poisson burst arrivals on top, Zipf-heavy prompt/output
lengths, and session reuse (a follow-up turn re-submits its whole
conversation, of which the home node's prefix cache already holds the
prefix).  ``generate_trace`` synthesises exactly that shape from one
seed, bitwise-reproducibly; ``replay`` drives a ``ServingCluster``
through it on the shared fabric timeline and reports the SLO metrics
that matter at the tail: p50/p99 time-to-first-token and per-token
decode latency, plus the admission layer's shed rate.

Determinism contract: every random draw goes through one
``numpy.random.Generator(PCG64(seed))`` in a fixed call order, so the
same ``TraceConfig`` yields an identical trace — and, the fabric tiers
being deterministic, an identical replay — on every run.  The CI gate
relies on this (same-seed snapshots diff at 0%).
"""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from repro.serving.cluster import ServingCluster
from repro.serving.engine import Request, TruncatedRunError


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Knobs of the synthetic workload (all rates in requests/second,
    all lengths in tokens, times in seconds on the replay timeline)."""

    n_requests: int = 1000
    seed: int = 0
    # -- arrival process: nonhomogeneous Poisson (thinning) ------------
    base_rate: float = 100.0     # diurnal midline arrival rate
    diurnal_amp: float = 0.6     # rate swings +-amp around the midline
    diurnal_period_s: float = 60.0   # one compressed "day"
    burst_rate: float = 0.05     # Poisson burst events per second
    burst_size: float = 8.0      # mean arrivals per burst (geometric)
    burst_span_s: float = 0.25   # a burst's arrivals land within this
    # -- length distributions: bounded Zipf (rank-frequency) -----------
    prompt_min: int = 16
    prompt_max: int = 256
    prompt_zipf_a: float = 1.4
    output_min: int = 8
    output_max: int = 96
    output_zipf_a: float = 1.2
    # -- session reuse -------------------------------------------------
    session_p: float = 0.35      # P(an arrival continues an old session)
    session_gap_s: float = 1.0   # think time before a follow-up turn
    max_context: int = 448       # cap on a turn's total prompt length


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One trace arrival.  ``prompt_tokens`` is the FULL conversation
    context the turn submits; ``warm_tokens`` is the prefix of it the
    session's home node still holds in its (modelled) prefix cache —
    a router that honours session affinity prefills only the cold
    suffix, one that bounces the turn elsewhere re-prefills it all."""

    rid: int
    t: float                     # arrival time (s)
    prompt_tokens: int
    output_tokens: int
    session: int
    turn: int                    # 0 = session opener
    warm_tokens: int


def _zipf_pmf(n: int, a: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** a
    return w / w.sum()


def _zipf_len(rng: np.random.Generator, lo: int, hi: int,
              a: float, pmf: np.ndarray) -> int:
    """Bounded Zipf over [lo, hi]: rank-1 (the mode) maps to ``lo``, so
    most lengths are short and the tail is heavy but capped."""
    return lo + int(rng.choice(len(pmf), p=pmf))


def generate_trace(cfg: TraceConfig) -> list[TraceRequest]:
    """Synthesise a seeded trace (sorted by arrival time).

    Arrival process: homogeneous Poisson at the diurnal peak rate,
    thinned to ``base_rate * (1 + amp*sin(2*pi*t/period))`` — the
    textbook nonhomogeneous-Poisson construction, exact and one-pass.
    Burst events arrive as their own Poisson process; each splashes a
    geometric number of extra arrivals across ``burst_span_s``.
    """
    rng = np.random.Generator(np.random.PCG64(cfg.seed))
    p_pmf = _zipf_pmf(cfg.prompt_max - cfg.prompt_min + 1,
                      cfg.prompt_zipf_a)
    o_pmf = _zipf_pmf(cfg.output_max - cfg.output_min + 1,
                      cfg.output_zipf_a)

    lam_max = cfg.base_rate * (1.0 + abs(cfg.diurnal_amp))
    t = 0.0
    base: list[float] = []
    while len(base) < cfg.n_requests:
        t += rng.exponential(1.0 / lam_max)
        rate = cfg.base_rate * (1.0 + cfg.diurnal_amp
                                * math.sin(2.0 * math.pi * t
                                           / cfg.diurnal_period_s))
        if rng.random() * lam_max < max(rate, 0.0):
            base.append(t)
    span = base[-1]
    arrivals = base
    n_bursts = int(rng.poisson(cfg.burst_rate * span))
    for _ in range(n_bursts):
        t_b = float(rng.uniform(0.0, span))
        g = int(rng.geometric(1.0 / max(cfg.burst_size, 1.0)))
        arrivals.extend(
            t_b + float(u)
            for u in rng.uniform(0.0, cfg.burst_span_s, size=g))
    arrivals.sort()
    arrivals = arrivals[:cfg.n_requests]

    # sessions: an arrival either opens a new session or continues an
    # idle one (last turn arrived >= session_gap_s ago) — the follow-up
    # re-submits the whole context, warm up to what the last turn built
    out: list[TraceRequest] = []
    last_ctx: dict[int, int] = {}     # session -> context it built
    last_t: dict[int, float] = {}     # session -> last arrival time
    turns: dict[int, int] = {}
    next_sid = 0
    for rid, t in enumerate(arrivals):
        eligible = sorted(s for s, lt in last_t.items()
                          if lt + cfg.session_gap_s <= t)
        sid = -1
        if eligible and rng.random() < cfg.session_p:
            sid = int(eligible[int(rng.integers(len(eligible)))])
            new_tokens = _zipf_len(rng, cfg.prompt_min, cfg.prompt_max,
                                   cfg.prompt_zipf_a, p_pmf)
            prompt = last_ctx[sid] + new_tokens
            if prompt > cfg.max_context:
                sid = -1              # conversation full: open fresh
        if sid < 0:
            sid = next_sid
            next_sid += 1
            prompt = _zipf_len(rng, cfg.prompt_min, cfg.prompt_max,
                               cfg.prompt_zipf_a, p_pmf)
            prompt = min(prompt, cfg.max_context)
            warm = 0
            turn = 0
        else:
            warm = last_ctx[sid]
            turn = turns[sid] + 1
        output = _zipf_len(rng, cfg.output_min, cfg.output_max,
                           cfg.output_zipf_a, o_pmf)
        out.append(TraceRequest(rid=rid, t=float(t),
                                prompt_tokens=int(prompt),
                                output_tokens=int(output),
                                session=sid, turn=turn,
                                warm_tokens=int(warm)))
        last_ctx[sid] = prompt + output
        last_t[sid] = t
        turns[sid] = turn
    return out


@dataclasses.dataclass
class ReplayReport:
    """What one replay measured.  ``metrics()`` is the deterministic
    subset (no wall time) the CI snapshots diff."""

    n_requests: int
    n_finished: int
    n_shed: int
    ttft_p50_s: float
    ttft_p99_s: float
    tpt_p50_s: float             # per-token decode latency
    tpt_p99_s: float
    makespan_s: float            # trace span on the fabric timeline
    steps: int                   # logical windows stepped
    n_migrations: int
    migrated_bytes: int
    wall_s: float                # host wall clock (NOT deterministic)

    @property
    def shed_rate(self) -> float:
        return self.n_shed / self.n_requests if self.n_requests else 0.0

    def metrics(self) -> dict[str, float]:
        # sorted-key ordering: deterministic independent of the literal's
        # (or any future caller's) insertion history, so snapshot diffs
        # and cross-tier comparisons never see a reordered dict
        return dict(sorted({
            "n_finished": float(self.n_finished),
            "shed_rate": float(self.shed_rate),
            "ttft_p50_s": float(self.ttft_p50_s),
            "ttft_p99_s": float(self.ttft_p99_s),
            "tpt_p50_s": float(self.tpt_p50_s),
            "tpt_p99_s": float(self.tpt_p99_s),
            "makespan_s": float(self.makespan_s),
            "steps": float(self.steps),
            "n_migrations": float(self.n_migrations),
            "migrated_bytes": float(self.migrated_bytes),
        }.items()))


def _pct(vals: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), q)) \
        if vals else 0.0


def replay(cluster: ServingCluster, trace: list[TraceRequest], *,
           rebalance: str = "proactive", rebalance_threshold: int = 2,
           rebalance_every_s: float | None = None,
           session_affinity: bool = True,
           qos_ctl=None, background=None,
           max_steps: int = 2_000_000,
           telemetry=None) -> ReplayReport:
    """Drive ``cluster`` through ``trace``, event-driven per node.

    Every node runs its own decode cadence: a per-node frontier
    ``busy[rank]`` advances by the analytic cost of the tokens that
    node's engine step carried (decode batch + cold prefill chunks), so
    one replica's long prefill never stalls the other 511 — the
    lock-step ``cluster.step()`` window is a fine model for a handful of
    nodes but turns a 512-node torus into a convoy.  The shared fabric
    simulator stays the single clock authority for everything that
    crosses the wire: migrations are priced (and probed) against
    whatever traffic is genuinely concurrent.

    ``rebalance`` selects the hook run after each event: ``"proactive"``
    (``rebalance_proactive``, needs an SloPolicy), ``"reactive"`` (the
    classic load-gap ``rebalance(threshold)``) or ``"none"``.  Both
    hooks scan every node, so they run at most once per
    ``rebalance_every_s`` of event-clock time (default: one token-time)
    — the same cadence for either mode, keeping the comparison fair.

    ``qos_ctl`` attaches a closed-loop QoS controller
    (``fabric.QosController``): at every hook tick it receives the
    per-token latencies of the requests that finished inside the window
    and may retune the live arbitration policy through ``sim.set_qos``.
    The controller is latched quiescent — on a replay where it never
    leaves the safe band it issues zero retunes, and the run is bitwise
    identical to ``qos_ctl=None``.

    ``background`` is a per-hook traffic callback ``(cluster, t)``:
    cross-traffic the request trace does not carry (checkpoint streams,
    a co-tenant's decode collectives) injected at every hook tick so it
    genuinely overlaps — in sim time — the migration PUTs priced inside
    the same window.  The event-driven driver otherwise serialises the
    fabric: a PUT runs the shared timeline to completion, so traffic
    injected at later event times can never contend with it.

    TTFT = first-token window end - arrival; per-token latency =
    (finish - first token) / (output tokens - 1).  Shed requests count
    in ``shed_rate`` and nowhere else.  Raises ``TruncatedRunError``
    instead of silently dropping the in-flight tail when ``max_steps``
    node-events pass.
    """
    if rebalance not in ("proactive", "reactive", "none"):
        raise ValueError(f"unknown rebalance mode {rebalance!r}")
    # the cluster's hub is the default reporting target; an explicit
    # ``telemetry=`` overrides (None + no cluster hub = zero telemetry
    # code on the replay path — bitwise-invisible)
    tel = telemetry if telemetry is not None \
        else getattr(cluster, "telemetry", None)
    t0 = time.perf_counter()
    t_tok = cluster.t_token_s
    reqs = [Request(rid=tr.rid,
                    prompt=np.zeros(tr.prompt_tokens, np.int32),
                    max_new_tokens=tr.output_tokens,
                    arrival_s=tr.t, warm_tokens=tr.warm_tokens,
                    session=tr.session)
            for tr in trace]
    home: dict[int, int] = {}    # session -> rank of its prefix cache
    busy: dict[int, float] = {r: 0.0 for r in cluster.nodes}
    i = 0
    steps = 0
    eps = 1e-12
    hook_dt = t_tok if rebalance_every_s is None else rebalance_every_s
    last_hook = -float("inf")
    win_tpts: list[float] = []   # per-token latencies finished this window

    def has_work(n) -> bool:
        e = n.engine
        return bool(e.pending or e.prefilling or e.running)

    while True:
        work = [busy[r] for r, n in cluster.nodes.items() if has_work(n)]
        nxt_arrival = reqs[i].arrival_s if i < len(reqs) else None
        if not work and nxt_arrival is None \
                and not cluster.admission_queue:
            break
        cands = []
        if work:
            cands.append(min(work))
        if nxt_arrival is not None:
            cands.append(nxt_arrival)
        if not cands:
            # only unplaceable stragglers queue: nothing decodes, so no
            # event advances the clock — jump past the wait cap so
            # admission sheds them instead of spinning
            wait = (cluster.slo.max_queue_wait_s
                    if cluster.slo is not None else 0.0)
            cands.append(cluster.sim.now + wait + 2 * eps)
        # the event clock is NOT clamped to the sim frontier: a settled
        # migration PUT may have pushed sim.now a few ms ahead, and
        # dragging every node's cadence forward with it would re-create
        # the convoy this driver exists to avoid.  advance() is a no-op
        # when the frontier is already ahead.
        t = min(cands)
        cluster.sim.advance(t)
        while i < len(reqs) and reqs[i].arrival_s <= t + eps:
            req = reqs[i]
            prefer = home.get(req.session) if session_affinity else None
            rank = cluster.submit(req, prefer=prefer) \
                if cluster.slo is not None else cluster.submit(req)
            if rank is not None and req.session >= 0:
                home[req.session] = rank
            i += 1
        cluster._drain_admission()
        for r in sorted(cluster.nodes):
            node = cluster.nodes[r]
            if busy[r] > t + eps or not has_work(node):
                continue
            eng = node.engine
            eng.step()
            tokens = (eng.window_decode_tokens
                      + eng.window_cold_prefill_tokens)
            end = t + t_tok * tokens
            for req in eng.window_first:
                if req.first_token_s is None:
                    req.first_token_s = end
            for req in eng.window_finished:
                # a request migrated off a node whose frontier ran ahead
                # of the hook clock can finish on a destination whose
                # frontier still trails its own first-token stamp; the
                # skew is bounded by one source window — clamp rather
                # than let the record claim a finish before the first
                # token
                req.finish_s = end if req.first_token_s is None \
                    else max(end, req.first_token_s)
                if qos_ctl is not None and len(req.out_tokens) > 1:
                    win_tpts.append((req.finish_s - req.first_token_s)
                                    / (len(req.out_tokens) - 1))
            eng.window_first = []
            eng.window_finished = []
            # a step that moved nothing (pool temporarily starved by an
            # inbound migration) polls again one token-time later rather
            # than busy-looping at the same instant
            busy[r] = end if tokens > 0 else t + t_tok
            steps += 1
        if (rebalance != "none" or qos_ctl is not None) \
                and t >= last_hook + hook_dt:
            last_hook = t
            if qos_ctl is not None:
                # controller first: a retune this window shapes the very
                # migrations the rebalancer is about to probe/price
                qos_ctl.window(cluster.sim, win_tpts)
                win_tpts = []
            if background is not None:
                background(cluster, t)
            if rebalance == "proactive":
                moves = cluster.rebalance_proactive()
            elif rebalance == "reactive":
                m = cluster.rebalance(threshold=rebalance_threshold)
                moves = [] if m is None else [m]
            else:
                moves = []
            if tel is not None:
                tel.add("replay.hooks")
                if moves:
                    tel.add("replay.rebalance_moves", float(len(moves)))
            for m in moves:
                # the destination resumes no earlier than the PUT's
                # contention-priced completion: the pages must land
                # before the migrated slot can decode — this is where
                # the fabric tier's pricing feeds back into the tail.
                # (The source's frontier is NOT inherited: that would
                # stall every request already on the destination for
                # one straggler's window; the bounded stamp skew is
                # clamped per-request at stamping time instead.)
                busy[m.dst] = max(busy[m.dst], t + m.modelled_s)
                if tel is not None:
                    tel.event(("cluster",), "rebalance", t,
                              rid=m.rid, src=m.src, dst=m.dst,
                              nbytes=float(m.nbytes))
            # the shared timeline outlives every window: drop settled
            # flows so probe snapshots stay O(in-flight), not O(uptime)
            cluster.sim.prune()
        if steps >= max_steps:
            raise TruncatedRunError(steps, cluster.in_flight)
    cluster.settle()
    if tel is not None:
        tel.collect(cluster.sim)   # route-cache gauges + final clock

    finished = cluster.finished
    ttfts = [r.first_token_s - r.arrival_s for r in finished
             if r.first_token_s is not None and r.arrival_s is not None]
    tpts = [(r.finish_s - r.first_token_s) / (len(r.out_tokens) - 1)
            for r in finished
            if r.finish_s is not None and r.first_token_s is not None
            and len(r.out_tokens) > 1]
    return ReplayReport(
        n_requests=len(trace),
        n_finished=len(finished),
        n_shed=len(cluster.shed),
        ttft_p50_s=_pct(ttfts, 50), ttft_p99_s=_pct(ttfts, 99),
        tpt_p50_s=_pct(tpts, 50), tpt_p99_s=_pct(tpts, 99),
        makespan_s=float(cluster.sim.now),
        steps=steps,
        n_migrations=len(cluster.migrations),
        migrated_bytes=sum(m.nbytes for m in cluster.migrations),
        wall_s=time.perf_counter() - t0)
