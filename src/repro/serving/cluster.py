"""Multi-node serving cluster with live RDMA KV-page migration.

The paper's headline capability — zero-copy, low-latency GPU-to-GPU RDMA
across the 3D torus (GPUDirect P2P, arXiv:1307.8276 measures exactly this
path) — is what lets a serving deployment move a *running* request between
nodes without restarting its decode: the request's KV-cache pages are the
whole decode state, and they travel as one bulk dimension-ordered RDMA PUT
(``RdmaEndpoint.put_pages`` over a ``fabric.lower_p2p`` schedule).

Topology: every serving node is a rank of one shared ``Torus`` (the
cluster fabric); ranks without a serving node are pass-through routers.
Each node owns a full model replica (``PagedLM`` + ``Engine``); a router
in front admits each request to the least-loaded node.

Live migration of a slot from node A to node B:

  1. ``A.lm.export_slot``   — snapshot the slot's KV pages (logical order)
                              and sequence length;
  2. ``B.lm.import_slot``   — claim fresh pages on B, land the contents
                              (fails cleanly when B is full: the request
                              stays on A untouched);
  3. ``A.endpoint.put_pages(B, ...)`` — model the wire: TLB translation on
                              both cards, host-interface DMA, and the
                              multi-hop unicast priced by ``fabric.estimate``
                              — rewritten by the fault machinery, so a dead
                              link on the route becomes a BFS detour
                              (``hops`` up, tokens unchanged) and a
                              partitioned fabric raises ``UnroutableError``;
  4. the request detaches from A's batch, frees A's pages, and resumes
     decode on B **bitwise-identically** to the unmigrated run (the page
     contents + seq_len are the complete decode state; positions past
     seq_len are masked on both nodes).

The alternative to migrating ~len(context) * bytes_per_token of KV is
re-prefilling the context on B — a whole-prompt forward that stalls B's
running decode batch.  ``MigrationReport`` carries both modelled numbers;
``benchmarks/migration.py`` gates migration being the cheaper move.

Time is ONE shared fabric timeline (``fabric.FabricSim``): every node's
RDMA endpoint and per-decode-step TP collectives inject flows into the
same event-driven, link-level simulator, so a migration PUT issued while
decode traffic is in flight is priced *with* the contention (and slows
the decode comm in return) — ``MigrationReport.contention_slowdown``
reports how much the old sum-of-isolated models under-priced the move.
``migrate`` picks its route by simulated completion time against that
live traffic (``route_policy="congestion"``), not hop count;
``benchmarks/contention.py`` gates both behaviours.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Iterable, Sequence

import numpy as np

import jax

from repro.core import fabric
from repro.core.apelink import NetModel
from repro.core.hw import PAPER_GPU_EFF_FLOPS as GPU_EFF_FLOPS
from repro.core.topology import Torus
from repro.models.common import ArchCfg
from repro.serving.engine import (Engine, PagedLM, Request,
                                  TruncatedRunError)


def reprefill_stall_s(n_params: int, context_tokens: int,
                      flops: float = GPU_EFF_FLOPS) -> float:
    """Modelled decode stall of re-prefilling ``context_tokens`` from
    scratch on the destination (2 FLOPs per param per token forward, at
    the paper-era rate of ``hw.PAPER_GPU_EFF_FLOPS``) — the cost
    migration avoids."""
    return 2.0 * n_params * context_tokens / flops


@dataclasses.dataclass(frozen=True)
class MigrationReport:
    """One slot migration: what moved, over what route, at what cost."""

    rid: int
    src: int                     # torus rank of the source node
    dst: int                     # torus rank of the destination node
    n_pages: int
    nbytes: int                  # KV payload on the wire
    hops: int                    # route length actually taken
    min_hops: int                # healthy-fabric dimension-ordered distance
    modelled_s: float            # put_pages: translation + DMA + wire,
    #                              priced on the shared timeline (contended)
    reprefill_s: float           # the decode stall migrating avoided
    isolated_s: float = 0.0      # sum-of-isolated price (quiet fabric)
    route_policy: str = "hops"   # how the route was picked
    stripes: int = 1             # wire legs the PUT was split across

    @property
    def rerouted(self) -> bool:
        """Route longer than the healthy minimal one — a fault detour or a
        congestion-motivated one."""
        return self.hops > self.min_hops

    @property
    def speedup(self) -> float:
        """Avoided stall per second of modelled migration time."""
        return self.reprefill_s / self.modelled_s if self.modelled_s else 0.0

    @property
    def contention_slowdown(self) -> float:
        """Contended price / quiet-fabric price (1.0 = nothing in the
        way); > 1 means the old sum-of-isolated models under-priced it."""
        return self.modelled_s / self.isolated_s if self.isolated_s else 1.0


@dataclasses.dataclass(frozen=True)
class SloPolicy:
    """Serving-level objectives + the knobs that defend them.

    Attaching one (``ServingCluster(slo=...)``) switches the router to
    *capacity-aware* admission: a request only lands on a node whose free
    slots AND free KV pages (net of what its queued requests will claim,
    and of ``min_free_pages`` headroom) can actually hold it; otherwise
    it waits in a cluster-level admission queue, and is **shed** when the
    queue overflows ``queue_limit`` or it has waited ``max_queue_wait_s``
    on the shared timeline.  Without a policy the router keeps the legacy
    least-loaded behaviour bit-for-bit.

    ``token_target_s`` is the per-token decode-latency SLO the proactive
    rebalancer defends: it acts when a node's *predicted* next-window
    per-token latency crosses ``token_target_s * headroom`` — before the
    breach, not after the p99 already moved.
    """

    ttft_target_s: float = 0.5       # reported against, not enforced
    token_target_s: float = 0.05     # per-token decode latency SLO
    headroom: float = 0.8            # act at target*headroom (pre-breach)
    queue_limit: int = 256           # admission queue cap; overflow sheds
    max_queue_wait_s: float = 2.0    # queued longer than this sheds
    min_free_pages: int = 0          # per-node KV page headroom kept free
    max_moves_per_window: int = 4    # proactive migration budget
    probe_dsts: int = 2              # destinations probed per candidate
    max_migration_s: float | None = None   # skip moves probed slower


@dataclasses.dataclass
class ClusterNode:
    """One serving node: a torus rank owning a model replica."""

    rank: int
    lm: PagedLM
    engine: Engine

    @property
    def load(self) -> int:
        return self.engine.load


class ServingCluster:
    """N model replicas on one torus fabric behind a least-loaded router.

    ``node_ranks`` selects which torus ranks carry a serving node (default:
    all of them) — a fabric larger than the serving set leaves the spare
    ranks as pure routers, exactly like compute-less switch hops.
    """

    def __init__(self, cfg: ArchCfg, params, *, torus: Torus,
                 node_ranks: Sequence[int] | None = None,
                 max_batch: int = 4, max_seq: int = 64,
                 page_tokens: int = 16, pool_pages: int | None = None,
                 chunked_prefill: bool = False,
                 tp_axes: tuple[str, ...] | None = (),
                 net=None, sim_kw: dict | None = None,
                 qos: fabric.QosPolicy | str | None = "auto",
                 fidelity: str = "packet",
                 modelled: bool = False,
                 n_params: int | float | None = None,
                 descriptor_bytes: float | None = None,
                 restripe_s: float | None = None,
                 slo: SloPolicy | None = None,
                 telemetry: "object | None" = None) -> None:
        self.cfg = cfg
        self.torus = torus
        # ``modelled=True`` builds accounting-only replicas (no K/V
        # tensors, no jit) — the trace-replay mode; ``n_params`` must
        # then be given explicitly (there are no real params to count)
        # so the analytic compute model prices decode windows.
        if modelled and not n_params:
            raise ValueError("modelled=True needs an explicit n_params "
                             "(no real params to size the compute model)")
        # qos="auto" (default) consults the fabric autotuner's pinned
        # ``best_configs.json`` ("serving" entry): a searched multi-class
        # policy when one is pinned, the legacy single-FIFO link when not.
        # Passing an explicit QosPolicy or None always wins.
        self._tuned = fabric.autotune.tuned_config("serving")
        if qos == "auto":
            qos = self._tuned.qos() if self._tuned is not None else None
        ranks = tuple(node_ranks) if node_ranks is not None \
            else tuple(torus.all_ranks())
        if len(set(ranks)) != len(ranks):
            raise ValueError(f"repeated node ranks {ranks}")
        # ONE event-driven timeline for the whole cluster: every node's
        # RDMA endpoint and decode-step TP collectives inject flows here,
        # so a migration PUT and live decode traffic genuinely contend for
        # the links they share (fabric.sim.FabricSim); one NetModel prices
        # every node's wire identically.  ``qos`` selects the link
        # arbiter: a multi-class QosPolicy gives decode-step TP flows
        # (DECODE) weighted protection from migration PUTs (BULK); the
        # default keeps the classic single-FIFO link.  ``fidelity``
        # selects the simulator tier (``fabric.make_sim``): "packet" is
        # the bitwise oracle, "fluid"/"hybrid" keep a big cluster's
        # shared timeline affordable (flow-level rate allocation; probes
        # stay cheap, so congestion-aware routing scales).
        self.net = net or NetModel()
        sim_kw = dict(sim_kw or {})
        if qos is not None:
            sim_kw.setdefault("qos", qos)
        # ONE optional Telemetry hub for the whole cluster: the shared
        # sim reports per-link counters/flow spans into it, every node's
        # RDMA endpoint reports PUT spans, and the cluster itself stamps
        # admission/shed/migration/fault-epoch events.  None (default)
        # is bitwise-invisible end to end.
        self.telemetry = telemetry
        if telemetry is not None:
            sim_kw.setdefault("telemetry", telemetry)
        self.sim = fabric.make_sim(torus, self.net, fidelity=fidelity,
                                   **sim_kw)
        self.nodes: dict[int, ClusterNode] = {}
        for r in ranks:
            lm = PagedLM(cfg, params, max_batch=max_batch, max_seq=max_seq,
                         page_tokens=page_tokens, pool_pages=pool_pages,
                         torus=torus, tp_axes=tp_axes, rank=r,
                         sim=self.sim, net=self.net, modelled=modelled,
                         descriptor_bytes=descriptor_bytes)
            if telemetry is not None:
                lm.endpoint.telemetry = telemetry
            self.nodes[r] = ClusterNode(
                r, lm, Engine(lm, chunked_prefill=chunked_prefill))
        self.page_tokens = page_tokens
        self.page_nbytes = (page_tokens
                            * self.nodes[ranks[0]].lm.bytes_per_token)
        if n_params is None:
            n_params = sum(int(np.prod(x.shape))
                           for x in jax.tree.leaves(params))
        self.n_params = int(n_params)
        self.modelled = modelled
        # mid-flight re-striping checkpoint for migration PUTs: after
        # ``restripe_s`` of wire time the remaining pages are re-split
        # across freshly probed routes (``RdmaEndpoint.put_pages``).
        # None (default) keeps every PUT on its launch-time routes.
        self.restripe_s = restripe_s
        self.slo = slo
        self.admission_queue: collections.deque[Request] = \
            collections.deque()
        self.shed: list[Request] = []
        self.faults = fabric.FaultMap()
        self.migrations: list[MigrationReport] = []
        self._window_start = 0.0
        self._window_open = False

    # -- fault feed (LO|FA|MO master view) --------------------------------------
    def fail_link(self, a: int, b: int) -> None:
        """Mark the first-neighbour link (a, b) dead; later migrations
        reroute around it (the fault machinery's BFS detour), and every
        node's decode TP twin is re-lowered through ``fabric.rewrite`` so
        the per-step TP flows price the shrunk/detoured rings honestly —
        not just via the sim's route resolution."""
        self.faults = fabric.FaultMap.normalized(
            self.faults.dead_nodes,
            set(self.faults.dead_links) | {(a, b)})
        self.sim.faults = self.faults   # sim flows detour the same map
        # route/BFS memo entries are keyed by fault epoch, so stale hits
        # are impossible — dropping the dead epoch's entries just keeps
        # the cache from accumulating one generation per fault event
        fabric.clear_route_cache()
        for node in self.nodes.values():
            node.lm.relower_tp(self.faults)
        if self.telemetry is not None:
            # exactly one fault-epoch stamp per fail_link call — the
            # sims themselves have no fault mutators, this is THE site
            self.telemetry.add("fabric.fault_epochs")
            self.telemetry.event(("cluster",), "fail_link",
                                 float(self.sim.now), a=a, b=b)

    def clear_faults(self) -> None:
        self.faults = fabric.FaultMap()
        self.sim.faults = self.faults
        fabric.clear_route_cache()
        for node in self.nodes.values():
            node.lm.relower_tp(self.faults)
        if self.telemetry is not None:
            self.telemetry.add("fabric.fault_epochs")
            self.telemetry.event(("cluster",), "clear_faults",
                                 float(self.sim.now))

    # -- router -----------------------------------------------------------------
    @property
    def t_token_s(self) -> float:
        """Analytic decode cost of one token on one replica (2 FLOPs per
        param per token at the paper-era effective rate)."""
        return 2.0 * self.n_params / GPU_EFF_FLOPS

    def _pages_needed(self, req: Request) -> int:
        return -(-(len(req.prompt) + req.max_new_tokens)
                 // self.page_tokens)

    def _can_host(self, node: ClusterNode, req: Request) -> bool:
        """Capacity check for SLO admission: a free slot AND enough free
        KV pages once the node's already-queued requests (which WILL
        claim theirs first) and the policy's headroom are netted out.
        ``Engine.load`` alone can't see pool pressure — two nodes with
        equal load can differ by a whole pool of committed pages."""
        eng = node.engine
        occupied = (len(eng.running) + len(eng.prefilling)
                    + len(eng.pending))
        if occupied >= node.lm.max_batch:
            return False
        reserved = sum(self._pages_needed(r) for r in eng.pending)
        free = (len(node.lm.allocator.free) - reserved
                - self.slo.min_free_pages)
        return free >= self._pages_needed(req)

    def submit(self, req: Request, *,
               prefer: int | None = None) -> int | None:
        """Route one request.

        Legacy mode (no ``slo``): admit to the least-loaded node (stable
        tie-break: lowest rank) unconditionally; returns the chosen rank.

        SLO mode: ``prefer`` (a session's home node — its modelled prefix
        cache holds ``req.warm_tokens``) wins when it has capacity;
        otherwise least-loaded among nodes that pass ``_can_host``.  A
        request routed away from its home node re-prefills cold
        (``warm_tokens`` is zeroed).  With no capacity anywhere the
        request queues — or is shed when the queue is past
        ``queue_limit`` — and ``None`` is returned.
        """
        if req.arrival_s is None:
            req.arrival_s = self.sim.now
        if self.slo is None:
            node = min(self.nodes.values(), key=lambda n: (n.load, n.rank))
            node.engine.submit(req)
            return node.rank
        node = None
        if prefer is not None and prefer in self.nodes \
                and self._can_host(self.nodes[prefer], req):
            node = self.nodes[prefer]
        else:
            fits = [n for n in self.nodes.values()
                    if self._can_host(n, req)]
            if fits:
                node = min(fits, key=lambda n: (n.load, n.rank))
        if node is None:
            if len(self.admission_queue) >= self.slo.queue_limit:
                req.shed_s = self.sim.now
                self.shed.append(req)
                if self.telemetry is not None:
                    self.telemetry.add("cluster.sheds")
            else:
                self.admission_queue.append(req)
            return None
        if prefer is not None and node.rank != prefer:
            req.warm_tokens = 0   # prefix cache is home-node-local
        node.engine.submit(req)
        req.admit_s = self.sim.now
        if self.telemetry is not None:
            self.telemetry.add("cluster.admitted")
            self.telemetry.add("cluster.queue_wait_s",
                               req.admit_s - (req.arrival_s or 0.0))
        return node.rank

    def _drain_admission(self) -> int:
        """Re-try the queued requests against current capacity (called at
        each window boundary): place what now fits, shed what has waited
        past ``max_queue_wait_s``.  FIFO with head-of-line skip — a short
        request behind a long one may be placed first; the wait cap
        bounds the starvation that trade accepts."""
        if self.slo is None or not self.admission_queue:
            return 0
        now = self.sim.now
        placed = 0
        keep: collections.deque[Request] = collections.deque()
        while self.admission_queue:
            req = self.admission_queue.popleft()
            if now - (req.arrival_s or 0.0) > self.slo.max_queue_wait_s:
                req.shed_s = now
                self.shed.append(req)
                if self.telemetry is not None:
                    self.telemetry.add("cluster.sheds")
                continue
            fits = [n for n in self.nodes.values()
                    if self._can_host(n, req)]
            if fits:
                node = min(fits, key=lambda n: (n.load, n.rank))
                req.warm_tokens = 0   # queue wait forfeits the warm prefix
                node.engine.submit(req)
                req.admit_s = now
                placed += 1
                if self.telemetry is not None:
                    self.telemetry.add("cluster.admitted")
                    self.telemetry.add("cluster.queue_wait_s",
                                       now - (req.arrival_s or 0.0))
            else:
                keep.append(req)
        self.admission_queue = keep
        return placed

    def step(self) -> None:
        """One engine step on every node — one *logical window* of the
        shared fabric timeline.  All nodes' decode TP flows enter at the
        window start; the window stays open until the next step (or
        stats), so a ``migrate()`` issued between steps lands in the same
        window and contends with the decode traffic already in flight."""
        self._close_window()
        self._drain_admission()
        self._window_start = self.sim.now
        self._window_open = True
        for node in self.nodes.values():
            node.engine.step()

    def _close_window(self) -> None:
        """Settle the open window: resolve every node's injected flows,
        advance the shared clock past both the contention-priced comm and
        the modelled decode compute of the busiest node, and stamp the
        per-request SLO times (first token / finish) with each node's own
        window end — a hot node's tokens genuinely land later than a cold
        node's in the same window, which is exactly the tail the SLO
        metrics must see."""
        if not self._window_open:
            return
        self._window_open = False
        ws = self._window_start
        t_tok = self.t_token_s
        end = ws
        for node in self.nodes.values():
            eng = node.engine
            comm_end = eng.settle_comm(ws)
            # per-node compute: every decoded token, plus (modelled lms
            # only) the cold prefill tokens admitted this window — the
            # real prefill path measures its own wall time instead
            tokens = (eng.window_decode_tokens
                      + eng.window_cold_prefill_tokens)
            node_end = max(comm_end, ws + t_tok * tokens)
            end = max(end, node_end)
            for req in eng.window_first:
                if req.first_token_s is None:
                    req.first_token_s = node_end
            for req in eng.window_finished:
                req.finish_s = node_end
            eng.window_first = []
            eng.window_finished = []
        self.sim.advance(end)
        # the window's finishes are all accounted for: drop the settled
        # flows so the long-lived timeline (and every route probe's copy
        # of it) stays O(in-flight), not O(uptime)
        self.sim.prune()

    def settle(self) -> None:
        """Close the open window (if any) — public seam for drivers
        (trace replay) that interleave their own work between steps and
        must settle the last window without another engine step."""
        self._close_window()

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        """Step until nothing is in flight.  Raises ``TruncatedRunError``
        when ``max_steps`` windows pass with requests still in flight —
        the silently-truncated alternative corrupts exactly the p99 tail
        a long replay exists to measure."""
        steps = 0
        while self.in_flight and steps < max_steps:
            self.step()
            steps += 1
        self._close_window()
        if self.in_flight:
            raise TruncatedRunError(steps, self.in_flight)

    @property
    def in_flight(self) -> int:
        return (sum(n.load for n in self.nodes.values())
                + len(self.admission_queue))

    @property
    def finished(self) -> list[Request]:
        out: list[Request] = []
        for node in self.nodes.values():
            out.extend(node.engine.finished)
        return sorted(out, key=lambda r: r.rid)

    # -- live migration ---------------------------------------------------------
    def _find_running(self, rid: int) -> tuple[ClusterNode, Request]:
        for node in self.nodes.values():
            for req in node.engine.running.values():
                if req.rid == rid:
                    return node, req
        raise KeyError(f"request {rid} is not running on any node "
                       "(pending/prefilling/finished requests don't migrate)")

    def migrate(self, rid: int, dst_rank: int, *,
                route_policy: str | None = None,
                stripe_k: int | None = None) -> MigrationReport:
        """Live-migrate a running request's KV pages to ``dst_rank``.

        Decode resumes on the destination with bitwise-identical tokens;
        raises ``UnroutableError`` when the fault map separates the nodes,
        and leaves the request untouched on the source when the
        destination has no free slot/pages.

        ``route_policy="congestion"`` (default) probes every candidate
        route (the fault BFS machinery's loop-free detour family) against
        the live traffic on the shared timeline and takes the one with the
        least *simulated completion time* — on a quiet fabric that is the
        minimal dimension-ordered path, but when decode collectives are
        hammering the direct links a longer detour can genuinely win.
        ``route_policy="hops"`` keeps the classic hop-count-minimal route.
        ``route_policy="striped"`` splits the PUT across the ``stripe_k``
        best-probed candidate routes at once (``fabric.striped_routes``),
        each stripe carrying a probed-goodput-proportional page share —
        multi-path bandwidth aggregation, priced with the receiver's
        reorder/settle model (``RdmaEndpoint.put_pages(stripes=...)``).
        The PUT rides the BULK traffic class: on a QoS fabric it cannot
        starve the decode-step collectives it contends with.

        Both knobs default to ``None`` — resolved from the autotuner's
        pinned ``best_configs.json`` ("serving" entry) when one exists,
        falling back to the hand-tuned ``"congestion"`` / ``stripe_k=3``
        otherwise.  Explicit values always win.
        """
        if route_policy is None:
            route_policy = (self._tuned.route_policy
                            if self._tuned is not None else "congestion")
        if stripe_k is None:
            stripe_k = (self._tuned.stripe_k
                        if self._tuned is not None else 3)
        src_node, req = self._find_running(rid)
        if dst_rank not in self.nodes:
            raise KeyError(f"no serving node at rank {dst_rank}")
        if dst_rank == src_node.rank:
            raise ValueError(f"request {rid} already lives on {dst_rank}")
        dst_node = self.nodes[dst_rank]
        old_slot = req.slot
        assert old_slot is not None
        state = src_node.lm.export_slot(old_slot)
        # route first: an unroutable fabric must fail before any state
        # moves (the request keeps decoding on the source)
        stripes = None
        if route_policy == "congestion":
            route, _ = fabric.best_route(
                self.sim, src_node.rank, dst_rank, state.nbytes,
                faults=self.faults)
            sched = fabric.lower_route(self.torus, route, faults=self.faults)
        elif route_policy == "hops":
            sched = fabric.lower_p2p(self.torus, src_node.rank, dst_rank,
                                     faults=self.faults)
        elif route_policy == "striped":
            plan = fabric.striped_routes(
                self.sim, src_node.rank, dst_rank, state.nbytes,
                k=stripe_k, faults=self.faults)
            stripes = self._stripe_pages(plan, state.n_pages)
            sched = max((s for s, _ in stripes), key=lambda s: s.max_hops)
        else:
            raise ValueError(f"unknown route_policy {route_policy!r}")
        new_slot = dst_node.lm.import_slot(state)
        # only the live pages ride the wire (headroom is claimed fresh on
        # the destination) — the same byte count the bench gate prices
        modelled = src_node.lm.endpoint.put_pages(
            dst_rank, src_node.lm.allocator.region,
            src_node.lm.live_pages(old_slot),
            page_nbytes=self.page_nbytes,
            dst_endpoint=dst_node.lm.endpoint,
            dst_region=dst_node.lm.allocator.region,
            dst_pages=dst_node.lm.slot_pages[new_slot][:state.n_pages],
            schedule=None if stripes is not None else sched,
            stripes=stripes, restripe_s=self.restripe_s,
            faults=self.faults)
        src_node.engine.detach(old_slot)
        src_node.lm.free_slot(old_slot)
        req.slot = new_slot
        dst_node.engine.attach(req)
        put = src_node.lm.endpoint.last_put_report or {}
        report = MigrationReport(
            rid=rid, src=src_node.rank, dst=dst_rank,
            n_pages=state.n_pages, nbytes=state.nbytes,
            hops=sched.max_hops,
            min_hops=self.torus.hop_distance(src_node.rank, dst_rank),
            modelled_s=modelled,
            reprefill_s=reprefill_stall_s(self.n_params, req.pos),
            isolated_s=put.get("isolated_s", modelled),
            route_policy=route_policy,
            stripes=put.get("stripes", 1))
        self.migrations.append(report)
        if self.telemetry is not None:
            self.telemetry.add("cluster.migrations")
            self.telemetry.add("cluster.migrated_bytes",
                               float(report.nbytes))
            self.telemetry.event(
                ("cluster",), "migrate", float(self.sim.now),
                rid=rid, src=report.src, dst=report.dst,
                n_pages=report.n_pages, stripes=report.stripes)
        return report

    def _stripe_pages(self, plan, n_pages: int) -> list[tuple]:
        """Turn a ``fabric.striped_routes`` plan into put_pages stripes:
        page-granular byte shares (``fabric.stripe_counts``, zero-page
        stripes dropped — a stripe must carry at least one page)."""
        counts = fabric.stripe_counts(plan, n_pages)
        stripes = []
        for (route, _), c in zip(plan, counts):
            if c <= 0:
                continue
            sched = fabric.lower_route(self.torus, route, faults=self.faults)
            stripes.append((sched, c * self.page_nbytes))
        if not stripes:   # zero live pages: one empty leg on the best route
            stripes = [(fabric.lower_route(self.torus, plan[0][0],
                                           faults=self.faults), 0)]
        return stripes

    def rebalance(self, threshold: int = 2) -> MigrationReport | None:
        """Migrate one running request from the most- to the least-loaded
        node when the load gap reaches ``threshold``; returns the report
        (or None when balanced / nothing migratable).

        A full destination is not "balanced": when the idlest node's
        pool/slots reject the move (``RuntimeError``), the next-idlest
        destination is tried, then the next candidate request — the old
        single-shot ``return None`` left a glaring gap standing whenever
        the one preferred destination happened to be page-starved."""
        busiest = max(self.nodes.values(), key=lambda n: (n.load, -n.rank))
        idlest = min(self.nodes.values(), key=lambda n: (n.load, n.rank))
        if busiest.rank == idlest.rank \
                or busiest.load - idlest.load < threshold \
                or not busiest.engine.running:
            return None
        # candidates: most decode work left first — it amortises the wire
        # cost over the largest avoided future imbalance
        cands = sorted(busiest.engine.running.values(),
                       key=lambda r: (-(r.max_new_tokens
                                        - len(r.out_tokens)), r.rid))
        # destinations: idlest first, but only while the move still
        # closes a meaningful gap (moving to a node one short of the
        # source just swaps the hotspot)
        dsts = sorted((n for n in self.nodes.values()
                       if n.rank != busiest.rank
                       and busiest.load - n.load >= threshold),
                      key=lambda n: (n.load, n.rank))
        for req in cands:
            for dst in dsts:
                try:
                    return self.migrate(req.rid, dst.rank)
                except fabric.UnroutableError:
                    raise   # a partitioned fabric is NOT "balanced"
                except RuntimeError:
                    continue   # dst pool/slots full: try the next one
        return None   # nothing migratable fits anywhere: stay put

    def _predicted_window_tokens(self, node: ClusterNode) -> int:
        """Compute tokens ``node``'s next engine step will carry: one per
        active decode, plus each prefilling request's next chunk (the
        whole cold remainder when prefill is monolithic), plus the first
        chunk of whatever admission will pull in from the local queue.
        Chunk-accurate: charging a queued prompt's entire cold prefill to
        one window would make every node with a queue look molten and
        every chunk-prefilling node look idle — exactly backwards."""
        eng = node.engine
        chunk = eng.chunk_tokens if eng.chunked_prefill else None
        toks = sum(1 for r in eng.running.values() if not r.done)
        for r in eng.prefilling.values():
            pos = r.pos if r.pos > 0 \
                else min(max(r.warm_tokens, 0), len(r.prompt))
            rem = max(len(r.prompt) - pos, 0)
            toks += min(chunk, rem) if chunk is not None else rem
        slots_free = (node.lm.max_batch - len(eng.running)
                      - len(eng.prefilling))
        for r in eng.pending[:max(slots_free, 0)]:
            cold = max(len(r.prompt) - max(r.warm_tokens, 0), 0)
            toks += min(chunk, cold) if chunk is not None else cold
        return toks

    def _predicted_token_latency(self, node: ClusterNode) -> float:
        """Predicted per-token decode latency of ``node``'s next window:
        analytic compute for the window's tokens vs the node's
        quiet-fabric TP comm floor — the pre-breach signal the proactive
        rebalancer acts on."""
        return max(self.t_token_s * self._predicted_window_tokens(node),
                   node.lm.predicted_tp_comm_s)

    def rebalance_proactive(self, max_moves: int | None = None
                            ) -> list[MigrationReport]:
        """SLO-defending rebalance: striped-migrate running requests off
        any node whose *predicted* next-window per-token latency exceeds
        ``token_target_s * headroom`` — before the p99 breach, not after.

        Unlike ``rebalance`` this is not load-count arithmetic: the
        trigger is the latency prediction, the destination must keep
        enough predicted headroom to absorb the request, and among the
        ``probe_dsts`` least-loaded-by-prediction destinations the one
        with the least *probed* PUT completion time on the live fabric
        wins (``fabric.best_route`` against current traffic, BULK class)
        — a destination behind a congested link is passed over even when
        its compute is idle.  Moves are capped at ``max_moves_per_window``
        and each PUT stripes across multi-path routes; a move whose
        probed wire time exceeds ``max_migration_s`` (when set) is
        skipped — it could not complete ahead of the breach it is meant
        to prevent.
        """
        if self.slo is None:
            raise ValueError("rebalance_proactive needs an SloPolicy "
                             "(ServingCluster(slo=...))")
        slo = self.slo
        budget = slo.token_target_s * slo.headroom
        limit = slo.max_moves_per_window if max_moves is None else max_moves
        t_tok = self.t_token_s
        pred = {r: self._predicted_token_latency(n)
                for r, n in self.nodes.items()}
        reports: list[MigrationReport] = []
        hot = sorted((n for n in self.nodes.values()
                      if pred[n.rank] > budget),
                     key=lambda n: (-pred[n.rank], n.rank))
        for node in hot:
            while (len(reports) < limit and pred[node.rank] > budget
                   and node.engine.running):
                cands = sorted(
                    (r for r in node.engine.running.values()
                     if not r.done),
                    key=lambda r: (-(r.max_new_tokens
                                     - len(r.out_tokens)), r.rid))
                moved = None
                for req in cands:
                    nbytes = (-(-max(req.pos, 1) // self.page_tokens)
                              * self.page_nbytes)
                    # destinations that keep predicted headroom after
                    # absorbing one more decode stream, best-predicted
                    # first; the top few are probed on the live fabric
                    dsts = sorted(
                        (d for d in self.nodes.values()
                         if d.rank != node.rank
                         and pred[d.rank] + t_tok <= budget),
                        key=lambda d: (pred[d.rank], d.rank))
                    probed = []
                    for d in dsts[:max(slo.probe_dsts, 1)]:
                        try:
                            _, wire = fabric.best_route(
                                self.sim, node.rank, d.rank, nbytes,
                                faults=self.faults,
                                cls=fabric.TrafficClass.BULK)
                        except fabric.UnroutableError:
                            continue
                        if slo.max_migration_s is not None \
                                and wire > slo.max_migration_s:
                            continue
                        probed.append((wire, d.rank, d))
                    for _, _, d in sorted(probed,
                                          key=lambda x: (x[0], x[1])):
                        try:
                            moved = self.migrate(req.rid, d.rank,
                                                 route_policy="striped")
                            break
                        except fabric.UnroutableError:
                            raise
                        except RuntimeError:
                            continue   # dst filled up since the probe
                    if moved is not None:
                        break
                if moved is None:
                    break   # nothing migratable fits anywhere cooler
                reports.append(moved)
                pred[node.rank] = self._predicted_token_latency(node)
                pred[moved.dst] = self._predicted_token_latency(
                    self.nodes[moved.dst])
        return reports

    def slo_stats(self) -> dict:
        """SLO-layer counters (admission + per-class fabric bytes) — the
        latency percentiles themselves live in ``serving.trace``, which
        owns the request population."""
        cs = self.sim.class_stats()
        return {
            "queued": len(self.admission_queue),
            "shed": len(self.shed),
            "class_bytes": {cls.name: float(v) for cls, v in cs.items()},
            "n_migrations": len(self.migrations),
            "migrated_bytes": sum(m.nbytes for m in self.migrations),
        }

    # -- reporting --------------------------------------------------------------
    def stats(self) -> dict:
        """Cluster-wide report.  A pure read: the open fabric window (if
        any) is left open, so a monitoring poll between ``step()`` and
        ``migrate()`` cannot quietly settle the in-flight decode traffic
        a contention-priced migration is about to contend with —
        ``sim_tp_comm_s`` therefore reflects *settled* windows only
        (``run_to_completion`` closes the last one)."""
        per_node = {r: dict(n.engine.stats(), load=n.load)
                    for r, n in self.nodes.items()}
        return {
            "nodes": per_node,
            "n_migrations": len(self.migrations),
            "migrated_bytes": sum(m.nbytes for m in self.migrations),
            "migration_modelled_s": sum(m.modelled_s
                                        for m in self.migrations),
            "migration_isolated_s": sum(m.isolated_s
                                        for m in self.migrations),
            "reprefill_avoided_s": sum(m.reprefill_s
                                       for m in self.migrations),
            "rerouted_migrations": sum(m.rerouted for m in self.migrations),
            "faults": {"dead_nodes": sorted(self.faults.dead_nodes),
                       "dead_links": sorted(self.faults.dead_links)},
            "fabric_sim_now_s": self.sim.now,
        }


def owners(cluster: ServingCluster,
           rids: Iterable[int]) -> dict[int, int | None]:
    """rid -> rank map over running/prefilling/pending requests (test and
    example helper; finished requests map to None)."""
    out: dict[int, int | None] = {rid: None for rid in rids}
    for node in cluster.nodes.values():
        eng = node.engine
        for req in (*eng.pending, *eng.prefilling.values(),
                    *eng.running.values()):
            if req.rid in out:
                out[req.rid] = node.rank
    return out
