"""Multi-node serving cluster with live RDMA KV-page migration.

The paper's headline capability — zero-copy, low-latency GPU-to-GPU RDMA
across the 3D torus (GPUDirect P2P, arXiv:1307.8276 measures exactly this
path) — is what lets a serving deployment move a *running* request between
nodes without restarting its decode: the request's KV-cache pages are the
whole decode state, and they travel as one bulk dimension-ordered RDMA PUT
(``RdmaEndpoint.put_pages`` over a ``fabric.lower_p2p`` schedule).

Topology: every serving node is a rank of one shared ``Torus`` (the
cluster fabric); ranks without a serving node are pass-through routers.
Each node owns a full model replica (``PagedLM`` + ``Engine``); a router
in front admits each request to the least-loaded node.

Live migration of a slot from node A to node B:

  1. ``A.lm.export_slot``   — snapshot the slot's KV pages (logical order)
                              and sequence length;
  2. ``B.lm.import_slot``   — claim fresh pages on B, land the contents
                              (fails cleanly when B is full: the request
                              stays on A untouched);
  3. ``A.endpoint.put_pages(B, ...)`` — model the wire: TLB translation on
                              both cards, host-interface DMA, and the
                              multi-hop unicast priced by ``fabric.estimate``
                              — rewritten by the fault machinery, so a dead
                              link on the route becomes a BFS detour
                              (``hops`` up, tokens unchanged) and a
                              partitioned fabric raises ``UnroutableError``;
  4. the request detaches from A's batch, frees A's pages, and resumes
     decode on B **bitwise-identically** to the unmigrated run (the page
     contents + seq_len are the complete decode state; positions past
     seq_len are masked on both nodes).

The alternative to migrating ~len(context) * bytes_per_token of KV is
re-prefilling the context on B — a whole-prompt forward that stalls B's
running decode batch.  ``MigrationReport`` carries both modelled numbers;
``benchmarks/migration.py`` gates migration being the cheaper move.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

import jax

from repro.core import fabric
from repro.core.hw import PAPER_GPU_EFF_FLOPS as GPU_EFF_FLOPS
from repro.core.topology import Torus
from repro.models.common import ArchCfg
from repro.serving.engine import Engine, PagedLM, Request


def reprefill_stall_s(n_params: int, context_tokens: int,
                      flops: float = GPU_EFF_FLOPS) -> float:
    """Modelled decode stall of re-prefilling ``context_tokens`` from
    scratch on the destination (2 FLOPs per param per token forward, at
    the paper-era rate of ``hw.PAPER_GPU_EFF_FLOPS``) — the cost
    migration avoids."""
    return 2.0 * n_params * context_tokens / flops


@dataclasses.dataclass(frozen=True)
class MigrationReport:
    """One slot migration: what moved, over what route, at what cost."""

    rid: int
    src: int                     # torus rank of the source node
    dst: int                     # torus rank of the destination node
    n_pages: int
    nbytes: int                  # KV payload on the wire
    hops: int                    # route length actually taken
    min_hops: int                # healthy-fabric dimension-ordered distance
    modelled_s: float            # put_pages: translation + DMA + wire
    reprefill_s: float           # the decode stall migrating avoided

    @property
    def rerouted(self) -> bool:
        return self.hops > self.min_hops

    @property
    def speedup(self) -> float:
        """Avoided stall per second of modelled migration time."""
        return self.reprefill_s / self.modelled_s if self.modelled_s else 0.0


@dataclasses.dataclass
class ClusterNode:
    """One serving node: a torus rank owning a model replica."""

    rank: int
    lm: PagedLM
    engine: Engine

    @property
    def load(self) -> int:
        return self.engine.load


class ServingCluster:
    """N model replicas on one torus fabric behind a least-loaded router.

    ``node_ranks`` selects which torus ranks carry a serving node (default:
    all of them) — a fabric larger than the serving set leaves the spare
    ranks as pure routers, exactly like compute-less switch hops.
    """

    def __init__(self, cfg: ArchCfg, params, *, torus: Torus,
                 node_ranks: Sequence[int] | None = None,
                 max_batch: int = 4, max_seq: int = 64,
                 page_tokens: int = 16, pool_pages: int | None = None,
                 chunked_prefill: bool = False) -> None:
        self.cfg = cfg
        self.torus = torus
        ranks = tuple(node_ranks) if node_ranks is not None \
            else tuple(torus.all_ranks())
        if len(set(ranks)) != len(ranks):
            raise ValueError(f"repeated node ranks {ranks}")
        self.nodes: dict[int, ClusterNode] = {}
        for r in ranks:
            lm = PagedLM(cfg, params, max_batch=max_batch, max_seq=max_seq,
                         page_tokens=page_tokens, pool_pages=pool_pages,
                         torus=torus, tp_axes=(), rank=r)
            self.nodes[r] = ClusterNode(
                r, lm, Engine(lm, chunked_prefill=chunked_prefill))
        self.page_nbytes = (page_tokens
                            * self.nodes[ranks[0]].lm.bytes_per_token)
        self.n_params = sum(int(np.prod(x.shape))
                            for x in jax.tree.leaves(params))
        self.faults = fabric.FaultMap()
        self.migrations: list[MigrationReport] = []

    # -- fault feed (LO|FA|MO master view) --------------------------------------
    def fail_link(self, a: int, b: int) -> None:
        """Mark the first-neighbour link (a, b) dead; later migrations
        reroute around it (the fault machinery's BFS detour)."""
        self.faults = fabric.FaultMap.normalized(
            self.faults.dead_nodes,
            set(self.faults.dead_links) | {(a, b)})

    def clear_faults(self) -> None:
        self.faults = fabric.FaultMap()

    # -- router -----------------------------------------------------------------
    def submit(self, req: Request) -> int:
        """Admit to the least-loaded node (stable tie-break: lowest rank);
        returns the chosen rank."""
        node = min(self.nodes.values(), key=lambda n: (n.load, n.rank))
        node.engine.submit(req)
        return node.rank

    def step(self) -> None:
        for node in self.nodes.values():
            node.engine.step()

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        steps = 0
        while self.in_flight and steps < max_steps:
            self.step()
            steps += 1

    @property
    def in_flight(self) -> int:
        return sum(n.load for n in self.nodes.values())

    @property
    def finished(self) -> list[Request]:
        out: list[Request] = []
        for node in self.nodes.values():
            out.extend(node.engine.finished)
        return sorted(out, key=lambda r: r.rid)

    # -- live migration ---------------------------------------------------------
    def _find_running(self, rid: int) -> tuple[ClusterNode, Request]:
        for node in self.nodes.values():
            for req in node.engine.running.values():
                if req.rid == rid:
                    return node, req
        raise KeyError(f"request {rid} is not running on any node "
                       "(pending/prefilling/finished requests don't migrate)")

    def migrate(self, rid: int, dst_rank: int) -> MigrationReport:
        """Live-migrate a running request's KV pages to ``dst_rank``.

        Decode resumes on the destination with bitwise-identical tokens;
        raises ``UnroutableError`` when the fault map separates the nodes,
        and leaves the request untouched on the source when the
        destination has no free slot/pages.
        """
        src_node, req = self._find_running(rid)
        if dst_rank not in self.nodes:
            raise KeyError(f"no serving node at rank {dst_rank}")
        if dst_rank == src_node.rank:
            raise ValueError(f"request {rid} already lives on {dst_rank}")
        dst_node = self.nodes[dst_rank]
        old_slot = req.slot
        assert old_slot is not None
        state = src_node.lm.export_slot(old_slot)
        # route first: an unroutable fabric must fail before any state
        # moves (the request keeps decoding on the source)
        sched = fabric.lower_p2p(self.torus, src_node.rank, dst_rank,
                                 faults=self.faults)
        new_slot = dst_node.lm.import_slot(state)
        # only the live pages ride the wire (headroom is claimed fresh on
        # the destination) — the same byte count the bench gate prices
        modelled = src_node.lm.endpoint.put_pages(
            dst_rank, src_node.lm.allocator.region,
            src_node.lm.live_pages(old_slot),
            page_nbytes=self.page_nbytes,
            dst_endpoint=dst_node.lm.endpoint,
            dst_region=dst_node.lm.allocator.region,
            dst_pages=dst_node.lm.slot_pages[new_slot][:state.n_pages],
            schedule=sched)
        src_node.engine.detach(old_slot)
        src_node.lm.free_slot(old_slot)
        req.slot = new_slot
        dst_node.engine.attach(req)
        report = MigrationReport(
            rid=rid, src=src_node.rank, dst=dst_rank,
            n_pages=state.n_pages, nbytes=state.nbytes,
            hops=sched.max_hops,
            min_hops=self.torus.hop_distance(src_node.rank, dst_rank),
            modelled_s=modelled,
            reprefill_s=reprefill_stall_s(self.n_params, req.pos))
        self.migrations.append(report)
        return report

    def rebalance(self, threshold: int = 2) -> MigrationReport | None:
        """Migrate one running request from the most- to the least-loaded
        node when the load gap reaches ``threshold``; returns the report
        (or None when balanced / nothing migratable)."""
        busiest = max(self.nodes.values(), key=lambda n: (n.load, -n.rank))
        idlest = min(self.nodes.values(), key=lambda n: (n.load, n.rank))
        if busiest.rank == idlest.rank \
                or busiest.load - idlest.load < threshold \
                or not busiest.engine.running:
            return None
        # move the request with the most decode work left — it amortises
        # the wire cost over the largest avoided future imbalance
        req = max(busiest.engine.running.values(),
                  key=lambda r: r.max_new_tokens - len(r.out_tokens))
        try:
            return self.migrate(req.rid, idlest.rank)
        except fabric.UnroutableError:
            raise   # a partitioned fabric is NOT "balanced" — surface it
        except RuntimeError:
            return None   # destination pool/slots full: stay put

    # -- reporting --------------------------------------------------------------
    def stats(self) -> dict:
        per_node = {r: dict(n.engine.stats(), load=n.load)
                    for r, n in self.nodes.items()}
        return {
            "nodes": per_node,
            "n_migrations": len(self.migrations),
            "migrated_bytes": sum(m.nbytes for m in self.migrations),
            "migration_modelled_s": sum(m.modelled_s
                                        for m in self.migrations),
            "reprefill_avoided_s": sum(m.reprefill_s
                                       for m in self.migrations),
            "rerouted_migrations": sum(m.rerouted for m in self.migrations),
            "faults": {"dead_nodes": sorted(self.faults.dead_nodes),
                       "dead_links": sorted(self.faults.dead_links)},
        }


def owners(cluster: ServingCluster,
           rids: Iterable[int]) -> dict[int, int | None]:
    """rid -> rank map over running/prefilling/pending requests (test and
    example helper; finished requests map to None)."""
    out: dict[int, int | None] = {rid: None for rid in rids}
    for node in cluster.nodes.values():
        eng = node.engine
        for req in (*eng.pending, *eng.prefilling.values(),
                    *eng.running.values()):
            if req.rid in out:
                out[req.rid] = node.rank
    return out
