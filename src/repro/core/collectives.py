"""Torus collectives — the APEnet+ fabric expressed in shard_map + ppermute.

APEnet+ moves data exclusively over first-neighbour torus links with
dimension-ordered routing (§1), and hides latency by keeping *two* DMA
engines per link in flight (§2.1, Fig 1: ~40% total-time reduction).  On a
TPU pod the ICI fabric has the same shape, and ``lax.ppermute`` *is* the
neighbour RDMA-put.  This module implements the collective layer a trainer
needs on such a fabric:

  * ``ring_reduce_scatter`` / ``ring_all_gather`` / ``ring_all_reduce`` —
    k-ary ring algorithms along one named mesh axis, built purely from
    neighbour ppermutes;

  * **bidirectional** variants (default) — each step ships two half-chunks
    in opposite directions over the full-duplex links; this is the "dual DMA
    engine" idea: 2x link utilisation, ~2x fewer bytes per direction;

  * multi-axis, **dimension-ordered** wrappers — reduce-scatter along X,
    then Y, then Z, and all-gather back in reverse order: the collective
    analogue of APEnet+'s X->Y->Z router policy;

  * ``ring_all_to_all`` — store-and-forward ring all-to-all (MoE dispatch
    on the torus) plus a direct XLA ``lax.all_to_all`` fast path;

  * ``halo_exchange`` — the one-sided neighbour put used by stencil demos
    and the LO|FA|MO status exchange.

All functions here are *per-shard* code: they must run inside ``shard_map``
(or any context where ``axis_name`` is bound).  ``make_*`` helpers wrap them
into jitted host-level callables for tests and demos.

Numerics note: ring reductions accumulate in fp32 when inputs are lower
precision (bf16/fp16), matching production all-reduce behaviour.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ----------------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------------

def _ring_perms(axis_size: int, step: int) -> list[tuple[int, int]]:
    """ppermute perm for a one-hop shift (+1 = "clockwise") along a ring."""
    return [(i, (i + step) % axis_size) for i in range(axis_size)]


def _acc_dtype(dtype: jnp.dtype) -> jnp.dtype:
    if jnp.issubdtype(dtype, jnp.floating) and jnp.finfo(dtype).bits < 32:
        return jnp.float32
    return dtype


def _flatten_pad(x: jax.Array, n: int) -> tuple[jax.Array, int]:
    """Flatten to 1D and zero-pad so the length divides ``n``."""
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, flat.size // n


# ----------------------------------------------------------------------------
# single-axis ring primitives (per-shard code)
# ----------------------------------------------------------------------------

def ring_reduce_scatter(x: jax.Array, axis_name: str, *,
                        bidirectional: bool = True,
                        mean: bool = False) -> jax.Array:
    """Reduce-scatter along a mesh-axis ring; rank r returns chunk r.

    Input: the full local array (same logical value on every rank is NOT
    required — this reduces across ranks elementwise, like psum, then
    scatters).  Output: flat fp32-accumulated chunk of size ceil(|x|/N)
    (zero-padded); see ``ring_all_reduce`` for the unpadded composite.
    """
    n = lax.axis_size(axis_name)
    r = lax.axis_index(axis_name)
    flat, chunk = _flatten_pad(x, n)
    acc = flat.reshape(n, chunk).astype(_acc_dtype(x.dtype))

    if n == 1:
        return acc[0]

    if not bidirectional:
        return _rs_oneway(acc, axis_name, n, r, step=+1, mean=mean)

    # Dual-DMA: front half of every chunk rides the +1 ring, back half the
    # -1 ring, concurrently.  Each direction moves chunk/2 per step.
    half = chunk // 2
    fwd = _rs_oneway(acc[:, :half], axis_name, n, r, step=+1, mean=mean)
    bwd = _rs_oneway(acc[:, half:], axis_name, n, r, step=-1, mean=mean)
    return jnp.concatenate([fwd, bwd], axis=0)


def _rs_oneway(acc: jax.Array, axis_name: str, n: int, r: jax.Array, *,
               step: int, mean: bool) -> jax.Array:
    """One directed ring reduce-scatter over ``acc`` of shape (n, chunk).

    After n-1 neighbour hops, rank r holds the fully-reduced chunk r.
    Chunk schedule (direction +1): at loop step s, rank r sends the partial
    for chunk (r - s - 1) mod n and receives/accumulates chunk
    (r - s - 2) mod n; the final accumulated index is r itself.
    """
    perm = _ring_perms(n, step)

    def body(s, acc):
        # Walk chunk indices against the send direction.
        send_idx = (r - step * (s + 1)) % n
        recv_idx = (r - step * (s + 2)) % n
        sent = lax.dynamic_index_in_dim(acc, send_idx, axis=0, keepdims=False)
        got = lax.ppermute(sent, axis_name, perm)
        cur = lax.dynamic_index_in_dim(acc, recv_idx, axis=0, keepdims=False)
        return lax.dynamic_update_index_in_dim(acc, cur + got, recv_idx, axis=0)

    acc = lax.fori_loop(0, n - 1, body, acc)
    out = lax.dynamic_index_in_dim(acc, r, axis=0, keepdims=False)
    return out / n if mean else out


def ring_all_gather(x: jax.Array, axis_name: str, *,
                    bidirectional: bool = True) -> jax.Array:
    """All-gather chunks along a ring: rank r contributes x, returns the
    concatenation ordered by rank, shape (n, *x.shape)."""
    n = lax.axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if n == 1:
        return x[None]

    if bidirectional:
        flat = x.reshape(-1)
        half = flat.size // 2
        fwd = _ag_oneway(flat[:half], axis_name, n, r, step=+1)
        bwd = _ag_oneway(flat[half:], axis_name, n, r, step=-1)
        return jnp.concatenate([fwd, bwd], axis=-1).reshape((n,) + x.shape)
    return _ag_oneway(x.reshape(-1), axis_name, n, r,
                      step=+1).reshape((n,) + x.shape)


def _ag_oneway(x: jax.Array, axis_name: str, n: int, r: jax.Array, *,
               step: int) -> jax.Array:
    """Directed ring all-gather of 1D ``x``; returns (n, |x|) rank-ordered."""
    perm = _ring_perms(n, step)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_index_in_dim(out, x, r, axis=0)

    def body(s, carry):
        out, cur = carry
        cur = lax.ppermute(cur, axis_name, perm)
        src = (r - step * (s + 1)) % n
        out = lax.dynamic_update_index_in_dim(out, cur, src, axis=0)
        return out, cur

    out, _ = lax.fori_loop(0, n - 1, body, (out, x))
    return out


def ring_all_reduce(x: jax.Array, axis_name: str, *,
                    bidirectional: bool = True,
                    mean: bool = False) -> jax.Array:
    """Ring all-reduce = reduce-scatter + all-gather (the classic 2(N-1)/N
    bytes-optimal schedule), preserving ``x``'s shape/dtype."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    chunk = ring_reduce_scatter(x, axis_name, bidirectional=bidirectional,
                                mean=mean)
    full = ring_all_gather(chunk, axis_name, bidirectional=bidirectional)
    return full.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)


# ----------------------------------------------------------------------------
# multi-axis, dimension-ordered composites (APEnet+ X->Y->Z routing)
# ----------------------------------------------------------------------------

def dim_ordered_all_reduce(x: jax.Array, axis_names: Sequence[str], *,
                           bidirectional: bool = True,
                           mean: bool = False) -> jax.Array:
    """All-reduce over several mesh axes: reduce-scatter X,Y,...,Z then
    all-gather Z,...,Y,X.  Each phase only ever talks to first neighbours
    along one torus dimension — the collective analogue of dimension-ordered
    routing, and bytes-optimal on a torus (each axis moves 2(Ni-1)/Ni of the
    data it still owns)."""
    if len(axis_names) == 1:
        return ring_all_reduce(x, axis_names[0], bidirectional=bidirectional,
                               mean=mean)
    # RS phase, X -> Z; each axis reduces and keeps 1/Ni of the working set.
    # Padding introduced at each stage is recorded so the AG phase (Z -> X)
    # can strip it as it reassembles — otherwise pad zeros would interleave
    # with payload in the final concatenation.
    work = x.reshape(-1)
    stage_sizes: list[int] = []
    for ax in axis_names:
        stage_sizes.append(work.size)
        work = ring_reduce_scatter(work, ax, bidirectional=bidirectional,
                                   mean=mean)
    for ax, size in zip(reversed(axis_names), reversed(stage_sizes)):
        work = ring_all_gather(work, ax, bidirectional=bidirectional)
        work = work.reshape(-1)[:size]
    return work.reshape(x.shape).astype(x.dtype)


def dim_ordered_reduce_scatter(x: jax.Array, axis_names: Sequence[str], *,
                               bidirectional: bool = True,
                               mean: bool = False) -> tuple[jax.Array, list[int]]:
    """Multi-axis RS; also returns per-stage pre-pad sizes for the inverse
    ``dim_ordered_all_gather`` (ZeRO-1 shard/unshard round trip)."""
    work = x.reshape(-1)
    stage_sizes: list[int] = []
    for ax in axis_names:
        stage_sizes.append(work.size)
        work = ring_reduce_scatter(work, ax, bidirectional=bidirectional,
                                   mean=mean)
    return work, stage_sizes


def dim_ordered_all_gather(x: jax.Array, axis_names: Sequence[str],
                           stage_sizes: Sequence[int], *,
                           bidirectional: bool = True) -> jax.Array:
    """Inverse of ``dim_ordered_reduce_scatter`` given its stage sizes."""
    work = x
    for ax, size in zip(reversed(tuple(axis_names)), reversed(tuple(stage_sizes))):
        work = ring_all_gather(work, ax, bidirectional=bidirectional)
        work = work.reshape(-1)[:size]
    return work


# ----------------------------------------------------------------------------
# all-to-all
# ----------------------------------------------------------------------------

def ring_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Store-and-forward ring all-to-all along one torus axis.

    ``x`` has shape (n, ...): row j is this rank's block destined for rank j.
    Returns shape (n, ...): row j is the block received from rank j.  Pure
    first-neighbour traffic: the full buffer circulates n-1 hops and every
    rank picks out its addressed row at each stop — exactly how a torus
    router forwards non-local packets.
    """
    n = lax.axis_size(axis_name)
    r = lax.axis_index(axis_name)
    if x.shape[0] != n:
        raise ValueError(f"leading dim {x.shape[0]} != axis size {n}")
    if n == 1:
        return x
    perm = _ring_perms(n, +1)
    out = jnp.zeros_like(x)
    out = lax.dynamic_update_index_in_dim(
        out, lax.dynamic_index_in_dim(x, r, 0, keepdims=False), r, axis=0)

    def body(s, carry):
        out, buf = carry
        buf = lax.ppermute(buf, axis_name, perm)  # buf originated at r-s-1
        src = (r - s - 1) % n
        mine = lax.dynamic_index_in_dim(buf, r, 0, keepdims=False)
        out = lax.dynamic_update_index_in_dim(out, mine, src, axis=0)
        return out, buf

    out, _ = lax.fori_loop(0, n - 1, body, (out, x))
    return out


def fast_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Direct XLA all-to-all (the compiler schedules it on the torus)."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                          tiled=False)


# ----------------------------------------------------------------------------
# halo exchange / neighbour put
# ----------------------------------------------------------------------------

def halo_exchange(x: jax.Array, axis_name: str, halo: int = 1,
                  dim: int = 0) -> tuple[jax.Array, jax.Array]:
    """Exchange ``halo``-wide boundary slabs with both ring neighbours.

    Returns (from_prev, from_next): the neighbours' facing edges — a pair of
    one-sided RDMA puts in APEnet+ terms.
    """
    n = lax.axis_size(axis_name)
    lo = lax.slice_in_dim(x, 0, halo, axis=dim)
    hi = lax.slice_in_dim(x, x.shape[dim] - halo, x.shape[dim], axis=dim)
    from_prev = lax.ppermute(hi, axis_name, _ring_perms(n, +1))
    from_next = lax.ppermute(lo, axis_name, _ring_perms(n, -1))
    return from_prev, from_next


# ----------------------------------------------------------------------------
# host-level wrappers (tests / demos / the apex DP layer)
# ----------------------------------------------------------------------------

def make_stacked_all_reduce(mesh: Mesh, axis_names: Sequence[str], *,
                            bidirectional: bool = True, mean: bool = False):
    """Host-level all-reduce for tests/demos.

    Takes a global array of shape (n_0, ..., n_k, *payload) whose leading
    dims are sharded over ``axis_names``; every (i, ..., j) slot is one
    rank's contribution.  Returns the same shape where every slot holds the
    (mean-)reduction — so correctness is checkable against ``x.sum(axis=lead)``.
    """
    axes = tuple(axis_names)
    lead = len(axes)

    def per_shard(x):
        y = x.reshape(x.shape[lead:])
        out = dim_ordered_all_reduce(y, axes, bidirectional=bidirectional,
                                     mean=mean)
        return out.reshape(x.shape)

    spec = P(*axes)
    mapped = jax.shard_map(per_shard, mesh=mesh, in_specs=(spec,),
                           out_specs=spec)
    return jax.jit(mapped)


def tree_all_reduce(tree, axis_names: Sequence[str], *,
                    bidirectional: bool = True, mean: bool = True):
    """Per-shard: all-reduce every leaf of a pytree (gradient sync)."""
    return jax.tree.map(
        lambda g: dim_ordered_all_reduce(g, axis_names,
                                         bidirectional=bidirectional,
                                         mean=mean), tree)
