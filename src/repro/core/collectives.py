"""Torus collectives — thin lowering wrappers over ``core.fabric``.

APEnet+ moves data exclusively over first-neighbour torus links with
dimension-ordered routing (§1), and hides latency by keeping *two* DMA
engines per link in flight (§2.1, Fig 1: ~40% total-time reduction).  On a
TPU pod the ICI fabric has the same shape, and ``lax.ppermute`` *is* the
neighbour RDMA-put.

Since the fabric refactor every collective here is *lowered* to an explicit
``fabric.CollectiveSchedule`` (which hop moves which bytes when) and then
executed by ``fabric.execute`` — the same schedule object the cost
estimator prices and the LO|FA|MO fault rewriter detours.  Each function
accepts an optional pre-lowered ``schedule`` (e.g. a fault-rewritten one);
without it the schedule is lowered on the fly against the ring implied by
the bound mesh axis.

The collective set a trainer needs on this fabric:

  * ``ring_reduce_scatter`` / ``ring_all_gather`` / ``ring_all_reduce`` —
    k-ary ring algorithms along one named mesh axis, built purely from
    neighbour ppermutes;
  * **bidirectional** variants (default) — each round ships two half-chunks
    in opposite directions over the full-duplex links, fused into a single
    loop (the "dual DMA engine" idea: 2x link utilisation, half the
    sequential rounds);
  * multi-axis, **dimension-ordered** wrappers — reduce-scatter along X,
    then Y, then Z, and all-gather back in reverse order: the collective
    analogue of APEnet+'s X->Y->Z router policy;
  * ``ring_all_to_all`` — store-and-forward ring all-to-all (MoE dispatch
    on the torus) plus a direct XLA ``lax.all_to_all`` fast path;
  * ``halo_exchange`` — the one-sided neighbour put used by stencil demos
    and the LO|FA|MO status exchange.

All functions here are *per-shard* code: they must run inside ``shard_map``
(or any context where ``axis_name`` is bound).  ``make_*`` helpers wrap them
into jitted host-level callables for tests and demos.

Numerics note: ring reductions accumulate in fp32 when inputs are lower
precision (bf16/fp16), matching production all-reduce behaviour.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import fabric, jaxcompat
from repro.core.fabric import CollectiveSchedule
# Re-exported executor helpers: the implementations (and all ring/hop math)
# live in core/fabric; these names are long-standing public API here.
from repro.core.fabric.execute import (_acc_dtype, _flatten_pad,  # noqa: F401
                                       _ring_perms)
from repro.core.topology import Torus


def _axis_torus(axis_names: Sequence[str]) -> Torus:
    """The ring/torus implied by the bound mesh axes (trace-time static)."""
    return Torus(tuple(jaxcompat.axis_size(ax) for ax in axis_names))


# ----------------------------------------------------------------------------
# single-axis ring primitives (per-shard code)
# ----------------------------------------------------------------------------

def ring_reduce_scatter(x: jax.Array, axis_name: str, *,
                        bidirectional: bool = True, mean: bool = False,
                        schedule: CollectiveSchedule | None = None
                        ) -> jax.Array:
    """Reduce-scatter along a mesh-axis ring; ring slot r returns chunk r.

    Input: the full local array (same logical value on every rank is NOT
    required — this reduces across ranks elementwise, like psum, then
    scatters).  Output: flat fp32-accumulated chunk of size ceil(|x|/N)
    (zero-padded); see ``ring_all_reduce`` for the unpadded composite.
    """
    if schedule is None:
        schedule = fabric.lower_reduce_scatter(
            _axis_torus((axis_name,)), (axis_name,),
            bidirectional=bidirectional, mean=mean)
    chunk, _ = fabric.execute_reduce_scatter(schedule, x)
    return chunk


def ring_all_gather(x: jax.Array, axis_name: str, *,
                    bidirectional: bool = True,
                    schedule: CollectiveSchedule | None = None) -> jax.Array:
    """All-gather chunks along a ring: slot r contributes x, returns the
    concatenation ordered by ring slot, shape (n, *x.shape)."""
    if schedule is None:
        schedule = fabric.lower_all_gather(
            _axis_torus((axis_name,)), (axis_name,),
            bidirectional=bidirectional)
    return fabric.execute_all_gather(schedule, x)


def ring_all_reduce(x: jax.Array, axis_name: str, *,
                    bidirectional: bool = True, mean: bool = False,
                    schedule: CollectiveSchedule | None = None) -> jax.Array:
    """Ring all-reduce = reduce-scatter + all-gather (the classic 2(N-1)/N
    bytes-optimal schedule), preserving ``x``'s shape/dtype."""
    if schedule is None:
        schedule = fabric.lower_all_reduce(
            _axis_torus((axis_name,)), (axis_name,),
            bidirectional=bidirectional, mean=mean)
    return fabric.execute_all_reduce(schedule, x)


# ----------------------------------------------------------------------------
# multi-axis, dimension-ordered composites (APEnet+ X->Y->Z routing)
# ----------------------------------------------------------------------------

def dim_ordered_all_reduce(x: jax.Array, axis_names: Sequence[str], *,
                           bidirectional: bool = True, mean: bool = False,
                           schedule: CollectiveSchedule | None = None
                           ) -> jax.Array:
    """All-reduce over several mesh axes: reduce-scatter X,Y,...,Z then
    all-gather Z,...,Y,X.  Each phase only ever talks to first neighbours
    along one torus dimension — the collective analogue of dimension-ordered
    routing, and bytes-optimal on a torus (each axis moves 2(Ni-1)/Ni of the
    data it still owns)."""
    if schedule is None:
        schedule = fabric.lower_all_reduce(
            _axis_torus(axis_names), tuple(axis_names),
            bidirectional=bidirectional, mean=mean)
    return fabric.execute_all_reduce(schedule, x)


def dim_ordered_reduce_scatter(x: jax.Array, axis_names: Sequence[str], *,
                               bidirectional: bool = True, mean: bool = False,
                               schedule: CollectiveSchedule | None = None
                               ) -> tuple[jax.Array, list[int]]:
    """Multi-axis RS; also returns per-stage pre-pad sizes for the inverse
    ``dim_ordered_all_gather`` (ZeRO-1 shard/unshard round trip)."""
    if schedule is None:
        schedule = fabric.lower_reduce_scatter(
            _axis_torus(axis_names), tuple(axis_names),
            bidirectional=bidirectional, mean=mean)
    return fabric.execute_reduce_scatter(schedule, x)


def dim_ordered_all_gather(x: jax.Array, axis_names: Sequence[str],
                           stage_sizes: Sequence[int], *,
                           bidirectional: bool = True,
                           schedule: CollectiveSchedule | None = None
                           ) -> jax.Array:
    """Inverse of ``dim_ordered_reduce_scatter`` given its stage sizes."""
    if schedule is None:
        axes = tuple(reversed(tuple(axis_names)))
        dims = tuple(reversed(range(len(axes))))
        schedule = fabric.lower_all_gather(_axis_torus(axis_names), axes,
                                           axis_dims=dims,
                                           bidirectional=bidirectional)
    return fabric.execute_all_gather(schedule, x, list(stage_sizes))


# ----------------------------------------------------------------------------
# all-to-all
# ----------------------------------------------------------------------------

def ring_all_to_all(x: jax.Array, axis_name: str, *,
                    schedule: CollectiveSchedule | None = None) -> jax.Array:
    """Store-and-forward ring all-to-all along one torus axis.

    ``x`` has shape (n, ...): row j is this rank's block destined for rank j.
    Returns shape (n, ...): row j is the block received from rank j.  Pure
    first-neighbour traffic: the full buffer circulates n-1 hops and every
    rank picks out its addressed row at each stop — exactly how a torus
    router forwards non-local packets.
    """
    if schedule is None:
        schedule = fabric.lower_all_to_all(_axis_torus((axis_name,)),
                                           axis_name)
    return fabric.execute_all_to_all(schedule, x)


def fast_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Direct XLA all-to-all (the compiler schedules it on the torus)."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                          tiled=False)


# ----------------------------------------------------------------------------
# halo exchange / neighbour put
# ----------------------------------------------------------------------------

def halo_exchange(x: jax.Array, axis_name: str, halo: int = 1,
                  dim: int = 0, *,
                  schedule: CollectiveSchedule | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Exchange ``halo``-wide boundary slabs with both ring neighbours.

    Returns (from_prev, from_next): the neighbours' facing edges — a pair of
    one-sided RDMA puts in APEnet+ terms.
    """
    if schedule is None:
        schedule = fabric.lower_halo_exchange(_axis_torus((axis_name,)),
                                              axis_name)
    return fabric.execute_halo_exchange(schedule, x, halo, dim)


# ----------------------------------------------------------------------------
# host-level wrappers (tests / demos / the apex DP layer)
# ----------------------------------------------------------------------------

def make_stacked_all_reduce(mesh: Mesh, axis_names: Sequence[str], *,
                            bidirectional: bool = True, mean: bool = False,
                            schedule: CollectiveSchedule | None = None):
    """Host-level all-reduce for tests/demos.

    Takes a global array of shape (n_0, ..., n_k, *payload) whose leading
    dims are sharded over ``axis_names``; every (i, ..., j) slot is one
    rank's contribution.  Returns the same shape where every slot holds the
    (mean-)reduction — so correctness is checkable against ``x.sum(axis=lead)``.
    """
    axes = tuple(axis_names)
    lead = len(axes)

    def per_shard(x):
        y = x.reshape(x.shape[lead:])
        out = dim_ordered_all_reduce(y, axes, bidirectional=bidirectional,
                                     mean=mean, schedule=schedule)
        return out.reshape(x.shape)

    spec = P(*axes)
    mapped = jaxcompat.shard_map(per_shard, mesh=mesh, in_specs=(spec,),
                                 out_specs=spec)
    return jax.jit(mapped)


def tree_all_reduce(tree, axis_names: Sequence[str], *,
                    bidirectional: bool = True, mean: bool = True,
                    schedule: CollectiveSchedule | None = None):
    """Per-shard: all-reduce every leaf of a pytree (gradient sync)."""
    return jax.tree.map(
        lambda g: dim_ordered_all_reduce(g, axis_names,
                                         bidirectional=bidirectional,
                                         mean=mean, schedule=schedule), tree)
