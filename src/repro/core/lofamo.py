"""LO|FA|MO — LOcal FAult MOnitor, paper §4 (Fig 4).

A lightweight mutual-watchdog protocol between each host and its NIC, plus
fault diffusion over the 3D torus, yielding *global* fault awareness at a
master node with no impact on data-transfer latency (diagnostic messages are
hidden in the communication protocol).

This module is a deterministic discrete-time simulator of that protocol, used

* by the fault-tolerant trainer (`repro.runtime.trainer`) to decide when to
  checkpoint-restart / re-mesh,
* by `benchmarks/lofamo.py` to reproduce the paper's awareness-time claim
  (Ta ~= 0.9 s at WD = 500 ms),
* by property tests: any fault pattern whose victims retain >= 1 live
  first-neighbour is detected, and detection reaches the master whenever the
  survivor graph is connected ("no area of the mesh can be isolated and no
  fault can remain undetected at global level").

Protocol model (one simulation tick = ``wd_period`` seconds, matching the
paper's watchdog granularity; sub-period phases are accounted analytically):

  * every live HOST increments its Host Watchdog Register each period;
  * every live NIC checks the host counter each period; a stale counter
    ⇒ ``HOST_FAULT`` raised locally;
  * every live NIC exchanges a status word with its torus neighbours each
    period (piggybacked on protocol traffic — zero added latency); a missing
    status word ⇒ ``NODE_FAULT`` recorded *about that neighbour*;
  * every live HOST reads its NIC's watchdog registers each period and
    forwards news to the MASTER over the service network (latency ~ ms,
    negligible vs. WD).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterable

from repro.core.topology import Torus


class Health(enum.Enum):
    OK = 0
    HOST_FAULT = 1    # host stopped updating its watchdog register
    NODE_FAULT = 2    # whole node (NIC included) unreachable
    LINK_FAULT = 3    # a torus link died; both endpoints still alive


@dataclasses.dataclass
class WatchdogRegisters:
    """The per-node LO|FA|MO register file (paper: 'a set of LO|FA|MO
    watchdog registers')."""

    host_counter: int = 0          # Host WD register (host increments)
    nic_counter: int = 0           # APEnet WD register (NIC increments)
    last_seen_host: int = -1       # NIC-side shadow of host_counter
    stale_reads: int = 0           # consecutive NIC reads w/o host progress
    self_status: Health = Health.OK
    # status the NIC holds about each first neighbour rank -> Health
    neighbor_status: dict[int, Health] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class FaultEvent:
    rank: int
    kind: Health
    t_fault: float                 # injection time (s)
    t_local: float | None = None   # local awareness (own/neighbour NIC)
    t_master: float | None = None  # global awareness at master

    @property
    def awareness_time(self) -> float | None:
        if self.t_master is None:
            return None
        return self.t_master - self.t_fault


@dataclasses.dataclass
class LinkFaultEvent:
    pair: tuple[int, int]          # undirected link (lo, hi)
    t_fault: float
    t_master: float | None = None  # master classifies the pair as LINK_FAULT
    kind: Health = Health.LINK_FAULT

    @property
    def awareness_time(self) -> float | None:
        if self.t_master is None:
            return None
        return self.t_master - self.t_fault


class LofamoSim:
    """Discrete-time simulation of LO|FA|MO over a torus."""

    def __init__(self, torus: Torus, wd_period: float = 0.5,
                 master: int = 0, service_latency: float = 1e-3) -> None:
        self.torus = torus
        self.wd = wd_period
        self.master = master
        self.service_latency = service_latency
        self.regs = {r: WatchdogRegisters() for r in torus.all_ranks()}
        for r in torus.all_ranks():
            self.regs[r].neighbor_status = {n: Health.OK
                                            for n in torus.neighbors(r)}
        self.host_dead: set[int] = set()
        self.node_dead: set[int] = set()
        self.link_dead: set[tuple[int, int]] = set()
        self.events: list[FaultEvent] = []
        self.link_events: list[LinkFaultEvent] = []
        self.master_view: dict[int, Health] = {r: Health.OK
                                               for r in torus.all_ranks()}
        # link faults the master has inferred: (lo, hi) -> awareness time
        self.master_links: dict[tuple[int, int], float] = {}
        self.t = 0.0

    # -- fault injection -------------------------------------------------------
    def kill_host(self, rank: int) -> FaultEvent:
        """Host hangs/crashes; NIC still alive (paper's Fig 4 scenario)."""
        ev = FaultEvent(rank, Health.HOST_FAULT, self.t)
        self.host_dead.add(rank)
        self.events.append(ev)
        return ev

    def kill_node(self, rank: int) -> FaultEvent:
        """Whole node dies (host + NIC): neighbours must detect it."""
        ev = FaultEvent(rank, Health.NODE_FAULT, self.t)
        self.host_dead.add(rank)
        self.node_dead.add(rank)
        self.events.append(ev)
        return ev

    def kill_link(self, a: int, b: int) -> tuple[int, int]:
        """One torus link dies; both endpoint nodes stay alive.

        Locally each endpoint's NIC stops receiving the other's status word
        and suspects a NODE_FAULT; the master disambiguates (companion work
        on APEnet+ fault awareness): a suspected node that itself keeps
        reporting over the service network is alive, so the fault must be
        the link between the pair.
        """
        if b not in self.torus.neighbors(a):
            raise ValueError(f"{a} and {b} are not torus neighbours")
        pair = (min(a, b), max(a, b))
        self.link_dead.add(pair)
        ev = LinkFaultEvent(pair, self.t)
        self.link_events.append(ev)
        return ev

    def _link_ok(self, a: int, b: int) -> bool:
        return (min(a, b), max(a, b)) not in self.link_dead

    # -- one watchdog period ---------------------------------------------------
    def step(self) -> None:
        t_end = self.t + self.wd
        # Phase 1: live hosts bump their watchdog register.
        for r, reg in self.regs.items():
            if r not in self.host_dead:
                reg.host_counter += 1
        # Phase 2: live NICs check their host and mark HOST_FAULT after two
        # consecutive stale reads (debounce: host update and NIC check run
        # unsynchronised, so one stale read is not yet a fault — this is why
        # the paper's Ta is ~1.8 x WD rather than ~1 x WD).
        for r, reg in self.regs.items():
            if r in self.node_dead:
                continue
            reg.nic_counter += 1
            if reg.host_counter == reg.last_seen_host:
                reg.stale_reads += 1
                if reg.stale_reads >= 2 and reg.self_status is Health.OK:
                    reg.self_status = Health.HOST_FAULT
                    self._mark_local(r, t_end)
            else:
                reg.stale_reads = 0
            reg.last_seen_host = reg.host_counter
        # Phase 3: live NICs exchange status words with torus neighbours
        # (diagnostic messages hidden in protocol traffic -> zero extra
        # latency on the data path).
        for r, reg in self.regs.items():
            if r in self.node_dead:
                continue
            for n in self.torus.neighbors(r):
                if n in self.node_dead or not self._link_ok(r, n):
                    # no status word arrives: locally indistinguishable from
                    # a dead neighbour node
                    if reg.neighbor_status.get(n) is not Health.NODE_FAULT:
                        reg.neighbor_status[n] = Health.NODE_FAULT
                        self._mark_local(n, t_end)
                else:
                    st = self.regs[n].self_status
                    reg.neighbor_status[n] = st
        # Phase 4: live hosts read NIC registers and report to the master
        # over the service network (plus a liveness heartbeat).  The master
        # disambiguates: a NODE_FAULT suspicion about a rank whose own host
        # still heartbeats must be the *link* between the pair.
        alive_hosts = {r for r in self.regs if r not in self.host_dead}
        for r, reg in self.regs.items():
            if r in self.host_dead:
                continue
            reports: list[tuple[int, Health]] = []
            if reg.self_status is not Health.OK:
                reports.append((r, reg.self_status))
            for n, st in reg.neighbor_status.items():
                if st is not Health.OK:
                    reports.append((n, st))
            for rank, st in reports:
                if st is Health.NODE_FAULT and rank in alive_hosts \
                        and rank != r:
                    pair = (min(r, rank), max(r, rank))
                    if pair not in self.master_links:
                        self.master_links[pair] = t_end + self.service_latency
                        for ev in self.link_events:
                            if ev.pair == pair and ev.t_master is None:
                                ev.t_master = self.master_links[pair]
                    continue
                if self.master_view.get(rank) is Health.OK:
                    self.master_view[rank] = st
                    self._mark_master(rank, t_end + self.service_latency)
        self.t = t_end

    def run(self, periods: int) -> None:
        for _ in range(periods):
            self.step()

    # -- bookkeeping -----------------------------------------------------------
    def _mark_local(self, rank: int, t: float) -> None:
        for ev in self.events:
            if ev.rank == rank and ev.t_local is None:
                ev.t_local = t

    def _mark_master(self, rank: int, t: float) -> None:
        for ev in self.events:
            if ev.rank == rank and ev.t_master is None:
                ev.t_master = t

    # -- queries ---------------------------------------------------------------
    def detected_at_master(self) -> set[int]:
        return {r for r, st in self.master_view.items() if st is not Health.OK}

    def detected_links_at_master(self) -> set[tuple[int, int]]:
        """Dead links the master has inferred (both endpoints still alive)."""
        return set(self.master_links)

    def all_detected(self, faults: Iterable[int] | None = None) -> bool:
        want = set(faults) if faults is not None else {e.rank for e in self.events}
        return want <= self.detected_at_master()


def awareness_time_model(wd_period: float, service_latency: float = 1e-3) -> float:
    """Analytic awareness time, dominated by the watchdog period (paper §4).

    A host fault is noticed when the NIC sees a *second* read of an unchanged
    counter; averaged over the fault phase within the period this costs
    1.8 x WD, plus the service-network report.  At the paper's operating
    point WD = 500 ms this gives Ta ~= 0.9 s (paper: "for a WD = 500 ms,
    Ta = 0.9 s").
    """
    return 1.8 * wd_period + service_latency
