"""RDMA primitives on the torus — paper §1 (APEnet+ programming model).

APEnet+ exposes one-sided RDMA PUT/GET between nodes of the 3D torus, with
zero-copy GPU endpoints (GPUDirect P2P).  On TPU, ``lax.ppermute`` *is* a
one-sided neighbour write over ICI (no host staging — the "zero-copy" mode
is the only mode), and a multi-hop transfer is a chain of neighbour writes
following the dimension-ordered route, exactly like the APEnet+ router's
store-and-forward.

Two API levels:

* per-shard functions (inside ``shard_map``): ``put_shift``, ``put_coords``,
  ``send_recv`` — used by the collectives and the halo/status exchanges;

* ``RdmaEndpoint`` — the host-side software stack: buffer *registration*
  through the §2.2 TLB (translation + pinning bookkeeping), a command queue
  with a configurable number of in-flight slots (the §2.1 "dual DMA engine"
  prefetchable queue), and a completion-cost model used by the Fig 1
  benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
from jax import lax

from repro.core import apelink, jaxcompat
from repro.core.tlb import PAGE_BYTES, Tlb
from repro.core.topology import Torus


# ----------------------------------------------------------------------------
# per-shard (in-shard_map) primitives
# ----------------------------------------------------------------------------

def put_shift(x: jax.Array, axis_name: str, step: int = +1) -> jax.Array:
    """One-sided put to the ring neighbour at signed offset ``step``.

    Multi-hop |step| is realised as |step| single-hop writes (neighbour
    links are the only physical channels on the torus)."""
    n = jaxcompat.axis_size(axis_name)
    hop = +1 if step >= 0 else -1
    perm = [(i, (i + hop) % n) for i in range(n)]
    for _ in range(abs(step)):
        x = lax.ppermute(x, axis_name, perm)
    return x


def put_coords(x: jax.Array, axis_names: Sequence[str],
               delta: Sequence[int]) -> jax.Array:
    """Dimension-ordered multi-axis put: shift by ``delta[i]`` hops along
    ``axis_names[i]``, X first then Y then Z (the APEnet+ routing order)."""
    if len(axis_names) != len(delta):
        raise ValueError("axis/delta arity mismatch")
    for ax, d in zip(axis_names, delta):
        if d:
            x = put_shift(x, ax, d)
    return x


def send_recv(x: jax.Array, axis_name: str,
              pairs: Sequence[tuple[int, int]]) -> jax.Array:
    """Explicit (src, dst) one-sided writes; ranks not addressed receive
    zeros (RDMA semantics: untouched remote memory, here a fresh buffer)."""
    return lax.ppermute(x, axis_name, list(pairs))


# ----------------------------------------------------------------------------
# host-side endpoint: registration (TLB) + command queue (dual DMA engines)
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class Region:
    handle: int
    vaddr: int
    nbytes: int


class RdmaEndpoint:
    """Software model of one node's APEnet+ card.

    * ``register`` pins a buffer and pre-translates its pages through the
      TLB (first touch = Nios II walk; later RDMA ops hit the HW TLB).
    * ``transfer_time`` models a PUT of ``nbytes`` with ``engines``
      concurrent DMA engines over the PCIe+link pipeline (Fig 1): with one
      engine the bus idles between a request's completion and the next
      issue; with two, requests overlap and the gap is hidden.
    """

    def __init__(self, torus: Torus, rank: int, *, tlb_entries: int = 512,
                 engines: int = 2, cq_slots: int | None = None,
                 net: apelink.NetModel | None = None) -> None:
        self.torus = torus
        self.rank = rank
        self.engines = engines
        # prefetchable command queue (§2.1): in-flight descriptor slots.
        # Two per engine by default — one draining, one prefetched — which
        # is what lets the second engine start without waiting for the
        # host.  ``fabric.estimate_overlapped`` consumes this as its
        # ``queue_depth``: depth 1 exposes the issue gap on every bucket.
        self.cq_slots = cq_slots if cq_slots is not None else 2 * engines
        if self.cq_slots < 1:
            raise ValueError(f"cq_slots must be >= 1, got {self.cq_slots}")
        self.tlb = Tlb(entries=tlb_entries)
        self.net = net or apelink.NetModel()
        self._regions: dict[int, Region] = {}
        self._next = 1
        self._next_vaddr = 1 << 20

    @property
    def queue_depth(self) -> int:
        """Command-queue depth feeding the fabric overlap model."""
        return self.cq_slots

    # -- registration ----------------------------------------------------------
    def register(self, nbytes: int) -> Region:
        region = Region(self._next, self._next_vaddr, nbytes)
        self._regions[self._next] = region
        self._next += 1
        self._next_vaddr += (nbytes + PAGE_BYTES - 1) // PAGE_BYTES * PAGE_BYTES
        return region

    def deregister(self, region: Region) -> None:
        del self._regions[region.handle]
        for off in range(0, region.nbytes, PAGE_BYTES):
            self.tlb.invalidate(region.vaddr + off)

    def translate_region(self, region: Region) -> float:
        """Translate every page of a region; returns modelled cost (s)."""
        if region.handle not in self._regions:
            raise KeyError("RDMA to unregistered region")
        cost = 0.0
        for off in range(0, max(region.nbytes, 1), PAGE_BYTES):
            _, c = self.tlb.translate(region.vaddr + off)
            cost += c
        return cost

    # -- Fig 1 cost model --------------------------------------------------------
    def transfer_time(self, nbytes: int, *, engines: int | None = None,
                      max_payload: int = 4096,
                      t_issue: float = 0.2e-6,
                      t_completion_gap: float = 0.85e-6) -> float:
        """Total time to push ``nbytes`` through the PCIe DMA stage.

        Each PCIe read request costs a descriptor issue (``t_issue``, never
        hideable), moves ``max_payload`` bytes, and its completion arrives
        ``t_completion_gap`` after issue (system-dependent dead time, §2.1).
        A single engine serialises issue+gap+transfer — effective bandwidth
        ~50% of theoretical, as the paper observed; ``k`` engines keep k
        requests outstanding, hiding the gap whenever (k-1)*t_xfer >= gap.
        Calibration reproduces both §2.1 claims: single-engine efficiency
        ~0.5 and dual-engine total-time reduction ~40% (Fig 1).
        """
        k = engines if engines is not None else self.engines
        nreq = max(1, (nbytes + max_payload - 1) // max_payload)
        t_xfer = max_payload / self.net.host_if.effective_bandwidth
        exposed_gap = max(0.0, t_completion_gap - (k - 1) * t_xfer)
        return nreq * (t_issue + t_xfer + exposed_gap)

    def put_time(self, dst: int, nbytes: int, region: Region) -> float:
        """End-to-end modelled PUT latency: translation + DMA + wire."""
        t = self.translate_region(region)
        t += self.transfer_time(nbytes)
        hops = self.torus.hop_distance(self.rank, dst)
        t += self.net.latency(nbytes, hops=hops)
        return t
