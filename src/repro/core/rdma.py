"""RDMA primitives on the torus — paper §1 (APEnet+ programming model).

APEnet+ exposes one-sided RDMA PUT/GET between nodes of the 3D torus, with
zero-copy GPU endpoints (GPUDirect P2P).  On TPU, ``lax.ppermute`` *is* a
one-sided neighbour write over ICI (no host staging — the "zero-copy" mode
is the only mode), and a multi-hop transfer is a chain of neighbour writes
following the dimension-ordered route, exactly like the APEnet+ router's
store-and-forward.

Two API levels:

* per-shard functions (inside ``shard_map``): ``put_shift``, ``put_coords``,
  ``send_recv`` — used by the collectives and the halo/status exchanges;

* ``RdmaEndpoint`` — the host-side software stack: buffer *registration*
  through the §2.2 TLB (translation + pinning bookkeeping), a command queue
  with a configurable number of in-flight slots (the §2.1 "dual DMA engine"
  prefetchable queue), and a completion-cost model used by the Fig 1
  benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
from jax import lax

from repro.core import apelink, jaxcompat
from repro.core.fabric.qos import TrafficClass
from repro.core.tlb import PAGE_BYTES, Tlb
from repro.core.topology import Torus


# ----------------------------------------------------------------------------
# per-shard (in-shard_map) primitives
# ----------------------------------------------------------------------------

def put_shift(x: jax.Array, axis_name: str, step: int = +1) -> jax.Array:
    """One-sided put to the ring neighbour at signed offset ``step``.

    Multi-hop |step| is realised as |step| single-hop writes (neighbour
    links are the only physical channels on the torus)."""
    n = jaxcompat.axis_size(axis_name)
    hop = +1 if step >= 0 else -1
    perm = [(i, (i + hop) % n) for i in range(n)]
    for _ in range(abs(step)):
        x = lax.ppermute(x, axis_name, perm)
    return x


def put_coords(x: jax.Array, axis_names: Sequence[str],
               delta: Sequence[int]) -> jax.Array:
    """Dimension-ordered multi-axis put: shift by ``delta[i]`` hops along
    ``axis_names[i]``, X first then Y then Z (the APEnet+ routing order)."""
    if len(axis_names) != len(delta):
        raise ValueError("axis/delta arity mismatch")
    for ax, d in zip(axis_names, delta):
        if d:
            x = put_shift(x, ax, d)
    return x


def send_recv(x: jax.Array, axis_name: str,
              pairs: Sequence[tuple[int, int]]) -> jax.Array:
    """Explicit (src, dst) one-sided writes; ranks not addressed receive
    zeros (RDMA semantics: untouched remote memory, here a fresh buffer)."""
    return lax.ppermute(x, axis_name, list(pairs))


# ----------------------------------------------------------------------------
# host-side endpoint: registration (TLB) + command queue (dual DMA engines)
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class Region:
    handle: int
    vaddr: int
    nbytes: int


class RdmaEndpoint:
    """Software model of one node's APEnet+ card.

    * ``register`` pins a buffer and pre-translates its pages through the
      TLB (first touch = Nios II walk; later RDMA ops hit the HW TLB).
    * ``transfer_time`` models a PUT of ``nbytes`` with ``engines``
      concurrent DMA engines over the PCIe+link pipeline (Fig 1): with one
      engine the bus idles between a request's completion and the next
      issue; with two, requests overlap and the gap is hidden.
    """

    def __init__(self, torus: Torus, rank: int, *, tlb_entries: int = 512,
                 engines: int = 2, cq_slots: int | None = None,
                 net: apelink.NetModel | None = None,
                 sim: "object | None" = None,
                 descriptor_bytes: float | None = None,
                 telemetry: "object | None" = None) -> None:
        self.torus = torus
        self.rank = rank
        self.engines = engines
        # §2.1 per-class command queues: with ``descriptor_bytes`` set and
        # a shared sim attached, put_pages occupies the host-IF FIFO as a
        # CHAIN of descriptor-granular occupancies instead of one
        # monolithic drain, so a queued higher-class descriptor (a decode
        # collective's DMA) overtakes the remaining bulk descriptors at
        # the next boundary instead of waiting out the whole PUT.  The
        # default None keeps the monolithic drain — bitwise identical to
        # the pre-descriptor timeline.
        self.descriptor_bytes = (float(descriptor_bytes)
                                 if descriptor_bytes else None)
        if self.descriptor_bytes is not None and self.descriptor_bytes <= 0:
            raise ValueError(
                f"descriptor_bytes must be > 0, got {descriptor_bytes}")
        # shared fabric timeline: when attached, put_pages/get_time inject
        # their host-IF DMA drain and wire legs as flows on it instead of
        # summing closed-form terms, so concurrent operations — this
        # card's or any other card sharing the sim — contend for links and
        # host-interface slots.  Any ``fabric.make_sim`` fidelity tier
        # works (the surface is duck-typed): the packet ``FabricSim``
        # oracle, or ``FluidSim``/``HybridSim`` for big clusters.
        # None = closed-form.
        self.sim = sim
        self.last_put_report: dict | None = None
        # optional Telemetry hub (the card's "hardware counters"): PUT /
        # GET / descriptor tallies + one span per PUT on this rank's
        # track.  Reporting only — None is bitwise-invisible.
        self.telemetry = telemetry
        # prefetchable command queue (§2.1): in-flight descriptor slots.
        # Two per engine by default — one draining, one prefetched — which
        # is what lets the second engine start without waiting for the
        # host.  ``fabric.estimate_overlapped`` consumes this as its
        # ``queue_depth``: depth 1 exposes the issue gap on every bucket.
        self.cq_slots = cq_slots if cq_slots is not None else 2 * engines
        if self.cq_slots < 1:
            raise ValueError(f"cq_slots must be >= 1, got {self.cq_slots}")
        self.tlb = Tlb(entries=tlb_entries)
        self.net = net or apelink.NetModel()
        self._regions: dict[int, Region] = {}
        self._next = 1
        self._next_vaddr = 1 << 20

    @property
    def queue_depth(self) -> int:
        """Command-queue depth feeding the fabric overlap model."""
        return self.cq_slots

    # -- registration ----------------------------------------------------------
    def register(self, nbytes: int) -> Region:
        region = Region(self._next, self._next_vaddr, nbytes)
        self._regions[self._next] = region
        self._next += 1
        # reserve at least one page: translate_region/deregister treat the
        # first page as owned even for zero-byte regions, so the address
        # space must too — otherwise a 0-byte region aliases the next
        # registration's vaddr and deregistering it would shoot down a
        # LIVE region's translations
        self._next_vaddr += (max(nbytes, 1) + PAGE_BYTES - 1) \
            // PAGE_BYTES * PAGE_BYTES
        return region

    def deregister(self, region: Region) -> None:
        """Unpin the region and shoot down its TLB entries.

        The sweep must cover exactly what translation can populate:
        ``translate_region`` walks ``max(nbytes, 1)`` bytes (a zero-byte
        region still owns its first page), so deregistering sweeps the
        same range — otherwise a stale translation for that page could
        hit after the region is gone.
        """
        del self._regions[region.handle]
        for off in range(0, max(region.nbytes, 1), PAGE_BYTES):
            self.tlb.invalidate(region.vaddr + off)

    def _check_registered(self, region: Region) -> None:
        """The region must be one THIS endpoint registered (a handle number
        alone can collide with another card's region)."""
        if self._regions.get(region.handle) is not region:
            raise KeyError("RDMA to a region this endpoint never registered")

    def translate_region(self, region: Region) -> float:
        """Translate every page of a region; returns modelled cost (s)."""
        self._check_registered(region)
        cost = 0.0
        for off in range(0, max(region.nbytes, 1), PAGE_BYTES):
            _, c = self.tlb.translate(region.vaddr + off)
            cost += c
        return cost

    # -- Fig 1 cost model --------------------------------------------------------
    def transfer_time(self, nbytes: int, *, engines: int | None = None,
                      max_payload: int = 4096,
                      t_issue: float = 0.2e-6,
                      t_completion_gap: float = 0.85e-6) -> float:
        """Total time to push ``nbytes`` through the PCIe DMA stage.

        Each PCIe read request costs a descriptor issue (``t_issue``, never
        hideable), moves ``max_payload`` bytes, and its completion arrives
        ``t_completion_gap`` after issue (system-dependent dead time, §2.1).
        A single engine serialises issue+gap+transfer — effective bandwidth
        ~50% of theoretical, as the paper observed; ``k`` engines keep k
        requests outstanding, hiding the gap whenever (k-1)*t_xfer >= gap.
        Calibration reproduces both §2.1 claims: single-engine efficiency
        ~0.5 and dual-engine total-time reduction ~40% (Fig 1).

        This is the *service time* of one DMA drain.  With a shared
        ``FabricSim`` attached, ``put_pages``/``get_time`` do not add it
        as a closed-form term: they occupy the card's host-interface FIFO
        resource (``("hostif", rank)``) on the shared timeline for this
        duration, so concurrent operations on one card queue behind each
        other.
        """
        k = engines if engines is not None else self.engines
        nreq = max(1, (nbytes + max_payload - 1) // max_payload)
        t_xfer = max_payload / self.net.host_if.effective_bandwidth
        exposed_gap = max(0.0, t_completion_gap - (k - 1) * t_xfer)
        return nreq * (t_issue + t_xfer + exposed_gap)

    def put_time(self, dst: int, nbytes: int, region: Region) -> float:
        """End-to-end modelled PUT latency: translation + DMA + wire."""
        t = self.translate_region(region)
        t += self.transfer_time(nbytes)
        hops = self.torus.hop_distance(self.rank, dst)
        t += self.net.latency(nbytes, hops=hops)
        return t

    # -- bulk region-to-region transfers (KV-page migration) --------------------
    def put_pages(self, dst: int, region: Region, pages: Sequence[int], *,
                  page_nbytes: int = PAGE_BYTES,
                  dst_endpoint: "RdmaEndpoint | None" = None,
                  dst_region: Region | None = None,
                  dst_pages: Sequence[int] | None = None,
                  faults=None, schedule=None, stripes=None,
                  restripe_s: float | None = None,
                  cls: TrafficClass = TrafficClass.BULK) -> float:
        """Bulk one-sided PUT of selected ``page_nbytes``-sized pages of a
        registered region to rank ``dst``; returns the modelled seconds.

        The wire leg is a ``fabric.lower_p2p`` schedule priced by
        ``fabric.estimate`` — multi-hop dimension-ordered unicast on a
        healthy fabric, the BFS detour of the same schedule under a
        ``FaultMap`` (pass ``faults``), ``UnroutableError`` when the map
        partitions the fabric.  A caller that already lowered the route
        (e.g. for hop reporting) passes it as ``schedule`` to skip the
        re-derivation.  On top of the wire: TX-side translation of
        every TLB granule the pages span (§2.2 — hot after registration)
        and the host-interface DMA drain (§2.1 dual-engine model).  When
        the caller hands over the receiving card (``dst_endpoint`` +
        ``dst_region`` [+ ``dst_pages``]), the RX-side translation of the
        landing byte range is charged to *its* TLB — the §2.2 critical
        path of the receive DMA.  (Per-``PAGE_BYTES``-granule, the same
        model as ``translate_region``; the serving allocator's
        one-entry-per-KV-page registration shortcut is separate and
        coarser.)

        **Multi-path striping**: pass ``stripes`` — a sequence of
        ``(schedule, nbytes)`` legs whose bytes sum to the payload — to
        split the PUT across several routes at once (the serving
        cluster's ``route_policy="striped"``).  The legs leave one DMA
        drain together and fly concurrently; the receiver cannot hand the
        pages over until every stripe has landed AND its reorder window
        has matched the out-of-order completions, modelled as one extra
        ``t_receive`` per additional stripe.  ``cls`` tags every timeline
        leg's traffic class (default ``BULK`` — a migration must not
        starve decode on a QoS fabric).

        **Mid-flight re-striping**: with a shared sim attached, pass
        ``restripe_s`` (seconds after the DMA drain) to set a checkpoint:
        the timeline runs to it, each leg's unsent remainder is re-probed
        against the *current* congestion (``fabric.striped_routes``) and
        re-split across the fresh plan — in-flight packets keep their
        per-packet route tags, only the uncommitted remainder moves.  A
        leg the host-IF backlog kept from starting by the checkpoint
        flies as originally planned (best-effort; nothing to re-split
        safely).  Re-striping pays a descriptor re-issue per new sibling,
        so callers trigger it on detected congestion shift, not always.
        """
        self._check_registered(region)
        if page_nbytes <= 0:
            raise ValueError(f"page_nbytes must be > 0, got {page_nbytes}")
        from repro.core import fabric
        t_src = self._translate_pages(self.tlb, region, pages, page_nbytes)
        nbytes = len(pages) * page_nbytes
        if stripes is not None:
            if schedule is not None:
                raise ValueError("pass schedule= or stripes=, not both")
            legs = [(s, float(b)) for s, b in stripes]
            if not legs:
                raise ValueError("stripes must list at least one leg")
            total_b = sum(b for _, b in legs)
            if abs(total_b - nbytes) > 0.5:
                raise ValueError(
                    f"stripe bytes {total_b} != payload {nbytes}")
        else:
            sched = schedule if schedule is not None else fabric.lower_p2p(
                self.torus, self.rank, dst, faults=faults)
            legs = [(sched, float(nbytes))]
        t_dma = self.transfer_time(nbytes)
        t_wire = max(fabric.estimate(s, b, self.net).total_s
                     for s, b in legs)
        # receiver reorder/settle: every stripe past the first is one more
        # out-of-order completion the RX window must match before the
        # landed pages are usable
        t_settle = (len(legs) - 1) * self.net.t_receive
        t_dst = 0.0
        if dst_endpoint is not None and dst_region is not None:
            dst_endpoint._check_registered(dst_region)
            t_dst = self._translate_pages(
                dst_endpoint.tlb, dst_region,
                dst_pages if dst_pages is not None else pages, page_nbytes)
        # the sum-of-isolated price: what this PUT costs on a quiet fabric
        isolated = t_src + t_dma + t_wire + t_settle + t_dst
        if self.sim is None:
            self.last_put_report = {"total_s": isolated,
                                    "isolated_s": isolated,
                                    "dma_s": t_dma, "wire_s": t_wire,
                                    "translate_s": t_src + t_dst,
                                    "stripes": len(legs),
                                    "settle_s": t_settle}
            if self.telemetry is not None:
                self.telemetry.add("rdma.puts")
                self.telemetry.add("rdma.put_bytes", float(nbytes))
                self.telemetry.add("rdma.descriptors")
            return isolated
        # shared timeline: the DMA drain occupies this card's host-IF slot,
        # then the payload walks its route(s) packet by packet — all legs
        # contending with whatever else is in flight on the sim
        start = self.sim.now
        desc = self.descriptor_bytes
        if desc is not None and nbytes > desc:
            # §2.1 per-class command queue: the drain is a CHAIN of
            # descriptor occupancies, preemptible at every boundary
            from repro.core.fabric.cost import hostif_descriptors
            chunks = hostif_descriptors(nbytes, desc)
            dma = None
            for i, cb in enumerate(chunks):
                dma = self.sim.occupy(
                    ("hostif", self.rank), t_dma * (cb / nbytes),
                    start_s=start + t_src,
                    after=(dma,) if dma is not None else (), cls=cls,
                    label=f"put_dma r{self.rank} d{i}")
            n_desc = len(chunks)
        else:
            dma = self.sim.occupy(("hostif", self.rank), t_dma,
                                  start_s=start + t_src, cls=cls,
                                  label=f"put_dma r{self.rank}")
            n_desc = 1
        wire_fids = []
        for i, (s, b) in enumerate(legs):
            route = s.route if s.collective == fabric.P2P else None
            wire_fids.append(self.sim.inject(
                self.rank, dst, b, route=route, after=(dma,), cls=cls,
                label=f"put {self.rank}->{dst}"
                      + (f" stripe{i}" if len(legs) > 1 else "")))
        restriped = 0
        if restripe_s is not None and hasattr(self.sim, "restripe"):
            checkpoint = start + t_src + t_dma + float(restripe_s)
            self.sim.run_until(checkpoint)
            final_fids = []
            for f in wire_fids:
                rem = self.sim.unsent_bytes(f)
                if rem <= 0.5 * page_nbytes:
                    final_fids.append(f)     # landed or nearly so
                    continue
                try:
                    plan = fabric.striped_routes(
                        self.sim, self.rank, dst, rem,
                        k=max(len(legs), 2), faults=faults, cls=cls)
                    got = self.sim.restripe(f, plan)
                except (ValueError, fabric.UnroutableError):
                    got = [f]                # leg not started / no detours
                restriped += len(got) - 1
                final_fids.extend(got)
            wire_fids = final_fids
            # the reorder window matches every landed leg, including the
            # re-striped siblings
            t_settle = (len(wire_fids) - 1) * self.net.t_receive
        wire_end = max(self.sim.finish_s(f) for f in wire_fids)
        total = (wire_end - start) + t_settle + t_dst
        self.last_put_report = {"total_s": total, "isolated_s": isolated,
                                "dma_s": t_dma, "wire_s": t_wire,
                                "translate_s": t_src + t_dst,
                                "stripes": len(legs),
                                "settle_s": t_settle,
                                "descriptors": n_desc,
                                "restriped": restriped}
        tel = self.telemetry
        if tel is not None:
            tel.add("rdma.puts")
            tel.add("rdma.put_bytes", float(nbytes))
            tel.add("rdma.descriptors", float(n_desc))
            tel.add("rdma.restriped", float(restriped))
            tel.event(("rdma", self.rank), f"put->{dst}", start, total,
                      nbytes=float(nbytes), stripes=len(legs),
                      descriptors=n_desc, restriped=restriped)
        return total

    def get_time(self, src: int, nbytes: int, region: Region, *,
                 faults=None) -> float:
        """Modelled one-sided GET of ``nbytes`` from rank ``src`` into a
        local registered region: descriptor out, payload back.

        A GET is a PUT initiated by the reader — a descriptor-sized request
        travels to ``src``, whose card streams the payload back along the
        reversed route; the local landing buffer is translated before the
        RX DMA can scatter into it.  Both legs reroute around ``faults``
        like ``put_pages``.  With a shared ``FabricSim`` attached the
        three legs become chained timeline events (request flow -> remote
        host-IF occupancy -> payload flow) instead of closed-form terms.
        """
        from repro.core import fabric
        if self.telemetry is not None:
            self.telemetry.add("rdma.gets")
            self.telemetry.add("rdma.get_bytes", float(nbytes))
        t_local = self.translate_region(region)
        req = fabric.lower_p2p(self.torus, self.rank, src, faults=faults)
        back = fabric.lower_p2p(self.torus, src, self.rank, faults=faults)
        if self.sim is None:
            t = t_local
            t += fabric.estimate(req, 64, self.net).total_s  # GET descriptor
            t += self.transfer_time(nbytes)                  # remote drain
            t += fabric.estimate(back, nbytes, self.net).total_s
            return t
        start = self.sim.now
        fid_req = self.sim.inject(self.rank, src, 64, route=req.route,
                                  start_s=start + t_local,
                                  cls=TrafficClass.CONTROL,
                                  label=f"get_req {self.rank}->{src}")
        fid_dma = self.sim.occupy(("hostif", src),
                                  self.transfer_time(nbytes),
                                  after=(fid_req,), cls=TrafficClass.BULK,
                                  label=f"get_dma r{src}")
        fid_back = self.sim.inject(src, self.rank, nbytes, route=back.route,
                                   after=(fid_dma,), cls=TrafficClass.BULK,
                                   label=f"get {src}->{self.rank}")
        return self.sim.finish_s(fid_back) - start

    @staticmethod
    def _translate_pages(tlb: Tlb, region: Region, pages: Sequence[int],
                         page_nbytes: int) -> float:
        """Translate every TLB granule the listed pages span."""
        cost = 0.0
        for p in pages:
            if p < 0 or (p + 1) * page_nbytes > region.nbytes:
                raise ValueError(
                    f"page {p} ({page_nbytes} B) outside region of "
                    f"{region.nbytes} bytes")
            base = region.vaddr + p * page_nbytes
            for off in range(0, page_nbytes, PAGE_BYTES):
                _, c = tlb.translate(base + off)
                cost += c
        return cost
