"""Version bridge over the installed JAX.

The repo targets the modern JAX surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``AbstractMesh(axis_sizes, axis_names)``); CI
images sometimes carry an older release where ``shard_map`` still lives in
``jax.experimental`` (``check_rep`` instead of ``check_vma``), ``make_mesh``
has no ``axis_types`` and ``AbstractMesh`` wants ``((name, size), ...)``
pairs.  Everything that touches one of those APIs goes through this module
so the skew is handled exactly once.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import AbstractMesh, Mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the old experimental entry point as fallback.

    ``check_vma`` defaults to False repo-wide: the per-shard collective code
    (ppermute chains, fori_loop-carried ring buffers) produces values the
    varying-axes checker cannot classify even when the output really is
    replicated.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def axis_size(axis_name: str) -> int:
    """``lax.axis_size`` (static size of a bound mesh axis) on any JAX."""
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    import jax.core as jcore
    frame = jcore.axis_frame(axis_name)
    return frame if isinstance(frame, int) else frame.size


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices=None) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    shape, axes = tuple(shape), tuple(axes)
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes),
                             devices=devices)
    except ImportError:
        return jax.make_mesh(shape, axes, devices=devices)


def abstract_mesh(shape: Sequence[int], axes: Sequence[str]) -> AbstractMesh:
    """Device-less mesh for spec-level sharding tests on any JAX version."""
    shape, axes = tuple(shape), tuple(axes)
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))
