"""3D (k-ary n-cube) torus topology math — APEnet+ §1/§5.

APEnet+ wires nodes into a 3D torus with 6 bidirectional links per node and
routes packets dimension-by-dimension (dimension-ordered routing).  This
module is the pure-Python model of that fabric: coordinates, neighbours,
routes, distances and fault-isolation analysis.  It backs

  * the torus collectives (`core.collectives`) — ring orderings per axis,
  * the LO|FA|MO fault simulator (`core.lofamo`) — neighbour graph,
  * property tests — routing/distance invariants.

Ranks are row-major over ``dims`` (last dim fastest), matching the device
order of ``jax.make_mesh``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Sequence


@dataclasses.dataclass(frozen=True)
class Torus:
    """A torus with ``dims[i]`` nodes along dimension ``i``."""

    dims: tuple[int, ...]

    def __post_init__(self) -> None:
        if not self.dims or any(d < 1 for d in self.dims):
            raise ValueError(f"invalid torus dims {self.dims!r}")

    # -- coordinates ---------------------------------------------------------
    @property
    def ndims(self) -> int:
        return len(self.dims)

    @property
    def size(self) -> int:
        out = 1
        for d in self.dims:
            out *= d
        return out

    def coords(self, rank: int) -> tuple[int, ...]:
        if not 0 <= rank < self.size:
            raise ValueError(f"rank {rank} out of range for {self.dims}")
        out = []
        for d in reversed(self.dims):
            out.append(rank % d)
            rank //= d
        return tuple(reversed(out))

    def rank(self, coords: Sequence[int]) -> int:
        if len(coords) != self.ndims:
            raise ValueError("coordinate arity mismatch")
        r = 0
        for c, d in zip(coords, self.dims):
            if not 0 <= c < d:
                raise ValueError(f"coordinate {coords} out of range {self.dims}")
            r = r * d + c
        return r

    def all_ranks(self) -> Iterator[int]:
        return iter(range(self.size))

    # -- links ---------------------------------------------------------------
    def neighbor(self, rank: int, dim: int, step: int) -> int:
        """Neighbour of ``rank`` along ``dim`` at signed offset ``step``."""
        c = list(self.coords(rank))
        c[dim] = (c[dim] + step) % self.dims[dim]
        return self.rank(c)

    def neighbors(self, rank: int) -> list[int]:
        """The (up to) 2*ndims distinct first-hop neighbours (6 for 3D)."""
        out: list[int] = []
        for dim in range(self.ndims):
            if self.dims[dim] == 1:
                continue
            for step in (+1, -1):
                n = self.neighbor(rank, dim, step)
                if n != rank and n not in out:
                    out.append(n)
        return out

    def links(self) -> list[tuple[int, int]]:
        """All undirected links (each once, as (lo, hi))."""
        seen = set()
        for r in self.all_ranks():
            for n in self.neighbors(r):
                seen.add((min(r, n), max(r, n)))
        return sorted(seen)

    # -- distances & routing -------------------------------------------------
    def dim_distance(self, a: int, b: int, dim: int) -> int:
        """Shortest signed-magnitude distance along one torus dimension."""
        d = self.dims[dim]
        delta = abs(self.coords(a)[dim] - self.coords(b)[dim])
        return min(delta, d - delta)

    def dim_step(self, a: int, b: int, dim: int) -> int:
        """Direction (+1/-1/0) of the minimal route along ``dim``."""
        d = self.dims[dim]
        ca, cb = self.coords(a)[dim], self.coords(b)[dim]
        if ca == cb:
            return 0
        fwd = (cb - ca) % d
        return +1 if fwd <= d - fwd else -1

    def hop_distance(self, a: int, b: int) -> int:
        return sum(self.dim_distance(a, b, i) for i in range(self.ndims))

    def route(self, src: int, dst: int) -> list[int]:
        """Dimension-ordered (X then Y then Z) minimal route, inclusive.

        This is exactly the APEnet+ router's static dimension-ordered policy:
        all hops along dim 0 first, then dim 1, then dim 2.
        """
        path = [src]
        cur = src
        for dim in range(self.ndims):
            step = self.dim_step(cur, dst, dim)
            while self.coords(cur)[dim] != self.coords(dst)[dim]:
                cur = self.neighbor(cur, dim, step)
                path.append(cur)
        assert cur == dst
        return path

    @property
    def diameter(self) -> int:
        return sum(d // 2 for d in self.dims)

    @property
    def bisection_links(self) -> int:
        """Links crossing a bisection of the longest dimension (torus: 2 rings
        per orthogonal position)."""
        longest = max(self.dims)
        other = self.size // longest
        wrap = 2 if longest > 2 else 1
        return other * wrap

    # -- ring orderings (for collectives) -------------------------------------
    def ring_perm(self, dim: int, step: int = +1) -> list[tuple[int, int]]:
        """(src, dst) pairs sending one hop along ``dim`` — a ppermute perm."""
        return [(r, self.neighbor(r, dim, step)) for r in self.all_ranks()]

    # -- fault analysis (LO|FA|MO support) ------------------------------------
    def live_components(self, failed: set[int]) -> list[set[int]]:
        """Connected components of the surviving node graph."""
        live = [r for r in self.all_ranks() if r not in failed]
        unvisited = set(live)
        comps: list[set[int]] = []
        while unvisited:
            seed = next(iter(unvisited))
            comp = {seed}
            frontier = [seed]
            while frontier:
                r = frontier.pop()
                for n in self.neighbors(r):
                    if n in unvisited and n not in comp:
                        comp.add(n)
                        frontier.append(n)
            unvisited -= comp
            comps.append(comp)
        return comps

    def is_fault_observable(self, failed_node: int, failed: set[int]) -> bool:
        """A failed node is observable iff >= 1 live first-neighbour survives
        (that neighbour's LO|FA|MO HW raises the alarm — paper §4)."""
        return any(n not in failed for n in self.neighbors(failed_node))

    def all_faults_observable(self, failed: set[int]) -> bool:
        return all(self.is_fault_observable(f, failed) for f in failed)


def enumerate_fault_sets(t: Torus, k: int) -> Iterator[set[int]]:
    """All fault sets of size exactly ``k`` (test helper; small tori only)."""
    for combo in itertools.combinations(range(t.size), k):
        yield set(combo)
