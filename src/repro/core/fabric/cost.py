"""Schedule cost estimator — prices a ``CollectiveSchedule`` with the
APElink analytic model (``core.apelink.NetModel``).

One rule, applied uniformly: transfers inside a step ride disjoint link
directions concurrently (full duplex / dual DMA), so a step costs the MAX
of its transfers; steps are sequential rounds, so a schedule costs the SUM
of its steps.  Every transfer is priced as one ``NetModel.latency`` message
of ``frac * nbytes`` payload over its ``hops`` — the same model the paper's
Fig 3 curves come from, now attached to every collective for free.

This is the only place collective time is predicted; benchmarks and the
runtime report *this* number against measured wall time.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.apelink import NetModel
from repro.core.fabric.schedule import BucketPlan, CollectiveSchedule


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    total_s: float
    phase_s: tuple[float, ...]       # per-phase breakdown, lowering order
    rounds: int                      # sequential ppermute rounds
    bytes_per_rank: float            # payload bytes each rank injects
    max_hops: int                    # worst detour in the schedule

    def __str__(self) -> str:
        return (f"{self.total_s * 1e6:.1f} us over {self.rounds} rounds "
                f"({self.bytes_per_rank / 1e6:.3f} MB/rank, "
                f"max {self.max_hops} hops)")


def message_time(nbytes: int, net: NetModel | None = None, *,
                 hops: int = 1, **endpoint_kw) -> float:
    """Single fabric message (the unit every step price is built from).

    A zero-byte message (pure sync step) prices header + latency only —
    injection, reception and the per-hop transits — with no phantom
    payload byte on the wire.
    """
    net = net or NetModel()
    return net.latency(max(int(nbytes), 0), hops=hops, **endpoint_kw)


def hostif_descriptors(nbytes: float,
                       descriptor_bytes: float) -> list[float]:
    """Byte sizes of the §2.1 prefetchable command-queue descriptors one
    host-IF DMA drain of ``nbytes`` splits into: full ``descriptor_bytes``
    chunks plus the partial tail, in issue order (sums to ``nbytes``
    exactly).  This is the preemption granularity of the host interface —
    a bulk drain occupies the host-IF FIFO one descriptor at a time, so a
    queued higher-class descriptor overtakes the *remaining* bulk
    descriptors instead of waiting out the whole PUT.  Shared by
    ``RdmaEndpoint.put_pages`` and the QoS controller benchmarks so both
    price the same split."""
    if descriptor_bytes <= 0:
        raise ValueError(
            f"descriptor_bytes must be > 0, got {descriptor_bytes}")
    if nbytes <= 0:
        return [max(nbytes, 0.0)]
    n = int(-(-nbytes // descriptor_bytes))
    out = [float(descriptor_bytes)] * (n - 1)
    out.append(float(nbytes) - (n - 1) * float(descriptor_bytes))
    return out


BACKENDS = ("analytic", "sim")
FIDELITIES = ("packet", "fluid", "hybrid")


def estimate(schedule: CollectiveSchedule, nbytes: int,
             net: NetModel | None = None, *, backend: str = "analytic",
             fidelity: str = "packet", cls=None,
             **endpoint_kw) -> CostEstimate:
    """Predicted completion time for the collective on an ``nbytes`` input
    (bytes of the per-rank input buffer, matching the transfers' ``frac``
    base).

    ``backend="analytic"`` (the fast path) prices every transfer in
    isolation with the closed-form model above; ``backend="sim"`` replays
    the schedule on the event-driven link-level simulator
    (``fabric.sim.simulate_schedule``) — same sequential-rounds rule, but
    messages become per-link packet walks with credit flow control, so
    transfers that share a link direction contend.  On single-flow
    schedules the two must agree (the ``tests/fabric_checks.py``
    differential); that agreement is the validation of both models.

    ``cls`` tags the traffic class (``fabric.qos.TrafficClass``) of the
    sim backend's flows; the analytic model ignores it — class weights
    only matter under contention, which the closed form never prices.

    ``fidelity`` selects the sim backend's simulator tier:
    ``"packet"`` (the bitwise oracle — the default), ``"fluid"``
    (flow-level rate allocation, O(flows) events — the fast path for
    large tori) or ``"hybrid"`` (fluid with packet-mode escalation of
    contended links).  The analytic backend ignores it.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown cost backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    if fidelity not in FIDELITIES:
        raise ValueError(f"unknown sim fidelity {fidelity!r}; "
                         f"expected one of {FIDELITIES}")
    if backend == "sim":
        from repro.core.fabric import sim as _sim
        if cls is not None:
            endpoint_kw["cls"] = cls
        return _sim.simulate_schedule(schedule, nbytes, net,
                                      fidelity=fidelity, **endpoint_kw)
    net = net or NetModel()
    phase_s = []
    for ph in schedule.phases:
        t = 0.0
        for st in ph.steps:
            if st.transfers:
                t += max(message_time(tr.frac * nbytes, net, hops=tr.hops,
                                      **endpoint_kw)
                         for tr in st.transfers)
        phase_s.append(t)
    return CostEstimate(total_s=sum(phase_s), phase_s=tuple(phase_s),
                        rounds=schedule.rounds,
                        bytes_per_rank=schedule.bytes_per_rank(nbytes),
                        max_hops=schedule.max_hops)


# ----------------------------------------------------------------------------
# overlap-aware estimate (the bucketed engine's timeline model)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OverlapEstimate:
    """Timeline of a bucketed, compute-overlapped schedule execution.

    The model is the schedule-level analogue of the paper's Fig 1 dual-DMA
    timeline: bucket i's collective can start once (a) its gradients exist
    (the backward compute segment feeding it finished) and (b) the fabric
    finished bucket i-1.  Comm that runs while backward compute is still in
    flight is *hidden*; whatever sticks out past the end of compute is
    *exposed* and is the only comm the step actually pays for.
    """

    total_s: float               # overlapped wall time (end of last bucket)
    sequential_s: float          # barrier baseline: compute + monolithic comm
    compute_s: float             # backward compute total
    comm_s: float                # sum of bucket wire times
    overhead_s: float            # exposed command-issue gaps (queue model)
    exposed_comm_s: float        # comm past the end of compute
    hidden_comm_s: float         # comm that ran under compute
    bucket_comm_s: tuple[float, ...]   # per-bucket wire time, issue order
    bucket_start_s: tuple[float, ...]  # per-bucket comm start on the timeline
    queue_depth: int

    @property
    def efficiency(self) -> float:
        """Fraction of fabric time hidden behind compute (1.0 = all)."""
        busy = self.hidden_comm_s + self.exposed_comm_s
        return self.hidden_comm_s / busy if busy > 0 else 1.0

    @property
    def reduction(self) -> float:
        """Total-time reduction vs the sequential barrier baseline."""
        if self.sequential_s <= 0:
            return 0.0
        return 1.0 - self.total_s / self.sequential_s

    def __str__(self) -> str:
        return (f"overlapped {self.total_s * 1e3:.3f} ms vs sequential "
                f"{self.sequential_s * 1e3:.3f} ms "
                f"({self.reduction * 100:.1f}% cut; "
                f"{self.hidden_comm_s * 1e3:.3f} ms comm hidden, "
                f"{self.exposed_comm_s * 1e3:.3f} ms exposed)")


def estimate_overlapped(schedule: CollectiveSchedule,
                        buckets: BucketPlan | Sequence[int],
                        compute_s: float | Sequence[float],
                        net: NetModel | None = None, *,
                        queue_depth: int = 2,
                        issue_gap_s: float = 0.85e-6,
                        backend: str = "analytic",
                        fidelity: str = "packet",
                        cls=None, **endpoint_kw) -> OverlapEstimate:
    """Price a bucketed, compute-overlapped execution of ``schedule``.

    ``buckets`` is a ``BucketPlan`` (or raw per-bucket byte counts) in
    issue order; ``compute_s`` is the backward compute trace — either one
    per-bucket segment each (segment i must finish before bucket i's grads
    exist) or a scalar total split proportionally to bucket bytes.

    ``queue_depth`` is the RDMA command queue's in-flight slots
    (``RdmaEndpoint.queue_depth``): with >= 2 slots the next bucket's
    command is prefetched while the fabric is busy, hiding the issue gap
    exactly like the second DMA engine of §2.1; a depth-1 queue pays
    ``issue_gap_s`` per bucket.  The sequential baseline is the monolithic
    post-backward barrier: all compute, then ONE schedule moving the whole
    payload.  ``backend`` (and, for the sim backend, ``fidelity``) selects
    how each bucket's wire time is priced (see ``estimate``); the timeline
    algebra on top is backend-agnostic.
    """
    net = net or NetModel()
    nbytes = (tuple(buckets.bucket_nbytes)
              if isinstance(buckets, BucketPlan) else tuple(buckets))
    if queue_depth < 1:
        raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
    nb = len(nbytes)
    if isinstance(compute_s, (int, float)):
        total = sum(nbytes)
        comp = (tuple(float(compute_s) * b / total for b in nbytes)
                if total > 0 else tuple(0.0 for _ in nbytes))
    else:
        comp = tuple(float(c) for c in compute_s)
        if len(comp) != nb:
            raise ValueError(
                f"compute trace has {len(comp)} segments for {nb} buckets")
    comm = tuple(estimate(schedule, b, net, backend=backend,
                          fidelity=fidelity, cls=cls, **endpoint_kw).total_s
                 for b in nbytes)
    compute_total = sum(comp)
    t = 0.0            # fabric busy-until
    elapsed = 0.0      # compute frontier
    starts, gaps = [], []
    for c_seg, m_s in zip(comp, comm):
        elapsed += c_seg           # this bucket's grads exist now
        if queue_depth >= 2 and t > elapsed:
            start, gap = t, 0.0    # command was prefetched while fabric busy
        else:
            start = max(t, elapsed) + issue_gap_s
            gap = issue_gap_s      # fabric idle at issue: gap is exposed
        starts.append(start)
        gaps.append(gap)
        t = start + m_s
    total_s = max(t, compute_total)
    exposed = total_s - compute_total
    busy = sum(comm) + sum(gaps)
    hidden = max(0.0, busy - exposed)
    seq = (compute_total + issue_gap_s
           + estimate(schedule, sum(nbytes), net, backend=backend,
                      fidelity=fidelity, cls=cls, **endpoint_kw).total_s
           if nbytes else compute_total)
    return OverlapEstimate(
        total_s=total_s, sequential_s=seq, compute_s=compute_total,
        comm_s=sum(comm), overhead_s=sum(gaps), exposed_comm_s=exposed,
        hidden_comm_s=hidden, bucket_comm_s=comm,
        bucket_start_s=tuple(starts), queue_depth=queue_depth)


def algorithmic_bandwidth(schedule: CollectiveSchedule, nbytes: int,
                          net: NetModel | None = None) -> float:
    """Collective goodput: input bytes / predicted time (bytes/s)."""
    t = estimate(schedule, nbytes, net).total_s
    return nbytes / t if t > 0 else float("inf")
