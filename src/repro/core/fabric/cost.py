"""Schedule cost estimator — prices a ``CollectiveSchedule`` with the
APElink analytic model (``core.apelink.NetModel``).

One rule, applied uniformly: transfers inside a step ride disjoint link
directions concurrently (full duplex / dual DMA), so a step costs the MAX
of its transfers; steps are sequential rounds, so a schedule costs the SUM
of its steps.  Every transfer is priced as one ``NetModel.latency`` message
of ``frac * nbytes`` payload over its ``hops`` — the same model the paper's
Fig 3 curves come from, now attached to every collective for free.

This is the only place collective time is predicted; benchmarks and the
runtime report *this* number against measured wall time.
"""
from __future__ import annotations

import dataclasses

from repro.core.apelink import NetModel
from repro.core.fabric.schedule import CollectiveSchedule


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    total_s: float
    phase_s: tuple[float, ...]       # per-phase breakdown, lowering order
    rounds: int                      # sequential ppermute rounds
    bytes_per_rank: float            # payload bytes each rank injects
    max_hops: int                    # worst detour in the schedule

    def __str__(self) -> str:
        return (f"{self.total_s * 1e6:.1f} us over {self.rounds} rounds "
                f"({self.bytes_per_rank / 1e6:.3f} MB/rank, "
                f"max {self.max_hops} hops)")


def message_time(nbytes: int, net: NetModel | None = None, *,
                 hops: int = 1, **endpoint_kw) -> float:
    """Single fabric message (the unit every step price is built from)."""
    net = net or NetModel()
    return net.latency(max(int(nbytes), 1), hops=hops, **endpoint_kw)


def estimate(schedule: CollectiveSchedule, nbytes: int,
             net: NetModel | None = None, **endpoint_kw) -> CostEstimate:
    """Predicted completion time for the collective on an ``nbytes`` input
    (bytes of the per-rank input buffer, matching the transfers' ``frac``
    base)."""
    net = net or NetModel()
    phase_s = []
    for ph in schedule.phases:
        t = 0.0
        for st in ph.steps:
            if st.transfers:
                t += max(message_time(tr.frac * nbytes, net, hops=tr.hops,
                                      **endpoint_kw)
                         for tr in st.transfers)
        phase_s.append(t)
    return CostEstimate(total_s=sum(phase_s), phase_s=tuple(phase_s),
                        rounds=schedule.rounds,
                        bytes_per_rank=schedule.bytes_per_rank(nbytes),
                        max_hops=schedule.max_hops)


def algorithmic_bandwidth(schedule: CollectiveSchedule, nbytes: int,
                          net: NetModel | None = None) -> float:
    """Collective goodput: input bytes / predicted time (bytes/s)."""
    t = estimate(schedule, nbytes, net).total_s
    return nbytes / t if t > 0 else float("inf")
