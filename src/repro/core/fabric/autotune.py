"""Fabric design-space autotuner — ArchGym-style agent/environment split
over the knobs PRs 1-6 exposed.

APEnet+'s authors tuned torus shape, channel arbitration and DMA buffer
sizing by hand across FPGA generations (arXiv:1311.1741 carries forward
the arXiv:1102.3796 switch datapath with re-tuned parameters).  This
repo exposed every one of those knobs in software — torus dims, per-class
``QosPolicy`` weights and credit fractions, the overlap engine's bucket
byte target, the multi-path stripe count, the migration route policy —
but every benchmark still ran hand-picked defaults.  This module turns
those one-offs into *searched, packet-verified* configurations, following
the agent/environment decomposition of ArchGym (Krishnan et al., ISCA
2023): a gym-style environment prices one candidate configuration per
``step`` on a replayed workload, and interchangeable search agents
(seeded random-walk, genetic, GP-based Bayesian optimisation) drive it.

The two-fidelity discipline is the point of the design: the *inner* loop
scores every candidate on the **fluid** tier (PR 6's flow-level rate
solver, ~150x cheaper than the packet oracle), and only the top-k
finalists are re-scored on the **packet** oracle before a winner is
declared — so the search is cheap and the published number is honest.

    space  = ConfigSpace(n_nodes=16)
    env    = FabricEnv(space, serving_replay(16), fidelity="fluid")
    result = search(env, GeneticAgent(), steps=40, seed=0)
    winner = rescore(env, finalists(result), fidelity="packet")

Winning configurations persist as ``best_configs.json`` (per workload:
config, fluid + packet objectives, trajectory summary).  ``TrainerConfig``
(``bucket_mb``) and ``ServingCluster`` (qos / route_policy / stripe_k)
load that file by default — explicit arguments always win, and a missing
file silently keeps the legacy defaults, so the artifact is an overlay,
never a dependency.  Set ``BEST_CONFIGS=<path>`` to point elsewhere or
``BEST_CONFIGS=0`` to disable loading (the test suite pins the latter).
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import time
from typing import Mapping, Sequence

import numpy as np

from repro.core import fabric
from repro.core.apelink import NetModel
from repro.core.fabric.qos import QosPolicy, TrafficClass
from repro.core.topology import Torus

ROUTE_POLICIES = ("hops", "congestion", "striped")

#: env var naming the best-config artifact ("0"/"" disables loading)
BEST_CONFIGS_ENV = "BEST_CONFIGS"
BEST_CONFIGS_FILE = "best_configs.json"

_CLASSES = tuple(TrafficClass)


# ---------------------------------------------------------------------------
# configuration point + typed search space
# ---------------------------------------------------------------------------

def torus_shapes(n_nodes: int, max_ndims: int = 4) -> tuple[tuple[int, ...], ...]:
    """Candidate torus shapes for ``n_nodes``: every factorization into
    dims >= 2 (sorted descending, up to ``max_ndims`` dims) plus the flat
    ring ``(n,)`` — the discrete geometry axis of the design space."""
    if n_nodes < 2:
        raise ValueError(f"need >= 2 nodes, got {n_nodes}")
    shapes: set[tuple[int, ...]] = {(n_nodes,)}

    def rec(rem: int, maxf: int, acc: tuple[int, ...]) -> None:
        if rem == 1 and len(acc) >= 2:
            shapes.add(acc)
            return
        if len(acc) >= max_ndims:
            return
        f = min(maxf, rem)
        while f >= 2:
            if rem % f == 0:
                rec(rem // f, f, acc + (f,))
            f -= 1

    rec(n_nodes, n_nodes, ())
    return tuple(sorted(shapes))


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """One point of the design space — every knob the search may turn.

    ``qos_weights`` / ``qos_credit_frac`` are in ``TrafficClass`` order
    (CONTROL, DECODE, COLLECTIVE, BULK); ``qos_single=True`` collapses
    them onto the legacy single-FIFO link (the pre-QoS default the
    search must beat).  ``ctl_gain`` / ``ctl_decay`` / ``ctl_floor`` are
    the closed-loop controller's step sizes and relief floor
    (``fabric.QosCtlPolicy`` — the static weights above are its
    *baseline*, these knobs shape how far and how fast it departs from
    them)."""

    torus_dims: tuple[int, ...]
    qos_single: bool = True
    qos_weights: tuple[float, ...] = (4.0, 16.0, 8.0, 1.0)
    qos_credit_frac: tuple[float, ...] = (0.10, 0.40, 0.30, 0.20)
    bucket_mb: float = 4.0
    stripe_k: int = 1
    route_policy: str = "hops"
    ctl_gain: float = 1.6
    ctl_decay: float = 0.6
    ctl_floor: float = 0.25

    def qos(self) -> QosPolicy:
        """The ``QosPolicy`` this config lowers to."""
        if self.qos_single:
            return QosPolicy(single_class=True)
        return QosPolicy(
            weights=dict(zip(_CLASSES, self.qos_weights)),
            credit_frac=dict(zip(_CLASSES, self.qos_credit_frac)))

    def to_jsonable(self) -> dict:
        d = dataclasses.asdict(self)
        d["torus_dims"] = list(self.torus_dims)
        d["qos_weights"] = list(self.qos_weights)
        d["qos_credit_frac"] = list(self.qos_credit_frac)
        return d

    @classmethod
    def from_jsonable(cls, d: Mapping) -> "FabricConfig":
        # controller knobs arrived a PR later than the rest: artifacts
        # pinned before them load with the defaults, not a KeyError
        return cls(torus_dims=tuple(int(x) for x in d["torus_dims"]),
                   qos_single=bool(d["qos_single"]),
                   qos_weights=tuple(float(x) for x in d["qos_weights"]),
                   qos_credit_frac=tuple(float(x)
                                         for x in d["qos_credit_frac"]),
                   bucket_mb=float(d["bucket_mb"]),
                   stripe_k=int(d["stripe_k"]),
                   route_policy=str(d["route_policy"]),
                   ctl_gain=float(d.get("ctl_gain", 1.6)),
                   ctl_decay=float(d.get("ctl_decay", 0.6)),
                   ctl_floor=float(d.get("ctl_floor", 0.25)))


class ConfigSpace:
    """The typed design space: sampling, mutation, crossover, a fixed
    vector encoding (for the GP agent and the env observation), and
    validation.  All randomness comes from the caller's ``random.Random``
    so searches are exactly reproducible from their seed."""

    def __init__(self, n_nodes: int, *,
                 bucket_range_mb: tuple[float, float] = (1.0, 256.0),
                 weight_range: tuple[float, float] = (1.0, 32.0),
                 min_credit_frac: float = 0.05,
                 stripe_max: int = 4,
                 ctl_gain_range: tuple[float, float] = (1.1, 3.0)) -> None:
        if bucket_range_mb[0] <= 0 or bucket_range_mb[0] > bucket_range_mb[1]:
            raise ValueError(f"bad bucket range {bucket_range_mb}")
        if stripe_max < 1:
            raise ValueError(f"stripe_max must be >= 1, got {stripe_max}")
        if not 1.0 < ctl_gain_range[0] <= ctl_gain_range[1]:
            raise ValueError(f"bad ctl_gain range {ctl_gain_range}")
        self.n_nodes = n_nodes
        self.shapes = torus_shapes(n_nodes)
        self.bucket_range_mb = (float(bucket_range_mb[0]),
                                float(bucket_range_mb[1]))
        self.weight_range = (float(weight_range[0]), float(weight_range[1]))
        self.min_credit_frac = float(min_credit_frac)
        self.stripe_max = int(stripe_max)
        self.ctl_gain_range = (float(ctl_gain_range[0]),
                               float(ctl_gain_range[1]))

    # -- canonical points -----------------------------------------------------
    def default(self) -> FabricConfig:
        """The hand-picked pre-QoS baseline every benchmark ran before
        this PR: squarest torus, single-FIFO link, dimension-ordered
        routing, no striping, 4 MB buckets.  This is the config the
        ``autotune_gain`` gate compares winners against."""
        return FabricConfig(torus_dims=self._squarest())

    def hand_tuned(self) -> FabricConfig:
        """The PR-5/6 hand-tuned operating point (default ``QosPolicy``,
        congestion-probed routes, 3-way striping) — a strong seed for the
        agents' initial populations, and the bar a search should at least
        reach."""
        return FabricConfig(
            torus_dims=self._squarest(), qos_single=False,
            qos_weights=tuple(float(w) for w in
                              QosPolicy().weight_vector()),
            qos_credit_frac=(0.10, 0.40, 0.30, 0.20),
            bucket_mb=4.0, stripe_k=3, route_policy="striped")

    def _squarest(self) -> tuple[int, ...]:
        # the repo's hand-pick convention: a balanced 2-ish-D mesh
        # (PagedLM defaults Torus((4, 4)), contention runs (4, 4, 4))
        return min(self.shapes,
                   key=lambda s: (abs(len(s) - 2), max(s) - min(s)))

    # -- sampling / perturbation ---------------------------------------------
    def sample(self, rng: random.Random) -> FabricConfig:
        lo, hi = self.weight_range
        blo, bhi = self.bucket_range_mb
        glo, ghi = self.ctl_gain_range
        fracs = self._norm_fracs([rng.random() + self.min_credit_frac
                                  for _ in _CLASSES])
        return FabricConfig(
            torus_dims=rng.choice(self.shapes),
            qos_single=rng.random() < 0.2,
            qos_weights=tuple(round(np.exp(rng.uniform(np.log(lo),
                                                       np.log(hi))), 4)
                              for _ in _CLASSES),
            qos_credit_frac=fracs,
            bucket_mb=round(float(np.exp(rng.uniform(np.log(blo),
                                                     np.log(bhi)))), 4),
            stripe_k=rng.randint(1, self.stripe_max),
            route_policy=rng.choice(ROUTE_POLICIES),
            ctl_gain=round(rng.uniform(glo, ghi), 4),
            ctl_decay=round(rng.uniform(0.3, 0.9), 4),
            ctl_floor=round(rng.uniform(0.1, 0.8), 4))

    def mutate(self, cfg: FabricConfig, rng: random.Random,
               scale: float = 0.5) -> FabricConfig:
        """Perturb 1-2 knobs of ``cfg`` (log-normal nudges on continuous
        knobs, neighbour moves on discrete ones)."""
        self.validate(cfg)
        d = cfg.to_jsonable()
        knobs = ["torus_dims", "qos_single", "qos_weights",
                 "qos_credit_frac", "bucket_mb", "stripe_k", "route_policy",
                 "ctl"]
        for knob in rng.sample(knobs, k=rng.randint(1, 2)):
            if knob == "torus_dims":
                d[knob] = list(rng.choice(self.shapes))
            elif knob == "qos_single":
                d[knob] = not d[knob]
            elif knob == "qos_weights":
                i = rng.randrange(len(_CLASSES))
                w = d[knob][i] * float(np.exp(rng.gauss(0.0, scale)))
                d[knob][i] = round(self._clip(w, *self.weight_range), 4)
                d["qos_single"] = False
            elif knob == "qos_credit_frac":
                i = rng.randrange(len(_CLASSES))
                d[knob][i] *= float(np.exp(rng.gauss(0.0, scale)))
                d[knob] = list(self._norm_fracs(d[knob]))
                d["qos_single"] = False
            elif knob == "bucket_mb":
                b = d[knob] * float(np.exp(rng.gauss(0.0, 2 * scale)))
                d[knob] = round(self._clip(b, *self.bucket_range_mb), 4)
            elif knob == "stripe_k":
                d[knob] = self._clip(d[knob] + rng.choice((-1, 1)),
                                     1, self.stripe_max)
            elif knob == "ctl":
                g = d["ctl_gain"] * float(np.exp(rng.gauss(0.0, scale)))
                d["ctl_gain"] = round(self._clip(g, *self.ctl_gain_range), 4)
                d["ctl_decay"] = round(self._clip(
                    d["ctl_decay"] * float(np.exp(rng.gauss(0.0, scale))),
                    0.3, 0.9), 4)
                d["ctl_floor"] = round(self._clip(
                    d["ctl_floor"] * float(np.exp(rng.gauss(0.0, scale))),
                    0.1, 0.8), 4)
            else:
                d[knob] = rng.choice(ROUTE_POLICIES)
        return FabricConfig.from_jsonable(d)

    def crossover(self, a: FabricConfig, b: FabricConfig,
                  rng: random.Random) -> FabricConfig:
        """Uniform per-knob crossover (QoS weights/fractions travel with
        the ``qos_single`` flag so a child never mixes FIFO with one
        parent's weight vector incoherently)."""
        da, db = a.to_jsonable(), b.to_jsonable()
        child = {}
        qos_src = da if rng.random() < 0.5 else db
        for k in ("qos_single", "qos_weights", "qos_credit_frac"):
            child[k] = qos_src[k]
        for k in ("torus_dims", "bucket_mb", "stripe_k", "route_policy"):
            child[k] = (da if rng.random() < 0.5 else db)[k]
        # the controller's three knobs travel together (gain/decay/floor
        # form one damping profile — mixing parents' halves of it breaks
        # the stability the search scored)
        ctl_src = da if rng.random() < 0.5 else db
        for k in ("ctl_gain", "ctl_decay", "ctl_floor"):
            child[k] = ctl_src[k]
        return FabricConfig.from_jsonable(child)

    # -- encoding (GP features / env observation) -----------------------------
    def encode(self, cfg: FabricConfig) -> np.ndarray:
        """Fixed-length [0, 1] feature vector: shape index, FIFO flag,
        log-weights, credit fractions, log-bucket, stripes, route index."""
        lo, hi = np.log(self.weight_range[0]), np.log(self.weight_range[1])
        blo, bhi = (np.log(self.bucket_range_mb[0]),
                    np.log(self.bucket_range_mb[1]))
        feats = [self.shapes.index(cfg.torus_dims) / max(len(self.shapes) - 1,
                                                         1),
                 1.0 if cfg.qos_single else 0.0]
        feats += [(np.log(w) - lo) / max(hi - lo, 1e-12)
                  for w in cfg.qos_weights]
        feats += list(cfg.qos_credit_frac)
        feats.append((np.log(cfg.bucket_mb) - blo) / max(bhi - blo, 1e-12))
        feats.append((cfg.stripe_k - 1) / max(self.stripe_max - 1, 1))
        feats.append(ROUTE_POLICIES.index(cfg.route_policy)
                     / (len(ROUTE_POLICIES) - 1))
        glo, ghi = self.ctl_gain_range
        feats.append((cfg.ctl_gain - glo) / max(ghi - glo, 1e-12))
        feats.append((cfg.ctl_decay - 0.3) / 0.6)
        feats.append((cfg.ctl_floor - 0.1) / 0.7)
        return np.asarray(feats, dtype=np.float64)

    @property
    def encoded_dim(self) -> int:
        return 8 + 2 * len(_CLASSES)

    # -- validation -----------------------------------------------------------
    def validate(self, cfg: FabricConfig) -> None:
        n = 1
        for d in cfg.torus_dims:
            n *= d
        if n != self.n_nodes:
            raise ValueError(f"torus_dims {cfg.torus_dims} has {n} nodes, "
                             f"space wants {self.n_nodes}")
        if cfg.torus_dims not in self.shapes:
            raise ValueError(f"torus_dims {cfg.torus_dims} not a canonical "
                             f"shape of {self.n_nodes} nodes")
        if len(cfg.qos_weights) != len(_CLASSES) \
                or len(cfg.qos_credit_frac) != len(_CLASSES):
            raise ValueError("need one weight + credit fraction per "
                             f"TrafficClass, got {cfg.qos_weights} / "
                             f"{cfg.qos_credit_frac}")
        if any(w <= 0 for w in cfg.qos_weights) \
                or any(f <= 0 for f in cfg.qos_credit_frac):
            raise ValueError("QoS weights and credit fractions must be > 0")
        if not (0 < cfg.bucket_mb):
            raise ValueError(f"bucket_mb must be > 0, got {cfg.bucket_mb}")
        if not 1 <= cfg.stripe_k <= self.stripe_max:
            raise ValueError(f"stripe_k {cfg.stripe_k} outside "
                             f"[1, {self.stripe_max}]")
        if cfg.route_policy not in ROUTE_POLICIES:
            raise ValueError(f"unknown route_policy {cfg.route_policy!r}; "
                             f"expected one of {ROUTE_POLICIES}")
        if not self.ctl_gain_range[0] <= cfg.ctl_gain \
                <= self.ctl_gain_range[1]:
            raise ValueError(f"ctl_gain {cfg.ctl_gain} outside "
                             f"{self.ctl_gain_range}")
        if not 0.0 < cfg.ctl_decay < 1.0:
            raise ValueError(
                f"ctl_decay must be in (0, 1), got {cfg.ctl_decay}")
        if not 0.0 < cfg.ctl_floor <= 1.0:
            raise ValueError(
                f"ctl_floor must be in (0, 1], got {cfg.ctl_floor}")

    def _norm_fracs(self, fracs: Sequence[float]) -> tuple[float, ...]:
        f = np.clip(np.asarray(fracs, dtype=float), self.min_credit_frac,
                    None)
        f = f / f.sum()
        return tuple(round(float(x), 4) for x in f)

    @staticmethod
    def _clip(v, lo, hi):
        return max(lo, min(hi, v))


# ---------------------------------------------------------------------------
# replayed workloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplaySpec:
    """One replayable workload: what traffic hits the fabric, and how the
    per-class completion spans weigh into the scalar objective.  The same
    spec replays identically at any fidelity tier — that is what makes
    the fluid-inner-loop / packet-finalist discipline coherent."""

    name: str
    n_nodes: int
    # serving side: chained decode-step TP all-reduces (DECODE class)
    decode_steps: int = 0
    tp_step_bytes: int = 8 << 20
    # bulk side: (src, dst, nbytes) one-shot PUTs (BULK class), each
    # preceded by a 64 B CONTROL descriptor — routed per config
    bulk: tuple[tuple[int, int, int], ...] = ()
    # trainer side: grad_bytes of fp32 gradients reduce-scattered in
    # config.bucket_mb buckets, bucket i's grads materialising at
    # (i+1)/n of compute_s (the backward-readiness stagger)
    grad_bytes: int = 0
    compute_s: float = 0.0
    # objective = decode_w*decode_span + bulk_w*bulk_span + train_w*train
    decode_weight: float = 1.0
    bulk_weight: float = 0.25
    train_weight: float = 1.0
    packet_bytes: int = 40960   # coarse packets: same grid both tiers


def serving_replay(n_nodes: int = 16, *, decode_steps: int = 4,
                   tp_step_bytes: int = 8 << 20,
                   bulk_bytes: int = 32 << 20) -> ReplaySpec:
    """The gated serving workload: a continuous decode TP stream while
    two bulk KV-migration PUTs cross the fabric — the co-location regime
    of ``benchmarks/contention``/``qos``, now as a search target."""
    t = Torus((n_nodes,))
    pairs = ((0, t.size // 2 + t.size // 8), (t.size // 4, t.size - 1))
    return ReplaySpec(name="serving", n_nodes=n_nodes,
                      decode_steps=decode_steps,
                      tp_step_bytes=tp_step_bytes,
                      bulk=tuple((s, d, bulk_bytes) for s, d in pairs))


def training_replay(n_nodes: int = 16, *, grad_bytes: int = 128 << 20,
                    compute_s: float = 15e-3) -> ReplaySpec:
    """The trainer workload: one backward pass's bucketed gradient
    reduce-scatter under the readiness stagger — the carried "sim-driven
    bucket sizing" item as an inner objective (too-small buckets pay
    per-message latency x count, too-big ones serialize behind compute)."""
    return ReplaySpec(name="train", n_nodes=n_nodes, grad_bytes=grad_bytes,
                      compute_s=compute_s)


@dataclasses.dataclass(frozen=True)
class ScoreReport:
    """One configuration priced on one fidelity tier."""

    objective_s: float
    decode_span_s: float
    bulk_span_s: float
    train_span_s: float
    makespan_s: float
    fidelity: str
    wall_s: float

    def to_jsonable(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# the environment
# ---------------------------------------------------------------------------

class FabricEnv:
    """Gym-style environment over ``make_sim`` + one replayed workload.

    ``reset() -> obs``; ``step(config) -> (obs, reward, done, info)`` with
    ``reward = -objective_s`` (negative modelled completion objective —
    decode-span-dominated for serving replays, makespan for training
    replays).  ``done`` is always False: the step budget belongs to the
    driver (``search``), not the env.  ``score`` is the pure pricing
    function ``step`` wraps; pass ``fidelity="packet"`` there to re-score
    a finalist on the oracle.

    Route resolution (``route_policy="congestion"|"striped"``) always
    probes a *fluid* replica of the workload, whatever fidelity then
    prices the resulting timeline — the probe tier is part of the
    configuration under test (it is what a production router on a big
    torus would run), and it keeps the flow set identical across tiers so
    the finalist re-score measures modelling error, not routing drift.
    """

    def __init__(self, space: ConfigSpace, spec: ReplaySpec, *,
                 fidelity: str = "fluid", net: NetModel | None = None)\
            -> None:
        if spec.n_nodes != space.n_nodes:
            raise ValueError(f"spec wants {spec.n_nodes} nodes, space has "
                             f"{space.n_nodes}")
        self.space = space
        self.spec = spec
        self.fidelity = fidelity
        self.net = net or NetModel()
        self.history: list[tuple[FabricConfig, ScoreReport]] = []
        self._last_obs = np.zeros(space.encoded_dim + 1)

    # -- gym surface ----------------------------------------------------------
    def reset(self, seed: int | None = None) -> np.ndarray:
        del seed   # the env itself is deterministic; agents own the rng
        self.history = []
        self._last_obs = np.zeros(self.space.encoded_dim + 1)
        return self._last_obs

    def step(self, config: FabricConfig)\
            -> tuple[np.ndarray, float, bool, dict]:
        report = self.score(config)
        self.history.append((config, report))
        obs = np.concatenate([self.space.encode(config),
                              [report.objective_s * 1e3]])
        self._last_obs = obs
        return obs, -report.objective_s, False, {"report": report,
                                                 "config": config}

    # -- pricing --------------------------------------------------------------
    def score(self, config: FabricConfig,
              fidelity: str | None = None) -> ScoreReport:
        self.space.validate(config)
        fidelity = fidelity or self.fidelity
        t0 = time.perf_counter()
        plans = self._resolve_bulk_routes(config)
        sim = self._make_sim(config, fidelity)
        decode, bulk, train = self._inject(sim, config, plans)
        sim.run()

        def span(fids):
            return max((sim.finish_s(f) for f in fids), default=0.0)

        d, b, tr = span(decode), span(bulk), span(train)
        obj = (self.spec.decode_weight * d + self.spec.bulk_weight * b
               + self.spec.train_weight * tr)
        return ScoreReport(objective_s=obj, decode_span_s=d, bulk_span_s=b,
                           train_span_s=tr, makespan_s=max(d, b, tr),
                           fidelity=fidelity,
                           wall_s=time.perf_counter() - t0)

    # -- workload replay ------------------------------------------------------
    def _make_sim(self, config: FabricConfig, fidelity: str):
        return fabric.make_sim(Torus(config.torus_dims), self.net,
                               fidelity=fidelity, qos=config.qos(),
                               packet_bytes=self.spec.packet_bytes)

    def _resolve_bulk_routes(self, config: FabricConfig) -> list[list]:
        """Per-bulk-transfer ``[(route | None, frac), ...]`` stripe plans,
        probed against a fluid replica carrying the decode stream and the
        previously-routed bulk flows."""
        if not self.spec.bulk:
            return []
        if config.route_policy == "hops":
            return [[(None, 1.0)] for _ in self.spec.bulk]
        probe = self._make_sim(config, "fluid")
        self._inject_decode(probe, Torus(config.torus_dims))
        plans: list[list] = []
        for src, dst, nbytes in self.spec.bulk:
            if config.route_policy == "congestion":
                route, _ = fabric.best_route(probe, src, dst, nbytes,
                                             cls=TrafficClass.BULK)
                plan = [(route, 1.0)]
            else:
                plan = [(r, f) for r, f in fabric.striped_routes(
                    probe, src, dst, nbytes, k=config.stripe_k,
                    cls=TrafficClass.BULK) if f > 0]
            plans.append(plan)
            for route, frac in plan:   # later probes see earlier bulk
                probe.inject(src, dst, frac * nbytes, route=route,
                             cls=TrafficClass.BULK)
        return plans

    def _inject_decode(self, sim, torus: Torus) -> list[int]:
        fids: list[int] = []
        if not self.spec.decode_steps:
            return fids
        tp = fabric.lower(fabric.AR, torus, tuple(range(torus.ndims)))
        tail: list[int] = []
        for _ in range(self.spec.decode_steps):
            tail = fabric.inject_schedule(
                sim, tp, self.spec.tp_step_bytes, start_s=0.0,
                after=tuple(tail), granularity="phase",
                cls=TrafficClass.DECODE)
            fids.extend(tail)
        return fids

    def _inject(self, sim, config: FabricConfig, plans: list[list])\
            -> tuple[list[int], list[int], list[int]]:
        torus = Torus(config.torus_dims)
        decode = self._inject_decode(sim, torus)
        bulk: list[int] = []
        for (src, dst, nbytes), plan in zip(self.spec.bulk, plans):
            sim.inject(src, dst, 64, cls=TrafficClass.CONTROL)
            for route, frac in plan:
                bulk.append(sim.inject(src, dst, frac * nbytes, route=route,
                                       cls=TrafficClass.BULK))
        train: list[int] = []
        if self.spec.grad_bytes:
            rs = fabric.lower(fabric.RS, torus, tuple(range(torus.ndims)))
            bucket = max(int(config.bucket_mb * (1 << 20)), 1)
            n = -(-self.spec.grad_bytes // bucket)
            tail: list[int] = []
            for i in range(n):
                nb = min(bucket, self.spec.grad_bytes - i * bucket)
                ready = (i + 1) * self.spec.compute_s / n
                tail = fabric.inject_schedule(
                    sim, rs, nb, start_s=ready, after=tuple(tail),
                    granularity="phase", cls=TrafficClass.COLLECTIVE)
                train.extend(tail)
        return decode, bulk, train


# ---------------------------------------------------------------------------
# search agents
# ---------------------------------------------------------------------------

class SearchAgent:
    """ask/tell agent base: ``reset(space, rng)`` binds the (seeded)
    stream, ``ask()`` proposes a config, ``tell(config, reward)`` reports
    its reward (bigger = better; the env's is ``-objective_s``)."""

    name = "agent"

    def reset(self, space: ConfigSpace, rng: random.Random) -> None:
        self.space = space
        self.rng = rng
        self.best: FabricConfig | None = None
        self.best_reward = -np.inf
        self._n = 0

    def ask(self) -> FabricConfig:
        raise NotImplementedError

    def tell(self, config: FabricConfig, reward: float) -> None:
        self._n += 1
        if reward > self.best_reward:
            self.best_reward = reward
            self.best = config

    def _seeds(self) -> list[FabricConfig]:
        """Every agent warm-starts from the two canonical points: the
        pre-QoS default and the PR-5 hand-tuned operating point."""
        return [self.space.default(), self.space.hand_tuned()]


class RandomWalkAgent(SearchAgent):
    """Seeded greedy random walk: mutate the incumbent best, with an
    ``eps`` chance of a fresh uniform sample (restart pressure)."""

    name = "random_walk"

    def __init__(self, eps: float = 0.25) -> None:
        self.eps = eps

    def ask(self) -> FabricConfig:
        seeds = self._seeds()
        if self._n < len(seeds):
            return seeds[self._n]
        if self.best is None or self.rng.random() < self.eps:
            return self.space.sample(self.rng)
        return self.space.mutate(self.best, self.rng)


class GeneticAgent(SearchAgent):
    """Steady-state GA: tournament parent selection over the telled
    population, crossover + mutation children, truncation survival."""

    name = "genetic"

    def __init__(self, pop_size: int = 8, tournament: int = 3,
                 crossover_p: float = 0.6) -> None:
        self.pop_size = pop_size
        self.tournament = tournament
        self.crossover_p = crossover_p

    def reset(self, space: ConfigSpace, rng: random.Random) -> None:
        super().reset(space, rng)
        self.pop: list[tuple[float, FabricConfig]] = []

    def ask(self) -> FabricConfig:
        seeds = self._seeds()
        if self._n < len(seeds):
            return seeds[self._n]
        if len(self.pop) < self.pop_size:
            return self.space.sample(self.rng)
        if self.rng.random() < self.crossover_p:
            a = self._select()
            b = self._select()
            child = self.space.crossover(a, b, self.rng)
            return self.space.mutate(child, self.rng)
        return self.space.mutate(self._select(), self.rng)

    def tell(self, config: FabricConfig, reward: float) -> None:
        super().tell(config, reward)
        self.pop.append((reward, config))
        if len(self.pop) > self.pop_size:
            self.pop.sort(key=lambda p: -p[0])
            del self.pop[self.pop_size:]

    def _select(self) -> FabricConfig:
        picks = [self.pop[self.rng.randrange(len(self.pop))]
                 for _ in range(min(self.tournament, len(self.pop)))]
        return max(picks, key=lambda p: p[0])[1]


class GpBoAgent(SearchAgent):
    """Plain-NumPy Gaussian-process Bayesian optimisation: RBF kernel on
    the space encoding, expected improvement maximised over a sampled
    candidate pool (half fresh samples, half mutations of the best telled
    configs) — the "simple BO loop" ArchGym fields beside GA/RL."""

    name = "gp_bo"

    def __init__(self, warmup: int = 6, pool: int = 96,
                 length_scale: float = 0.5, noise: float = 1e-6) -> None:
        self.warmup = warmup
        self.pool = pool
        self.length_scale = length_scale
        self.noise = noise

    def reset(self, space: ConfigSpace, rng: random.Random) -> None:
        super().reset(space, rng)
        self.X: list[np.ndarray] = []
        self.y: list[float] = []
        self.telled: list[tuple[float, FabricConfig]] = []

    def ask(self) -> FabricConfig:
        seeds = self._seeds()
        if self._n < len(seeds):
            return seeds[self._n]
        if len(self.y) < self.warmup:
            return self.space.sample(self.rng)
        cands = [self.space.sample(self.rng) for _ in range(self.pool // 2)]
        top = sorted(self.telled, key=lambda p: -p[0])[:4]
        for _ in range(self.pool - len(cands)):
            _, base = top[self.rng.randrange(len(top))]
            cands.append(self.space.mutate(base, self.rng))
        ei = self._expected_improvement(
            np.stack([self.space.encode(c) for c in cands]))
        return cands[int(np.argmax(ei))]

    def tell(self, config: FabricConfig, reward: float) -> None:
        super().tell(config, reward)
        self.X.append(self.space.encode(config))
        self.y.append(reward)
        self.telled.append((reward, config))

    def _kernel(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        sq = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * sq / self.length_scale ** 2)

    def _expected_improvement(self, Xc: np.ndarray) -> np.ndarray:
        X = np.stack(self.X)
        y = np.asarray(self.y)
        mu0, sd0 = y.mean(), max(y.std(), 1e-12)
        z = (y - mu0) / sd0
        K = self._kernel(X, X) + self.noise * np.eye(len(X))
        alpha = np.linalg.solve(K, z)
        Ks = self._kernel(Xc, X)
        mu = Ks @ alpha
        v = np.linalg.solve(K, Ks.T)
        var = np.clip(1.0 - np.einsum("ij,ji->i", Ks, v), 1e-12, None)
        sd = np.sqrt(var)
        best = z.max()
        imp = mu - best
        zz = imp / sd
        # N(0,1) pdf/cdf without scipy
        pdf = np.exp(-0.5 * zz ** 2) / np.sqrt(2 * np.pi)
        cdf = 0.5 * (1.0 + _erf(zz / np.sqrt(2.0)))
        return imp * cdf + sd * pdf


def _erf(x: np.ndarray) -> np.ndarray:
    """Vectorized erf (Abramowitz-Stegun 7.1.26, |err| < 1.5e-7) — keeps
    the GP loop scipy-free."""
    sign = np.sign(x)
    x = np.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * x)
    poly = t * (0.254829592 + t * (-0.284496736 + t * (
        1.421413741 + t * (-1.453152027 + t * 1.061405429))))
    return sign * (1.0 - poly * np.exp(-x * x))


AGENTS = {"random_walk": RandomWalkAgent, "genetic": GeneticAgent,
          "gp_bo": GpBoAgent}


# ---------------------------------------------------------------------------
# search driver + packet-oracle finalist re-score
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchResult:
    workload: str
    agent: str
    seed: int
    steps: int
    trajectory: list[dict]        # per step: objective, best-so-far, config
    best_config: FabricConfig
    best_objective_s: float
    wall_s: float

    def summary(self) -> dict:
        """The compact trajectory record ``best_configs.json`` carries —
        enough to reconstruct the search curve, not the whole history."""
        return {"agent": self.agent, "seed": self.seed, "steps": self.steps,
                "best_objective_ms": self.best_objective_s * 1e3,
                "wall_s": round(self.wall_s, 3),
                "best_objective_ms_per_step": [
                    round(t["best_objective_s"] * 1e3, 6)
                    for t in self.trajectory]}


def search(env: FabricEnv, agent: SearchAgent, *, steps: int,
           seed: int = 0) -> SearchResult:
    """Run ``agent`` against ``env`` for ``steps`` evaluations.  Fully
    deterministic in ``seed``: the agent's only entropy source is the
    ``random.Random(seed)`` stream, and the env is a pure function of the
    config — same seed, bitwise-same trajectory and winner."""
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    t0 = time.perf_counter()
    agent.reset(env.space, random.Random(seed))
    env.reset(seed)
    trajectory: list[dict] = []
    best_cfg, best_obj = None, np.inf
    for i in range(steps):
        cfg = agent.ask()
        _, reward, _, info = env.step(cfg)
        agent.tell(cfg, reward)
        obj = info["report"].objective_s
        if obj < best_obj:
            best_obj, best_cfg = obj, cfg
        trajectory.append({"step": i, "objective_s": obj,
                           "best_objective_s": best_obj,
                           "config": cfg.to_jsonable()})
    return SearchResult(workload=env.spec.name, agent=agent.name, seed=seed,
                        steps=steps, trajectory=trajectory,
                        best_config=best_cfg, best_objective_s=best_obj,
                        wall_s=time.perf_counter() - t0)


def finalists(results: SearchResult | Sequence[SearchResult],
              k: int = 3) -> list[FabricConfig]:
    """The ``k`` best *distinct* configs across one or more searches'
    trajectories, by fluid objective — the candidates worth the packet
    oracle's time."""
    if isinstance(results, SearchResult):
        results = [results]
    seen: dict[str, tuple[float, FabricConfig]] = {}
    for res in results:
        for t in res.trajectory:
            cfg = FabricConfig.from_jsonable(t["config"])
            key = json.dumps(cfg.to_jsonable(), sort_keys=True)
            if key not in seen or t["objective_s"] < seen[key][0]:
                seen[key] = (t["objective_s"], cfg)
    ranked = sorted(seen.values(), key=lambda p: p[0])
    return [cfg for _, cfg in ranked[:k]]


def rescore(env: FabricEnv, configs: Sequence[FabricConfig], *,
            fidelity: str = "packet") -> list[ScoreReport]:
    """Price ``configs`` on ``fidelity`` (default: the packet oracle) —
    the verification half of the fluid-inner-loop discipline."""
    return [env.score(c, fidelity=fidelity) for c in configs]


# ---------------------------------------------------------------------------
# best_configs.json — the pinned artifact trainer/cluster load by default
# ---------------------------------------------------------------------------

def best_configs_path(path: str | None = None) -> str | None:
    """Resolve the artifact path: explicit arg > ``$BEST_CONFIGS`` (the
    values ``""``/``"0"`` disable loading entirely) > ``./best_configs.json``
    in the current working directory."""
    if path is not None:
        return path
    env = os.environ.get(BEST_CONFIGS_ENV)
    if env is not None:
        return env if env not in ("", "0") else None
    return os.path.join(os.getcwd(), BEST_CONFIGS_FILE)


def save_best_configs(entries: Mapping[str, Mapping], *,
                      path: str | None = None) -> str:
    """Write the artifact.  ``entries`` maps workload name -> a jsonable
    record whose ``"config"`` key is a ``FabricConfig.to_jsonable`` dict
    (the loader ignores everything else, so searches are free to attach
    scores and trajectory summaries).  Deterministic output: sorted keys,
    no timestamps — the same search seed writes the same bytes."""
    out = best_configs_path(path)
    if out is None:
        raise ValueError("best-config saving disabled "
                         f"(${BEST_CONFIGS_ENV} is {os.environ.get(BEST_CONFIGS_ENV)!r})")
    payload = {"version": 1, "workloads": dict(entries)}
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return out


def load_best_configs(path: str | None = None) -> dict:
    """Read the artifact; a missing, disabled, or unparsable file returns
    ``{}`` (the legacy-defaults escape hatch must never crash a consumer
    that merely *might* have tuned configs)."""
    p = best_configs_path(path)
    if p is None or not os.path.exists(p):
        return {}
    try:
        with open(p) as f:
            data = json.load(f)
        return dict(data.get("workloads", {}))
    except (json.JSONDecodeError, OSError, AttributeError):
        return {}


def tuned_config(workload: str, path: str | None = None)\
        -> FabricConfig | None:
    """The pinned winning ``FabricConfig`` for ``workload``, or ``None``
    when no artifact (or no such workload entry) exists."""
    entry = load_best_configs(path).get(workload)
    if not entry or "config" not in entry:
        return None
    try:
        return FabricConfig.from_jsonable(entry["config"])
    except (KeyError, TypeError, ValueError):
        return None


def tuned_knob(workload: str, knob: str, default=None,
               path: str | None = None):
    """One knob of the pinned config (e.g. ``("train", "bucket_mb")``),
    falling back to ``default`` when nothing is pinned."""
    cfg = tuned_config(workload, path)
    if cfg is None:
        return default
    return getattr(cfg, knob, default)
