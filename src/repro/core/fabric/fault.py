"""Fault-aware schedule rewriting — LO|FA|MO awareness turned into action.

The LO|FA|MO protocol (paper §4, and the companion work arXiv:2201.01088)
gives the master node global knowledge of dead hosts, dead NICs and dead
links.  This module closes the loop: given that fault map, an existing
``CollectiveSchedule`` is re-lowered against the surviving fabric —

  * **shrunk rings**: dead axis positions drop out of every ring pass, so
    live ranks keep exchanging with their nearest live neighbours;
  * **detour hops**: a transfer whose direct link died is priced (and
    annotated) with its BFS detour over the surviving graph;
  * **axis reordering**: for multi-axis all-reduce, fault-free axes are
    processed first in the reduce-scatter leg — the faulted (per-byte more
    expensive) axes then only carry the already-shrunk working set, which
    is free to do because the all-reduce result is axis-order invariant.

The rewriter only *re-lowers*; all ring/hop derivation stays in
``fabric.lower`` and execution stays in ``fabric.execute`` — a rewritten
schedule is indistinguishable, structurally, from a freshly lowered one.
"""
from __future__ import annotations

from repro.core.fabric import lower as L
from repro.core.fabric.schedule import (
    A2A, AG, AR, HALO, P2P, RS, CollectiveSchedule, FaultMap)
from repro.core.topology import Torus

UnroutableError = L.UnroutableError


def fault_map_from_lofamo(sim) -> FaultMap:
    """The master node's current view of the fabric, as a ``FaultMap``.

    Works with any object exposing ``detected_at_master()`` (dead ranks)
    and optionally ``detected_links_at_master()`` (dead (a, b) pairs) —
    i.e. ``core.lofamo.LofamoSim``.
    """
    nodes = set(sim.detected_at_master())
    links = set(getattr(sim, "detected_links_at_master", lambda: ())())
    return FaultMap.normalized(nodes, links)


def _ordered_axes(schedule: CollectiveSchedule, torus: Torus,
                  faults: FaultMap) -> list[tuple[str, int]]:
    """Fault-free axes first (they carry the most reduce-scatter bytes),
    faulted axes last — stable for equally clean axes."""
    entries = list(zip(schedule.axes, schedule.axis_dims))
    return sorted(entries,
                  key=lambda e: L.axis_fault_penalty(torus, e[1], faults))


def rewrite(schedule: CollectiveSchedule, faults: FaultMap, *,
            reorder_axes: bool = True) -> CollectiveSchedule:
    """Re-lower ``schedule`` against the surviving fabric.

    Raises ``UnroutableError`` when the fault map partitions the fabric (or
    kills a rank an all-to-all must deliver to) — the caller should fall
    back to checkpoint-restart on a re-meshed machine, exactly like the
    trainer's elastic re-mesh path.
    """
    if not faults:
        return schedule
    torus = Torus(schedule.torus_dims)
    axes, dims = schedule.axes, schedule.axis_dims
    if schedule.collective == AR and reorder_axes and len(axes) > 1:
        entries = _ordered_axes(schedule, torus, faults)
        axes = tuple(a for a, _ in entries)
        dims = tuple(d for _, d in entries)
    if schedule.collective == RS:
        return L.lower_reduce_scatter(
            torus, axes, axis_dims=dims, bidirectional=schedule.bidirectional,
            mean=schedule.mean, faults=faults)
    if schedule.collective == AG:
        return L.lower_all_gather(
            torus, axes, axis_dims=dims, bidirectional=schedule.bidirectional,
            faults=faults)
    if schedule.collective == AR:
        return L.lower_all_reduce(
            torus, axes, axis_dims=dims, bidirectional=schedule.bidirectional,
            mean=schedule.mean, faults=faults)
    if schedule.collective == A2A:
        return L.lower_all_to_all(torus, axes[0], axis_dims=dims,
                                  faults=faults)
    if schedule.collective == HALO:
        return L.lower_halo_exchange(torus, axes[0], axis_dims=dims,
                                     faults=faults)
    if schedule.collective == P2P:
        # the route annotation carries the endpoints: first rank of the
        # first phase's ring, last rank of the last phase's ring
        route_src = schedule.phases[0].ring[0]
        route_dst = schedule.phases[-1].ring[-1]
        return L.lower_p2p(torus, route_src, route_dst, faults=faults)
    raise ValueError(f"unknown collective {schedule.collective!r}")
