"""Schedule executor — walks a ``CollectiveSchedule`` and emits the
shard_map/ppermute program implementing it.

All entry points are *per-shard* code: they must run inside ``shard_map``
(or any context binding the schedule's axis names).  The executor is the
only consumer that turns schedule steps into data movement; it derives
nothing about rings or hops itself — perms come verbatim from the
schedule's transfers, so a fault-rewritten schedule executes with zero
extra code.

Dual-DMA fusion: where the legacy collectives ran the +1 ring pass to
completion and then the -1 pass (2(n-1) sequential ppermute rounds), the
executor advances both directions of a bidirectional phase inside ONE
fori_loop — n-1 rounds, each issuing two data-independent ppermutes that
XLA overlaps exactly like the two DMA engines of an APEnet+ link (paper
§2.1, Fig 1).  ``schedule.rounds`` is therefore the true sequential depth.

Numerics: ring reductions accumulate in fp32 when inputs are lower
precision (bf16/fp16), matching production all-reduce behaviour.  Layouts
match the legacy collectives bit-for-bit on healthy fabrics: reduce-scatter
hands ring-slot r the contiguous chunk r (front half via the +1 ring, back
half via the -1 ring), all-gather returns slot-ordered rows.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import jaxcompat
from repro.core.fabric.schedule import (
    A2A, AG, AR, HALO, RS, BucketPlan, CollectiveSchedule, Phase)


# ----------------------------------------------------------------------------
# small helpers (shared with core.collectives for API continuity)
# ----------------------------------------------------------------------------

def _ring_perms(axis_size: int, step: int) -> list[tuple[int, int]]:
    """ppermute perm for a one-hop shift (+1 = "clockwise") along a ring."""
    return [(i, (i + step) % axis_size) for i in range(axis_size)]


def _acc_dtype(dtype: jnp.dtype) -> jnp.dtype:
    if jnp.issubdtype(dtype, jnp.floating) and jnp.finfo(dtype).bits < 32:
        return jnp.float32
    return dtype


def _flatten_pad(x: jax.Array, n: int) -> tuple[jax.Array, int]:
    """Flatten to 1D and zero-pad so the length divides ``n``."""
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, flat.size // n


def ring_slot(phase: Phase, axis_name: str | None = None):
    """This rank's slot on the phase ring (traced; = axis index when the
    ring is the identity).  Ranks at dead positions get slot 0 — their
    output is undefined, they send nothing and receive zeros."""
    axis = axis_name or phase.axis
    pos = lax.axis_index(axis)
    n = jaxcompat.axis_size(axis)
    if phase.ring == tuple(range(n)):
        return pos
    inv = np.zeros((n,), np.int32)
    for j, p in enumerate(phase.ring):
        inv[p] = j
    return jnp.asarray(inv)[pos]


def _phase_perms(phase: Phase) -> list[list[tuple[int, int]]]:
    return [list(tr.perm) for tr in phase.steps[0].transfers]


# ----------------------------------------------------------------------------
# reduce-scatter
# ----------------------------------------------------------------------------

def _rs_directed(acc, axis: str, perm, slot, m: int, sgn: int, nsteps: int):
    """One directed ring pass over ``acc`` of shape (m, chunk); returns the
    fully reduced chunk owned by this rank's slot."""
    def body(s, acc):
        send_idx = (slot - sgn * (s + 1)) % m
        recv_idx = (slot - sgn * (s + 2)) % m
        sent = lax.dynamic_index_in_dim(acc, send_idx, axis=0, keepdims=False)
        got = lax.ppermute(sent, axis, perm)
        cur = lax.dynamic_index_in_dim(acc, recv_idx, axis=0, keepdims=False)
        return lax.dynamic_update_index_in_dim(acc, cur + got, recv_idx,
                                               axis=0)

    acc = lax.fori_loop(0, nsteps, body, acc)
    return lax.dynamic_index_in_dim(acc, slot, axis=0, keepdims=False)


def _rs_bidi(acc_f, acc_b, axis: str, perm_f, perm_b, slot, m: int,
             nsteps: int):
    """Both ring directions advanced per round — the fused dual-DMA pass."""
    def body(s, carry):
        af, ab = carry
        send_f = (slot - (s + 1)) % m
        recv_f = (slot - (s + 2)) % m
        send_b = (slot + (s + 1)) % m
        recv_b = (slot + (s + 2)) % m
        got_f = lax.ppermute(
            lax.dynamic_index_in_dim(af, send_f, 0, keepdims=False),
            axis, perm_f)
        got_b = lax.ppermute(
            lax.dynamic_index_in_dim(ab, send_b, 0, keepdims=False),
            axis, perm_b)
        cur_f = lax.dynamic_index_in_dim(af, recv_f, 0, keepdims=False)
        cur_b = lax.dynamic_index_in_dim(ab, recv_b, 0, keepdims=False)
        af = lax.dynamic_update_index_in_dim(af, cur_f + got_f, recv_f, 0)
        ab = lax.dynamic_update_index_in_dim(ab, cur_b + got_b, recv_b, 0)
        return af, ab

    acc_f, acc_b = lax.fori_loop(0, nsteps, body, (acc_f, acc_b))
    out_f = lax.dynamic_index_in_dim(acc_f, slot, 0, keepdims=False)
    out_b = lax.dynamic_index_in_dim(acc_b, slot, 0, keepdims=False)
    return out_f, out_b


def _exec_rs_phase(work: jax.Array, phase: Phase) -> jax.Array:
    """Reduce-scatter one ring phase over flat ``work``; returns this
    slot's fp32-accumulated chunk (front half via +1, back half via -1)."""
    m = phase.ring_size
    flat, chunk = _flatten_pad(work, max(m, 1))
    if m <= 1 or not phase.steps:
        return flat.astype(_acc_dtype(work.dtype))
    acc = flat.reshape(m, chunk).astype(_acc_dtype(work.dtype))
    slot = ring_slot(phase)
    perms = _phase_perms(phase)
    nsteps = len(phase.steps)
    if phase.directions == 2:
        half = chunk // 2
        out_f, out_b = _rs_bidi(acc[:, :half], acc[:, half:], phase.axis,
                                perms[0], perms[1], slot, m, nsteps)
        out = jnp.concatenate([out_f, out_b], axis=0)
    else:
        out = _rs_directed(acc, phase.axis, perms[0], slot, m, +1, nsteps)
    return out / m if phase.mean else out


# ----------------------------------------------------------------------------
# all-gather
# ----------------------------------------------------------------------------

def _ag_directed(x, axis: str, perm, slot, m: int, sgn: int, nsteps: int):
    out = jnp.zeros((m,) + x.shape, x.dtype)
    out = lax.dynamic_update_index_in_dim(out, x, slot, axis=0)

    def body(s, carry):
        out, cur = carry
        cur = lax.ppermute(cur, axis, perm)
        src = (slot - sgn * (s + 1)) % m
        out = lax.dynamic_update_index_in_dim(out, cur, src, axis=0)
        return out, cur

    out, _ = lax.fori_loop(0, nsteps, body, (out, x))
    return out


def _ag_bidi(x_f, x_b, axis: str, perm_f, perm_b, slot, m: int, nsteps: int):
    out_f = jnp.zeros((m,) + x_f.shape, x_f.dtype)
    out_b = jnp.zeros((m,) + x_b.shape, x_b.dtype)
    out_f = lax.dynamic_update_index_in_dim(out_f, x_f, slot, axis=0)
    out_b = lax.dynamic_update_index_in_dim(out_b, x_b, slot, axis=0)

    def body(s, carry):
        out_f, cur_f, out_b, cur_b = carry
        cur_f = lax.ppermute(cur_f, axis, perm_f)
        cur_b = lax.ppermute(cur_b, axis, perm_b)
        src_f = (slot - (s + 1)) % m
        src_b = (slot + (s + 1)) % m
        out_f = lax.dynamic_update_index_in_dim(out_f, cur_f, src_f, axis=0)
        out_b = lax.dynamic_update_index_in_dim(out_b, cur_b, src_b, axis=0)
        return out_f, cur_f, out_b, cur_b

    out_f, _, out_b, _ = lax.fori_loop(0, nsteps, body,
                                       (out_f, x_f, out_b, x_b))
    return out_f, out_b


def _exec_ag_phase(work: jax.Array, phase: Phase) -> jax.Array:
    """All-gather one ring phase: flat local chunk -> (m, chunk) rows in
    ring-slot order."""
    m = phase.ring_size
    flat = work.reshape(-1)
    if m <= 1 or not phase.steps:
        return flat[None]
    slot = ring_slot(phase)
    perms = _phase_perms(phase)
    nsteps = len(phase.steps)
    if phase.directions == 2:
        half = flat.size // 2
        out_f, out_b = _ag_bidi(flat[:half], flat[half:], phase.axis,
                                perms[0], perms[1], slot, m, nsteps)
        return jnp.concatenate([out_f, out_b], axis=-1)
    return _ag_directed(flat, phase.axis, perms[0], slot, m, +1, nsteps)


# ----------------------------------------------------------------------------
# whole-schedule executors
# ----------------------------------------------------------------------------

def execute_reduce_scatter(schedule: CollectiveSchedule, x: jax.Array
                           ) -> tuple[jax.Array, list[int]]:
    """Returns (chunk, stage_sizes): the reduced flat chunk this rank owns
    and the per-phase pre-pad sizes an inverse all-gather needs."""
    assert schedule.collective == RS, schedule.collective
    work = x.reshape(-1)
    sizes: list[int] = []
    for ph in schedule.phases:
        sizes.append(work.size)
        work = _exec_rs_phase(work, ph)
    return work, sizes


def execute_all_gather(schedule: CollectiveSchedule, x: jax.Array,
                       stage_sizes: list[int] | None = None) -> jax.Array:
    """Single-phase schedules return slot-ordered rows (m, *x.shape);
    multi-phase (dimension-ordered) walks need ``stage_sizes`` from the
    forward reduce-scatter and return the flat reassembled array."""
    assert schedule.collective == AG, schedule.collective
    if stage_sizes is None:
        if len(schedule.phases) != 1:
            raise ValueError("multi-phase all-gather needs stage_sizes")
        ph = schedule.phases[0]
        out = _exec_ag_phase(x.reshape(-1), ph)
        return out.reshape((max(ph.ring_size, 1),) + x.shape)
    work = x.reshape(-1)
    for ph, size in zip(schedule.phases, reversed(tuple(stage_sizes))):
        work = _exec_ag_phase(work, ph).reshape(-1)[:size]
    return work


def execute_all_reduce(schedule: CollectiveSchedule, x: jax.Array
                       ) -> jax.Array:
    assert schedule.collective == AR, schedule.collective
    work = x.reshape(-1)
    sizes: list[int] = []
    for ph in schedule.phases:
        if ph.kind == RS:
            sizes.append(work.size)
            work = _exec_rs_phase(work, ph)
        else:
            work = _exec_ag_phase(work, ph).reshape(-1)[: sizes.pop()]
    return work.reshape(x.shape).astype(x.dtype)


def execute_all_to_all(schedule: CollectiveSchedule, x: jax.Array
                       ) -> jax.Array:
    """Store-and-forward: x[j] is this rank's block for rank j; returns
    rows holding the block received from each rank."""
    assert schedule.collective == A2A, schedule.collective
    ph = schedule.phases[0]
    n = ph.ring_size
    if ph.ring != tuple(range(n)):
        raise ValueError("all-to-all schedules keep the identity ring")
    if x.shape[0] != n:
        raise ValueError(f"leading dim {x.shape[0]} != ring size {n}")
    if not ph.steps:
        return x
    r = lax.axis_index(ph.axis)
    perm = _phase_perms(ph)[0]
    out = jnp.zeros_like(x)
    out = lax.dynamic_update_index_in_dim(
        out, lax.dynamic_index_in_dim(x, r, 0, keepdims=False), r, axis=0)

    def body(s, carry):
        out, buf = carry
        buf = lax.ppermute(buf, ph.axis, perm)  # buf originated at r-s-1
        src = (r - s - 1) % n
        mine = lax.dynamic_index_in_dim(buf, r, 0, keepdims=False)
        out = lax.dynamic_update_index_in_dim(out, mine, src, axis=0)
        return out, buf

    out, _ = lax.fori_loop(0, len(ph.steps), body, (out, x))
    return out


def execute_halo_exchange(schedule: CollectiveSchedule, x: jax.Array,
                          halo: int = 1, dim: int = 0
                          ) -> tuple[jax.Array, jax.Array]:
    """Returns (from_prev, from_next): both ring neighbours' facing slabs —
    a pair of one-sided puts fired in the same round."""
    assert schedule.collective == HALO, schedule.collective
    ph = schedule.phases[0]
    lo = lax.slice_in_dim(x, 0, halo, axis=dim)
    hi = lax.slice_in_dim(x, x.shape[dim] - halo, x.shape[dim], axis=dim)
    if not ph.steps:
        return hi, lo  # ring of one: own edges wrap straight around
    perm_f, perm_b = _phase_perms(ph)
    from_prev = lax.ppermute(hi, ph.axis, perm_f)
    from_next = lax.ppermute(lo, ph.axis, perm_b)
    return from_prev, from_next


# ----------------------------------------------------------------------------
# bucketed gradient hook — the overlap engine's executor entry point
# ----------------------------------------------------------------------------

def _bucket_identity(schedule: CollectiveSchedule, phase: Phase, m: int,
                     metas: tuple):
    """A tuple-identity whose VJP reduce-scatters the incoming cotangents.

    The forward is a no-op; the backward executes ``schedule`` on each
    leaf's gradient *at the point in the backward pass where that gradient
    materialises* — the fabric rounds are therefore free to overlap the
    remaining backward compute, exactly like the dual-DMA engine draining
    its prefetchable command queue while the host is still producing work
    (paper §2.1).  The returned cotangent is zeros except this rank's
    reduced chunk at its ring slot — the pre-reduced ZeRO-1 shard, embedded
    in a full-size buffer so it is a valid cotangent for the primal.
    ``metas`` are static (shape, dtype) pairs for the bucket's leaves.
    """

    @jax.custom_vjp
    def ident(*leaves):
        return leaves

    def fwd(*leaves):
        return leaves, None

    def bwd(_, gs):
        slot = ring_slot(phase)
        outs = []
        for (shape, dtype), g in zip(metas, gs):
            chunk, _ = execute_reduce_scatter(schedule, g)
            full = jnp.zeros((chunk.shape[0] * m,), chunk.dtype)
            full = lax.dynamic_update_slice(full, chunk,
                                            (slot * chunk.shape[0],))
            n = int(np.prod(shape)) if shape else 1
            outs.append(full[:n].reshape(shape).astype(dtype))
        return tuple(outs)

    ident.defvjp(fwd, bwd)
    return ident


def make_bucket_grad_hook(plan: BucketPlan, schedule: CollectiveSchedule):
    """Per-shard identity over a param tree that bucket-reduce-scatters
    gradients inside the backward pass.

    ``schedule`` must be a single-axis reduce-scatter (possibly fault-
    rewritten).  Wrap the params fed to the differentiated loss:

        hook = make_bucket_grad_hook(plan, rs_schedule)
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(hook(p), batch))(params)

    ``grads`` then hold each leaf's *reduced* chunk at this rank's slice
    (zeros elsewhere); pair with ``apex_zero1_update(pre_reduced=True)``.
    Wire numerics match the sequential per-leaf path bit-for-bit for fp32
    params (lower-precision params pay one extra wire-dtype cast, like any
    bucketed DDP implementation).
    """
    if schedule.collective != RS:
        raise ValueError(
            f"bucket hook needs a reduce-scatter schedule, got "
            f"{schedule.collective!r}")
    if len(schedule.phases) != 1:
        raise ValueError("bucket hook supports single-axis schedules only")
    phase = schedule.phases[0]
    m = max(phase.ring_size, 1)
    if phase.ring != tuple(range(m)):
        # a node-fault-shrunk/reordered ring changes where each rank's
        # reduced chunk lands, but the pre-reduced ZeRO update slices at
        # axis_index over the FULL axis — silent divergence.  Link-fault
        # rewrites keep the identity ring and are fine; node faults must
        # remesh (which the trainer does) rather than reroute.
        raise ValueError(
            f"bucket hook requires the identity ring, got {phase.ring}; "
            "node-fault-shrunk rings change the ZeRO chunk layout")

    def hook(tree):
        leaves, treedef = jax.tree.flatten(tree)
        if len(leaves) != plan.n_leaves:
            raise ValueError(f"tree has {len(leaves)} leaves, plan expects "
                             f"{plan.n_leaves}")
        out = list(leaves)
        for b in plan.buckets:
            group = tuple(leaves[i] for i in b.leaves)
            metas = tuple((jnp.shape(lf), jnp.result_type(lf))
                          for lf in group)
            group = _bucket_identity(schedule, phase, m, metas)(*group)
            for i, v in zip(b.leaves, group):
                out[i] = v
        return jax.tree.unflatten(treedef, out)

    return hook


_EXECUTORS = {
    RS: execute_reduce_scatter,
    AG: execute_all_gather,
    AR: execute_all_reduce,
    A2A: execute_all_to_all,
    HALO: execute_halo_exchange,
}


def execute(schedule: CollectiveSchedule, x: jax.Array, **kw):
    """Dispatch on the schedule's collective kind (per-shard code)."""
    fn = _EXECUTORS.get(schedule.collective)
    if fn is None:
        raise ValueError(
            f"schedule kind {schedule.collective!r} has no per-shard "
            "executor (p2p schedules are priced and fault-rewritten; their "
            "data movement is modelled by the RDMA layer's put_pages)")
    return fn(schedule, x, **kw)
