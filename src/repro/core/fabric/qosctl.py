"""Closed-loop QoS controller — the SLO feedback loop over ``QosPolicy``.

The autotuner picks one static weight vector per workload; under the
overload traces that vector is wrong twice over.  While decode's p99 is
comfortably inside the SLO the fabric still gives DECODE its full 27:1
arbitration share, starving the BULK migrations that would *relieve* the
hotspot; and once the p99 has breached, decode is queue-bound — no
arbitration weight can buy tokens a saturated replica isn't producing,
yet the static policy keeps paying for one.  APEnet+'s §2.1 host
interface exposes per-class prefetchable command queues precisely so
priorities can change *while work is in flight* (arXiv:1311.1741); the
follow-up TX-path work (arXiv:2201.01088) makes the same argument for
congestion-reactive injection.  This module is that reactivity at the
fabric-policy level: once per replay window the controller reads the
measured per-token p99 and the per-class byte deltas
(``FabricSim.class_stats(since=...)``) and retunes the live policy
through ``sim.set_qos`` — a damped multiplicative rule bounded by
per-class floors.

Control law (``QosController.window``), acting on a single scalar
``boost`` — the DECODE weight multiplier over the static baseline:

* **safe** (p99 < target * headroom): decode has latency headroom to
  give back — decay ``boost`` toward the relief ``floor`` so BULK
  drains faster (``boost *= decay``, clamped at ``floor``).
* **at-risk** (target * headroom <= p99 < target): the pre-breach band
  the proactive rebalancer also acts in — multiplicative increase
  (``boost *= gain``, capped at ``max_boost``), but only when the
  window actually moved DECODE bytes: a replica that is compute- or
  queue-bound gains nothing from more arbitration share.
* **breached** (p99 >= target): boosting cannot help — release toward
  the ``floor`` so migrations get the bandwidth to drain the hotspot.

The controller is **latched quiescent**: until the first at-risk or
breached window it never calls ``set_qos`` at all, so a no-overload
replay with the controller attached is *bitwise identical* to one
without it (the quiescence gate in ``benchmarks/qosctl.py``).  Credit
fractions mirror the weight move with a damped exponent
(``boost ** credit_gain``) so a boosted class also gets buffer landing
room, floored at ``min_credit_frac`` per class — ``partition_credits``
renormalizes, so fractions are relative shares.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fabric.qos import QosPolicy, TrafficClass


@dataclasses.dataclass(frozen=True)
class QosCtlPolicy:
    """The controller's gains and floors (the autotuner's search knobs).

    ``gain``/``decay`` are the per-window multiplicative step sizes of
    the boost (up in the at-risk band, down otherwise); ``max_boost`` /
    ``floor`` bound it above and below as multiples of the baseline
    DECODE weight; ``credit_gain`` damps how much of the weight move the
    credit partition mirrors; ``min_credit_frac`` is the per-class
    credit floor no retune may cross."""

    gain: float = 1.6          # at-risk multiplicative increase
    decay: float = 0.6         # safe/breached release multiplier
    max_boost: float = 4.0     # cap, x baseline DECODE weight
    floor: float = 0.25        # relief floor, x baseline DECODE weight
    credit_gain: float = 0.5   # credit shift = boost ** credit_gain
    min_credit_frac: float = 0.05

    def __post_init__(self) -> None:
        if self.gain <= 1.0:
            raise ValueError(f"gain must be > 1, got {self.gain}")
        if not 0.0 < self.decay < 1.0:
            raise ValueError(f"decay must be in (0, 1), got {self.decay}")
        if self.max_boost < 1.0:
            raise ValueError(
                f"max_boost must be >= 1, got {self.max_boost}")
        if not 0.0 < self.floor <= 1.0:
            raise ValueError(f"floor must be in (0, 1], got {self.floor}")
        if not 0.0 <= self.credit_gain <= 1.0:
            raise ValueError(
                f"credit_gain must be in [0, 1], got {self.credit_gain}")
        if not 0.0 < self.min_credit_frac < 0.25:
            raise ValueError("min_credit_frac must be in (0, 0.25), "
                             f"got {self.min_credit_frac}")

    @classmethod
    def tuned(cls, workload: str = "serving") -> "QosCtlPolicy":
        """The pinned ``best_configs.json`` gains for ``workload`` when an
        artifact is loadable (same explicit-arg-wins / ``BEST_CONFIGS=0``
        escape hatch as every other tuned knob), else the defaults."""
        from repro.core.fabric import autotune
        cfg = autotune.tuned_config(workload)
        if cfg is None:
            return cls()
        return cls(gain=cfg.ctl_gain, decay=cfg.ctl_decay,
                   floor=cfg.ctl_floor)


class QosController:
    """One control loop bound to a live sim's ``set_qos`` actuator.

    Construct with the *static* baseline policy (what the autotuner
    pinned) and the serving ``SloPolicy`` whose ``token_target_s`` /
    ``headroom`` define the bands; call :meth:`window` once per replay
    window with the per-token latency samples that window produced.
    ``policy=None`` loads :meth:`QosCtlPolicy.tuned`.
    """

    def __init__(self, base: QosPolicy, slo, *,
                 policy: QosCtlPolicy | None = None,
                 telemetry: "object | None" = None) -> None:
        if base.single_class:
            raise ValueError("closed-loop QoS needs a multi-class baseline "
                             "(single_class has no DECODE channel to boost)")
        self.base = base
        self.slo = slo
        self.policy = policy if policy is not None else QosCtlPolicy.tuned()
        self.boost = 1.0           # current DECODE multiplier
        self.engaged = False       # latched on first at-risk/breached window
        self.n_retunes = 0         # set_qos calls actually issued
        self._applied = 1.0        # boost the sim currently runs
        self._last_stats: dict | None = None
        self.history: list[tuple[str, float | None, float]] = []
        # optional Telemetry hub: one controller-track event per window
        # plus window/retune counters.  Pure reporting — None changes
        # nothing about the control law or its timeline.
        self.telemetry = telemetry

    # -- control step ---------------------------------------------------------
    def window(self, sim, tpt_samples) -> bool:
        """One control step; returns True when the actuator fired.

        ``tpt_samples`` are the per-token decode latencies of the
        requests that *finished inside this window* — the controller
        steers on the measured tail, not a prediction.  ``sim`` is any
        fabric tier exposing ``class_stats`` / ``set_qos``.
        """
        pol = self.policy
        stats = sim.class_stats()
        delta = (sim.class_stats(since=self._last_stats)
                 if self._last_stats is not None else dict(stats))
        self._last_stats = stats
        samples = [float(x) for x in tpt_samples]
        p99 = (float(np.percentile(np.asarray(samples, np.float64), 99))
               if samples else None)
        target = float(self.slo.token_target_s)
        edge = target * float(self.slo.headroom)
        if p99 is None:
            band = "idle"
        elif p99 >= target:
            band = "breached"
        elif p99 >= edge:
            band = "at-risk"
        else:
            band = "safe"
        new_boost = self.boost
        if band in ("at-risk", "breached"):
            self.engaged = True
        if band == "at-risk":
            if delta.get(TrafficClass.DECODE, 0.0) > 0.0:
                new_boost = min(self.boost * pol.gain, pol.max_boost)
            # at-risk but no DECODE bytes moved: the replica is compute/
            # queue-bound, arbitration share is not the lever — hold.
        elif self.engaged and band in ("breached", "safe"):
            new_boost = max(self.boost * pol.decay, pol.floor)
        self.history.append((band, p99, new_boost))
        self.boost = new_boost
        tel = self.telemetry
        if tel is not None:
            tel.add("qosctl.windows")
            tel.event(("controller",), band, float(sim.now),
                      p99_ms=-1.0 if p99 is None else p99 * 1e3,
                      boost=new_boost)
        if not self.engaged or abs(new_boost - self._applied) <= 1e-12:
            return False
        sim.set_qos(self.retuned())
        self._applied = new_boost
        self.n_retunes += 1
        if tel is not None:
            tel.add("qosctl.retunes")
            tel.event(("controller",), "retune", float(sim.now),
                      boost=new_boost)
        return True

    # -- policy lowering ------------------------------------------------------
    def retuned(self) -> QosPolicy:
        """The ``QosPolicy`` the current boost lowers to.

        Weights: baseline with DECODE scaled by ``boost``.  Credit
        fractions: DECODE's share scaled by ``boost ** credit_gain``,
        every class floored at ``min_credit_frac`` (fractions are
        relative — ``partition_credits`` renormalizes)."""
        pol = self.policy
        w = dict(self.base.weights)
        w[TrafficClass.DECODE] = w[TrafficClass.DECODE] * self.boost
        f = dict(self.base.credit_frac)
        f[TrafficClass.DECODE] = (f[TrafficClass.DECODE]
                                  * self.boost ** pol.credit_gain)
        total = sum(f.values())
        f = {c: max(v, pol.min_credit_frac * total)
             for c, v in f.items()}
        return QosPolicy(weights=w, credit_frac=f)

    def describe(self) -> str:
        last = self.history[-1] if self.history else ("idle", None, 1.0)
        p99 = "n/a" if last[1] is None else f"{last[1] * 1e3:.2f} ms"
        return (f"QosController(boost={self.boost:.3f}, "
                f"engaged={self.engaged}, retunes={self.n_retunes}, "
                f"last window: {last[0]}, p99 {p99})")
