"""Unified fabric telemetry: counter registry + event tracer + Perfetto export.

The APEnet+ board ships hardware performance counters and diagnostic
registers because a multi-hop RDMA fabric is undebuggable without
per-link, per-channel visibility (arXiv:1311.1741 §4; arXiv:2201.01088
extends the monitoring for fault diagnosis).  This module is the
software twin: ONE ``Telemetry`` hub that every dynamic subsystem —
packet/fluid/hybrid sims, the RDMA endpoint, the serving cluster, the
trace-replay driver, the closed-loop QoS controller, the trainer —
optionally reports into.

Two stores:

* a typed **counter/gauge registry** keyed ``(name, key, cls)`` —
  per-link-direction bytes / busy time / credit-stall time per traffic
  class, escape-credit loans and repayments, host-IF descriptor
  preemptions, restripes, probe counts, BFS-cache hits, queue waits,
  sheds, migrations, controller retunes;
* an **event/span tracer** with bounded ring-buffer storage — flow
  inject→drain spans, descriptor segments, controller windows,
  rebalance decisions, fault epochs — exported by :meth:`to_perfetto`
  as Chrome-trace JSON (one track per link direction / node /
  controller) loadable in ``ui.perfetto.dev`` or ``chrome://tracing``.

Invariants the rest of the stack depends on:

* **Disabled mode is bitwise-invisible.**  Every producer gates its
  hook on ``telemetry is not None`` (and on not being inside a probe
  journal); with the default ``telemetry=None`` no telemetry code runs
  on any hot path and every sim/replay timeline is bit-identical to a
  build without this module (gated at exactly 0 diff by
  ``benchmarks/telemetry.py``).
* **Counters mirror the sim's own float-addition order**, so
  :meth:`cross_check` against ``link_stats()`` is EXACT (0.0), not
  approximately-equal: per-key busy/bytes accumulate in the same order
  the sim adds to ``link.busy_s`` / ``_stats[key]``.
* **Probes are ghosts.**  Producers suppress hooks while a probe
  journal / ``_probing`` flag is active; only the deterministic
  top-level ``fabric.probes`` count is stamped after rollback.  A
  probed sim's counters and event ring match a never-probed control
  (same discipline as the PR-5 probe-ghost test).
* **Deterministic export.**  No wall-clock anywhere; timestamps are sim
  times, track ids are first-seen order, args are sorted — same seed
  produces a byte-identical ``.trace.json``.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Any, Iterable

__all__ = [
    "Telemetry",
    "ordered_link_items",
    "canon_key",
    "validate_perfetto",
]


# ----------------------------------------------------------------------------
# deterministic ordering over mixed-type keys
# ----------------------------------------------------------------------------

def canon_key(part: Any) -> Any:
    """Total order over the mixed key vocabulary of the fabric: wire
    keys ``(a, b, ch)`` are int tuples, resource keys are
    ``("hostif", rank)`` — Python can't compare ``int`` with ``str``,
    so every scalar maps to a (type-rank, value) pair and tuples map
    recursively.  Shared by both sim tiers' ``link_stats`` so the two
    schemas iterate in one deterministic order (satellite: metric-name
    drift fix)."""
    if isinstance(part, tuple):
        return (2, tuple(canon_key(p) for p in part))
    if isinstance(part, bool):
        return (1, str(part))
    if isinstance(part, (int, float)):
        return (0, float(part))
    if part is None:
        return (-1, 0.0)
    return (1, str(part))


def ordered_link_items(items: Iterable[tuple[Any, Any]]) -> list[tuple[Any, Any]]:
    """Sort ``link_stats``-style ``(key, stats)`` pairs into the one
    canonical order both sim tiers share."""
    return sorted(items, key=lambda kv: canon_key(kv[0]))


def _json_safe(v: Any) -> Any:
    """Coerce event args to JSON-stable scalars (tuples/lists become
    their compact str repr — routes, stripe plans)."""
    if isinstance(v, bool) or v is None:
        return v
    if isinstance(v, (int, float, str)):
        return v
    if isinstance(v, (tuple, list)):
        return "(" + ",".join(str(_json_safe(x)) for x in v) + ")"
    return str(v)


def _track_label(track: tuple) -> str:
    """Human-readable Perfetto thread name for a track tuple."""
    kind = track[0] if track else "?"
    rest = track[1:]
    if kind == "link" and rest:
        key = rest[0]
        if isinstance(key, tuple) and len(key) == 3 and all(
                isinstance(p, int) for p in key):
            a, b, ch = key
            return f"link {a}->{b} vc{ch}"
        return f"link {key}"
    if kind == "node" and rest:
        key = rest[0]
        if isinstance(key, tuple):   # resource key like ("hostif", rank)
            return " ".join(str(p) for p in key)
        return f"node {key}"
    if kind == "rdma" and rest:
        return f"rdma rank{rest[0]}"
    if kind == "controller":
        return "qos controller"
    if kind == "cluster":
        return "cluster"
    return " ".join(str(p) for p in track)


# ----------------------------------------------------------------------------
# the hub
# ----------------------------------------------------------------------------

class Telemetry:
    """Counter/gauge registry + bounded event ring, shared by every
    subsystem that accepts ``telemetry=``.

    ``ring`` bounds event storage (a deque; oldest spans drop first —
    ``n_events``/``dropped`` record the total and the loss so a
    truncated trace is never silently mistaken for a complete one).
    Counters are unbounded but small: one float per (name, key, class)
    label actually touched.
    """

    def __init__(self, *, ring: int = 65536) -> None:
        if ring < 1:
            raise ValueError(f"ring must be >= 1, got {ring}")
        self.ring = ring
        # (name, key, cls) -> float.  key/cls None = scalar counter.
        self.counters: dict[tuple, float] = {}
        # (ts, track, name, dur, ((k, v), ...)) — ts/dur in sim seconds
        self.events: deque = deque(maxlen=ring)
        self.n_events = 0
        # hub-side derived state — NEVER stored on a sim object, so
        # attaching a hub cannot perturb sim behavior or snapshots:
        self._stall_from: dict = {}   # link key -> credit-block start
        self._last_cls: dict = {}     # resource key -> last class served

    # -- registry ------------------------------------------------------------
    def add(self, name: str, value: float = 1.0, *,
            key: Any = None, cls: int | None = None) -> None:
        """Accumulate ``value`` into the counter labelled
        ``(name, key, cls)``."""
        label = (name, key, cls)
        self.counters[label] = self.counters.get(label, 0.0) + value

    def set_gauge(self, name: str, value: float, *,
                  key: Any = None, cls: int | None = None) -> None:
        """Overwrite a gauge (last-write-wins; cache sizes, hit rates)."""
        self.counters[(name, key, cls)] = float(value)

    def value(self, name: str, *, key: Any = None,
              cls: int | None = None) -> float:
        return self.counters.get((name, key, cls), 0.0)

    def counters_snapshot(self) -> dict[str, float]:
        """Flat ``{label: value}`` view with deterministic label
        strings and ordering — the comparison surface for the probe-
        ghost and invisibility tests."""
        out: dict[str, float] = {}
        for (name, key, cls), v in sorted(
                self.counters.items(),
                key=lambda kv: (kv[0][0], canon_key(kv[0][1]),
                                -1 if kv[0][2] is None else kv[0][2])):
            label = name
            if key is not None:
                label += f"@{key}"
            if cls is not None:
                label += f"#c{cls}"
            out[label] = v
        return out

    # -- tracer --------------------------------------------------------------
    def event(self, track: tuple, name: str, ts: float,
              dur: float = 0.0, **args: Any) -> None:
        """Record one span (``dur > 0``) or instant (``dur == 0``) on
        ``track`` at sim time ``ts`` seconds."""
        packed = tuple(sorted((k, _json_safe(v)) for k, v in args.items()))
        self.events.append((float(ts), track, name, float(dur), packed))
        self.n_events += 1

    @property
    def dropped(self) -> int:
        """Events lost to the ring bound."""
        return self.n_events - len(self.events)

    def events_snapshot(self) -> tuple:
        return tuple(self.events)

    # -- sim fast-path hooks -------------------------------------------------
    # Each mirrors the sim's own accounting EXACTLY (same float-addition
    # order per key), so cross_check() is exact.  Producers gate the
    # call on `telemetry is not None and <not probing>`; the hooks
    # themselves never touch sim state.

    def on_link_tx(self, key: Any, cls: int, nbytes: float, dur: float,
                   start: float, resource: bool) -> None:
        """Packet tier: one packet/occupancy started service on link or
        host-IF resource ``key`` (mirrors ``_try_start`` accounting)."""
        self.add("link.busy_s", dur, key=key)
        self.add("link.bytes", nbytes, key=key)
        self.add("link.bytes", nbytes, key=key, cls=cls)
        self.add("link.busy_s", dur, key=key, cls=cls)
        if resource:
            last = self._last_cls.get(key)
            if last is not None and last != cls:
                # a different class took the host interface at a
                # descriptor boundary — the §2.1 preemption event
                self.add("hostif.preemptions")
            self._last_cls[key] = cls
        else:
            t0 = self._stall_from.pop(key, None)
            if t0 is not None and start > t0:
                # credit-blocked interval ends at this tx's start;
                # attribute the stall to the class that finally went
                self.add("link.credit_stall_s", start - t0, key=key, cls=cls)

    def on_credit_block(self, key: Any, now: float) -> None:
        """Packet tier: arbiter found every backlogged channel on
        ``key`` credit-blocked at ``now`` (start of a stall window)."""
        self._stall_from.setdefault(key, now)
        self.add("link.credit_blocks", key=key)

    def on_escape_loan(self, key: Any, cls: int, need: int) -> None:
        """Packet tier: deadlock-recovery escape-credit loan on ``key``
        channel ``cls`` — repaid in the same call by construction, so
        loans and repayments move in lockstep (invariant-tested)."""
        self.add("escape.loans")
        self.add("escape.loan_credits", float(need))
        self.add("escape.repayments")

    def on_flow_drain(self, link_keys: Iterable[Any], cls: int,
                      nbytes: float, busy: float) -> None:
        """Fluid tier: a flow drained — mirrors ``_drain``'s per-key
        stats loop in the same key order."""
        for key in link_keys:
            self.add("link.busy_s", busy, key=key)
            self.add("link.bytes", nbytes, key=key)
            self.add("link.bytes", nbytes, key=key, cls=cls)
            self.add("link.busy_s", busy, key=key, cls=cls)

    def on_resource_busy(self, key: Any, service_s: float,
                         cls: int) -> None:
        """Fluid tier: a flow's host-IF occupancy activated — mirrors
        ``_activate``'s resource accounting."""
        self.add("link.busy_s", service_s, key=key)
        self.add("link.busy_s", service_s, key=key, cls=cls)
        last = self._last_cls.get(key)
        if last is not None and last != cls:
            self.add("hostif.preemptions")
        self._last_cls[key] = cls

    def flow_span(self, track: tuple, name: str, start: float,
                  finish: float, **args: Any) -> None:
        """Convenience: inject→drain span of one flow on ``track``."""
        self.event(track, name, start, max(finish - start, 0.0), **args)

    # -- pull-based gauges ---------------------------------------------------
    def collect(self, sim: Any = None) -> None:
        """Pull module-level route-cache gauges (and optional per-sim
        totals) into the registry.  Explicit, not hot-path: the route
        caches are free functions shared by every sim, so their stats
        live in a module counter dict that this copies in as gauges."""
        from . import sim as _simmod   # local import avoids a cycle
        for k, v in sorted(_simmod.ROUTE_CACHE_STATS.items()):
            self.set_gauge(f"route_cache.{k}", float(v))
        if sim is not None:
            self.set_gauge("sim.now", float(getattr(sim, "now", 0.0)))

    # -- verification --------------------------------------------------------
    def cross_check(self, sim: Any) -> float:
        """Max absolute difference between this hub's per-link counters
        and the sim's own ``link_stats()``.  EXACTLY 0.0 when the hub
        was attached at construction: both sides added the same floats
        in the same order.  (Gated at 0 by ``benchmarks/telemetry.py``.)"""
        worst = 0.0
        for key, st in sim.link_stats().items():
            worst = max(worst, abs(st["busy_s"]
                                   - self.value("link.busy_s", key=key)))
            worst = max(worst, abs(st["bytes"]
                                   - self.value("link.bytes", key=key)))
            for c, b in enumerate(st["class_bytes"]):
                worst = max(worst, abs(b - self.value("link.bytes",
                                                      key=key, cls=c)))
        return worst

    # -- export --------------------------------------------------------------
    def to_perfetto(self) -> str:
        """Chrome-trace JSON (the legacy JSON format Perfetto ingests):
        one pid, one tid per track (first-seen order), ``M`` metadata
        rows naming each track, ``X`` complete events for spans, ``i``
        instants for point events.  ts/dur in microseconds.  Fully
        deterministic — same seed, byte-identical file."""
        tids: dict[tuple, int] = {}
        trace_events: list[dict] = []
        for ts, track, name, dur, args in self.events:
            tid = tids.get(track)
            if tid is None:
                tid = tids[track] = len(tids) + 1
            ev: dict[str, Any] = {
                "pid": 0, "tid": tid, "name": name,
                "cat": str(track[0]) if track else "event",
                "ts": round(ts * 1e6, 3),
            }
            if args:
                ev["args"] = dict(args)
            if dur > 0.0:
                ev["ph"] = "X"
                ev["dur"] = round(dur * 1e6, 3)
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            trace_events.append(ev)
        meta = [{"pid": 0, "tid": tid, "ph": "M", "name": "thread_name",
                 "args": {"name": _track_label(track)}}
                for track, tid in tids.items()]
        obj = {"displayTimeUnit": "ms",
               "traceEvents": meta + trace_events}
        return json.dumps(obj, sort_keys=True, separators=(",", ":"))

    def summary_table(self, *, top: int = 24) -> str:
        """Plain-text counter summary — the ``scripts/fabric_trace.py``
        stdout report."""
        snap = self.counters_snapshot()
        scalars = {k: v for k, v in snap.items() if "@" not in k}
        labelled = {k: v for k, v in snap.items() if "@" in k}
        lines = ["== telemetry summary =="]
        lines.append(f"events: {self.n_events} recorded, "
                     f"{self.dropped} dropped (ring={self.ring})")
        for k, v in scalars.items():
            lines.append(f"  {k:<32s} {v:>14.6g}")
        busiest = sorted(
            ((k, v) for k, v in labelled.items()
             if k.startswith("link.busy_s@") and "#c" not in k),
            key=lambda kv: (-kv[1], kv[0]))[:top]
        if busiest:
            lines.append(f"  -- busiest links (top {len(busiest)}) --")
            for k, v in busiest:
                lines.append(f"  {k:<40s} {v:>12.6g} s")
        return "\n".join(lines)


# ----------------------------------------------------------------------------
# trace-file schema validation (scripts/fabric_trace.py --validate)
# ----------------------------------------------------------------------------

def validate_perfetto(obj: Any) -> list[str]:
    """Hand-rolled Chrome-trace JSON schema check (no jsonschema dep).
    Returns a list of violations; empty = valid."""
    errs: list[str] = []
    if not isinstance(obj, dict):
        return [f"top level must be an object, got {type(obj).__name__}"]
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["missing or non-list 'traceEvents'"]
    named_tids: set = set()
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            errs.append(f"{where}: bad ph {ph!r}")
            continue
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                errs.append(f"{where}: missing int {field!r}")
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            errs.append(f"{where}: missing name")
        if ph == "M":
            args = ev.get("args")
            if not (isinstance(args, dict)
                    and isinstance(args.get("name"), str)):
                errs.append(f"{where}: metadata row lacks args.name")
            else:
                named_tids.add((ev.get("pid"), ev.get("tid")))
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: complete event with bad dur {dur!r}")
        if "args" in ev and not isinstance(ev["args"], dict):
            errs.append(f"{where}: non-object args")
    for i, ev in enumerate(evs):
        if isinstance(ev, dict) and ev.get("ph") in ("X", "i"):
            ident = (ev.get("pid"), ev.get("tid"))
            if ident not in named_tids:
                errs.append(f"traceEvents[{i}]: tid {ident} has no "
                            "thread_name metadata row")
                break   # one unnamed tid implies many; report once
    return errs
