"""Flow-level (fluid) fabric simulator — the fast fidelity tier for
large tori, with optional packet-mode escalation of contended links.

``FabricSim`` (``fabric/sim.py``) walks every packet of every flow
through every router: exact, and the bitwise oracle — but pure-Python
event dispatch caps it at a few dozen nodes.  The paper's own pitch is
petaflops-class machines (arXiv:1102.3796 frames APEnet+ entirely in
aggregate-bandwidth-vs-concurrent-flows terms), and the ROADMAP's
autotuner and trace-replay items both need an 8x8x8 torus with thousands
of live flows to settle in milliseconds.  This module adds that tier:

  * ``FluidSim`` models each flow as a *rate* over its route instead of a
    packet walk.  Whenever the set of transmitting flows changes, a
    vectorized **hierarchical weighted max-min** solver (progressive
    filling / waterfilling over the links x flows incidence) re-allocates
    every link-direction's bandwidth: first across backlogged traffic
    classes in proportion to the ``QosPolicy`` arbiter weights (the
    virtual-channel arbiter), then within a class in proportion to each
    flow's packet size (the FIFO round-robins concurrent flows packet by
    packet, so within-class goodput is packet-size-proportional).  Time
    then fast-forwards to the next rate-change event (flow start / drain)
    — the event count is O(flows), not O(packets x hops).  The solver
    runs on flat numpy index arrays by default; ``solver="jnp"`` switches
    to a jit-compiled dense-incidence waterfill (``jnp`` matmuls over a
    links x flows matrix, padded to stable shapes), useful when XLA's
    host devices are available (``xla_force_host_platform_device_count``).
  * per-flow endpoint costs are carried over from the packet model
    *exactly*: activation + ``t_inject``/GPU touch, a drain window whose
    byte integral is the payload, then the store-and-forward tail
    ``(h-1) * tail_bytes / B + h * t_hop`` and ``t_receive``.  On an
    uncontended route the fluid finish time equals the packet sim's to
    float precision — the differential tests pin that down.
  * ``HybridSim`` watches the solver for saturated links (utilization
    above ``escalate_util`` with >= 2 competing flows), re-runs exactly
    the flows crossing those links through a packet-mode ``FabricSim``
    sub-simulation, and stitches the timelines back (packet-accurate
    finishes on contended links, fluid everywhere else; downstream
    dependents shift by their dependencies' slip).
  * the public surface duck-types ``FabricSim`` — ``inject`` / ``occupy``
    / ``run`` / ``finish_s`` / ``flow`` / ``probe_route`` / ``link_stats``
    / ``class_stats`` / ``advance`` / ``prune`` — so ``RdmaEndpoint``,
    the serving cluster/engine and the route prober run unmodified on
    either tier; ``make_sim(..., fidelity=)`` is the one constructor
    every consumer threads through.

What the fluid tier does NOT model (the documented fidelity contract):
packet-granular interleaving transients, credit-window backpressure
transients, and contention among sub-packet-size flows (a flow smaller
than one packet holds a link for one packet time; the fluid tier prices
its latency exactly but does not charge other flows for it).  The
differential harness (tests/test_fluid_sim.py) holds fluid completion
times to within 10% of packet mode on multi-packet workloads — the same
bar the sim/analytic differential uses.
"""
from __future__ import annotations

import heapq
from typing import Hashable, Sequence

import numpy as np

from repro.core import apelink
from repro.core.apelink import NetModel
from repro.core.fabric.lower import UnroutableError
from repro.core.fabric.qos import SINGLE_CLASS, QosPolicy, TrafficClass
from repro.core.fabric.schedule import FaultMap
from repro.core.fabric.sim import (
    DEFAULT_MAX_PACKETS, DEFAULT_PACKET_BYTES, FabricSim, FlowResult,
    _cached_bfs, link_key, packetize)
from repro.core.fabric.telemetry import ordered_link_items
from repro.core.topology import Torus

FIDELITIES = ("packet", "fluid", "hybrid")

# a drain below half a byte is float dust from settling, not payload
_BYTE_EPS = 0.5
# progressive-filling rounds: each round freezes at least one link or
# flow, so depth bounds the distinct bottleneck levels resolved exactly
_MAX_ROUNDS = 64


def make_sim(torus: Torus, net: NetModel | None = None, *,
             fidelity: str = "packet", **kw):
    """The one constructor for every fabric-simulator fidelity tier.

    ``"packet"`` -> ``FabricSim`` (bitwise oracle), ``"fluid"`` ->
    ``FluidSim`` (flow-level fast path), ``"hybrid"`` -> ``HybridSim``
    (fluid with packet-mode escalation of contended links).  Extra
    keyword arguments go to the tier's constructor."""
    if fidelity == "packet":
        return FabricSim(torus, net, **kw)
    if fidelity == "fluid":
        return FluidSim(torus, net, **kw)
    if fidelity == "hybrid":
        return HybridSim(torus, net, **kw)
    raise ValueError(
        f"unknown fidelity {fidelity!r}: expected one of {FIDELITIES}")


class _FFlow:
    """One fluid-tier flow: a drain window over its route plus exact
    endpoint terms (packet-model parity, see module docstring)."""

    __slots__ = ("fid", "route", "links", "link_keys", "nbytes", "remaining",
                 "rate", "weight", "tail_s", "tail_bytes", "cls", "cidx",
                 "req_start", "start_s", "drain_s", "finish_s", "pending",
                 "deps", "dependents", "src_over", "dst_over", "rate_cap",
                 "resource", "service_s", "label", "channel", "src_gpu",
                 "dst_gpu", "version")

    def __init__(self, fid: int) -> None:
        self.fid = fid
        self.route: tuple[int, ...] = ()
        self.links: np.ndarray | None = None   # interned link ids, int64
        self.link_keys: tuple = ()
        self.nbytes = 0.0
        self.remaining = 0.0      # drain bytes left (payload minus tail)
        self.rate = 0.0           # current allocated rate, bytes/s
        self.weight = 1.0         # within-class arbiter weight (pkt bytes)
        self.tail_s = 0.0         # store-and-forward tail + hop latency
        self.tail_bytes = 0.0     # last-packet bytes riding the tail
        self.cls: TrafficClass | None = None
        self.cidx = 0
        self.req_start = 0.0
        self.start_s: float | None = None     # activation (deps satisfied)
        self.drain_s: float | None = None     # payload fully injected
        self.finish_s: float | None = None
        self.pending = 0
        self.deps: tuple[int, ...] = ()
        self.dependents: list[int] = []
        self.src_over = 0.0
        self.dst_over = 0.0
        self.rate_cap = float("inf")          # GPU-outbound source pacing
        self.resource: Hashable | None = None
        self.service_s: float | None = None
        self.label = ""
        self.channel = 0
        self.src_gpu = False
        self.dst_gpu = False
        self.version = 0          # drain-event staleness stamp


class FluidSim:
    """Flow-level fabric simulator over one ``Torus`` — same public
    surface as ``FabricSim``, O(flows) events instead of O(packets).

    ``solver`` picks the rate solver: ``"np"`` (flat-index numpy
    progressive filling, the default) or ``"jnp"`` (jit-compiled dense
    waterfill over the links x flows incidence).  ``exact_below`` and
    ``resolve_frac`` trade solver invocations for staleness: with more
    than ``exact_below`` active flows, a re-solve after drains is only
    triggered once ``resolve_frac`` of the active set has drained (rates
    between solves are *stale but conservative* — a drained flow only
    frees bandwidth, so surviving flows never finish later than the lazy
    schedule predicts).  ``coalesce_s`` widens the same-instant event
    batch window so staggered activations share one solve."""

    def __init__(self, torus: Torus, net: NetModel | None = None, *,
                 packet_bytes: int = DEFAULT_PACKET_BYTES,
                 credit_bytes: float | None = None,
                 max_packets_per_flow: int = DEFAULT_MAX_PACKETS,
                 faults: FaultMap | None = None,
                 qos: QosPolicy | None = None,
                 solver: str = "np",
                 exact_below: int = 64,
                 resolve_frac: float = 0.05,
                 coalesce_s: float = 0.0,
                 telemetry: "object | None" = None) -> None:
        if packet_bytes <= 0:
            raise ValueError(f"packet_bytes must be > 0, got {packet_bytes}")
        if solver not in ("np", "jnp"):
            raise ValueError(f"unknown solver {solver!r}")
        self.torus = torus
        self.net = net or NetModel()
        self.faults = faults or FaultMap()
        self.qos = qos or SINGLE_CLASS
        self.link_bw = apelink.sustained_bandwidth(self.net.link)
        self.credit_bytes = (float(credit_bytes) if credit_bytes is not None
                             else apelink.channel_footprint_bytes(
                                 self.net.link))
        if self.credit_bytes <= 0:
            raise ValueError("credit_bytes must be > 0")
        self.packet_bytes = min(packet_bytes, int(self.credit_bytes) or 1)
        self.max_packets = max(1, max_packets_per_flow)
        self.solver = solver
        self.exact_below = max(1, exact_below)
        self.resolve_frac = resolve_frac
        self.coalesce_s = coalesce_s
        self._weights = self.qos.weight_vector()
        self._class_credits = self.qos.partition_credits(self.credit_bytes)
        self._flows: dict[int, _FFlow] = {}
        self._active: dict[int, _FFlow] = {}   # transmitting (insertion =
        self._heap: list = []                  # deterministic solve order)
        self._seq_n = 0
        self._fid_n = 0
        self._frontier = 0.0
        self._solve_t = 0.0       # time the active set's rates are valid from
        self._version = 0
        self._dirty = False
        self._drained_since = 0   # drains since the last re-solve
        self._lid: dict = {}      # link key -> dense id (solver index)
        self._lid_keys: list = []
        self._stats: dict = {}    # link key -> [busy_s, bytes, class_bytes[]]
        self._res_free: dict = {} # resource key -> FIFO free-at time
        self._probing = False
        # optional Telemetry hub — every hook gated on
        # ``telemetry is not None and not self._probing`` (None is
        # bitwise-invisible; probe ghosts never reach the hub)
        self.telemetry = telemetry
        self.n_solves = 0         # solver invocations (reporting)
        self.n_warm_solves = 0    # solves that reused cached incidence
        # warm-start cache: the flat incidence arrays ``_rates_np`` builds
        # are a pure function of (active flow set, interned link count) —
        # a re-solve where only the QoS weights changed (the controller's
        # per-window retune) reuses them verbatim, so the waterfill rounds
        # re-run against identical inputs and the allocation is bitwise
        # equal to a cold solve at a fraction of the Python cost
        self._inc_cache: tuple | None = None
        # hybrid escalation hooks (populated by the solver when tracking)
        self.escalate_util: float | None = None
        self._hot: set[int] = set()
        self.last_probe_report: dict | None = None

    # -- clock ----------------------------------------------------------------
    @property
    def now(self) -> float:
        """The timeline frontier (latest processed/advanced time)."""
        return self._frontier

    def advance(self, t: float) -> None:
        """Move the frontier forward (never backward)."""
        self._frontier = max(self._frontier, t)

    # -- injection ------------------------------------------------------------
    def _resolve_route(self, src: int, dst: int,
                       route: Sequence[int] | None) -> tuple[int, ...]:
        if route is not None:
            route = tuple(route)
            if len(route) < 1 or route[0] != src or route[-1] != dst:
                raise ValueError(f"route {route} does not join {src}->{dst}")
            return route
        if src == dst:
            return (src,)
        if not self.faults:
            return tuple(self.torus.route(src, dst))
        path = _cached_bfs(self.torus, src, dst, self.faults)
        if path is None:
            raise UnroutableError(
                f"no surviving route {src} -> {dst} in the simulated fabric")
        return tuple(path)

    def _lid_of(self, key) -> int:
        lid = self._lid.get(key)
        if lid is None:
            lid = self._lid[key] = len(self._lid_keys)
            self._lid_keys.append(key)
        return lid

    def _stat(self, key) -> list:
        st = self._stats.get(key)
        if st is None:
            st = self._stats[key] = [0.0, 0.0, [0.0] * len(TrafficClass)]
        return st

    def _new_flow(self, start_s: float | None,
                  after: Sequence[int]) -> _FFlow:
        f = _FFlow(self._fid_n)
        self._fid_n += 1
        f.req_start = self._frontier if start_s is None else float(start_s)
        self._flows[f.fid] = f
        f.deps = tuple(after)
        for dep_fid in after:
            dep = self._flows[dep_fid]
            if dep.finish_s is None:
                dep.dependents.append(f.fid)
                f.pending += 1
            else:
                f.req_start = max(f.req_start, dep.finish_s)
        if f.pending == 0:
            self._push(f.req_start, "start", f.fid)
        return f

    def inject(self, src: int, dst: int, nbytes: float, *,
               start_s: float | None = None,
               route: Sequence[int] | None = None,
               after: Sequence[int] = (),
               src_gpu: bool = False, dst_gpu: bool = False,
               channel: int = 0, label: str = "",
               cls: TrafficClass = TrafficClass.BULK) -> int:
        """Inject one flow of ``nbytes`` from rank ``src`` to ``dst`` —
        the ``FabricSim.inject`` contract, priced at flow level."""
        f = self._new_flow(start_s, after)
        f.route = self._resolve_route(src, dst, route)
        f.channel = channel
        f.cls = TrafficClass(cls)
        f.cidx = self.qos.class_index(f.cls)
        f.nbytes = float(nbytes)
        f.src_gpu = src_gpu
        f.dst_gpu = dst_gpu
        cap = self._class_credits[f.cidx]
        if not self.qos.single_class:
            # same >= 2-packets-per-credit-window rule as the packet tier
            cap = max(cap * 0.5, 1.0)
        pkt, npkts = packetize(f.nbytes, cap, self.packet_bytes,
                               self.max_packets)
        tail = max(f.nbytes - (npkts - 1) * pkt, 0.0)
        h = len(f.route) - 1
        # drain window carries payload-minus-tail at the allocated rate;
        # the last packet crosses every hop at wire speed (store-and-
        # forward boundary) — on a quiet route this reproduces the packet
        # sim's finish exactly: t0 + src_over + nbytes/B
        #                       + (h-1)*tail/B + h*t_hop + dst_over
        f.remaining = max(f.nbytes - tail, 0.0)
        f.tail_bytes = tail
        f.tail_s = h * tail / self.link_bw + h * self.net.t_hop
        f.weight = pkt if pkt > 0 else 1.0
        f.src_over = self.net.t_inject \
            + (self.net.gpu_touch_overhead if src_gpu else 0.0)
        f.dst_over = self.net.t_receive \
            + (self.net.gpu_touch_overhead if dst_gpu else 0.0)
        if src_gpu and self.net.gpu_read_cap < self.link_bw:
            # GPU-outbound read bottleneck as a per-flow rate cap
            f.rate_cap = float(self.net.gpu_read_cap)
        if h > 0:
            keys = tuple(
                link_key(self.torus, f.route[i], f.route[i + 1], channel)
                for i in range(h))
            f.link_keys = keys
            f.links = np.fromiter((self._lid_of(k) for k in keys),
                                  dtype=np.int64, count=h)
        f.label = label
        return f.fid

    def occupy(self, resource: Hashable, busy_s: float, *,
               start_s: float | None = None,
               after: Sequence[int] = (), label: str = "",
               cls: TrafficClass = TrafficClass.BULK) -> int:
        """Occupy a rank-local FIFO resource for ``busy_s`` seconds (the
        host-interface DMA drain) — FIFO-serialized at flow level."""
        if busy_s < 0:
            raise ValueError(f"negative busy_s {busy_s}")
        f = self._new_flow(start_s, after)
        f.resource = resource
        f.service_s = float(busy_s)
        f.label = label
        f.cls = TrafficClass(cls)
        f.cidx = self.qos.class_index(f.cls)
        return f.fid

    # -- event machinery ------------------------------------------------------
    def _push(self, t: float, kind: str, arg) -> None:
        heapq.heappush(self._heap, (t, self._seq_n, kind, arg))
        self._seq_n += 1

    def _activate(self, f: _FFlow, t: float) -> None:
        f.start_s = t
        if f.resource is not None:
            free = self._res_free.get(f.resource, 0.0)
            beg = max(t, free)
            end = beg + (f.service_s or 0.0)
            self._res_free[f.resource] = end
            self._stat(f.resource)[0] += f.service_s or 0.0
            if self.telemetry is not None and not self._probing:
                self.telemetry.on_resource_busy(
                    f.resource, f.service_s or 0.0, int(f.cls))
            if end > t:
                self._push(end, "complete", f.fid)
            else:
                self._finish(f, t)
            return
        if len(f.route) < 2:          # self-send: no wire
            self._finish(f, t)
            return
        if f.src_over > 0:
            self._push(t + f.src_over, "go", f.fid)
        else:
            self._go(f, t)

    def _go(self, f: _FFlow, t: float) -> None:
        """The flow's payload starts transmitting: join the rate solve."""
        if f.remaining <= _BYTE_EPS:
            self._drain(f, t)         # sub-packet flow: tail terms only
            return
        self._active[f.fid] = f
        self._dirty = True

    def _drain(self, f: _FFlow, t: float) -> None:
        """The payload has fully entered the wire; account the route's
        byte/busy stats and schedule the store-and-forward tail."""
        f.drain_s = t
        f.remaining = 0.0
        self._active.pop(f.fid, None)
        busy = f.nbytes / self.link_bw
        for key in f.link_keys:
            st = self._stat(key)
            st[0] += busy
            st[1] += f.nbytes
            st[2][int(f.cls)] += f.nbytes
        if self.telemetry is not None and not self._probing:
            # mirrors the per-key loop above in the same order, so the
            # hub's counters cross-check exactly against _stats
            self.telemetry.on_flow_drain(f.link_keys, int(f.cls),
                                         f.nbytes, busy)
        fin = t + f.tail_s + f.dst_over
        if fin > t:
            self._push(fin, "complete", f.fid)
        else:
            self._finish(f, t)
        self._drained_since += 1
        n_act = len(self._active)
        if n_act and (n_act <= self.exact_below
                      or self._drained_since >= max(
                          1.0, self.resolve_frac * n_act)):
            self._dirty = True

    def _finish(self, f: _FFlow, t: float) -> None:
        f.finish_s = t
        self._frontier = max(self._frontier, t)
        tel = self.telemetry
        if tel is not None and not self._probing:
            start = f.start_s if f.start_s is not None else f.req_start
            if f.resource is not None:
                track = ("node", f.resource)
            elif f.link_keys:
                track = ("link", f.link_keys[0])
            else:
                track = ("node", f.route[0] if f.route else -1)
            tel.flow_span(track, f.label or f"flow{f.fid}", start, t,
                          cls=int(f.cls), nbytes=f.nbytes, fid=f.fid)
        for dep_fid in f.dependents:
            dep = self._flows[dep_fid]
            dep.pending -= 1
            dep.req_start = max(dep.req_start, t)
            if dep.pending == 0:
                self._push(dep.req_start, "start", dep.fid)
        f.dependents = []

    def _settle(self, t: float) -> None:
        """Advance every active flow's drain integral to ``t`` under the
        current rates (progress is only materialized at solve points)."""
        dt = t - self._solve_t
        if dt > 0:
            for f in self._active.values():
                f.remaining = max(f.remaining - f.rate * dt, 0.0)
        self._solve_t = max(self._solve_t, t)

    def _solve(self, t: float) -> None:
        """Re-allocate link bandwidth across the active flows and refresh
        their predicted drain events (version-stamped: predictions from
        older solves are ignored when popped)."""
        self._settle(t)
        self._dirty = False
        self._drained_since = 0
        act = list(self._active.values())
        if not act:
            return
        self.n_solves += 1
        self._version += 1
        ver = self._version
        if len(act) == 1:
            rates = [min(self.link_bw, act[0].rate_cap)]
        elif self.solver == "jnp":
            rates = self._rates_jnp(act)
        else:
            rates = self._rates_np(act)
        for f, r in zip(act, rates):
            f.rate = float(r)
            f.version = ver
            if f.remaining <= _BYTE_EPS:
                self._push(t, "drain", (f.fid, ver))
            else:
                self._push(t + f.remaining / f.rate, "drain", (f.fid, ver))

    def _rates_np(self, act: list[_FFlow]) -> np.ndarray:
        """Hierarchical weighted max-min progressive filling on flat
        index arrays: every round grants each unfrozen flow its min
        bottleneck share — residual * (class weight / active class
        weights) * (flow weight / class weight sum on that link) — then
        freezes flows touching saturated links (or at their source rate
        cap).  Each round saturates at least one link or cap, so rounds
        are bounded by the distinct bottleneck levels."""
        B = self.link_bw
        nc = self.qos.n_classes
        n_lids = len(self._lid_keys)
        n_flows = len(act)
        ckey = (n_lids, tuple(f.fid for f in act))
        cached = self._inc_cache
        if cached is not None and cached[0] == ckey:
            hop_flow, hop_link, cidx, wf, cap = cached[1]
            self.n_warm_solves += 1
        else:
            hop_flow = np.repeat(np.arange(n_flows, dtype=np.int64),
                                 [len(f.links) for f in act])
            hop_link = np.concatenate([f.links for f in act])
            cidx = np.fromiter((f.cidx for f in act), dtype=np.int64,
                               count=n_flows)
            wf = np.fromiter((f.weight for f in act), dtype=np.float64,
                             count=n_flows)
            cap = np.fromiter((f.rate_cap for f in act), dtype=np.float64,
                              count=n_flows)
            self._inc_cache = (ckey, (hop_flow, hop_link, cidx, wf, cap))
        wc = np.asarray(self._weights, dtype=np.float64)
        resid = np.full(n_lids, B)
        rate = np.zeros(n_flows)
        unfrozen = np.ones(n_flows, dtype=bool)
        for _ in range(_MAX_ROUNDS):
            live = unfrozen[hop_flow]
            hf = hop_flow[live]
            hl = hop_link[live]
            if hf.size == 0:
                break
            hc = cidx[hf]
            key = hl * nc + hc
            class_w = np.zeros(n_lids * nc)
            np.add.at(class_w, key, wf[hf])
            active_w = (class_w.reshape(n_lids, nc) > 0.0) @ wc
            share = resid[hl] * (wc[hc] / active_w[hl]) * (wf[hf]
                                                           / class_w[key])
            inc = np.full(n_flows, np.inf)
            np.minimum.at(inc, hf, share)
            np.minimum(inc, cap - rate, out=inc)
            inc[~unfrozen] = 0.0
            np.maximum(inc, 0.0, out=inc)
            rate += inc
            used = np.zeros(n_lids)
            np.add.at(used, hl, inc[hf])
            resid -= used
            sat = resid <= B * 1e-9
            np.maximum(resid, 0.0, out=resid)
            flow_sat = np.zeros(n_flows, dtype=bool)
            flow_sat[hf[sat[hl]]] = True
            capped = rate >= cap * (1.0 - 1e-12)
            newly = unfrozen & (flow_sat | capped)
            if not newly.any() and inc.max(initial=0.0) <= B * 1e-12:
                break
            unfrozen &= ~newly
            if not unfrozen.any():
                break
        if self.escalate_util is not None and not self._probing:
            # hybrid hook: saturated links shared by >= 2 flows
            count = np.zeros(n_lids)
            np.add.at(count, hop_link, 1.0)
            hot = np.flatnonzero(
                (resid <= B * (1.0 - self.escalate_util)) & (count >= 2.0))
            self._hot.update(int(x) for x in hot)
        return rate

    def _rates_jnp(self, act: list[_FFlow]) -> np.ndarray:
        """Dense-incidence waterfill on JAX: the same progressive filling
        as ``_rates_np`` expressed as jit-compiled matmuls over a padded
        links x flows 0/1 incidence matrix (stable shapes, one compile
        per padded size)."""
        B = self.link_bw
        nc = self.qos.n_classes
        n_lids = len(self._lid_keys)
        n_flows = len(act)
        pad = _pad_to(n_flows), _pad_to(n_lids)
        inc_mat = np.zeros((pad[1], pad[0]), dtype=np.float32)
        for i, f in enumerate(act):
            inc_mat[f.links, i] = 1.0
        onehot = np.zeros((pad[0], nc), dtype=np.float32)
        wf = np.zeros(pad[0], dtype=np.float32)
        cap = np.full(pad[0], np.inf, dtype=np.float32)
        alive = np.zeros(pad[0], dtype=np.float32)
        for i, f in enumerate(act):
            onehot[i, f.cidx] = 1.0
            wf[i] = f.weight
            cap[i] = min(f.rate_cap, 3.4e38)
            alive[i] = 1.0
        wc = np.asarray(self._weights, dtype=np.float32)
        rate, resid = _jnp_waterfill(inc_mat, wf, onehot, cap, alive,
                                     wc, float(B), _MAX_ROUNDS)
        rate = np.asarray(rate, dtype=np.float64)[:n_flows]
        if self.escalate_util is not None and not self._probing:
            resid = np.asarray(resid, dtype=np.float64)[:n_lids]
            count = inc_mat.sum(axis=1)[:n_lids]
            hot = np.flatnonzero(
                (resid <= B * (1.0 - self.escalate_util)) & (count >= 2.0))
            self._hot.update(int(x) for x in hot)
        return rate

    def run(self) -> float:
        """Process every pending event; returns the frontier time."""
        heap = self._heap
        while heap:
            self._step(heapq.heappop(heap))
        return self._frontier

    def run_until(self, until: float) -> float:
        """Process every event up to and including ``until``, settle the
        active drain integrals to that instant, and stop with later
        events pending — the mid-flight re-striping checkpoint.  A later
        ``run()`` resumes in the same heap order; on the hybrid tier a
        partial drain never escalates (escalation is a full-``run``
        stitch)."""
        heap = self._heap
        while heap and heap[0][0] <= until:
            self._step(heapq.heappop(heap))
        if self._dirty and self._active:
            self._solve(max(self._frontier, self._solve_t))
        self._settle(max(self._frontier, until, self._solve_t))
        self._frontier = max(self._frontier, until)
        return self._frontier

    def _step(self, ev: tuple) -> None:
        t, _, kind, arg = ev
        if t < self._solve_t:
            t = self._solve_t     # clock guard (coalesced batches)
        self._frontier = max(self._frontier, t)
        if kind == "start":
            self._activate(self._flows[arg], t)
        elif kind == "go":
            f = self._flows[arg]
            if f.finish_s is None and f.drain_s is None:
                self._go(f, t)
        elif kind == "drain":
            fid, ver = arg
            f = self._flows.get(fid)
            if f is not None and f.version == ver \
                    and f.drain_s is None:
                self._drain(f, t)
        elif kind == "complete":
            f = self._flows.get(arg)
            if f is not None and f.finish_s is None:
                self._finish(f, t)
        if self._dirty and (not self._heap
                            or self._heap[0][0] > t + self.coalesce_s):
            self._solve(t)

    # -- results --------------------------------------------------------------
    def finish_s(self, fid: int) -> float:
        flow = self._flows[fid]
        if flow.finish_s is None:
            self.run()
        if flow.finish_s is None:
            raise RuntimeError(f"flow {fid} never completed "
                               "(unsatisfied dependency?)")
        return flow.finish_s

    def flow(self, fid: int) -> FlowResult:
        f = self._flows[fid]
        return FlowResult(
            fid=fid,
            src=f.route[0] if f.route else -1,
            dst=f.route[-1] if f.route else -1,
            nbytes=f.nbytes, hops=max(len(f.route) - 1, 0),
            start_s=f.start_s if f.start_s is not None else f.req_start,
            finish_s=self.finish_s(fid), label=f.label, cls=f.cls)

    def link_stats(self) -> dict:
        """Per-directed-link busy seconds / carried bytes / class bytes —
        the ``FabricSim.link_stats`` shape, accounted at flow drains."""
        return {k: {"busy_s": v[0], "bytes": v[1],
                    "class_bytes": tuple(v[2])}
                for k, v in ordered_link_items(self._stats.items())}

    def class_stats(self, since: dict | None = None
                    ) -> dict[TrafficClass, float]:
        """Bytes carried per traffic-class tag over every directed link
        (each wire hop counts) — identical accounting to the packet tier,
        so per-class byte conservation is exact across fidelities.
        ``since`` takes a previous ``class_stats()`` mapping and returns
        the per-window DELTA (see ``FabricSim.class_stats``); the read
        never mutates the sim."""
        totals = [0.0] * len(TrafficClass)
        for st in self._stats.values():
            for c in range(len(TrafficClass)):
                totals[c] += st[2][c]
        out = {cls: totals[int(cls)] for cls in TrafficClass}
        if since is not None:
            for cls in out:
                out[cls] -= float(since.get(cls, 0.0))
        return out

    # -- live QoS retune -------------------------------------------------------
    def set_qos(self, policy: QosPolicy) -> None:
        """Swap the arbitration policy on a LIVE timeline — the fluid
        expression of ``FabricSim.set_qos``.  The waterfill honors the
        retuned weights from this instant on: active drain integrals are
        settled under the old rates up to now, then one immediate re-solve
        re-allocates every link under the new weights (warm-started — the
        active set did not change, so the cached incidence arrays are
        reused and only the class-weight vector differs)."""
        if self._probing:
            raise RuntimeError("set_qos during an active probe")
        if policy.n_classes != self.qos.n_classes:
            raise ValueError(
                "cannot change the virtual-channel count of a live sim "
                f"({self.qos.n_classes} -> {policy.n_classes})")
        self.qos = policy
        self._weights = policy.weight_vector()
        self._class_credits = policy.partition_credits(self.credit_bytes)
        if self._active:
            self._solve(max(self._frontier, self._solve_t))
        if self.telemetry is not None:
            self.telemetry.add("fabric.qos_retunes")

    # -- mid-flight re-striping ------------------------------------------------
    def unsent_bytes(self, fid: int) -> float:
        """Drain bytes of ``fid`` not yet injected into the wire at the
        last settle point (``run_until`` settles to its checkpoint) — the
        remainder a mid-flight re-stripe may re-split.  The fluid tier
        tracks a continuous drain integral, so "unsent" is the remaining
        integral rather than a packet count; the store-and-forward tail
        stays with the original flow."""
        f = self._flows[fid]
        if f.finish_s is not None or f.drain_s is not None \
                or f.resource is not None:
            return 0.0
        if f.start_s is None:
            return f.nbytes
        return max(f.remaining, 0.0)

    def restripe(self, fid: int, plan: Sequence[tuple]) -> list[int]:
        """Re-split flow ``fid``'s unsent remainder across a fresh
        ``striped_routes`` plan — ``FabricSim.restripe`` at flow level.
        The flow is re-pointed at ``plan[0]`` carrying that route's share
        (its byte/busy stats account on the final route — the fluid
        fidelity contract trades per-hop exactness for O(flows) cost);
        sibling flows carry the other shares from now.  Triggers an
        immediate re-solve so no drain integral ever advances under a
        stale route."""
        if self._probing:
            raise RuntimeError("restripe during an active probe")
        f = self._flows[fid]
        if f.resource is not None:
            raise ValueError("cannot restripe a resource occupancy")
        if f.start_s is None:
            raise ValueError(f"flow {fid} has not started; nothing is "
                             "committed yet — re-plan the whole transfer")
        rem = self.unsent_bytes(fid)
        routes: list[tuple[int, ...]] = []
        fracs: list[float] = []
        for route, frac in plan:
            route = tuple(route)
            if route[0] != f.route[0] or route[-1] != f.route[-1]:
                raise ValueError(f"plan route {route} does not join "
                                 f"{f.route[0]}->{f.route[-1]}")
            if frac > 0.0:
                routes.append(route)
                fracs.append(float(frac))
        if rem <= _BYTE_EPS or not routes:
            return [fid]
        total = sum(fracs)
        shares = [rem * fr / total for fr in fracs]
        taken = rem - shares[0]
        f.nbytes = max(f.nbytes - taken, 0.0)
        f.remaining = shares[0]
        f.route = routes[0]
        h = len(f.route) - 1
        f.tail_s = h * f.tail_bytes / self.link_bw + h * self.net.t_hop
        keys = tuple(
            link_key(self.torus, f.route[i], f.route[i + 1], f.channel)
            for i in range(h))
        f.link_keys = keys
        f.links = np.fromiter((self._lid_of(k) for k in keys),
                              dtype=np.int64, count=h)
        self._inc_cache = None     # the flow's incidence row changed
        out = [fid]
        for route, share in zip(routes[1:], shares[1:]):
            out.append(self.inject(
                route[0], route[-1], share, start_s=self._frontier,
                route=route, src_gpu=f.src_gpu, dst_gpu=f.dst_gpu,
                channel=f.channel, cls=f.cls,
                label=(f.label + "+restripe") if f.label else "restripe"))
        if f.fid in self._active:
            self._dirty = True
            self._solve(max(self._frontier, self._solve_t))
        if self.telemetry is not None:
            self.telemetry.add("fabric.restripes")
            self.telemetry.add("fabric.restripe_siblings",
                               float(len(out) - 1))
        return out

    def prune(self) -> int:
        """Drop finished flows from the registry; returns how many."""
        done = [fid for fid, f in self._flows.items()
                if f.finish_s is not None]
        for fid in done:
            del self._flows[fid]
        return len(done)

    # -- what-if probing -------------------------------------------------------
    def _snapshot(self) -> tuple:
        flows = {fid: (f.remaining, f.rate, f.version, f.req_start,
                       f.start_s, f.drain_s, f.finish_s, f.pending,
                       list(f.dependents))
                 for fid, f in self._flows.items()}
        stats = {k: (v[0], v[1], list(v[2]))
                 for k, v in self._stats.items()}
        return (flows, list(self._active.keys()), list(self._heap),
                dict(self._res_free), stats, len(self._lid_keys),
                self._frontier, self._solve_t, self._version, self._dirty,
                self._drained_since, self._seq_n, self._fid_n,
                set(self._hot))

    def _restore(self, snap: tuple) -> None:
        (flows, active, heap, res_free, stats, n_lids, frontier, solve_t,
         version, dirty, drained, seq_n, fid_n, hot) = snap
        for fid in [fid for fid in self._flows if fid not in flows]:
            del self._flows[fid]
        for fid, (remaining, rate, ver, req_start, start_s, drain_s,
                  finish_s, pending, dependents) in flows.items():
            f = self._flows[fid]
            f.remaining = remaining
            f.rate = rate
            f.version = ver
            f.req_start = req_start
            f.start_s = start_s
            f.drain_s = drain_s
            f.finish_s = finish_s
            f.pending = pending
            f.dependents = dependents
        self._active = {fid: self._flows[fid] for fid in active}
        self._heap = heap
        self._res_free = res_free
        for k in [k for k in self._stats if k not in stats]:
            del self._stats[k]
        for k, (busy, carried, class_bytes) in stats.items():
            st = self._stats[k]
            st[0] = busy
            st[1] = carried
            st[2] = class_bytes
        for key in self._lid_keys[n_lids:]:
            del self._lid[key]
        del self._lid_keys[n_lids:]
        self._frontier = frontier
        self._solve_t = solve_t
        self._version = version
        self._dirty = dirty
        self._drained_since = drained
        self._seq_n = seq_n
        self._fid_n = fid_n
        self._hot = hot
        # flow ids may be reused after the rollback with different routes;
        # a stale incidence cache keyed on those ids would be wrong
        self._inc_cache = None

    def probe_route(self, route: Sequence[int], nbytes: float, *,
                    start_s: float | None = None, **kw) -> float:
        """Simulated completion time of a hypothetical flow along
        ``route`` against the current traffic, with full rollback — the
        ``FabricSim.probe_route`` contract on the fluid tier, which is
        what makes congestion-aware routing affordable at 512 nodes."""
        snap = self._snapshot()
        was_probing = self._probing
        self._probing = True
        try:
            start = self._frontier if start_s is None else start_s
            fid = self.inject(route[0], route[-1], nbytes, start_s=start,
                              route=route, **kw)
            out = self.finish_s(fid) - start
        finally:
            self._probing = was_probing
            self._restore(snap)
        self.last_probe_report = {
            "flows_touched": len(snap[0]), "links_touched": len(route) - 1,
        }
        if self.telemetry is not None and not self._probing:
            # once per TOP-LEVEL probe, after restore — the one counter
            # a probe moves (nested probes stay fully suppressed)
            self.telemetry.add("fabric.probes")
        return out


class HybridSim(FluidSim):
    """Fluid tier with packet-mode escalation: links the rate solver
    finds saturated (utilization >= ``escalate_util`` with >= 2 competing
    flows) flag their flows, and after the fluid pass those flows re-run
    through a packet-mode ``FabricSim`` sub-simulation on the same torus
    / QoS policy / fault map — injected at their fluid activation times
    with their intra-set dependencies.  The packet finishes replace the
    fluid ones and downstream dependents shift by their dependencies'
    slip, so contended links get packet-accurate completion (credit
    backpressure, packet interleaving and all) while the quiet majority
    of the fabric stays on the O(flows) fast path.  Probes never
    escalate — route selection stays cheap."""

    def __init__(self, torus: Torus, net: NetModel | None = None, *,
                 escalate_util: float = 0.85, **kw) -> None:
        super().__init__(torus, net, **kw)
        if not 0.0 < escalate_util <= 1.0:
            raise ValueError(
                f"escalate_util must be in (0, 1], got {escalate_util}")
        self.escalate_util = escalate_util
        self.last_escalation: dict | None = None

    def run(self) -> float:
        open_fids = [fid for fid, f in self._flows.items()
                     if f.finish_s is None]
        self._hot.clear()
        super().run()
        if self._probing or not self._hot:
            return self._frontier
        batch = [self._flows[fid] for fid in open_fids
                 if self._flows[fid].finish_s is not None]
        hot = self._hot
        esc_ids = {f.fid for f in batch
                   if f.links is not None
                   and any(int(l) in hot for l in f.links)}
        if not esc_ids:
            return self._frontier
        # Close the set under link-sharing: packet queues serve FIFO by
        # arrival, so even an *uncontended* sharer of some non-hot link
        # shifts the interleaving seen downstream — the sub-sim is only
        # authoritative if no outside flow touches any queue it contains.
        # Under full saturation the closure approaches the whole batch
        # and hybrid degrades gracefully to packet accuracy (and cost).
        used = {int(l) for f in batch if f.fid in esc_ids
                for l in (f.links if f.links is not None else ())}
        rest = [f for f in batch
                if f.fid not in esc_ids and f.links is not None]
        changed = True
        while changed:
            changed = False
            still = []
            for f in rest:
                if any(int(l) in used for l in f.links):
                    esc_ids.add(f.fid)
                    used.update(int(l) for l in f.links)
                    changed = True
                else:
                    still.append(f)
            rest = still
        esc = [f for f in batch if f.fid in esc_ids]
        sub = FabricSim(self.torus, self.net,
                        packet_bytes=self.packet_bytes,
                        credit_bytes=self.credit_bytes,
                        max_packets_per_flow=self.max_packets,
                        faults=self.faults, qos=self.qos)
        idmap: dict[int, int] = {}
        for f in sorted(esc, key=lambda f: (f.start_s, f.fid)):
            idmap[f.fid] = sub.inject(
                f.route[0], f.route[-1], f.nbytes, start_s=f.start_s,
                route=f.route,
                after=[idmap[d] for d in f.deps if d in idmap],
                src_gpu=f.src_gpu, dst_gpu=f.dst_gpu, channel=f.channel,
                label=f.label,
                cls=TrafficClass.BULK if f.cls is None else f.cls)
        sub.run()
        # stitch: escalated flows take their packet finish (the sub-sim
        # holds the full link-sharing closure around the hot links, so no
        # absent flow can perturb any of its queues and it is authoritative
        # there — faster or slower than the fluid guess); everyone
        # downstream shifts by the worst slip among its dependencies.
        # Slip is one-directional: a packet finish earlier than fluid
        # never pulls dependents earlier (their other contention was
        # priced by fluid and stays).
        fluid_fin = {f.fid: f.finish_s for f in batch}
        slip: dict[int, float] = {}
        for f in sorted(batch, key=lambda f: (
                f.start_s if f.start_s is not None else f.req_start,
                f.fid)):
            s = 0.0
            mine = f.fid in idmap
            for d in f.deps:
                if d in slip and not (mine and d in idmap):
                    s = max(s, slip[d])   # intra-set deps already in sub
            if mine:
                new = sub.finish_s(idmap[f.fid]) + s
            else:
                new = fluid_fin[f.fid] + s
            if new > fluid_fin[f.fid]:
                slip[f.fid] = new - fluid_fin[f.fid]
            f.finish_s = new
            self._frontier = max(self._frontier, new)
        # flows still waiting on unfinished deps saw the fluid finishes
        # when their other deps completed — re-bump their earliest start
        for g in self._flows.values():
            if g.finish_s is None and g.pending > 0:
                for d in g.deps:
                    if d in slip:
                        g.req_start = max(g.req_start,
                                          self._flows[d].finish_s or 0.0)
        self.last_escalation = {
            "hot_links": len(hot), "escalated_flows": len(esc),
            "batch_flows": len(batch),
        }
        if self.telemetry is not None:
            # the sub-sim runs WITHOUT the hub: its link traffic is the
            # same payload the fluid pass already accounted (only the
            # timing is refined), so reporting both would double-count.
            # Escalated flows' spans keep their fluid finishes (stitching
            # rewrites finish_s post-hoc); the escalation itself is one
            # instant event plus counters.
            self.telemetry.add("fabric.escalations")
            self.telemetry.add("fabric.escalated_flows", float(len(esc)))
            self.telemetry.event(
                ("hybrid",), "escalation", self._frontier,
                hot_links=len(hot), escalated=len(esc),
                batch=len(batch))
        return self._frontier


# ----------------------------------------------------------------------------
# jnp dense waterfill (solver="jnp")
# ----------------------------------------------------------------------------

def _pad_to(n: int, quantum: int = 64) -> int:
    return max(quantum, -(-n // quantum) * quantum)


_JNP_CACHE: dict = {}


def _jnp_waterfill(inc_mat: np.ndarray, wf: np.ndarray, onehot: np.ndarray,
                   cap: np.ndarray, alive: np.ndarray, wc: np.ndarray,
                   B: float, rounds: int):
    """Jit-compiled hierarchical progressive filling over a dense
    links x flows incidence matrix — the ``jnp`` expression of
    ``_rates_np`` (one XLA compile per padded shape)."""
    import jax
    import jax.numpy as jnp

    key = (inc_mat.shape, onehot.shape[1])
    fn = _JNP_CACHE.get(key)
    if fn is None:
        def waterfill(A, wf, onehot, cap, alive, wc):
            wcf = onehot @ wc                       # (F,) class weight
            eps = jnp.float32(1e-30)

            def body(_, st):
                rate, resid, unf = st
                wfa = wf * unf
                S = A @ (wfa[:, None] * onehot)     # (L, C) class wsum
                active_w = (S > 0).astype(S.dtype) @ wc
                s_lf = A * (S @ onehot.T)           # S[l, class(f)] on A
                ok = (A > 0) & (s_lf > 0) & (unf[None, :] > 0)
                share = jnp.where(
                    ok,
                    resid[:, None] * (wcf[None, :]
                                      / jnp.maximum(active_w, eps)[:, None])
                    * (wf[None, :] / jnp.maximum(s_lf, eps)),
                    jnp.inf)
                inc = jnp.min(share, axis=0)
                inc = jnp.minimum(inc, cap - rate)
                inc = jnp.where((unf > 0) & jnp.isfinite(inc),
                                jnp.maximum(inc, 0.0), 0.0)
                rate = rate + inc
                resid = jnp.maximum(resid - A @ inc, 0.0)
                sat = (resid <= B * 1e-6).astype(A.dtype)
                flow_sat = jnp.max(A * sat[:, None], axis=0)
                capped = (rate >= cap * (1.0 - 1e-6)).astype(A.dtype)
                unf = unf * (1.0 - jnp.maximum(flow_sat, capped))
                return rate, resid, unf

            init = (jnp.zeros_like(wf), jnp.full(A.shape[0], B,
                                                 dtype=A.dtype), alive)
            rate, resid, _ = jax.lax.fori_loop(0, rounds, body, init)
            return rate, resid

        fn = _JNP_CACHE[key] = jax.jit(waterfill)
    return fn(inc_mat, wf, onehot, cap, alive, wc)
