"""``repro.core.fabric`` — the collective fabric layer.

One IR, four consumers:

    lower(collective, Torus, axes)  ->  CollectiveSchedule
        execute.*   the shard_map/ppermute program (fused dual-DMA rounds)
        cost.*      predicted completion time (apelink.NetModel pricing;
                    ``backend="analytic"`` closed-form or ``"sim"``)
        fault.*     schedule rewritten around a LO|FA|MO fault map
        sim.*       event-driven link-level timeline (``FabricSim``):
                    per-link-direction FIFOs + credit flow control; the
                    shared clock RDMA endpoints and the serving cluster
                    inject concurrent flows into, so traffic CONTENDS

``core.collectives`` wraps the executor behind the familiar per-shard
collective API; everything else (trainer, serving engine, benchmarks)
consumes schedules directly.

``fabric.qos`` adds traffic classes on top of the sim: every link of
``FabricSim`` carries per-class virtual channels drained by a
class-weighted arbiter with partitioned credits, so latency-critical
DECODE flows are protected from BULK migrations sharing their links.

``fabric.fluid`` adds the flow-level fast fidelity tier on top:
``make_sim(..., fidelity="packet"|"fluid"|"hybrid")`` builds the packet
oracle, the O(flows) fluid simulator (vectorized max-min rate
allocation) or the hybrid (fluid with packet escalation of contended
links) behind the same duck-typed surface.
"""
from repro.core.fabric.cost import (BACKENDS, CostEstimate, OverlapEstimate,
                                    algorithmic_bandwidth, estimate,
                                    estimate_overlapped, hostif_descriptors,
                                    message_time)
from repro.core.fabric.fluid import (FIDELITIES, FluidSim, HybridSim,
                                     make_sim)
from repro.core.fabric.execute import (execute, execute_all_gather,
                                       execute_all_reduce,
                                       execute_all_to_all,
                                       execute_halo_exchange,
                                       execute_reduce_scatter,
                                       make_bucket_grad_hook, ring_slot)
from repro.core.fabric.fault import (UnroutableError, fault_map_from_lofamo,
                                     rewrite)
from repro.core.fabric.lower import (axis_fault_penalty, live_ring, lower,
                                     lower_all_gather, lower_all_reduce,
                                     lower_all_to_all, lower_halo_exchange,
                                     lower_p2p, lower_reduce_scatter,
                                     lower_route, plan_buckets)
from repro.core.fabric.schedule import (A2A, AG, AR, HALO, P2P, RS, Bucket,
                                        BucketPlan, CollectiveSchedule,
                                        FaultMap, Phase, Step, Transfer)
from repro.core.fabric.qos import (DEFAULT_CREDIT_FRAC, DEFAULT_WEIGHTS,
                                   SINGLE_CLASS, QosPolicy, TrafficClass)
from repro.core.fabric.qosctl import QosController, QosCtlPolicy
from repro.core.fabric.sim import (FabricSim, FlowResult, best_route,
                                   candidate_routes, clear_route_cache,
                                   inject_schedule, simulate_schedule,
                                   stripe_counts, striped_routes)
from repro.core.fabric.telemetry import (Telemetry, canon_key,
                                         ordered_link_items,
                                         validate_perfetto)
# autotune references this package lazily (``from repro.core import
# fabric``), so it must come after every name it may resolve at call time
from repro.core.fabric.autotune import (AGENTS, ConfigSpace, FabricConfig,
                                        FabricEnv, GeneticAgent, GpBoAgent,
                                        RandomWalkAgent, ReplaySpec,
                                        ScoreReport, SearchResult,
                                        finalists, load_best_configs,
                                        rescore, save_best_configs, search,
                                        serving_replay, torus_shapes,
                                        training_replay, tuned_config,
                                        tuned_knob)

__all__ = [
    "A2A", "AG", "AR", "HALO", "P2P", "RS",
    "Bucket", "BucketPlan", "CollectiveSchedule", "FaultMap", "Phase",
    "Step", "Transfer",
    "BACKENDS", "CostEstimate", "OverlapEstimate", "algorithmic_bandwidth",
    "estimate", "estimate_overlapped", "hostif_descriptors", "message_time",
    "execute", "execute_all_gather", "execute_all_reduce",
    "execute_all_to_all", "execute_halo_exchange", "execute_reduce_scatter",
    "make_bucket_grad_hook", "ring_slot",
    "UnroutableError", "fault_map_from_lofamo", "rewrite",
    "axis_fault_penalty", "live_ring", "lower", "lower_all_gather",
    "lower_all_reduce", "lower_all_to_all", "lower_halo_exchange",
    "lower_p2p", "lower_reduce_scatter", "lower_route", "plan_buckets",
    "FabricSim", "FlowResult", "best_route", "candidate_routes",
    "clear_route_cache", "inject_schedule", "simulate_schedule",
    "stripe_counts", "striped_routes",
    "FIDELITIES", "FluidSim", "HybridSim", "make_sim",
    "Telemetry", "canon_key", "ordered_link_items", "validate_perfetto",
    "DEFAULT_CREDIT_FRAC", "DEFAULT_WEIGHTS", "SINGLE_CLASS", "QosPolicy",
    "QosController", "QosCtlPolicy", "TrafficClass",
    "AGENTS", "ConfigSpace", "FabricConfig", "FabricEnv", "GeneticAgent",
    "GpBoAgent", "RandomWalkAgent", "ReplaySpec", "ScoreReport",
    "SearchResult", "finalists", "load_best_configs", "rescore",
    "save_best_configs", "search", "serving_replay", "torus_shapes",
    "training_replay", "tuned_config", "tuned_knob",
]
