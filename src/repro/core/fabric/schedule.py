"""The ``CollectiveSchedule`` IR — "which hop moves which bytes when".

APEnet+ moves every payload as a sequence of first-neighbour, dimension-
ordered hops on the 3D torus, with the two DMA engines of each link keeping
both directions in flight (paper §1, §2.1).  This module reifies that
structure: a collective *lowered* against a ``Torus`` + axis spec becomes an
explicit, inspectable schedule that three independent consumers walk:

  * ``fabric.execute``  — emits the shard_map/ppermute program (the fabric's
    RDMA puts), fusing the two link directions of every round;
  * ``fabric.cost``     — prices each step with ``apelink.NetModel`` (hops,
    bytes, per-direction bandwidth) into a predicted completion time;
  * ``fabric.fault``    — rewrites the schedule around a LO|FA|MO fault map
    (shrunk rings, detour hops, axis reordering).

Vocabulary (outer to inner):

  ``CollectiveSchedule`` — one collective over one or more mesh axes;
  ``Phase``    — one ring pass along one axis (e.g. the reduce-scatter leg
                 along X); carries the ring ordering of participating axis
                 positions and the fraction of the original working set that
                 is still live when the phase starts;
  ``Step``     — one wall-clock round: its transfers fire *concurrently*
                 (the dual-DMA trick — one per link direction);
  ``Transfer`` — one ppermute's worth of messages: a (src, dst) position
                 permutation along the phase axis, the per-rank byte
                 fraction it moves, and the physical link hops each message
                 traverses (1 on a healthy ring; >1 when detouring).

Everything is a frozen dataclass: schedules are values, safe to hash, cache
and compare, and a rewritten schedule never aliases the original.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator


RS = "reduce_scatter"
AG = "all_gather"
AR = "all_reduce"
A2A = "all_to_all"
HALO = "halo_exchange"
P2P = "p2p"

PHASE_KINDS = (RS, AG, A2A, HALO, P2P)
COLLECTIVES = (RS, AG, AR, A2A, HALO, P2P)


@dataclasses.dataclass(frozen=True)
class FaultMap:
    """Fabric faults as LO|FA|MO's master node sees them.

    ``dead_nodes`` are torus ranks; ``dead_links`` are undirected first-
    neighbour links as (lo, hi) rank pairs.  An empty map is falsy.
    """

    dead_nodes: frozenset[int] = frozenset()
    dead_links: frozenset[tuple[int, int]] = frozenset()

    def __bool__(self) -> bool:
        return bool(self.dead_nodes or self.dead_links)

    def link_ok(self, a: int, b: int) -> bool:
        return (a not in self.dead_nodes and b not in self.dead_nodes
                and (min(a, b), max(a, b)) not in self.dead_links)

    @staticmethod
    def normalized(nodes=(), links=()) -> "FaultMap":
        return FaultMap(frozenset(nodes),
                        frozenset((min(a, b), max(a, b)) for a, b in links))


@dataclasses.dataclass(frozen=True)
class Transfer:
    """One directed ppermute: every listed src position sends one message."""

    perm: tuple[tuple[int, int], ...]   # (src, dst) positions along the axis
    frac: float                         # bytes per rank / collective input
    hops: int = 1                       # worst-case physical hops per message
    combine: str = "sum"                # "sum" | "write" | "shift"

    def __post_init__(self) -> None:
        if self.hops < 1:
            raise ValueError(f"hops must be >= 1, got {self.hops}")
        if self.frac < 0:
            raise ValueError(f"negative frac {self.frac}")


@dataclasses.dataclass(frozen=True)
class Step:
    """One wall-clock round; transfers fire concurrently (full duplex)."""

    transfers: tuple[Transfer, ...]


@dataclasses.dataclass(frozen=True)
class Phase:
    """One ring pass along one mesh axis.

    ``ring`` lists the *participating* axis positions in ring order — the
    identity ``(0..n-1)`` on a healthy fabric, a shrunk/reordered tuple
    after a fault rewrite.  ``scale`` is the working-set size entering this
    phase as a fraction of the collective's input (dimension-ordered
    reduce-scatter shrinks it by the axis size per phase; all-gather legs
    grow it back).
    """

    kind: str
    axis: str
    ring: tuple[int, ...]
    steps: tuple[Step, ...]
    scale: float = 1.0
    mean: bool = False

    def __post_init__(self) -> None:
        if self.kind not in PHASE_KINDS:
            raise ValueError(f"unknown phase kind {self.kind!r}")
        if len(set(self.ring)) != len(self.ring):
            raise ValueError(f"ring has repeats: {self.ring}")

    @property
    def ring_size(self) -> int:
        return len(self.ring)

    @property
    def directions(self) -> int:
        """1 = unidirectional, 2 = dual-DMA bidirectional."""
        return max((len(s.transfers) for s in self.steps), default=1)


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One size-targeted group of gradient leaves issued as a unit.

    ``leaves`` are flat param-tree leaf indices in *issue order* (the order
    their gradients materialise during backward); ``nbytes`` is the wire
    payload the bucket injects per rank when its schedule fires.
    """

    index: int
    leaves: tuple[int, ...]
    nbytes: int

    def __post_init__(self) -> None:
        if not self.leaves:
            raise ValueError("empty bucket")
        if self.nbytes < 0:
            raise ValueError(f"negative bucket bytes {self.nbytes}")


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Gradient bucketing for compute-overlapped collective issue.

    The software analogue of the APEnet+ dual-DMA prefetchable command
    queue (paper §2.1, Fig 1): instead of one monolithic post-backward
    collective, the payload is split into ``buckets`` whose schedules are
    issued as soon as their gradients exist, so the fabric rounds of bucket
    i overlap the remaining backward compute.  Lowered by
    ``fabric.plan_buckets``; consumed by the executor's bucket grad hook,
    the overlap cost model (``fabric.estimate_overlapped``) and the
    trainer's apex path.
    """

    buckets: tuple[Bucket, ...]
    bucket_bytes: int            # the size target each bucket was packed to
    n_leaves: int                # leaves of the source param tree

    def __post_init__(self) -> None:
        seen: set[int] = set()
        for b in self.buckets:
            dup = seen.intersection(b.leaves)
            if dup:
                raise ValueError(f"leaves {sorted(dup)} in multiple buckets")
            seen.update(b.leaves)

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def total_bytes(self) -> int:
        return sum(b.nbytes for b in self.buckets)

    @property
    def bucket_nbytes(self) -> tuple[int, ...]:
        """Per-bucket wire bytes in issue order (the overlap model's input)."""
        return tuple(b.nbytes for b in self.buckets)

    def describe(self) -> str:
        lines = [f"BucketPlan: {self.n_buckets} buckets over "
                 f"{self.n_leaves} leaves, target {self.bucket_bytes} B"]
        for b in self.buckets:
            lines.append(f"  bucket {b.index}: {len(b.leaves)} leaves, "
                         f"{b.nbytes / 1e6:.3f} MB")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class CollectiveSchedule:
    """A collective lowered to explicit neighbour transfers.

    ``axes`` are mesh axis names in lowering order; ``axis_dims[i]`` is the
    torus dimension backing ``axes[i]``; ``torus_dims`` records the fabric
    shape so consumers (cost, fault rewrite) can rebuild the ``Torus``
    without re-deriving hop math anywhere else.
    """

    collective: str
    axes: tuple[str, ...]
    axis_dims: tuple[int, ...]
    torus_dims: tuple[int, ...]
    phases: tuple[Phase, ...]
    faults: FaultMap = dataclasses.field(default_factory=FaultMap)
    bidirectional: bool = True   # dual-DMA: both link directions per round
    mean: bool = False           # reduce phases divide by the live ring size

    def __post_init__(self) -> None:
        if self.collective not in COLLECTIVES:
            raise ValueError(f"unknown collective {self.collective!r}")
        if len(self.axes) != len(self.axis_dims):
            raise ValueError("axes/axis_dims arity mismatch")

    # -- walkers -------------------------------------------------------------
    def steps(self) -> Iterator[tuple[Phase, Step]]:
        for ph in self.phases:
            for st in ph.steps:
                yield ph, st

    @property
    def rounds(self) -> int:
        """Sequential wall-clock rounds (the executor's ppermute depth)."""
        return sum(len(ph.steps) for ph in self.phases)

    @property
    def n_messages(self) -> int:
        """Total directed ppermutes issued (2 per round when bidirectional)."""
        return sum(len(st.transfers) for _, st in self.steps())

    @property
    def max_hops(self) -> int:
        return max((tr.hops for _, st in self.steps()
                    for tr in st.transfers), default=0)

    def bytes_per_rank(self, nbytes: int) -> float:
        """Payload bytes each participating rank injects into the fabric."""
        return sum(tr.frac * nbytes for _, st in self.steps()
                   for tr in st.transfers)

    @property
    def route(self) -> tuple[int, ...]:
        """The rank-by-rank forwarding route of a P2P (unicast) schedule —
        the phase ring annotation ``lower_p2p``/``lower_route`` wrote.
        Consumers (the fabric simulator, ``RdmaEndpoint``) replay the
        unicast along exactly these links."""
        if self.collective != P2P:
            raise ValueError(
                f"{self.collective} schedules are axis-addressed; only p2p "
                "schedules carry a rank route")
        return self.phases[0].ring

    def describe(self) -> str:
        lines = [f"{self.collective} over axes {self.axes} "
                 f"on torus {self.torus_dims}"
                 + (f"  [faults: {sorted(self.faults.dead_nodes)} nodes, "
                    f"{sorted(self.faults.dead_links)} links]"
                    if self.faults else "")]
        for ph in self.phases:
            hops = max((tr.hops for st in ph.steps for tr in st.transfers),
                       default=0)
            lines.append(
                f"  {ph.kind:<15s} axis={ph.axis:<6s} ring={ph.ring} "
                f"rounds={len(ph.steps)} dirs={ph.directions} "
                f"scale={ph.scale:.4g} max_hops={hops}")
        return "\n".join(lines)
